"""L1 correctness: Pallas neuron_update vs the pure-jnp oracle.

Covers all four neuron configurations the neuron macro supports
(IF/LIF x hard/soft reset), plus targeted dynamics checks: reset
semantics, leak direction, threshold edge cases, and hypothesis sweeps.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.neuron import neuron_update
from compile.kernels.ref import neuron_update_ref
from compile.quantize import PRECISIONS, PrecisionConfig

CONFIGS = [(leaky, soft) for leaky in (False, True) for soft in (False, True)]


def _case(rng, m, k, cfg, theta=None, leak=None):
    p = rng.integers(cfg.vmem_min, cfg.vmem_max + 1, (m, k), dtype=np.int32)
    v = rng.integers(cfg.vmem_min, cfg.vmem_max + 1, (m, k), dtype=np.int32)
    theta = theta if theta is not None else int(rng.integers(1, cfg.vmem_max))
    leak = leak if leak is not None else int(rng.integers(0, max(cfg.vmem_max // 8, 1)))
    return jnp.asarray(p), jnp.asarray(v), theta, leak


@pytest.mark.parametrize("leaky,soft", CONFIGS)
@pytest.mark.parametrize("wb,vb", PRECISIONS)
def test_all_neuron_models_match_ref(leaky, soft, wb, vb):
    cfg = PrecisionConfig(wb, vb)
    rng = np.random.default_rng(wb + leaky * 10 + soft * 100)
    p, v, theta, leak = _case(rng, 64, 48, cfg)
    s1, v1 = neuron_update(p, v, theta, leak, vb, leaky=leaky, soft_reset=soft)
    s2, v2 = neuron_update_ref(p, v, theta, leak, vb, leaky=leaky,
                               soft_reset=soft)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_hard_reset_zeroes_fired_neurons():
    p = jnp.asarray([[25, 0]], dtype=jnp.int32)
    v = jnp.asarray([[10, 10]], dtype=jnp.int32)
    s, vn = neuron_update(p, v, 30, 0, 7, leaky=False, soft_reset=False)
    assert np.asarray(s).tolist() == [[1, 0]]
    assert np.asarray(vn).tolist() == [[0, 10]]


def test_soft_reset_retains_residual():
    p = jnp.asarray([[25]], dtype=jnp.int32)
    v = jnp.asarray([[10]], dtype=jnp.int32)
    s, vn = neuron_update(p, v, 30, 0, 7, leaky=False, soft_reset=True)
    assert np.asarray(s).tolist() == [[1]]
    assert np.asarray(vn).tolist() == [[5]]  # 35 - 30


def test_integration_wraps_at_vmem_bits():
    """20 + 50 = 70 wraps to -58 in 7-bit: no spike, then the underflow
    floor clamps the wrapped value at -theta (DESIGN §2 contract)."""
    p = jnp.asarray([[50]], dtype=jnp.int32)
    v = jnp.asarray([[20]], dtype=jnp.int32)
    s, vn = neuron_update(p, v, 30, 0, 7, leaky=False, soft_reset=False)
    assert np.asarray(s).tolist() == [[0]]
    assert np.asarray(vn).tolist() == [[-30]]


def test_shift_leak_decays_toward_zero():
    """LIF leak is an arithmetic shift: v -= v >> k (k = leak)."""
    p = jnp.zeros((1, 2), dtype=jnp.int32)
    v = jnp.asarray([[16, -16]], dtype=jnp.int32)
    s, vn = neuron_update(p, v, 100, 2, 7, leaky=True, soft_reset=True)
    assert np.asarray(s).tolist() == [[0, 0]]
    # 16>>2=4 -> 12 ; -16>>2=-4 -> -12
    assert np.asarray(vn).tolist() == [[12, -12]]


def test_negative_vmem_floors_at_minus_theta():
    """Digital underflow guard: Vmem never drops below -theta."""
    p = jnp.asarray([[-50]], dtype=jnp.int32)
    v = jnp.asarray([[-10]], dtype=jnp.int32)
    s, vn = neuron_update(p, v, 20, 0, 7, leaky=False, soft_reset=True)
    assert np.asarray(s).tolist() == [[0]]
    assert np.asarray(vn).tolist() == [[-20]]


def test_threshold_boundary_fires_at_exact_theta():
    """The macro compares Vmem >= theta (paper: threshold comparison)."""
    p = jnp.asarray([[0, 0]], dtype=jnp.int32)
    v = jnp.asarray([[30, 29]], dtype=jnp.int32)
    s, _ = neuron_update(p, v, 30, 0, 7, leaky=False, soft_reset=False)
    assert np.asarray(s).tolist() == [[1, 0]]


def test_if_neuron_ignores_leak_value():
    rng = np.random.default_rng(9)
    cfg = PrecisionConfig(4, 7)
    p, v, theta, _ = _case(rng, 16, 12, cfg)
    s1, v1 = neuron_update(p, v, theta, 0, 7, leaky=False, soft_reset=True)
    s2, v2 = neuron_update(p, v, theta, 63, 7, leaky=False, soft_reset=True)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_partial_shape_mismatch_raises():
    p = jnp.zeros((2, 3), dtype=jnp.int32)
    v = jnp.zeros((2, 4), dtype=jnp.int32)
    with pytest.raises(ValueError, match="partial shape"):
        neuron_update(p, v, 1, 0, 7, leaky=False, soft_reset=True)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 72),
    k=st.integers(1, 48),
    wb=st.sampled_from([4, 6, 8]),
    leaky=st.booleans(),
    soft=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(m, k, wb, leaky, soft, seed):
    vb = {4: 7, 6: 11, 8: 15}[wb]
    cfg = PrecisionConfig(wb, vb)
    rng = np.random.default_rng(seed)
    p, v, theta, leak = _case(rng, m, k, cfg)
    s1, v1 = neuron_update(p, v, theta, leak, vb, leaky=leaky, soft_reset=soft)
    s2, v2 = neuron_update_ref(p, v, theta, leak, vb, leaky=leaky,
                               soft_reset=soft)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
