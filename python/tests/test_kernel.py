"""L1 correctness: Pallas spiking_matmul vs the pure-jnp oracle.

This is the core correctness signal for the compute-macro kernel:
bit-exact equality against ``ref.spiking_matmul_ref`` across shapes,
precisions, sparsities and block configurations — including hypothesis
sweeps over the shape/sparsity space (the Pallas analogue of fuzzing
the macro's address space).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import spiking_matmul_ref
from compile.kernels.spiking_matmul import spiking_matmul, vmem_footprint_bytes
from compile.quantize import PRECISIONS, PrecisionConfig


def _random_case(rng, m, f, k, cfg, density):
    spikes = (rng.random((m, f)) < density).astype(np.int32)
    weights = rng.integers(cfg.weight_min, cfg.weight_max + 1, (f, k),
                           dtype=np.int32)
    vmem = rng.integers(cfg.vmem_min, cfg.vmem_max + 1, (m, k),
                        dtype=np.int32)
    return jnp.asarray(spikes), jnp.asarray(weights), jnp.asarray(vmem)


@pytest.mark.parametrize("wb,vb", PRECISIONS)
@pytest.mark.parametrize("density", [0.0, 0.05, 0.25, 1.0])
def test_matches_ref_across_precisions(wb, vb, density):
    cfg = PrecisionConfig(wb, vb)
    rng = np.random.default_rng(wb * 100 + int(density * 10))
    s, w, v = _random_case(rng, 96, 72, 24, cfg, density)
    out = spiking_matmul(s, w, v, vb)
    ref = spiking_matmul_ref(s, w, v, vb)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_macro_native_shape():
    """The silicon-native case: 128x16 IFspad, 48-col macro at 4-bit."""
    cfg = PrecisionConfig(4, 7)
    rng = np.random.default_rng(1)
    s, w, v = _random_case(rng, 16, 128, 12, cfg, 0.2)
    out = spiking_matmul(s, w, v, 7)
    ref = spiking_matmul_ref(s, w, v, 7)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_wraparound_is_exercised():
    """Saturating-range inputs must wrap, not clamp."""
    s = jnp.ones((1, 4), dtype=jnp.int32)
    w = jnp.full((4, 1), 7, dtype=jnp.int32)   # +28 accumulation
    v = jnp.full((1, 1), 60, dtype=jnp.int32)  # 60 + 28 = 88 > 63
    out = np.asarray(spiking_matmul(s, w, v, 7))
    # 88 wraps to 88 - 128 = -40 in 7-bit two's complement.
    assert out[0, 0] == -40


def test_zero_spikes_identity():
    """With no input spikes the macro must not disturb Vmems."""
    rng = np.random.default_rng(3)
    cfg = PrecisionConfig(6, 11)
    s = jnp.zeros((32, 54), dtype=jnp.int32)
    w = jnp.asarray(rng.integers(-32, 32, (54, 8), dtype=np.int32))
    v = jnp.asarray(rng.integers(-1024, 1024, (32, 8), dtype=np.int32))
    out = spiking_matmul(s, w, v, 11)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))


def test_block_configs_equivalent():
    """Tiling must not change numerics (order-independence contract)."""
    cfg = PrecisionConfig(4, 7)
    rng = np.random.default_rng(4)
    s, w, v = _random_case(rng, 64, 90, 36, cfg, 0.3)
    outs = [
        np.asarray(spiking_matmul(s, w, v, 7, block_m=bm, block_k=bk))
        for bm, bk in [(64, 36), (32, 12), (16, 9), (8, 4)]
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_shape_mismatch_raises():
    s = jnp.zeros((4, 8), dtype=jnp.int32)
    w = jnp.zeros((9, 2), dtype=jnp.int32)
    v = jnp.zeros((4, 2), dtype=jnp.int32)
    with pytest.raises(ValueError, match="fan-in"):
        spiking_matmul(s, w, v, 7)
    w_ok = jnp.zeros((8, 2), dtype=jnp.int32)
    v_bad = jnp.zeros((5, 2), dtype=jnp.int32)
    with pytest.raises(ValueError, match="vmem shape"):
        spiking_matmul(s, w_ok, v_bad, 7)


def test_vmem_footprint_positive_and_monotone():
    small = vmem_footprint_bytes(128, 72, 12)
    big = vmem_footprint_bytes(128, 1152, 48)
    assert 0 < small < big


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 96),
    f=st.integers(1, 160),
    k=st.integers(1, 48),
    wb=st.sampled_from([4, 6, 8]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(m, f, k, wb, density, seed):
    """Randomized shape/precision/sparsity sweep, kernel == oracle."""
    vb = {4: 7, 6: 11, 8: 15}[wb]
    cfg = PrecisionConfig(wb, vb)
    rng = np.random.default_rng(seed)
    s, w, v = _random_case(rng, m, f, k, cfg, density)
    out = spiking_matmul(s, w, v, vb)
    ref = spiking_matmul_ref(s, w, v, vb)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
