"""Quantization contract tests (mirrored by rust/src/quant tests)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quantize import (
    PRECISIONS,
    PrecisionConfig,
    quantize_leak,
    quantize_threshold,
    quantize_weights,
    saturate_to_bits,
    wrap_to_bits,
)


@pytest.mark.parametrize("wb,vb", PRECISIONS)
def test_precision_ranges(wb, vb):
    cfg = PrecisionConfig(wb, vb)
    assert cfg.vmem_bits == 2 * cfg.weight_bits - 1  # paper §II-A
    assert cfg.weight_max == 2 ** (wb - 1) - 1
    assert cfg.vmem_min == -(2 ** (vb - 1))
    assert cfg.neurons_per_row == 48 // wb


def test_unsupported_precision_rejected():
    with pytest.raises(ValueError):
        PrecisionConfig(5, 9)


def test_wrap_known_values():
    x = jnp.asarray([63, 64, 127, 128, -64, -65], dtype=jnp.int32)
    out = np.asarray(wrap_to_bits(x, 7))
    assert out.tolist() == [63, -64, -1, 0, -64, 63]


def test_wrap_idempotent_in_range():
    x = jnp.arange(-64, 64, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(wrap_to_bits(x, 7)),
                                  np.asarray(x))


def test_saturate_clamps():
    x = jnp.asarray([1000, -1000, 5], dtype=jnp.int32)
    out = np.asarray(saturate_to_bits(x, 7))
    assert out.tolist() == [63, -64, 5]


@settings(max_examples=50, deadline=None)
@given(x=st.integers(-(2**30), 2**30), bits=st.sampled_from([7, 11, 15]))
def test_wrap_matches_modular_arithmetic(x, bits):
    expected = ((x + (1 << (bits - 1))) % (1 << bits)) - (1 << (bits - 1))
    got = int(np.asarray(wrap_to_bits(jnp.asarray([x], dtype=jnp.int32),
                                      bits))[0])
    assert got == expected


def test_wrap_is_additive_homomorphism():
    """wrap(a)+b then wrap == wrap(a+b): order independence, DESIGN §2."""
    rng = np.random.default_rng(0)
    a = rng.integers(-60, 60, 100)
    b = rng.integers(-60, 60, 100)
    c = rng.integers(-60, 60, 100)
    lhs = wrap_to_bits(
        wrap_to_bits(jnp.asarray(a + b, dtype=jnp.int32), 7)
        + jnp.asarray(c, dtype=jnp.int32), 7)
    rhs = wrap_to_bits(jnp.asarray(a + b + c, dtype=jnp.int32), 7)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@pytest.mark.parametrize("wb,vb", PRECISIONS)
def test_quantize_weights_range_and_roundtrip(wb, vb):
    cfg = PrecisionConfig(wb, vb)
    rng = np.random.default_rng(wb)
    w = rng.normal(0, 0.5, (64, 16)).astype(np.float32)
    wq, scale = quantize_weights(w, cfg)
    assert wq.min() >= cfg.weight_min and wq.max() <= cfg.weight_max
    # reconstruction error bounded by scale/2 per element
    np.testing.assert_allclose(wq * scale, w, atol=scale * 0.5 + 1e-9)


def test_quantize_weights_zero_tensor():
    cfg = PrecisionConfig(4, 7)
    wq, scale = quantize_weights(np.zeros((3, 3)), cfg)
    assert scale == 1.0
    assert wq.sum() == 0


def test_quantize_threshold_at_least_one():
    cfg = PrecisionConfig(4, 7)
    assert quantize_threshold(0.0001, 1.0, cfg) == 1
    assert quantize_threshold(1e9, 1.0, cfg) == cfg.vmem_max


def test_quantize_leak_is_shift_amount():
    cfg = PrecisionConfig(4, 7)
    assert quantize_leak(-5.0, 1.0, cfg) == 0     # no leak
    assert quantize_leak(0.25, 0.01, cfg) == 2    # 2^-2 decay
    assert quantize_leak(0.5, 1.0, cfg) == 1
    assert quantize_leak(0.015625, 1.0, cfg) == 6
