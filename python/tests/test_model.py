"""L2 model tests: im2col layout, layer geometry, network stepping."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    LayerSpec,
    QuantizedNetwork,
    build_layers,
    conv_out,
    flow_topology,
    gesture_topology,
    im2col,
    layer_step,
    maxpool_spikes,
    network_step,
    run_network,
)
from compile.quantize import PrecisionConfig


def test_im2col_layout_contract():
    """F = (c*KH + dy)*KW + dx; M = y*W_out + x — the hardware layout."""
    c, h, w = 2, 4, 4
    x = np.arange(c * h * w, dtype=np.int32).reshape(c, h, w)
    patches = np.asarray(im2col(jnp.asarray(x), 3, 3, 1, 1))
    assert patches.shape == (16, 18)
    # output pixel (1,1) with pad 1 sees input window [0:3, 0:3]
    m = 1 * 4 + 1
    for ci in range(c):
        for dy in range(3):
            for dx in range(3):
                f = (ci * 3 + dy) * 3 + dx
                assert patches[m, f] == x[ci, dy, dx]


def test_im2col_zero_padding():
    x = jnp.ones((1, 3, 3), dtype=jnp.int32)
    patches = np.asarray(im2col(x, 3, 3, 1, 1))
    # corner output pixel (0,0): only the 2x2 in-bounds part is 1
    assert patches[0].sum() == 4


def test_im2col_stride():
    x = jnp.ones((1, 6, 6), dtype=jnp.int32)
    patches = np.asarray(im2col(x, 3, 3, 2, 1))
    ho, wo = conv_out(6, 6, 3, 3, 2, 1)
    assert patches.shape == (ho * wo, 9)


def test_maxpool_binary():
    x = jnp.asarray(
        [[[1, 0, 0, 0], [0, 0, 0, 0], [0, 0, 0, 1], [0, 0, 1, 0]]],
        dtype=jnp.int32)
    out = np.asarray(maxpool_spikes(x, 2, 2))
    assert out.tolist() == [[[1, 0], [0, 1]]]


def _tiny_conv_layer(c=1, h=4, w=4, k=2, accumulate=False, seed=0):
    rng = np.random.default_rng(seed)
    wq = rng.integers(-7, 8, (c * 9, k), dtype=np.int32)
    ho, wo = conv_out(h, w, 3, 3, 1, 1)
    return LayerSpec(
        kind="conv", in_shape=(c, h, w), out_shape=(k, ho, wo),
        weights=wq, theta=5, leak=1, leaky=True, soft_reset=True,
        accumulate=accumulate)


def test_layer_step_conv_shapes():
    layer = _tiny_conv_layer()
    spikes = jnp.asarray(
        np.random.default_rng(1).integers(0, 2, (1, 4, 4), dtype=np.int32))
    vmem = jnp.zeros(layer.vmem_shape, dtype=jnp.int32)
    out, vnext = layer_step(layer, spikes, vmem, 7)
    assert out.shape == (2, 4, 4)
    assert vnext.shape == layer.vmem_shape
    assert set(np.unique(np.asarray(out))) <= {0, 1}


def test_accumulate_layer_never_spikes():
    layer = _tiny_conv_layer(accumulate=True)
    spikes = jnp.ones((1, 4, 4), dtype=jnp.int32)
    vmem = jnp.zeros(layer.vmem_shape, dtype=jnp.int32)
    out, vnext = layer_step(layer, spikes, vmem, 7)
    assert np.asarray(out).sum() == 0
    assert np.asarray(vnext).any()


def test_spike_reshape_channel_major():
    """Spikes (M, K) -> (K, H, W) must be channel-major (K first)."""
    c, h, w, k = 1, 2, 2, 3
    wq = np.zeros((9, k), dtype=np.int32)
    wq[4, 1] = 7  # center tap, channel 1 only
    layer = LayerSpec(kind="conv", in_shape=(c, h, w), out_shape=(k, h, w),
                      weights=wq, theta=5, leaky=False, soft_reset=False)
    spikes = jnp.asarray([[[1, 0], [0, 0]]], dtype=jnp.int32)
    vmem = jnp.zeros((h * w, k), dtype=jnp.int32)
    out, _ = layer_step(layer, spikes, vmem, 7)
    out = np.asarray(out)
    assert out[1, 0, 0] == 1          # channel 1 fires at (0,0)
    assert out.sum() == 1             # nowhere else


def _build_gesture_net(hw=(16, 16), wb=4, seed=0, timesteps=4):
    vb = {4: 7, 6: 11, 8: 15}[wb]
    cfg = PrecisionConfig(wb, vb)
    topo = gesture_topology()
    rng = np.random.default_rng(seed)
    c, h, w = 2, hw[0], hw[1]
    weights = []
    ch, hh, ww = c, h, w
    for t in topo:
        if t["kind"] == "pool":
            stride = min(t["stride"], min(t["size"], hh, ww))
            hh, ww = hh // stride, ww // stride
            continue
        if t["kind"] == "conv":
            f = ch * 9
            weights.append(rng.integers(cfg.weight_min, cfg.weight_max + 1,
                                        (f, t["out_ch"]), dtype=np.int32))
            ch = t["out_ch"]
        else:
            f = ch * hh * ww
            weights.append(rng.integers(cfg.weight_min, cfg.weight_max + 1,
                                        (f, t["out_ch"]), dtype=np.int32))
            ch, hh, ww = t["out_ch"], 1, 1
    layers = build_layers(topo, (2, hw[0], hw[1]), weights)
    return QuantizedNetwork(name="gesture", layers=layers, precision=cfg,
                            weight_scales=tuple([0.1] * len(weights)),
                            timesteps=timesteps)


def test_gesture_network_geometry():
    net = _build_gesture_net(hw=(64, 64))
    stateful = net.stateful_layers
    assert len(stateful) == 6                      # 5 conv + 1 fc
    assert stateful[-1].kind == "fc"
    assert stateful[-1].fan_in == 64               # paper: FC(64, 11)
    assert stateful[-1].out_shape[0] == 11
    assert stateful[-1].accumulate


def test_flow_network_geometry():
    topo = flow_topology()
    assert len(topo) == 8
    assert topo[0]["in_ch"] == 2 and topo[0]["out_ch"] == 32
    assert topo[-1]["out_ch"] == 2 and topo[-1]["accumulate"]


def test_network_step_state_evolution():
    net = _build_gesture_net()
    vmems = net.init_vmems()
    frame = jnp.asarray(
        np.random.default_rng(2).integers(0, 2, (2, 16, 16), dtype=np.int32))
    out, counts, vmems2 = network_step(net, frame, vmems)
    assert out.shape == (1, 11)
    assert counts.shape == (6,)
    assert int(counts[0]) == int(frame.sum())
    # at least the first layer's Vmem must have changed
    assert not np.array_equal(np.asarray(vmems[0]), np.asarray(vmems2[0]))


def test_run_network_accumulates_over_time():
    net = _build_gesture_net(timesteps=3)
    rng = np.random.default_rng(3)
    frames = rng.integers(0, 2, (3, 2, 16, 16), dtype=np.int32)
    out, counts = run_network(net, frames)
    assert out.shape == (1, 11)
    assert counts.shape == (3, 6)


def test_empty_frames_keep_everything_zero():
    net = _build_gesture_net(timesteps=2)
    frames = np.zeros((2, 2, 16, 16), dtype=np.int32)
    out, counts = run_network(net, frames)
    assert np.asarray(out).sum() == 0
    assert counts.sum() == 0


def test_build_layers_rejects_bad_weights():
    topo = gesture_topology()
    with pytest.raises(ValueError, match="weight shape"):
        build_layers(topo, (2, 16, 16), [np.zeros((5, 5), dtype=np.int32)] * 6)
