"""AOT pipeline tests: HLO text generation, swb bundles, manifests.

Tests that need trained weights are skipped until `make artifacts` has
run (they then validate the real artifacts in-place).
"""

import pathlib
import struct

import numpy as np
import pytest

from compile.aot import (
    SWB_MAGIC,
    lower_macro,
    manifest_entry,
    to_hlo_text,
    write_swb,
)

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_lower_macro_produces_hlo_text():
    text = lower_macro(4, m=16, f=8, k=4)
    assert "HloModule" in text
    assert "ENTRY" in text
    # int32 computation with our shapes somewhere in the module
    assert "s32[16,8]" in text
    assert "s32[8,4]" in text


def test_lower_macro_all_precisions():
    for wb in (4, 6, 8):
        assert "HloModule" in lower_macro(wb, m=8, f=8, k=4)


def test_to_hlo_text_simple_fn():
    import jax
    import jax.numpy as jnp

    def f(x):
        return (x * 2 + 1,)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.int32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text


def test_swb_roundtrip(tmp_path):
    wqs = [np.arange(12, dtype=np.int32).reshape(4, 3),
           np.full((2, 5), -3, dtype=np.int32)]
    path = tmp_path / "t.swb"
    write_swb(path, wqs, [0.5, 0.25], [10, 20], [1, 2])
    blob = path.read_bytes()
    magic, n = struct.unpack_from("<II", blob, 0)
    assert magic == SWB_MAGIC and n == 2
    off = 8
    fan_in, k, th, lk, sc = struct.unpack_from("<IIiid", blob, off)
    assert (fan_in, k, th, lk, sc) == (4, 3, 10, 1, 0.5)
    off += struct.calcsize("<IIiid")
    w0 = np.frombuffer(blob, dtype="<i4", count=12, offset=off)
    np.testing.assert_array_equal(w0.reshape(4, 3), wqs[0])


def test_manifest_entry_macro():
    lines = manifest_entry("macro", "macro_w4", None,
                           {"weight_bits": 4, "m": 128})
    assert lines[0] == "artifact macro_w4"
    assert "  kind macro" in lines
    assert lines[-1] == "end"


needs_artifacts = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.txt").exists(),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_real_manifest_lists_all_artifacts():
    text = (ARTIFACTS / "manifest.txt").read_text()
    for task in ("gesture", "flow"):
        for wb in (4, 6, 8):
            assert f"artifact {task}_w{wb}" in text
            assert (ARTIFACTS / f"{task}_w{wb}.hlo.txt").exists()
    for wb in (4, 6, 8):
        assert (ARTIFACTS / f"macro_w{wb}.hlo.txt").exists()


@needs_artifacts
def test_real_artifacts_are_hlo_text():
    for p in ARTIFACTS.glob("*.hlo.txt"):
        head = p.read_text()[:200]
        assert "HloModule" in head, p


@needs_artifacts
def test_real_swb_bundles_parse():
    for p in (ARTIFACTS / "weights").glob("*.swb"):
        blob = p.read_bytes()
        magic, n = struct.unpack_from("<II", blob, 0)
        assert magic == SWB_MAGIC
        off = 8
        for _ in range(n):
            fan_in, k, th, lk, sc = struct.unpack_from("<IIiid", blob, off)
            assert fan_in > 0 and k > 0 and th >= 1 and lk >= 0 and sc > 0
            off += struct.calcsize("<IIiid") + 4 * fan_in * k
        assert off == len(blob), p


@needs_artifacts
def test_fig16_eval_results_recorded():
    import json

    data = json.loads((ARTIFACTS / "fig16_eval.json").read_text())
    assert set(data["tasks"]) == {"gesture", "flow"}
    for task, entry in data["tasks"].items():
        assert set(entry["precisions"]) == {"4", "6", "8"}
        for wb, m in entry["precisions"].items():
            val = m[entry["metric"]]
            assert np.isfinite(val)
