"""Synthetic event-generator tests: determinism, sparsity bands, PRNG."""

import numpy as np
import pytest

from compile.data import (
    NUM_GESTURE_CLASSES,
    SplitMix64,
    flow_batch,
    gesture_batch,
    make_flow_scene,
    make_gesture,
)


def test_splitmix64_known_vector():
    """Golden values mirrored by rust/src/prop/rng.rs tests."""
    rng = SplitMix64(0)
    vals = [rng.next_u64() for _ in range(3)]
    assert vals == [
        0xE220A8397B1DCDAF,
        0x6E789E6AA1B965F4,
        0x06C45D188009454F,
    ]


def test_splitmix64_f64_range():
    rng = SplitMix64(42)
    xs = [rng.next_f64() for _ in range(1000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert 0.3 < float(np.mean(xs)) < 0.7


def test_gesture_deterministic():
    a = make_gesture(3, seed=11, height=32, width=32, timesteps=5)
    b = make_gesture(3, seed=11, height=32, width=32, timesteps=5)
    np.testing.assert_array_equal(a.frames, b.frames)


def test_gesture_classes_distinct():
    a = make_gesture(0, seed=5, height=32, width=32, timesteps=8)
    b = make_gesture(1, seed=5, height=32, width=32, timesteps=8)
    assert not np.array_equal(a.frames, b.frames)


def test_gesture_shape_and_binary():
    s = make_gesture(2, seed=1, height=48, width=40, timesteps=6)
    assert s.frames.shape == (6, 2, 48, 40)
    assert set(np.unique(s.frames)) <= {0, 1}
    assert s.label == 2


def test_gesture_sparsity_band():
    """Input sparsity must land in the high-sparsity DVS regime."""
    s = make_gesture(4, seed=9, height=64, width=64, timesteps=20)
    density = s.frames.mean()
    assert 0.001 < density < 0.15, density


def test_gesture_label_validation():
    with pytest.raises(ValueError):
        make_gesture(NUM_GESTURE_CLASSES, seed=0)


def test_flow_scene_shapes():
    s = make_flow_scene(seed=3, height=24, width=32, timesteps=5)
    assert s.frames.shape == (5, 2, 24, 32)
    assert s.flow.shape == (2, 24, 32)
    assert set(np.unique(s.frames)) <= {0, 1}


def test_flow_deterministic():
    a = make_flow_scene(seed=7, height=24, width=32, timesteps=4)
    b = make_flow_scene(seed=7, height=24, width=32, timesteps=4)
    np.testing.assert_array_equal(a.frames, b.frames)
    np.testing.assert_array_equal(a.flow, b.flow)


def test_flow_has_motion_events():
    s = make_flow_scene(seed=5, height=32, width=48, timesteps=8)
    # events should exist after the first frame (temporal contrast)
    assert s.frames[1:].sum() > 0
    # flow magnitude should be non-trivial somewhere
    mag = np.sqrt(s.flow[0] ** 2 + s.flow[1] ** 2)
    assert mag.max() > 0.1


def test_flow_denser_than_gesture():
    """The flow workload drives the low-sparsity regime of Fig. 5."""
    g = make_gesture(1, seed=2, height=48, width=64, timesteps=10)
    f = make_flow_scene(seed=2, height=48, width=64, timesteps=10)
    assert f.frames.mean() > g.frames.mean()


def test_batches():
    frames, labels = gesture_batch(4, seed=1, height=16, width=16,
                                   timesteps=3)
    assert frames.shape == (4, 3, 2, 16, 16)
    assert labels.shape == (4,)
    frames2, flows = flow_batch(3, seed=1, height=16, width=16, timesteps=3)
    assert frames2.shape == (3, 3, 2, 16, 16)
    assert flows.shape == (3, 2, 16, 16)
