"""Synthetic event-camera workload generators (build-time Python side).

The paper evaluates on IBM DVS Gesture [19] and DSEC-flow [20]; neither
dataset ships with this environment, so we substitute parametric event
generators that preserve the properties the architecture cares about
(DESIGN.md §2):

  * binary ON/OFF event frames with realistic, *layer-varying* sparsity
    (the entire point of Figs. 4/5/17 is how efficiency tracks sparsity),
  * temporally-coherent motion so SNN state (Vmem) carries information
    across timesteps,
  * ground truth (class label / dense optical flow) for Fig. 16.

``rust/src/dvs/`` implements the same generators with the same splitmix64
PRNG so Rust-side benches and Python-side training see identical
distributions (and identical frames for a given seed: cross-checked in
integration tests).
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: Gesture classes: 11, mirroring IBM DVS Gesture.
NUM_GESTURE_CLASSES = 11


def _splitmix64(state: int) -> tuple[int, int]:
    """One step of splitmix64; mirrored by ``rust/src/prop/rng.rs``."""
    state = (state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return state, z ^ (z >> 31)


class SplitMix64:
    """Deterministic, language-portable PRNG (same stream as Rust)."""

    def __init__(self, seed: int):
        self.state = seed & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        self.state, out = _splitmix64(self.state)
        return out

    def next_f64(self) -> float:
        """Uniform in [0, 1): top 53 bits / 2^53 (same as Rust)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()


@dataclasses.dataclass(frozen=True)
class GestureSample:
    """One synthetic gesture clip: frames ``(T, 2, H, W)`` uint8, label."""

    frames: np.ndarray
    label: int


def make_gesture(
    label: int,
    seed: int,
    *,
    height: int = 64,
    width: int = 64,
    timesteps: int = 20,
    noise_rate: float = 0.008,
) -> GestureSample:
    """Generate one synthetic DVS gesture clip.

    Each of the 11 classes is a parametric motion pattern of a bright
    "arm" segment (orbit direction/speed/radius and oscillation mode
    differ per class). Events fire where the rendered arm edge moves
    between consecutive sub-frames: ON (channel 0) where intensity rises,
    OFF (channel 1) where it falls — the DVS contrast model. Poisson-ish
    background noise is added per pixel per channel.
    """
    if not 0 <= label < NUM_GESTURE_CLASSES:
        raise ValueError(f"label {label} out of range")
    rng = SplitMix64((seed << 8) ^ (label * 0x9E37) ^ 0xD5)
    # Class-parametric motion, kept identical in rust/src/dvs/gesture.rs.
    # Classes are separable both spatially (each class orbits around a
    # class-specific center displaced from the image center) and
    # temporally (orbit direction alternates by class parity) — like
    # real DVS gestures, where "left-arm wave" vs "right-arm wave"
    # differ in both where and how events fire.
    min_hw = min(height, width)
    class_ang = 6.28318 * label / NUM_GESTURE_CLASSES
    cy = height / 2.0 + 0.26 * min_hw * np.sin(class_ang)
    cx = width / 2.0 + 0.26 * min_hw * np.cos(class_ang)
    direction = 1.0 if label % 2 == 0 else -1.0
    omega = 0.30 + 0.06 * (label % 3)
    radius0 = 0.14 * min_hw
    wobble = 0.0
    phase = rng.uniform(0.0, 6.28318)
    arm_len = 0.22 * min_hw
    thickness = 2.2

    def render(t: float) -> np.ndarray:
        ang = phase + direction * omega * t
        r = radius0 * (1.0 + wobble * np.sin(0.5 * t + phase))
        bx, by = cx + r * np.cos(ang), cy + r * np.sin(ang)
        ex = bx + arm_len * np.cos(ang + 1.2)
        ey = by + arm_len * np.sin(ang + 1.2)
        ys, xs = np.mgrid[0:height, 0:width].astype(np.float64)
        # distance from each pixel to the segment (bx,by)-(ex,ey)
        dx, dy = ex - bx, ey - by
        seg_len2 = dx * dx + dy * dy + 1e-9
        tproj = np.clip(((xs - bx) * dx + (ys - by) * dy) / seg_len2, 0.0, 1.0)
        px, py = bx + tproj * dx, by + tproj * dy
        dist = np.sqrt((xs - px) ** 2 + (ys - py) ** 2)
        return (dist < thickness).astype(np.float64)

    frames = np.zeros((timesteps, 2, height, width), dtype=np.uint8)
    prev = render(-1.0)
    for t in range(timesteps):
        cur = render(float(t))
        diff = cur - prev
        frames[t, 0] = (diff > 0.5).astype(np.uint8)   # ON events
        frames[t, 1] = (diff < -0.5).astype(np.uint8)  # OFF events
        prev = cur
    # Background noise, deterministic per (t, c, y, x) order.
    for t in range(timesteps):
        for c in range(2):
            mask = np.array(
                [rng.next_f64() < noise_rate
                 for _ in range(height * width)], dtype=np.uint8
            ).reshape(height, width)
            frames[t, c] |= mask
    return GestureSample(frames=frames, label=label)


@dataclasses.dataclass(frozen=True)
class FlowSample:
    """One synthetic driving-flow clip.

    frames: ``(T, 2, H, W)`` uint8 event frames.
    flow:   ``(2, H, W)`` float32 ground-truth pixel displacement per
            timestep (u = x-flow, v = y-flow), constant over the clip.
    """

    frames: np.ndarray
    flow: np.ndarray


def make_flow_scene(
    seed: int,
    *,
    height: int = 48,
    width: int = 64,
    timesteps: int = 10,
    num_blobs: int = 24,
    noise_rate: float = 0.005,
) -> FlowSample:
    """Generate a translating textured scene with ground-truth flow.

    A field of Gaussian intensity blobs translates rigidly with a random
    per-clip velocity (plus a weak expansion component, as in forward
    driving motion). Events fire on temporal contrast like the gesture
    generator. Dense ground-truth flow is the per-pixel displacement per
    timestep, which for rigid translation + expansion is analytic.
    """
    rng = SplitMix64((seed << 8) ^ 0xF10)
    vx = rng.uniform(-1.5, 1.5)
    vy = rng.uniform(-1.0, 1.0)
    expand = rng.uniform(0.0, 0.008)  # per-timestep radial expansion
    cy, cx = height / 2.0, width / 2.0
    blobs = [
        (rng.uniform(-8, height + 8), rng.uniform(-8, width + 8),
         rng.uniform(1.2, 3.0), rng.uniform(0.5, 1.0))
        for _ in range(num_blobs)
    ]
    ys, xs = np.mgrid[0:height, 0:width].astype(np.float64)

    def render(t: float) -> np.ndarray:
        img = np.zeros((height, width), dtype=np.float64)
        s = 1.0 + expand * t
        for (by, bx, sig, amp) in blobs:
            # rigid translation + expansion about the image center
            py = cy + (by - cy) * s + vy * t
            px = cx + (bx - cx) * s + vx * t
            img += amp * np.exp(-(((ys - py) ** 2 + (xs - px) ** 2)
                                  / (2.0 * sig * sig)))
        return img

    thresh = 0.08
    frames = np.zeros((timesteps, 2, height, width), dtype=np.uint8)
    prev = render(-1.0)
    for t in range(timesteps):
        cur = render(float(t))
        diff = cur - prev
        frames[t, 0] = (diff > thresh).astype(np.uint8)
        frames[t, 1] = (diff < -thresh).astype(np.uint8)
        prev = cur
    for t in range(timesteps):
        for c in range(2):
            mask = np.array(
                [rng.next_f64() < noise_rate
                 for _ in range(height * width)], dtype=np.uint8
            ).reshape(height, width)
            frames[t, c] |= mask

    u = vx + expand * (xs - cx)
    v = vy + expand * (ys - cy)
    flow = np.stack([u, v]).astype(np.float32)
    return FlowSample(frames=frames, flow=flow)


def gesture_batch(num: int, seed: int, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Batch of gesture clips: ``(N, T, 2, H, W)`` frames + ``(N,)`` labels."""
    frames, labels = [], []
    for i in range(num):
        label = (seed + i) % NUM_GESTURE_CLASSES
        s = make_gesture(label, seed=seed * 1000 + i, **kw)
        frames.append(s.frames)
        labels.append(s.label)
    return np.stack(frames), np.array(labels, dtype=np.int32)


def flow_batch(num: int, seed: int, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Batch of flow clips: ``(N, T, 2, H, W)`` frames + ``(N, 2, H, W)`` flow."""
    frames, flows = [], []
    for i in range(num):
        s = make_flow_scene(seed=seed * 1000 + i, **kw)
        frames.append(s.frames)
        flows.append(s.flow)
    return np.stack(frames), np.stack(flows)
