"""L1 Pallas kernels: the SpiDR compute-macro and neuron-macro math."""

from .neuron import neuron_update
from .spiking_matmul import spiking_matmul, vmem_footprint_bytes

__all__ = ["neuron_update", "spiking_matmul", "vmem_footprint_bytes"]
