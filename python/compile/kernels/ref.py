"""Pure-jnp reference oracles for the Pallas kernels.

These are the *numerical contract*: straightforward, obviously-correct
implementations of the CIM macro math (``spiking_matmul_ref``) and the
neuron macro math (``neuron_update_ref``). The Pallas kernels in
``spiking_matmul.py`` / ``neuron.py`` must match them bit-for-bit
(pytest + hypothesis enforce this), and the Rust cycle-level simulator
matches the same trajectories through the AOT artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quantize import wrap_to_bits


def spiking_matmul_ref(
    spikes: jnp.ndarray,
    weights: jnp.ndarray,
    vmem_in: jnp.ndarray,
    vmem_bits: int,
) -> jnp.ndarray:
    """Accumulate weights into partial Vmems for binary input spikes.

    This is what one SpiDR compute macro does for one IFspad worth of
    input: every spike at IFspad position (Y, X) adds weight row Y into
    the Vmem entry X of each mapped output neuron, with the B_v-bit
    adder chain wrapping on overflow.

    Args:
      spikes:  ``(M, F)`` int32 in {0, 1} — im2col'd input spikes.
               M = number of output pixels (Vmem entries), F = fan-in.
      weights: ``(F, K)`` int32 quantized weights, K = output neurons.
      vmem_in: ``(M, K)`` int32 partial Vmems (already in B_v range).
      vmem_bits: adder chain width B_v.

    Returns:
      ``(M, K)`` int32 updated partial Vmems, wrapped to B_v bits.
    """
    acc = jnp.matmul(
        spikes.astype(jnp.int32),
        weights.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return wrap_to_bits(vmem_in.astype(jnp.int32) + acc, vmem_bits)


def neuron_update_ref(
    vmem_partial: jnp.ndarray,
    vmem_full: jnp.ndarray,
    theta: jnp.ndarray,
    leak: jnp.ndarray,
    vmem_bits: int,
    *,
    leaky: bool,
    soft_reset: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One neuron-macro pass: integrate partials, leak, fire, reset.

    Ordering contract (mirrored by ``rust/src/sim/neuron_macro.rs``):

      1. leak   : decay the *full* Vmem toward zero by an arithmetic
                  shift (LIF only): v -= v >> leak  (leak = shift amount)
      2. integrate: add the partial Vmem (wrapping at B_v)
      3. fire   : spike where Vmem >= theta
      4. reset  : hard -> 0, soft -> Vmem - theta (wrapping)
      5. floor  : clamp negative Vmems at -theta (digital-SNN
                  underflow guard; keeps drift away from the wrap
                  boundary — see DESIGN.md §2)

    Args:
      vmem_partial: ``(M, K)`` int32 partial Vmems from compute units.
      vmem_full:    ``(M, K)`` int32 full Vmems (persistent state).
      theta:        scalar int32 firing threshold (>= 1).
      leak:         scalar int32 leak *shift* (>= 1, ignored if not leaky).
      vmem_bits:    adder chain width B_v.
      leaky:        IF (False) or LIF (True) neuron model.
      soft_reset:   subtract-threshold reset (True) or reset-to-zero (False).

    Returns:
      ``(spikes, vmem_next)`` — int32 {0,1} spikes and updated full Vmems.
    """
    v = vmem_full.astype(jnp.int32)
    theta = jnp.asarray(theta, dtype=jnp.int32)
    leak = jnp.asarray(leak, dtype=jnp.int32)
    if leaky:
        v = v - jnp.right_shift(v, jnp.maximum(leak, 1))
    v = wrap_to_bits(v + vmem_partial.astype(jnp.int32), vmem_bits)
    spikes = (v >= theta).astype(jnp.int32)
    if soft_reset:
        v_reset = wrap_to_bits(v - theta, vmem_bits)
    else:
        v_reset = jnp.zeros_like(v)
    vmem_next = jnp.where(spikes == 1, v_reset, v)
    vmem_next = jnp.maximum(vmem_next, -theta)
    return spikes, vmem_next
