"""Pallas kernel for the SpiDR compute-macro hot path.

The compute macro performs weight-to-Vmem accumulation for binary input
spikes: a GEMM where the left operand is a {0,1} spike matrix. This
kernel is the L1 hot-spot of the stack — every spiking Conv/FC layer in
the L2 JAX model lowers its im2col'd inner loop to ``spiking_matmul``.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the silicon macro
is weight-stationary with 48 columns and 128 weight rows, streaming
IFspad blocks of 128x16 spikes. The Pallas tiling mirrors that schedule:

  * the weight tile ``(F, bk)`` stays resident in VMEM across the whole
    grid row (weight-stationary),
  * the spike matrix streams through in ``(bm, F)`` blocks — the IFspad
    role — via BlockSpec index maps,
  * accumulation happens into a ``(bm, bk)`` Vmem tile, wrapped to the
    B_v-bit adder-chain width on the way out.

On a real TPU the inner product maps onto the MXU with int8/int32
accumulation; here the kernel runs under ``interpret=True`` (the CPU
PJRT plugin cannot execute Mosaic custom-calls) and its numerics are
pinned to ``ref.spiking_matmul_ref`` bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantize import wrap_to_bits

#: Default block sizes. 128 matches the macro's weight-row count; the
#: lane dimension tiles in multiples of the 48-column macro width.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 48


def _kernel(s_ref, w_ref, v_ref, o_ref, *, vmem_bits: int):
    """One grid step: o = wrap(v + s @ w, B_v) for one (bm, bk) tile."""
    s = s_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    v = v_ref[...].astype(jnp.int32)
    acc = jax.lax.dot_general(
        s,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = wrap_to_bits(v + acc, vmem_bits)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= ``preferred``.

    Keeps the grid exact (no padding logic in the kernel) while staying
    close to the macro-shaped tile sizes for typical layer dimensions.
    """
    if dim <= preferred:
        return dim
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(
    jax.jit, static_argnames=("vmem_bits", "block_m", "block_k", "interpret")
)
def spiking_matmul(
    spikes: jnp.ndarray,
    weights: jnp.ndarray,
    vmem_in: jnp.ndarray,
    vmem_bits: int,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Accumulate binary spikes x quantized weights into partial Vmems.

    Args:
      spikes:  ``(M, F)`` int32 {0,1} im2col'd input spikes.
      weights: ``(F, K)`` int32 quantized weights.
      vmem_in: ``(M, K)`` int32 partial Vmems.
      vmem_bits: B_v adder width (7, 11 or 15).
      block_m / block_k: tile sizes (clamped to divisors of M / K).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      ``(M, K)`` int32 updated partial Vmems, wrapped to B_v bits.
    """
    m, f = spikes.shape
    f2, k = weights.shape
    if f != f2:
        raise ValueError(f"fan-in mismatch: spikes {spikes.shape} vs weights {weights.shape}")
    if vmem_in.shape != (m, k):
        raise ValueError(f"vmem shape {vmem_in.shape} != ({m}, {k})")

    bm = _pick_block(m, block_m)
    bk = _pick_block(k, block_k)
    grid = (m // bm, k // bk)

    return pl.pallas_call(
        functools.partial(_kernel, vmem_bits=vmem_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((f, bk), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.int32),
        interpret=interpret,
    )(spikes.astype(jnp.int32), weights.astype(jnp.int32), vmem_in.astype(jnp.int32))


def vmem_footprint_bytes(m: int, f: int, k: int, block_m: int = DEFAULT_BLOCK_M,
                         block_k: int = DEFAULT_BLOCK_K) -> int:
    """Estimated VMEM bytes held live per grid step (perf-model input).

    spike tile (bm, F) + weight tile (F, bk) + two Vmem tiles (bm, bk),
    all int32. Used by DESIGN.md §Perf to check tiles fit a ~16 MiB VMEM
    budget and to estimate MXU occupancy on real hardware.
    """
    bm = _pick_block(m, block_m)
    bk = _pick_block(k, block_k)
    return 4 * (bm * f + f * bk + 2 * bm * bk)
