"""Pallas kernel for the SpiDR neuron-macro pass.

The neuron macro (72x48 SRAM: 32 partial-Vmem rows, 32 full-Vmem rows,
8 parameter rows) integrates partial Vmems received from compute units
into full Vmems, applies the configured neuron dynamics (IF / LIF) and
reset mode (hard / soft), and emits output spikes.

All four (leaky, soft_reset) combinations compile to distinct kernels —
exactly like the silicon, where the neuron model is a configuration
register latched before execution, not a per-cycle decision.

Numerics are pinned bit-for-bit to ``ref.neuron_update_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantize import wrap_to_bits

#: The neuron macro integrates 32 partial rows per pass (paper eq. 3).
DEFAULT_BLOCK_M = 32
DEFAULT_BLOCK_K = 48


def _kernel(p_ref, v_ref, t_ref, l_ref, s_out, v_out, *,
            vmem_bits: int, leaky: bool, soft_reset: bool):
    """One grid step over a (bm, bk) Vmem tile."""
    p = p_ref[...].astype(jnp.int32)
    v = v_ref[...].astype(jnp.int32)
    theta = t_ref[0, 0]
    if leaky:
        leak = l_ref[0, 0]
        v = v - jnp.right_shift(v, jnp.maximum(leak, 1))
    v = wrap_to_bits(v + p, vmem_bits)
    spikes = (v >= theta).astype(jnp.int32)
    if soft_reset:
        v_reset = wrap_to_bits(v - theta, vmem_bits)
    else:
        v_reset = jnp.zeros_like(v)
    s_out[...] = spikes
    v_out[...] = jnp.maximum(jnp.where(spikes == 1, v_reset, v), -theta)


def _pick_block(dim: int, preferred: int) -> int:
    if dim <= preferred:
        return dim
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(
    jax.jit,
    static_argnames=("vmem_bits", "leaky", "soft_reset", "block_m", "block_k",
                     "interpret"),
)
def neuron_update(
    vmem_partial: jnp.ndarray,
    vmem_full: jnp.ndarray,
    theta: jnp.ndarray,
    leak: jnp.ndarray,
    vmem_bits: int,
    *,
    leaky: bool,
    soft_reset: bool,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Integrate partial Vmems, apply neuron dynamics, emit spikes.

    Args:
      vmem_partial: ``(M, K)`` int32 partial Vmems.
      vmem_full:    ``(M, K)`` int32 persistent full Vmems.
      theta: scalar int32 threshold (>= 1).
      leak:  scalar int32 leak magnitude (LIF only).
      vmem_bits: B_v adder width.
      leaky / soft_reset: neuron model configuration (static).

    Returns:
      ``(spikes, vmem_next)`` int32 arrays of shape ``(M, K)``.
    """
    m, k = vmem_full.shape
    if vmem_partial.shape != (m, k):
        raise ValueError(
            f"partial shape {vmem_partial.shape} != full shape {(m, k)}")
    bm = _pick_block(m, block_m)
    bk = _pick_block(k, block_k)
    grid = (m // bm, k // bk)

    theta2d = jnp.asarray(theta, dtype=jnp.int32).reshape(1, 1)
    leak2d = jnp.asarray(leak, dtype=jnp.int32).reshape(1, 1)

    return pl.pallas_call(
        functools.partial(
            _kernel, vmem_bits=vmem_bits, leaky=leaky, soft_reset=soft_reset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
        ],
        interpret=interpret,
    )(vmem_partial.astype(jnp.int32), vmem_full.astype(jnp.int32),
      theta2d, leak2d)
