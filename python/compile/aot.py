"""AOT lowering: JAX network steps -> HLO text artifacts for the Rust runtime.

Emits, for every (task, precision) pair trained by ``train.py``:

  artifacts/{task}_w{B}.hlo.txt   — one full-network timestep
      inputs : frame (C,H,W) i32, then one (M,K) i32 Vmem per stateful
               layer, in layer order
      outputs: tuple(out_acc (M_out,K_out) i32, counts (L,) i32,
               vmem'_0, ..., vmem'_{L-1})

plus a standalone compute-macro artifact used by the quickstart example
and runtime unit tests:

  artifacts/macro_w{B}.hlo.txt    — spiking_matmul at a fixed small shape

and machine-readable metadata for the Rust side:

  artifacts/manifest.txt          — line-oriented artifact descriptions
  artifacts/weights/{task}_w{B}.swb — integer weight bundle (see swb format
      doc below) consumed by the cycle-level simulator so that the sim and
      the PJRT golden model compute from identical integers.

HLO *text* (never ``HloModuleProto.serialize``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that the
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

swb ("SpiDR weight bundle") binary format, all little-endian:
    u32 magic = 0x53574231 ("SWB1")
    u32 num_layers
    per layer: u32 fan_in, u32 k, i32 theta, i32 leak, f64 scale,
               i32 weights[fan_in * k]   (row-major, W[f][k])
"""

from __future__ import annotations

import argparse
import pathlib
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import QuantizedNetwork, build_layers, flow_topology, gesture_topology, network_step
from .quantize import PRECISIONS, PrecisionConfig

SWB_MAGIC = 0x53574231


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (the interchange format).

    ``print_large_constants=True`` is essential: the default printer
    elides big dense literals as ``constant({...})``, which the text
    parser on the Rust side then fills with garbage — the baked-in
    trained weights would silently turn into nonsense (this bit us; see
    EXPERIMENTS.md §Fig16 'HLO text round-trip' note).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "constant({...})" in text:
        raise RuntimeError(
            "HLO text still contains elided constants; the artifact "
            "would be corrupt"
        )
    return text


def load_bundle(path: pathlib.Path):
    """Load a train.py npz bundle -> (wqs, scales, thetas, leaks, meta)."""
    z = np.load(path)
    n = int(z["num_layers"])
    wqs = [z[f"w{i}"] for i in range(n)]
    meta = {
        "timesteps": int(z["timesteps"]),
        "input_shape": tuple(int(x) for x in z["input_shape"]),
    }
    return wqs, list(z["scales"]), list(z["thetas"]), list(z["leaks"]), meta


def write_swb(path: pathlib.Path, wqs, scales, thetas, leaks) -> None:
    """Write the integer weight bundle the Rust simulator consumes."""
    with open(path, "wb") as f:
        f.write(struct.pack("<II", SWB_MAGIC, len(wqs)))
        for wq, s, th, lk in zip(wqs, scales, thetas, leaks):
            fan_in, k = wq.shape
            f.write(struct.pack("<IIiid", fan_in, k, int(th), int(lk), float(s)))
            f.write(np.ascontiguousarray(wq, dtype="<i4").tobytes())


def build_network(task: str, wb: int, weights_dir: pathlib.Path) -> QuantizedNetwork:
    """Reconstruct the quantized network for one (task, precision)."""
    vb = {4: 7, 6: 11, 8: 15}[wb]
    cfg = PrecisionConfig(wb, vb)
    wqs, scales, thetas, leaks, meta = load_bundle(
        weights_dir / f"{task}_w{wb}.npz")
    topology = gesture_topology() if task == "gesture" else flow_topology()
    layers = build_layers(topology, meta["input_shape"], wqs, thetas, leaks)
    return QuantizedNetwork(
        name=task, layers=layers, precision=cfg,
        weight_scales=tuple(scales), timesteps=meta["timesteps"])


def lower_network_step(net: QuantizedNetwork) -> str:
    """Lower one full-network timestep to HLO text."""
    c, h, w = net.layers[0].in_shape
    frame_spec = jax.ShapeDtypeStruct((c, h, w), jnp.int32)
    vmem_specs = [
        jax.ShapeDtypeStruct(l.vmem_shape, jnp.int32)
        for l in net.stateful_layers
    ]

    def step(frame, *vmems):
        out_acc, counts, vmems_next = network_step(net, frame, list(vmems))
        return (out_acc, counts, *vmems_next)

    lowered = jax.jit(step).lower(frame_spec, *vmem_specs)
    return to_hlo_text(lowered)


def lower_macro(wb: int, m: int = 128, f: int = 72, k: int = 12) -> str:
    """Lower a standalone compute-macro op (quickstart / runtime tests)."""
    from .kernels.spiking_matmul import spiking_matmul
    vb = {4: 7, 6: 11, 8: 15}[wb]

    def macro(spikes, weights, vmem):
        return (spiking_matmul(spikes, weights, vmem, vb),)

    specs = (
        jax.ShapeDtypeStruct((m, f), jnp.int32),
        jax.ShapeDtypeStruct((f, k), jnp.int32),
        jax.ShapeDtypeStruct((m, k), jnp.int32),
    )
    return to_hlo_text(jax.jit(macro).lower(*specs))


def manifest_entry(kind: str, name: str, net: QuantizedNetwork | None,
                   extra: dict) -> list[str]:
    """Line-oriented manifest block (one `artifact` stanza)."""
    lines = [f"artifact {name}", f"  kind {kind}"]
    for key, val in extra.items():
        lines.append(f"  {key} {val}")
    if net is not None:
        c, h, w = net.layers[0].in_shape
        lines.append(f"  task {net.name}")
        lines.append(f"  weight_bits {net.precision.weight_bits}")
        lines.append(f"  vmem_bits {net.precision.vmem_bits}")
        lines.append(f"  timesteps {net.timesteps}")
        lines.append(f"  frame_shape {c} {h} {w}")
        lines.append(f"  output_scale {float(net.output_scale):.17g}")
        for i, l in enumerate(net.stateful_layers):
            msize, ksize = l.vmem_shape
            lines.append(f"  vmem {i} {msize} {ksize}")
        out_l = net.stateful_layers[-1]
        lines.append(f"  out_shape {out_l.vmem_shape[0]} {out_l.vmem_shape[1]}")
        lines.append(f"  num_state_layers {len(net.stateful_layers)}")
    lines.append("end")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tasks", nargs="*", default=["gesture", "flow"])
    ap.add_argument("--precisions", nargs="*", type=int, default=[4, 6, 8])
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    weights_dir = out_dir / "weights"
    if not weights_dir.exists():
        print("error: run `python -m compile.train` first (no weights found)",
              file=sys.stderr)
        raise SystemExit(1)

    manifest: list[str] = ["# SpiDR artifact manifest (generated by aot.py)"]

    # Standalone macro artifacts (one per precision).
    for wb in args.precisions:
        name = f"macro_w{wb}"
        text = lower_macro(wb)
        (out_dir / f"{name}.hlo.txt").write_text(text)
        manifest += manifest_entry(
            "macro", name, None,
            {"weight_bits": wb, "vmem_bits": {4: 7, 6: 11, 8: 15}[wb],
             "m": 128, "f": 72, "k": 12})
        print(f"wrote {name}.hlo.txt ({len(text)} chars)")

    # Full-network step artifacts.
    for task in args.tasks:
        for wb in args.precisions:
            net = build_network(task, wb, weights_dir)
            name = f"{task}_w{wb}"
            text = lower_network_step(net)
            (out_dir / f"{name}.hlo.txt").write_text(text)
            manifest += manifest_entry("network_step", name, net, {})
            print(f"wrote {name}.hlo.txt ({len(text)} chars)")

            wqs, scales, thetas, leaks, _ = load_bundle(
                weights_dir / f"{task}_w{wb}.npz")
            write_swb(weights_dir / f"{task}_w{wb}.swb",
                      wqs, scales, thetas, leaks)

    (out_dir / "manifest.txt").write_text("\n".join(manifest) + "\n")
    print(f"wrote manifest.txt ({len(manifest)} lines)")


if __name__ == "__main__":
    main()
