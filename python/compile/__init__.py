"""Build-time Python for the SpiDR reproduction.

Layers L1 (Pallas kernels) and L2 (JAX model), plus the AOT lowering
(`aot.py`) that produces the HLO-text artifacts the Rust runtime loads.
Never imported on the request path.
"""
