"""Fixed-point quantization contract shared with the Rust simulator.

SpiDR stores synaptic weights at B_w in {4, 6, 8} bits and membrane
potentials (Vmems) at B_v = 2*B_w - 1 in {7, 11, 15} bits (paper §II-A).
Both are signed two's-complement integers. Accumulation inside the CIM
macro is performed by a B_v-bit column adder chain which *wraps* on
overflow (two's-complement modular arithmetic).

Wrap-around is the architectural contract of this reproduction: modular
addition is associative and commutative, so the order in which the S2A
drains spikes from the even/odd FIFOs — and the order in which partial
Vmems hop across compute units in Mode 2 — cannot change the result.
This is what makes the JAX golden model (one int32 GEMM, then a single
wrap) bit-exact against the cycle-level Rust simulator (per-event
accumulation with per-step wraps).

Everything in this module is mirrored by ``rust/src/quant/`` and covered
by cross-language bit-exactness tests.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: Supported (weight, Vmem) precision pairs, from paper Fig. 8a.
PRECISIONS = ((4, 7), (6, 11), (8, 15))


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """A reconfigurable precision operating point of the compute macro."""

    weight_bits: int
    vmem_bits: int

    def __post_init__(self) -> None:
        if (self.weight_bits, self.vmem_bits) not in PRECISIONS:
            raise ValueError(
                f"unsupported precision {self.weight_bits}/{self.vmem_bits}; "
                f"supported: {PRECISIONS}"
            )

    @property
    def weight_min(self) -> int:
        return -(1 << (self.weight_bits - 1))

    @property
    def weight_max(self) -> int:
        return (1 << (self.weight_bits - 1)) - 1

    @property
    def vmem_min(self) -> int:
        return -(1 << (self.vmem_bits - 1))

    @property
    def vmem_max(self) -> int:
        return (1 << (self.vmem_bits - 1)) - 1

    @property
    def neurons_per_row(self) -> int:
        """Output neurons stored per 48-bit weight row (48 / B_w)."""
        return 48 // self.weight_bits


P4_7 = PrecisionConfig(4, 7)
P6_11 = PrecisionConfig(6, 11)
P8_15 = PrecisionConfig(8, 15)


def wrap_to_bits(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Two's-complement wrap of int32 values to ``bits`` bits.

    Implemented as an arithmetic shift-up/shift-down pair, which XLA
    lowers to two cheap vector ops and which is exactly the sign
    extension a ``bits``-wide adder chain performs in silicon.
    """
    shift = 32 - bits
    x = x.astype(jnp.int32)
    return jnp.right_shift(jnp.left_shift(x, shift), shift)


def saturate_to_bits(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Saturating clamp to a signed ``bits``-bit range (optional mode)."""
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return jnp.clip(x.astype(jnp.int32), lo, hi)


def quantize_weights(
    w: np.ndarray, cfg: PrecisionConfig
) -> tuple[np.ndarray, float]:
    """Symmetric per-tensor quantization of float weights.

    Returns ``(w_q, scale)`` with ``w ≈ w_q * scale`` and
    ``w_q`` in ``[weight_min, weight_max]``.
    """
    w = np.asarray(w, dtype=np.float64)
    max_abs = float(np.max(np.abs(w))) if w.size else 0.0
    if max_abs == 0.0:
        return np.zeros_like(w, dtype=np.int32), 1.0
    scale = max_abs / cfg.weight_max
    w_q = np.clip(np.round(w / scale), cfg.weight_min, cfg.weight_max)
    return w_q.astype(np.int32), scale


def quantize_threshold(theta: float, scale: float, cfg: PrecisionConfig) -> int:
    """Quantize a firing threshold into the Vmem integer domain.

    Vmem accumulates quantized weights directly (binary spikes), so the
    Vmem scale equals the weight scale and thresholds divide through by
    the same factor. Thresholds are clamped to be at least 1 so that a
    quantized neuron can never fire on a zero Vmem.
    """
    q = int(round(theta / scale))
    return max(1, min(q, cfg.vmem_max))


def quantize_leak(leak: float, scale: float, cfg: PrecisionConfig) -> int:
    """Convert a float LIF decay fraction into a leak *shift* amount.

    The digital neuron macro implements leak as an arithmetic shift:
    ``v -= v >> k``, i.e. a decay fraction of ``2^-k`` per timestep —
    scale-free, so the same shift works at every precision pair.
    ``leak`` is the float decay fraction (e.g. 0.25 -> k = 2).
    """
    del scale, cfg
    if leak <= 0.0:
        return 0
    k = round(-np.log2(min(max(leak, 1e-6), 0.5)))
    return int(max(1, min(k, 8)))
