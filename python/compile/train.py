"""Surrogate-gradient training for the Table-II workloads (build time).

SpiDR is an inference chip: the paper's networks are trained offline
with standard surrogate-gradient BPTT ("no modified training
methodology", Table III) and deployed quantized. This module is that
offline pipeline:

  1. train a float *shadow* network (same topology, same im2col layout,
     subtractive-leak LIF dynamics, fast-sigmoid surrogate spike),
  2. post-training-quantize weights/thresholds/leaks to each supported
     precision pair (4/7, 6/11, 8/15),
  3. evaluate accuracy (gesture) / average endpoint error (flow) at
     every precision — the data behind Fig. 16,
  4. save per-precision integer weights for ``aot.py`` to bake into the
     HLO artifacts the Rust runtime executes.

Run as ``python -m compile.train --out ../artifacts`` (the Makefile's
``artifacts`` target drives this, then ``aot.py``).
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import build_layers, conv_out, flow_topology, gesture_topology
from .quantize import (
    PRECISIONS,
    PrecisionConfig,
    quantize_leak,
    quantize_threshold,
    quantize_weights,
)

# Float neuron parameters used for all hidden layers during training.
# THETA is deliberately low and INIT_GAIN high relative to a He baseline:
# spiking nets with sparse DVS inputs go silent in deep layers otherwise
# (zero spikes -> zero surrogate gradient -> dead network).
THETA = 0.5
LEAK = 0.25  # per-timestep LIF decay fraction (shift 2 in hardware)
SURROGATE_SLOPE = 4.0
INIT_GAIN = 3.0


# ---------------------------------------------------------------------------
# Float shadow model (differentiable twin of model.py's integer network)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def spike_fn(v: jnp.ndarray) -> jnp.ndarray:
    """Heaviside step with a fast-sigmoid surrogate derivative."""
    return (v >= 0.0).astype(v.dtype)


def _spike_fwd(v):
    return spike_fn(v), v


def _spike_bwd(v, g):
    # fast sigmoid surrogate: 1 / (1 + k|v|)^2
    surr = 1.0 / (1.0 + SURROGATE_SLOPE * jnp.abs(v)) ** 2
    return (g * surr,)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def _im2col_f(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int) -> jnp.ndarray:
    """Batched float im2col, same (c, dy, dx) layout as model.im2col.

    x: (B, C, H, W) -> (B, M, F).
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    h_out = (h + 2 * pad - kh) // stride + 1
    w_out = (w + 2 * pad - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(
                jax.lax.slice(
                    xp,
                    (0, 0, dy, dx),
                    (b, c, dy + stride * (h_out - 1) + 1,
                     dx + stride * (w_out - 1) + 1),
                    (1, 1, stride, stride),
                )
            )
    stacked = jnp.stack(cols, axis=0).reshape(kh * kw, b, c, h_out * w_out)
    # -> (B, C, kh*kw, M) -> (B, F, M) -> (B, M, F)
    patches = jnp.transpose(stacked, (1, 2, 0, 3)).reshape(
        b, c * kh * kw, h_out * w_out)
    return jnp.transpose(patches, (0, 2, 1))


def _maxpool_f(x: jnp.ndarray, size: int, stride: int) -> jnp.ndarray:
    """Maxpool over (B, C, H, W) float spike planes."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, 1, size, size),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def init_weights(topology: list[dict], input_shape, seed: int) -> list[np.ndarray]:
    """He-initialized float weights, (F, K) layout per stateful layer."""
    rng = np.random.default_rng(seed)
    c, h, w = input_shape
    ws = []
    for t in topology:
        if t["kind"] == "pool":
            stride = min(t["stride"], min(t["size"], h, w))
            h, w = h // stride, w // stride
            continue
        if t["kind"] == "conv":
            f = c * t["kh"] * t["kw"]
            k = t["out_ch"]
            ws.append(rng.normal(0.0, INIT_GAIN * np.sqrt(2.0 / f),
                                 (f, k)).astype(np.float32))
            h, w = conv_out(h, w, t["kh"], t["kw"], t["stride"], t["pad"])
            c = k
        else:  # fc
            f = c * h * w
            k = t["out_ch"]
            ws.append(rng.normal(0.0, INIT_GAIN * np.sqrt(2.0 / f),
                                 (f, k)).astype(np.float32))
            c, h, w = k, 1, 1
    return ws


def _fake_quant_weight(w: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Straight-through fake quantization of a weight tensor.

    Returns (w_fq, scale): the forward value equals the dequantized
    integer weights the chip will use; the gradient passes through.
    """
    max_abs = jax.lax.stop_gradient(jnp.max(jnp.abs(w)) + 1e-12)
    scale = max_abs / cfg.weight_max
    q = jnp.clip(jnp.round(w / scale), cfg.weight_min, cfg.weight_max) * scale
    return w + jax.lax.stop_gradient(q - w), scale


def float_forward(
    weights: Sequence[jnp.ndarray],
    topology: list[dict],
    input_shape: tuple[int, int, int],
    frames: jnp.ndarray,
    fake_quant=None,
) -> jnp.ndarray:
    """Run the float shadow network over a clip.

    frames: (B, T, C, H, W) float {0,1}. Returns the accumulated output
    (B, M, K) of the final (non-spiking) layer.

    With ``fake_quant`` set to a PrecisionConfig, runs QAT-style: weights
    are fake-quantized (straight-through estimator) and Vmems are clipped
    to the B_v-bit range in float units, so the network learns to keep
    its state inside the chip's adder-chain range. Without this, deep
    accumulators drift past ±2^(B_v−1) and the deployed wrap-around
    arithmetic destroys low-precision metrics (see EXPERIMENTS.md).
    """
    b, timesteps = frames.shape[0], frames.shape[1]

    if fake_quant is not None:
        fq = [_fake_quant_weight(w, fake_quant) for w in weights]
        weights = [w for w, _ in fq]
        vmem_clip = [
            (s * fake_quant.vmem_min, s * fake_quant.vmem_max) for _, s in fq
        ]
    else:
        vmem_clip = None

    # Pre-compute static geometry per layer.
    geo = []
    c, h, w = input_shape
    for t in topology:
        if t["kind"] == "pool":
            size = min(t["size"], h, w)
            stride = min(t["stride"], size)
            geo.append(("pool", size, stride))
            h, w = h // stride, w // stride
        elif t["kind"] == "conv":
            ho, wo = conv_out(h, w, t["kh"], t["kw"], t["stride"], t["pad"])
            geo.append(("conv", t, (c, h, w), (t["out_ch"], ho, wo)))
            c, h, w = t["out_ch"], ho, wo
        else:
            geo.append(("fc", t, (c, h, w), (t["out_ch"], 1, 1)))
            c, h, w = t["out_ch"], 1, 1

    # Vmem states per stateful layer: (B, M, K).
    vmems = []
    for g in geo:
        if g[0] == "conv":
            _, _, _, (k, ho, wo) = g
            vmems.append(jnp.zeros((b, ho * wo, k), dtype=jnp.float32))
        elif g[0] == "fc":
            _, _, _, (k, _, _) = g
            vmems.append(jnp.zeros((b, 1, k), dtype=jnp.float32))

    def step(vmems, frame):
        x = frame.astype(jnp.float32)
        new_vmems = []
        si = 0
        out = None
        for g in geo:
            if g[0] == "pool":
                x = _maxpool_f(x, g[1], g[2])
                continue
            t = g[1]
            if g[0] == "conv":
                patches = _im2col_f(x, t["kh"], t["kw"], t["stride"], t["pad"])
            else:
                x_b = x.reshape(b, 1, -1)
                patches = x_b
            w_l = weights[si]
            partial = jnp.einsum("bmf,fk->bmk", patches, w_l)
            v = vmems[si]
            if t["accumulate"]:
                v = v + partial
                if vmem_clip is not None:
                    lo, hi = vmem_clip[si]
                    v = jnp.clip(v, lo, hi)
                new_vmems.append(v)
                out = v
                # output layer is last; no spikes propagate
                si += 1
                continue
            if t.get("leaky", False):
                v = v * (1.0 - LEAK)
            v = v + partial
            if vmem_clip is not None:
                lo, hi = vmem_clip[si]
                v = jnp.clip(v, lo, hi)
            s = spike_fn(v - THETA)
            v = v - THETA * s  # soft reset
            v = jnp.maximum(v, -THETA)  # digital underflow floor
            new_vmems.append(v)
            si += 1
            if g[0] == "conv":
                k, ho, wo = g[3]
                x = jnp.transpose(s, (0, 2, 1)).reshape(b, k, ho, wo)
            else:
                x = s
        return new_vmems, out

    out = None
    for t in range(timesteps):
        vmems, out = step(vmems, frames[:, t])
    return out


# ---------------------------------------------------------------------------
# Hand-rolled Adam (optax is not available in this environment)
# ---------------------------------------------------------------------------


def adam_init(params):
    return ([jnp.zeros_like(p) for p in params],
            [jnp.zeros_like(p) for p in params], 0)


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t += 1
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1 ** t)
        vhat = vi / (1 - b2 ** t)
        new_params.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_params, (new_m, new_v, t)


# ---------------------------------------------------------------------------
# Tasks
# ---------------------------------------------------------------------------


#: Accumulated output Vmems grow with timesteps; temper the CE softmax.
LOGIT_SCALE = 0.2


def gesture_loss(weights, topology, input_shape, frames, labels, fq=None):
    out = float_forward(weights, topology, input_shape, frames,
                        fake_quant=fq)  # (B,1,11)
    logits = out[:, 0, :] * LOGIT_SCALE
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def flow_loss(weights, topology, input_shape, frames, flows, fq=None):
    out = float_forward(weights, topology, input_shape, frames,
                        fake_quant=fq)  # (B,M,2)
    b = flows.shape[0]
    gt = flows.reshape(b, 2, -1).transpose(0, 2, 1)  # (B,M,2)
    return jnp.mean(jnp.sum((out - gt) ** 2, axis=-1))


def train_task(
    task: str,
    *,
    steps: int,
    batch: int,
    seed: int,
    input_hw: tuple[int, int],
    timesteps: int,
    lr: float,
    init: Sequence[np.ndarray] | None = None,
    fake_quant=None,
    log=print,
) -> tuple[list[np.ndarray], list[dict], dict]:
    """Train one task; returns (float_weights, topology, train_info).

    Pass ``init`` + ``fake_quant`` to run a QAT fine-tune from an
    existing float checkpoint at one precision.
    """
    h, w = input_hw
    input_shape = (2, h, w)
    if task == "gesture":
        topology = gesture_topology()
        loss_fn = gesture_loss
    elif task == "flow":
        topology = flow_topology()
        loss_fn = flow_loss
    else:
        raise ValueError(task)

    if init is not None:
        weights = [jnp.asarray(x) for x in init]
    else:
        weights = [jnp.asarray(x) for x in init_weights(topology, input_shape, seed)]
    opt = adam_init(weights)

    grad_fn = jax.jit(lambda ws, fr, tg: jax.value_and_grad(
        lambda ws_: loss_fn(ws_, topology, input_shape, fr, tg,
                            fq=fake_quant))(ws))

    losses = []
    t0 = time.time()
    for step in range(steps):
        if task == "gesture":
            frames, target = data.gesture_batch(
                batch, seed=seed + step * 17, height=h, width=w,
                timesteps=timesteps)
        else:
            frames, target = data.flow_batch(
                batch, seed=seed + step * 17, height=h, width=w,
                timesteps=timesteps)
        loss, grads = grad_fn(
            weights, jnp.asarray(frames, dtype=jnp.float32),
            jnp.asarray(target))
        # Global-norm gradient clipping: spiking BPTT is spiky (pun intended).
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
        clip = jnp.minimum(1.0, 5.0 / (gnorm + 1e-9))
        grads = [g * clip for g in grads]
        weights, opt = adam_update(weights, grads, opt, lr=lr)
        losses.append(float(loss))
        if step % 10 == 0 or step == steps - 1:
            log(f"  [{task}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    info = {"losses": losses, "steps": steps, "batch": batch,
            "input_hw": list(input_hw), "timesteps": timesteps,
            "train_seconds": time.time() - t0}
    return [np.asarray(wt) for wt in weights], topology, info


# ---------------------------------------------------------------------------
# Quantization + evaluation (Fig. 16 data)
# ---------------------------------------------------------------------------


def quantize_network(float_weights, cfg: PrecisionConfig):
    """PTQ to one precision pair: (int weights, scales, thetas, leaks)."""
    wqs, scales, thetas, leaks = [], [], [], []
    for wf in float_weights:
        wq, s = quantize_weights(wf, cfg)
        wqs.append(wq)
        scales.append(s)
        thetas.append(quantize_threshold(THETA, s, cfg))
        leaks.append(quantize_leak(LEAK, s, cfg))
    return wqs, scales, thetas, leaks


def eval_gesture_float(weights, topology, input_shape, frames, labels) -> float:
    out = float_forward([jnp.asarray(w) for w in weights], topology,
                        input_shape, jnp.asarray(frames, dtype=jnp.float32))
    pred = np.asarray(jnp.argmax(out[:, 0, :], axis=-1))
    return float(np.mean(pred == labels))


def eval_flow_float(weights, topology, input_shape, frames, flows) -> float:
    out = float_forward([jnp.asarray(w) for w in weights], topology,
                        input_shape, jnp.asarray(frames, dtype=jnp.float32))
    b = flows.shape[0]
    gt = flows.reshape(b, 2, -1).transpose(0, 2, 1)
    epe = np.asarray(jnp.sqrt(jnp.sum((out - gt) ** 2, axis=-1)))
    return float(np.mean(epe))


def eval_gesture_quant(net, frames_batch, labels) -> float:
    from .model import run_network
    correct = 0
    for i in range(frames_batch.shape[0]):
        out, _ = run_network(net, frames_batch[i])
        pred = int(np.argmax(np.asarray(out)[0]))
        correct += int(pred == labels[i])
    return correct / frames_batch.shape[0]


def eval_flow_quant(net, frames_batch, flows) -> float:
    from .model import run_network
    epes = []
    for i in range(frames_batch.shape[0]):
        out, _ = run_network(net, frames_batch[i])
        pred = np.asarray(out).astype(np.float64) * net.output_scale
        h, w = flows.shape[2], flows.shape[3]
        gt = flows[i].reshape(2, -1).T  # (M, 2)
        epes.append(np.mean(np.sqrt(np.sum((pred - gt) ** 2, axis=-1))))
    return float(np.mean(epes))


def build_quantized(task, topology, input_shape, wqs, scales, thetas, leaks,
                    cfg, timesteps):
    from .model import QuantizedNetwork
    layers = build_layers(topology, input_shape, wqs, thetas, leaks)
    return QuantizedNetwork(
        name=task, layers=layers, precision=cfg,
        weight_scales=tuple(scales), timesteps=timesteps)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps-gesture", type=int, default=300)
    ap.add_argument("--steps-flow", type=int, default=120)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--eval-clips", type=int, default=22)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--gesture-hw", type=int, nargs=2, default=(64, 64),
                    help="training/eval resolution for the gesture net "
                         "(weights are resolution-independent; Table-II "
                         "deploy resolution is 64x64)")
    ap.add_argument("--flow-hw", type=int, nargs=2, default=(24, 32),
                    help="training/eval resolution for the flow net "
                         "(Table-II deploy resolution is 288x384)")
    ap.add_argument("--gesture-timesteps", type=int, default=10)
    ap.add_argument("--flow-timesteps", type=int, default=10)
    ap.add_argument("--qat-steps", type=int, default=40,
                    help="per-precision QAT fine-tune steps")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    (out_dir / "weights").mkdir(parents=True, exist_ok=True)
    fig16: dict = {"tasks": {}}

    jobs = [
        ("gesture", args.steps_gesture, tuple(args.gesture_hw),
         args.gesture_timesteps, 1.5e-3),
        ("flow", args.steps_flow, tuple(args.flow_hw),
         args.flow_timesteps, 5e-4),
    ]
    for task, steps, hw, timesteps, lr in jobs:
        print(f"=== training {task} at {hw} x{timesteps}t ===")
        weights, topology, info = train_task(
            task, steps=steps, batch=args.batch, seed=args.seed,
            input_hw=hw, timesteps=timesteps, lr=lr)
        input_shape = (2, hw[0], hw[1])

        # Held-out eval set.
        if task == "gesture":
            ev_frames, ev_target = data.gesture_batch(
                args.eval_clips, seed=990_000, height=hw[0], width=hw[1],
                timesteps=timesteps)
            float_metric = eval_gesture_float(
                weights, topology, input_shape, ev_frames, ev_target)
            metric_name = "accuracy"
        else:
            ev_frames, ev_target = data.flow_batch(
                args.eval_clips, seed=990_000, height=hw[0], width=hw[1],
                timesteps=timesteps)
            float_metric = eval_flow_float(
                weights, topology, input_shape, ev_frames, ev_target)
            metric_name = "aee"
        print(f"  float {metric_name}: {float_metric:.4f}")

        task_entry = {"metric": metric_name, "float": float_metric,
                      "train": {k: v for k, v in info.items() if k != "losses"},
                      "loss_first": info["losses"][0],
                      "loss_last": info["losses"][-1],
                      "precisions": {}}

        for wb, vb in PRECISIONS:
            cfg = PrecisionConfig(wb, vb)
            # Short QAT fine-tune from the float checkpoint: the
            # straight-through fake-quant forward + Vmem range clipping
            # teaches the network to live inside the B_v-bit adder
            # range, which post-training quantization alone does not
            # (see EXPERIMENTS.md §Fig16 for the ablation).
            qat_weights, _, qinfo = train_task(
                task, steps=args.qat_steps, batch=args.batch,
                seed=args.seed + wb, input_hw=hw, timesteps=timesteps,
                lr=lr / 3.0, init=weights, fake_quant=cfg)
            print(f"  qat w{wb}: loss {qinfo['losses'][0]:.4f} -> "
                  f"{qinfo['losses'][-1]:.4f}")
            wqs, scales, thetas, leaks = quantize_network(qat_weights, cfg)
            net = build_quantized(task, topology, input_shape, wqs, scales,
                                  thetas, leaks, cfg, timesteps)
            if task == "gesture":
                qm = eval_gesture_quant(net, ev_frames, ev_target)
            else:
                qm = eval_flow_quant(net, ev_frames, ev_target)
            print(f"  {wb}/{vb}-bit {metric_name}: {qm:.4f}")
            task_entry["precisions"][str(wb)] = {metric_name: qm}

            np.savez(
                out_dir / "weights" / f"{task}_w{wb}.npz",
                num_layers=len(wqs),
                timesteps=timesteps,
                input_shape=np.array(input_shape, dtype=np.int32),
                scales=np.array(scales, dtype=np.float64),
                thetas=np.array(thetas, dtype=np.int32),
                leaks=np.array(leaks, dtype=np.int32),
                **{f"w{i}": wq for i, wq in enumerate(wqs)},
            )
        fig16["tasks"][task] = task_entry

    with open(out_dir / "fig16_eval.json", "w") as f:
        json.dump(fig16, f, indent=2)
    print(f"wrote {out_dir}/fig16_eval.json")


if __name__ == "__main__":
    main()
