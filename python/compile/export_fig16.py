"""Export fig16_eval.json to the line format the Rust bench reads.

Run automatically by `make artifacts` after training. Output lines:
``<task> <metric> <precision|float> <value>``.
"""

from __future__ import annotations

import argparse
import json
import pathlib


def export(json_path: pathlib.Path, txt_path: pathlib.Path) -> None:
    data = json.loads(json_path.read_text())
    lines = []
    for task, entry in data["tasks"].items():
        metric = entry["metric"]
        lines.append(f"{task} {metric} float {entry['float']:.6f}")
        for wb, metrics in sorted(entry["precisions"].items()):
            lines.append(f"{task} {metric} {wb} {metrics[metric]:.6f}")
    txt_path.write_text("\n".join(lines) + "\n")
    print(f"wrote {txt_path} ({len(lines)} lines)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    d = pathlib.Path(args.artifacts)
    export(d / "fig16_eval.json", d / "fig16_eval.txt")


if __name__ == "__main__":
    main()
