"""L2: the paper's SNN workloads as quantized JAX compute graphs.

Implements both Table-II networks as *integer* spiking networks whose
inner loops are the L1 Pallas kernels (``spiking_matmul`` for the
compute macro, ``neuron_update`` for the neuron macro):

  * Optical flow estimation — Conv(2,32) + 6x Conv(32,32) + Conv(32,2),
    3x3/stride 1/pad 1, LIF soft-reset hidden layers, non-spiking
    accumulator output (flow regressed from the output layer's Vmem).
  * Gesture recognition — Conv(2,16) + 4x Conv(16,16) with 2x2 maxpool
    after every two intermediate convs, a readout maxpool to 2x2, then
    FC(64, 11) as a non-spiking accumulator (classify by Vmem argmax).

Everything is ``int32`` end to end with B_v-bit wrap-around arithmetic —
the same contract the Rust cycle simulator implements, so spike/Vmem
trajectories are bit-exact across the two implementations.

The im2col layout contract (shared with ``rust/src/snn/`` and the
input-loader model in ``rust/src/sim/input_loader.rs``):

    fan-in index  F = (c * KH + dy) * KW + dx
    pixel index   M = y_out * W_out + x_out
    weight matrix W[F, K], K = output channel

``network_step`` is the unit the AOT pipeline lowers to HLO: one
timestep of the whole network, carrying all per-layer Vmems.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.neuron import neuron_update
from .kernels.spiking_matmul import spiking_matmul
from .quantize import PrecisionConfig, wrap_to_bits


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int, pad: int) -> jnp.ndarray:
    """Unfold ``(C, H, W)`` into patches ``(M, F)`` (hardware layout).

    This mirrors exactly what the SpiDR input loader does in hardware
    when it populates the IFspad: padding and stride are folded into the
    data layout, and the fan-in dimension is ordered (c, dy, dx).
    """
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    h_out = (h + 2 * pad - kh) // stride + 1
    w_out = (w + 2 * pad - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(
                jax.lax.slice(
                    xp,
                    (0, dy, dx),
                    (c, dy + stride * (h_out - 1) + 1, dx + stride * (w_out - 1) + 1),
                    (1, stride, stride),
                )
            )
    # (kh*kw, C, Ho*Wo) -> (C, kh*kw, Ho*Wo) -> (F, M) -> (M, F)
    stacked = jnp.stack(cols, axis=0).reshape(kh * kw, c, h_out * w_out)
    patches = jnp.transpose(stacked, (1, 0, 2)).reshape(c * kh * kw, h_out * w_out)
    return patches.T


def maxpool_spikes(x: jnp.ndarray, size: int, stride: int) -> jnp.ndarray:
    """2D maxpool over binary spike planes ``(C, H, W)``."""
    return jax.lax.reduce_window(
        x,
        jnp.int32(0),
        jax.lax.max,
        window_dimensions=(1, size, size),
        window_strides=(1, stride, stride),
        padding="VALID",
    )


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of a quantized SpiDR network.

    ``kind`` is one of ``conv`` / ``fc`` / ``pool``. Conv and FC layers
    carry quantized integer weights ``(F, K)`` plus neuron parameters;
    pool layers carry only the window geometry. ``accumulate=True``
    marks a non-spiking output layer whose Vmem integrates across
    timesteps (flow regression / classification logits).
    """

    kind: str
    in_shape: tuple[int, int, int]          # (C, H, W) input
    out_shape: tuple[int, int, int]         # (C, H, W) output
    weights: Optional[np.ndarray] = None    # (F, K) int32
    theta: int = 1
    leak: int = 0
    leaky: bool = False
    soft_reset: bool = True
    accumulate: bool = False
    kh: int = 3
    kw: int = 3
    stride: int = 1
    pad: int = 1

    @property
    def has_state(self) -> bool:
        return self.kind in ("conv", "fc")

    @property
    def vmem_shape(self) -> tuple[int, int]:
        """State carried for this layer: (M pixels, K neurons)."""
        if self.kind == "conv":
            _, h, w = self.out_shape
            return (h * w, self.out_shape[0])
        if self.kind == "fc":
            return (1, self.out_shape[0])
        raise ValueError(f"{self.kind} layer has no Vmem")

    @property
    def fan_in(self) -> int:
        if self.kind == "conv":
            return self.in_shape[0] * self.kh * self.kw
        if self.kind == "fc":
            c, h, w = self.in_shape
            return c * h * w
        raise ValueError(f"{self.kind} layer has no fan-in")

    @property
    def synops_per_spike(self) -> int:
        """Synaptic operations triggered by one input spike (for GOPS)."""
        return self.out_shape[0]


@dataclasses.dataclass(frozen=True)
class QuantizedNetwork:
    """A full quantized network plus its precision operating point."""

    name: str
    layers: tuple[LayerSpec, ...]
    precision: PrecisionConfig
    weight_scales: tuple[float, ...]   # per stateful layer, in layer order
    timesteps: int

    @property
    def stateful_layers(self) -> list[LayerSpec]:
        return [l for l in self.layers if l.has_state]

    def init_vmems(self) -> list[jnp.ndarray]:
        return [
            jnp.zeros(l.vmem_shape, dtype=jnp.int32) for l in self.stateful_layers
        ]

    @property
    def output_scale(self) -> float:
        """Scale converting the output accumulator to float units."""
        return self.weight_scales[-1]


def layer_step(
    layer: LayerSpec,
    spikes_in: jnp.ndarray,
    vmem: Optional[jnp.ndarray],
    vmem_bits: int,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Run one layer for one timestep.

    Args:
      spikes_in: ``(C, H, W)`` int32 {0,1} input spike plane.
      vmem: layer state ``(M, K)`` or None for pool layers.

    Returns:
      ``(spikes_out (C', H', W'), vmem_next)``.
    """
    if layer.kind == "pool":
        return maxpool_spikes(spikes_in, layer.kh, layer.stride), None

    if layer.kind == "conv":
        patches = im2col(spikes_in, layer.kh, layer.kw, layer.stride, layer.pad)
    else:  # fc
        patches = spikes_in.reshape(1, -1)

    w = jnp.asarray(layer.weights, dtype=jnp.int32)
    zero = jnp.zeros(layer.vmem_shape, dtype=jnp.int32)
    partial = spiking_matmul(patches, w, zero, vmem_bits, interpret=interpret)

    if layer.accumulate:
        # Non-spiking output layer: the neuron macro only integrates.
        vmem_next = wrap_to_bits(vmem + partial, vmem_bits)
        k, h, wid = layer.out_shape
        spikes_out = jnp.zeros((k, h, wid), dtype=jnp.int32)
        return spikes_out, vmem_next

    spikes_flat, vmem_next = neuron_update(
        partial,
        vmem,
        jnp.int32(layer.theta),
        jnp.int32(layer.leak),
        vmem_bits,
        leaky=layer.leaky,
        soft_reset=layer.soft_reset,
        interpret=interpret,
    )
    k, h, wid = layer.out_shape
    spikes_out = spikes_flat.T.reshape(k, h, wid)
    return spikes_out, vmem_next


def network_step(
    net: QuantizedNetwork,
    frame: jnp.ndarray,
    vmems: Sequence[jnp.ndarray],
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, list[jnp.ndarray]]:
    """One timestep of the full network.

    Args:
      frame: ``(C, H, W)`` int32 {0,1} input event frame.
      vmems: per-stateful-layer Vmem states.

    Returns:
      ``(out_acc, spike_counts, vmems_next)`` where ``out_acc`` is the
      output layer's accumulated Vmem ``(M, K)``, and ``spike_counts``
      is an int32 vector with the number of *input* spikes each stateful
      layer consumed this timestep (layer-sparsity telemetry, Fig. 5).
    """
    spikes = frame.astype(jnp.int32)
    vmems = list(vmems)
    vmems_next: list[jnp.ndarray] = []
    counts: list[jnp.ndarray] = []
    si = 0
    out_acc = None
    for layer in net.layers:
        if layer.has_state:
            counts.append(jnp.sum(spikes, dtype=jnp.int32))
            spikes, v = layer_step(
                layer, spikes, vmems[si], net.precision.vmem_bits,
                interpret=interpret)
            vmems_next.append(v)
            if layer.accumulate:
                out_acc = v
            si += 1
        else:
            spikes, _ = layer_step(
                layer, spikes, None, net.precision.vmem_bits,
                interpret=interpret)
    assert out_acc is not None, "network must end in an accumulate layer"
    return out_acc, jnp.stack(counts), vmems_next


def run_network(
    net: QuantizedNetwork,
    frames: np.ndarray,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, np.ndarray]:
    """Run all timesteps of a clip. Returns (out_acc, counts (T, L))."""
    vmems = net.init_vmems()
    all_counts = []
    out = None
    for t in range(frames.shape[0]):
        out, counts, vmems = network_step(
            net, jnp.asarray(frames[t], dtype=jnp.int32), vmems,
            interpret=interpret)
        all_counts.append(np.asarray(counts))
    return out, np.stack(all_counts)


# ---------------------------------------------------------------------------
# Table-II network topologies
# ---------------------------------------------------------------------------


def conv_out(h: int, w: int, kh: int, kw: int, stride: int, pad: int) -> tuple[int, int]:
    return ((h + 2 * pad - kh) // stride + 1, (w + 2 * pad - kw) // stride + 1)


def flow_topology() -> list[dict]:
    """Optical-flow net (Table II row 1): Conv(2,32) + 6x Conv(32,32) + Conv(32,2)."""
    spec = []
    chans = [2] + [32] * 7 + [2]
    for i in range(8):
        spec.append(dict(kind="conv", in_ch=chans[i], out_ch=chans[i + 1],
                         kh=3, kw=3, stride=1, pad=1,
                         accumulate=(i == 7), leaky=True, soft_reset=True))
    return spec


def gesture_topology() -> list[dict]:
    """Gesture net (Table II row 2): Conv(2,16) + 4x Conv(16,16) + FC(64,11).

    2x2 maxpool (stride 2) after every two intermediate conv layers; a
    final readout maxpool brings the remaining plane to 2x2 so the FC
    sees 16 ch * 2 * 2 = 64 inputs, matching the paper's FC(64, 11).
    """
    spec = [dict(kind="conv", in_ch=2, out_ch=16, kh=3, kw=3, stride=1, pad=1,
                 accumulate=False, leaky=False, soft_reset=True)]
    for i in range(4):
        spec.append(dict(kind="conv", in_ch=16, out_ch=16, kh=3, kw=3,
                         stride=1, pad=1, accumulate=False, leaky=False,
                         soft_reset=True))
        if i % 2 == 1:
            spec.append(dict(kind="pool", size=2, stride=2))
    spec.append(dict(kind="pool", size=8, stride=8))
    spec.append(dict(kind="fc", out_ch=11, accumulate=True))
    return spec


def build_layers(
    topology: list[dict],
    input_shape: tuple[int, int, int],
    weights: Sequence[np.ndarray],
    thetas: Optional[Sequence[int]] = None,
    leaks: Optional[Sequence[int]] = None,
) -> tuple[LayerSpec, ...]:
    """Materialize LayerSpecs from a topology + quantized weight list.

    The readout pool in ``gesture_topology`` adapts its window to
    whatever spatial size remains, so topologies work at any input
    resolution (weights are resolution-independent).
    """
    layers: list[LayerSpec] = []
    c, h, w = input_shape
    wi = 0
    for t in topology:
        if t["kind"] == "pool":
            size = min(t["size"], h, w)
            stride = min(t["stride"], size)
            ho, wo = h // stride, w // stride
            layers.append(LayerSpec(
                kind="pool", in_shape=(c, h, w), out_shape=(c, ho, wo),
                kh=size, kw=size, stride=stride, pad=0))
            h, w = ho, wo
            continue
        theta = thetas[wi] if thetas is not None else t.get("theta", 1)
        leak = leaks[wi] if leaks is not None else t.get("leak", 0)
        if t["kind"] == "conv":
            ho, wo = conv_out(h, w, t["kh"], t["kw"], t["stride"], t["pad"])
            wq = np.asarray(weights[wi], dtype=np.int32)
            want = (c * t["kh"] * t["kw"], t["out_ch"])
            if wq.shape != want:
                raise ValueError(f"layer {wi}: weight shape {wq.shape} != {want}")
            layers.append(LayerSpec(
                kind="conv", in_shape=(c, h, w),
                out_shape=(t["out_ch"], ho, wo), weights=wq,
                theta=theta, leak=leak,
                leaky=t["leaky"], soft_reset=t["soft_reset"],
                accumulate=t["accumulate"], kh=t["kh"], kw=t["kw"],
                stride=t["stride"], pad=t["pad"]))
            c, h, w = t["out_ch"], ho, wo
        else:  # fc
            f = c * h * w
            wq = np.asarray(weights[wi], dtype=np.int32)
            if wq.shape != (f, t["out_ch"]):
                raise ValueError(
                    f"fc layer {wi}: weight shape {wq.shape} != {(f, t['out_ch'])}")
            layers.append(LayerSpec(
                kind="fc", in_shape=(c, h, w),
                out_shape=(t["out_ch"], 1, 1), weights=wq,
                theta=theta, leak=leak,
                leaky=t.get("leaky", False),
                soft_reset=t.get("soft_reset", True),
                accumulate=t["accumulate"], kh=1, kw=1, stride=1, pad=0))
            c, h, w = t["out_ch"], 1, 1
        wi += 1
    return tuple(layers)
