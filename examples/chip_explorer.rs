//! Chip-design explorer: sweep the reconfigurable design space
//! (precision x sparsity x corner x FIFO depth x multi-core) and print
//! the resulting operating points — the kind of what-if analysis the
//! paper's reconfigurability enables.
//!
//! ```text
//! cargo run --release --example chip_explorer
//! ```

use spidr::coordinator::MultiCoreScheduler;
use spidr::energy::calibration::{measure, peak_layer};
use spidr::energy::model::Corner;
use spidr::energy::tech::scale_efficiency_to_node;
use spidr::prop::SplitMix64;
use spidr::quant::{Precision, ALL_PRECISIONS};
use spidr::sim::SimConfig;
use spidr::snn::spikes::SpikePlane;
use spidr::snn::tensor::Mat;

fn main() -> spidr::Result<()> {
    println!("== operating-point sweep (precision x sparsity, LOW corner) ==");
    println!(
        "{:>6} {:>9} {:>10} {:>10} {:>9} {:>14}",
        "prec", "sparsity", "GOPS", "TOPS/W", "mW", "TOPS/W @28nm"
    );
    for &p in &ALL_PRECISIONS {
        for s in [0.70, 0.85, 0.95] {
            let op = measure(p, Corner::LOW, s);
            println!(
                "{:>6} {:>8.0}% {:>10.2} {:>10.2} {:>9.2} {:>14.2}",
                format!("{}b", p.weight_bits()),
                s * 100.0,
                op.gops,
                op.tops_per_watt,
                op.power_mw,
                scale_efficiency_to_node(op.tops_per_watt, 65.0, 28.0)
            );
        }
    }

    println!("\n== multi-core scaling (channel-parallel, 72-ch layer) ==");
    let layer = {
        let mut l = peak_layer(Precision::W4V7);
        // widen to 72 channels so a single core needs 2 passes
        let mut w = Mat::zeros(l.fan_in(), 72);
        let mut rng = SplitMix64::new(5);
        for f in 0..l.fan_in() {
            for k in 0..72 {
                w.set(f, k, rng.below(15) as i32 - 7);
            }
        }
        l.weights = Some(w);
        l.out_shape = (72, l.out_shape.1, l.out_shape.2);
        l
    };
    let frames: Vec<SpikePlane> = (0..2)
        .map(|i| {
            let mut rng = SplitMix64::new(100 + i);
            let (c, h, w) = layer.in_shape;
            let mut p = SpikePlane::zeros(c, h, w);
            for j in 0..p.len() {
                if rng.chance(0.05) {
                    p.as_mut_slice()[j] = 1;
                }
            }
            p
        })
        .collect();
    let (m, k) = layer.vmem_shape()?;
    let mut base = 0u64;
    for cores in [1usize, 2, 4] {
        let sched = MultiCoreScheduler::new(cores, SimConfig::timing_only(Precision::W4V7));
        let mut state = Mat::zeros(m, k);
        let (_, stats) = sched.run_layer(&layer, &frames, &mut state)?;
        if cores == 1 {
            base = stats.cycles;
        }
        println!(
            "  {cores} core(s): {:>8} cycles  speedup {:.2}x  balance {:?}",
            stats.cycles,
            base as f64 / stats.cycles as f64,
            stats.per_core_cycles
        );
    }

    println!("\n== FIFO-depth ablation (S2A batching, see Fig. 10 bench) ==");
    for depth in [1usize, 4, 16] {
        let mut cfg = SimConfig::timing_only(Precision::W4V7);
        cfg.fifo_depth = depth;
        let core = spidr::sim::SpidrCore::new(cfg);
        let layer = peak_layer(Precision::W4V7);
        let frames: Vec<SpikePlane> = (0..2)
            .map(|i| {
                let mut rng = SplitMix64::new(7 + i);
                let (c, h, w) = layer.in_shape;
                let mut p = SpikePlane::zeros(c, h, w);
                for j in 0..p.len() {
                    if rng.chance(0.15) {
                        p.as_mut_slice()[j] = 1;
                    }
                }
                p
            })
            .collect();
        let (m, k) = layer.vmem_shape()?;
        let mut state = Mat::zeros(m, k);
        let (_, stats) = core.run_layer(&layer, &frames, &mut state)?;
        println!(
            "  depth {depth:>2}: {} parity switches, {} cycles",
            stats.run.parity_switches, stats.run.cycles
        );
    }
    Ok(())
}
