//! Batch-parallel bit-plane serving, end to end (DESIGN.md §Perf).
//!
//! ```text
//! cargo run --release --example batched
//! ```
//!
//! Packs a batch of clips into `u64` spike lanes, runs them through
//! the [`BatchedEngine`] — one union address stream and one CIM-row
//! sweep per batch — verifies every lane against the per-clip
//! reference executor, times batched against per-clip throughput, and
//! finishes with the engine selected through `ServerConfig::batch` on
//! the streaming server.

use std::time::Instant;

use spidr::coordinator::{
    BatchConfig, BatchedEngine, Engine, FunctionalEngine, InferenceServer, ReferenceEngine,
    ServerConfig,
};
use spidr::dvs::event::{Event, Polarity};
use spidr::prop::SplitMix64;
use spidr::snn::network::{demo_serving_network, Network};
use spidr::snn::spikes::SpikePlane;

/// One synthetic DVS burst over the clip window.
fn burst(seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    (0..180)
        .map(|_| Event {
            y: rng.below(16) as u16,
            x: rng.below(16) as u16,
            polarity: if rng.chance(0.5) { Polarity::On } else { Polarity::Off },
            t_us: rng.below(10_000) as u32,
        })
        .collect()
}

/// Random clip of binned frames at a given spike density.
fn random_clip(net: &Network, t: usize, density: f64, seed: u64) -> Vec<SpikePlane> {
    let (c, h, w) = net.layers[0].in_shape;
    let mut rng = SplitMix64::new(seed);
    (0..t)
        .map(|_| {
            let mut p = SpikePlane::zeros(c, h, w);
            for i in 0..p.len() {
                if rng.chance(density) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            p
        })
        .collect()
}

fn main() -> spidr::Result<()> {
    // 1. Pack 64 clips into bit-plane lanes and sweep them through
    //    the CIM rows once; every lane must be bit-identical to a
    //    per-clip run of the reference executor.
    let net = demo_serving_network(10)?;
    let clips: Vec<Vec<SpikePlane>> = (0..64)
        .map(|b| random_clip(&net, 10, 0.05, 100 + b as u64))
        .collect();
    let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();

    let mut batched = BatchedEngine::new(net.clone(), BatchConfig::default())?;
    let outs = batched.infer_lanes(&refs)?;
    let mut reference = ReferenceEngine::new(net.clone())?;
    for (b, clip) in clips.iter().enumerate() {
        assert_eq!(outs[b], reference.infer(clip)?, "lane {b} diverged");
    }
    println!("64-clip batch: every lane bit-identical to the per-clip reference");

    // 2. Where the throughput comes from: the loader walk, union
    //    address extraction, and CIM-row sweep are paid once per batch
    //    instead of once per clip.
    let t0 = Instant::now();
    let _ = batched.infer_lanes(&refs)?;
    let t_batch = t0.elapsed();
    let t0 = Instant::now();
    for clip in &clips {
        let _ = reference.infer(clip)?;
    }
    let t_clip = t0.elapsed();
    println!(
        "64 clips: per-clip {t_clip:?} vs batched {t_batch:?} ({:.2}x, {:.0} clips/s batched)",
        t_clip.as_secs_f64() / t_batch.as_secs_f64(),
        64.0 / t_batch.as_secs_f64(),
    );

    // 3. The same engine selected by config on the streaming server:
    //    the serve loop drains the ingest queue into lane batches.
    let cfg = ServerConfig {
        height: 16,
        width: 16,
        timesteps: 10,
        bin_us: 1000,
        queue_depth: 8,
        batch: Some(BatchConfig::default()),
        ..Default::default()
    };
    let server = InferenceServer::new(cfg);
    let requests: Vec<Vec<Event>> = (0..24).map(|i| burst(900 + i)).collect();
    let mut engine = FunctionalEngine::from_config(net, cfg.pipeline, cfg.distributed, cfg.batch)?;
    assert_eq!(engine.max_batch(), 64);
    let (responses, metrics) = server.serve(requests, &mut engine)?;
    println!(
        "served {} clips through the batched engine: p50 {} us, wall {:?}",
        responses.len(),
        metrics.percentile_us(50.0),
        metrics.wall,
    );
    Ok(())
}
