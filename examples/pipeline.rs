//! Timestep-pipelined layer-group execution, end to end
//! (DESIGN.md §Pipeline).
//!
//! ```text
//! cargo run --release --example pipeline
//! ```
//!
//! Drives the pipelined engine on the serving-demo workload, prints
//! the stage topology and the per-stage occupancy/stall/fill/drain
//! counters, shows the engine being selected through
//! `ServerConfig::pipeline` on the streaming server, and finishes
//! with the deeper pipeline-demo network where staged execution cuts
//! single-clip latency below the sequential executor's.

use std::time::Instant;

use spidr::coordinator::{
    Engine, FunctionalEngine, InferenceServer, PipelineConfig, PipelinedEngine, ReferenceEngine,
    ServerConfig,
};
use spidr::dvs::event::{Event, Polarity};
use spidr::prop::SplitMix64;
use spidr::snn::network::{demo_pipeline_network, demo_serving_network, Network};
use spidr::snn::spikes::SpikePlane;

/// One synthetic DVS burst over the clip window.
fn burst(seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    (0..180)
        .map(|_| Event {
            y: rng.below(16) as u16,
            x: rng.below(16) as u16,
            polarity: if rng.chance(0.5) { Polarity::On } else { Polarity::Off },
            t_us: rng.below(10_000) as u32,
        })
        .collect()
}

/// Random clip of binned frames for the deeper workload.
fn random_clip(net: &Network, t: usize, seed: u64) -> Vec<SpikePlane> {
    let (c, h, w) = net.layers[0].in_shape;
    let mut rng = SplitMix64::new(seed);
    (0..t)
        .map(|_| {
            let mut p = SpikePlane::zeros(c, h, w);
            for i in 0..p.len() {
                if rng.chance(0.2) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            p
        })
        .collect()
}

fn print_stages(engine: &PipelinedEngine) {
    let net = engine.network();
    for sm in engine.stage_metrics() {
        let layers: Vec<String> = net.layers[sm.layers.0..sm.layers.1]
            .iter()
            .map(|l| l.describe())
            .collect();
        println!(
            "  stage {}: [{}] {} steps, occupancy {:>3.0}%, stall in/out {:?}/{:?}, \
             fill {:?}, drain {:?}",
            sm.stage,
            layers.join(" → "),
            sm.steps,
            sm.occupancy() * 100.0,
            sm.stall_in,
            sm.stall_out,
            sm.fill,
            sm.drain,
        );
    }
}

fn main() -> spidr::Result<()> {
    // 1. The pipelined engine on the serving-demo workload: each of
    //    the two layer groups runs on its own stage thread, bounded
    //    spike-frame channels handshaking between them.
    let net = demo_serving_network(10)?;
    let clip = random_clip(&net, 10, 5);
    let mut reference = ReferenceEngine::new(net.clone())?;
    let want = reference.infer(&clip)?;
    let mut pipe = PipelinedEngine::new(net.clone(), PipelineConfig::with_stages(2))?;
    let got = pipe.infer(&clip)?;
    assert_eq!(want, got, "pipelined output must be bit-identical");
    println!("serving-demo, 2 stages, bit-identical to the reference executor:");
    print_stages(&pipe);

    // 2. The same engine selected by config on the streaming server.
    let cfg = ServerConfig {
        height: 16,
        width: 16,
        timesteps: 10,
        bin_us: 1000,
        queue_depth: 4,
        pipeline: Some(PipelineConfig::with_stages(2)),
        ..Default::default()
    };
    let server = InferenceServer::new(cfg);
    let requests: Vec<Vec<Event>> = (0..12).map(|i| burst(900 + i)).collect();
    let mut engine = FunctionalEngine::from_config(net, cfg.pipeline, cfg.distributed, cfg.batch)?;
    let (responses, mut metrics) = server.serve(requests, &mut engine)?;
    metrics.stages = engine.stage_metrics().to_vec();
    println!(
        "served {} clips through the pipelined engine: p50 {} us, \
         mean stage occupancy {:.0}%",
        responses.len(),
        metrics.percentile_us(50.0),
        metrics.pipeline_occupancy() * 100.0,
    );

    // 3. Where the latency win comes from: on the deeper
    //    pipeline-demo network (five stateful layers), stage g steps
    //    timestep t while stage g-1 steps t+1, so clip latency
    //    approaches the slowest stage instead of the layer sum.
    let deep = demo_pipeline_network(12)?;
    let clip = random_clip(&deep, 12, 17);
    let mut seq = ReferenceEngine::new(deep.clone())?;
    let want = seq.infer(&clip)?;
    let t0 = Instant::now();
    let _ = seq.infer(&clip)?;
    let t_seq = t0.elapsed();
    let mut pipe = PipelinedEngine::new(deep, PipelineConfig::with_stages(4))?;
    let got = pipe.infer(&clip)?;
    assert_eq!(want, got);
    let t0 = Instant::now();
    let _ = pipe.infer(&clip)?;
    let t_pipe = t0.elapsed();
    println!(
        "pipeline-demo single-clip latency: sequential {t_seq:?} vs pipelined {t_pipe:?} \
         ({:.2}x, groups {:?})",
        t_seq.as_secs_f64() / t_pipe.as_secs_f64(),
        pipe.groups(),
    );
    print_stages(&pipe);
    Ok(())
}
