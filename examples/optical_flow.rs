//! **End-to-end validation driver** (DESIGN.md): optical-flow
//! estimation on a synthetic driving scene — the paper's headline
//! workload — exercising all three layers of the stack:
//!
//!  * L1/L2: the AOT-compiled JAX/Pallas network artifact executes on
//!    the PJRT CPU client (golden model),
//!  * L3: the cycle-level SpiDR simulator runs the *same integers* and
//!    reports cycles/energy; its Vmem trajectory is checked bit-exact
//!    against the golden model's on the fly,
//!  * headline metric: AEE (px/step) + TOPS/W at the LOW corner.
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md.

use spidr::coordinator::NetworkCompiler;
use spidr::dvs::flow_scene::{average_endpoint_error, make_flow_scene, FlowSceneConfig};
use spidr::energy::model::Corner;
use spidr::error::Result;
use spidr::quant::Precision;
use spidr::runtime::{ArtifactStore, GoldenModel};
use spidr::sim::SimConfig;
use spidr::snn::network::flow_network;
use spidr::snn::WeightBundle;

fn main() -> Result<()> {
    let wb = 8u32; // best-AEE precision point
    let mut store = ArtifactStore::open("artifacts")?;
    let mut golden = GoldenModel::new(&store, &format!("flow_w{wb}"))?;
    let (_, h, w) = golden.frame_shape();
    let timesteps = golden.timesteps;
    println!("artifact flow_w{wb}: {h}x{w}, {timesteps} timesteps");

    let p = Precision::from_weight_bits(wb)?;
    let bundle = WeightBundle::load(store.swb_path("flow", wb))?;
    let net = flow_network(&bundle, p, h, w, timesteps)?;
    // functional + timing: we want the Vmem trajectory for the
    // bit-exactness check
    let compiled = NetworkCompiler::compile(net, SimConfig::default())?;

    let cfg = FlowSceneConfig { height: h, width: w, timesteps, ..Default::default() };
    let clips = 5;
    let mut total_aee = 0.0;
    let mut total_uj = 0.0;
    let mut total_tw = 0.0;
    for i in 0..clips {
        let scene = make_flow_scene(51_000 + i as u64, &cfg);

        // golden model (PJRT)
        golden.run_clip(&mut store, &scene.frames)?;
        let pred = golden.out_float();
        let m = h * w;
        let pred_u: Vec<f32> = (0..m).map(|j| pred[j * 2] as f32).collect();
        let pred_v: Vec<f32> = (0..m).map(|j| pred[j * 2 + 1] as f32).collect();
        let aee = average_endpoint_error(&scene, &pred_u, &pred_v);

        // cycle simulator on the same integers
        let mut state = compiled.network.init_state()?;
        let report = compiled.run_clip(&scene.frames, &mut state)?;

        // bit-exactness: simulator's output accumulator == golden's
        let sim_acc = state.vmems.last().unwrap().as_slice();
        assert_eq!(
            sim_acc, &golden.out_acc[..],
            "simulator diverged from the PJRT golden model"
        );

        let uj = report.total.total_energy_pj(Corner::LOW) / 1e6;
        let tw = report.total.tops_per_watt(Corner::LOW);
        total_aee += aee;
        total_uj += uj;
        total_tw += tw;
        println!(
            "clip {i}: AEE {:.3} px/step | sim {:.0} kcycles ({:.2} ms @50MHz), \
             {:.2} uJ, {:.2} TOPS/W | golden==sim ✓",
            aee,
            report.total.cycles as f64 / 1e3,
            report.total.seconds(Corner::LOW) * 1e3,
            uj,
            tw
        );
    }
    println!(
        "\nHEADLINE: mean AEE {:.3} px/step, {:.2} uJ/inference, {:.2} TOPS/W \
         over {clips} clips (flow_w{wb}, {h}x{w})",
        total_aee / clips as f64,
        total_uj / clips as f64,
        total_tw / clips as f64
    );
    Ok(())
}
