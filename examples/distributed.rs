//! Distributed shard serving, end to end — now with blank-shard
//! provisioning and a kill-a-replica failover demo (DESIGN.md
//! §Distributed).
//!
//! ```text
//! # self-hosted loopback constellation (blank shards, weight-pushed,
//! # 2 replicas per hop, one replica killed mid-stream):
//! cargo run --release --example distributed
//!
//! # against real shard processes (the CI two-process smoke; the
//! # shards start blank — the coordinator provisions them):
//! cargo run --release -- shard --listen 127.0.0.1:7401 --sessions 1 &
//! cargo run --release -- shard --listen 127.0.0.1:7402 --sessions 1 &
//! cargo run --release --example distributed -- --connect 127.0.0.1:7401,127.0.0.1:7402
//!
//! # replicated: consecutive addresses group into hops of --replicas
//! # links; --kill-replica K severs replica K of every hop mid-stream
//! # (the CI three-process failover smoke):
//! cargo run --release --example distributed -- \
//!     --connect 127.0.0.1:7403,127.0.0.1:7404 --replicas 2 --kill-replica 0
//!
//! # lane-batched: after the scalar clips, pack N clips into one v3
//! # lane batch per hop and check the wire-frame amortization (the CI
//! # lane-batch smoke; loopback runs this by default with N=64):
//! cargo run --release --example distributed -- \
//!     --connect 127.0.0.1:7405,127.0.0.1:7406 --batch 64
//!
//! # congestion-adaptive windows: a deliberately skewed loopback
//! # constellation (one throttled, high-latency hop) served with the
//! # fixed default window, then with stall-driven retuning — asserts
//! # the retuned schedule wins >=1.2x, bit-identical (the CI
//! # auto-tune smoke):
//! cargo run --release --example distributed -- --autotune
//!
//! # deadline-bounded lane-batch assembly on the streaming server
//! # (DESIGN.md §Planner), with per-hop stage metrics surfaced:
//! cargo run --release --example distributed -- --deadline-us 2000
//!
//! # observability: export one Chrome-trace JSON joining coordinator
//! # and shard spans per clip (open it in Perfetto), plus a Prometheus
//! # metrics snapshot with the clip-latency histogram (DESIGN.md
//! # §Observability):
//! cargo run --release --example distributed -- \
//!     --replicas 2 --trace trace.json --metrics metrics.prom
//! ```
//!
//! Either way the example acts as the coordinator: it builds the
//! pipeline-demo workload, provisions every shard replica over the
//! wire (the shards need no local artifact), runs the same clips
//! through the sequential reference executor and the distributed
//! engine — killing a replica halfway when the demo is replicated —
//! and **asserts the outputs and Vmems stay bit-identical** (a
//! non-zero exit means the wire path, or the failover replay, diverged
//! — this is the CI smokes' oracle), then prints the shard topology,
//! per-hop wire metrics and failovers absorbed.

use std::time::{Duration, Instant};

use spidr::coordinator::{
    Engine, FunctionalEngine, InferenceServer, ReferenceEngine, ServerConfig,
};
use spidr::dvs::event::{Event, Polarity};
use spidr::net::{DistributedConfig, DistributedEngine, LinkSpec, TcpTransport, Transport};
use spidr::obs::{hub, trace, tracer};
use spidr::prop::SplitMix64;
use spidr::snn::network::{demo_pipeline_network, demo_serving_network, Network};
use spidr::snn::spikes::{SpikePlane, MAX_LANES};

const TIMESTEPS: usize = 12;

/// Random clip of binned frames for the workload.
fn random_clip(net: &Network, seed: u64) -> Vec<SpikePlane> {
    let (c, h, w) = net.layers[0].in_shape;
    let mut rng = SplitMix64::new(seed);
    (0..TIMESTEPS)
        .map(|_| {
            let mut p = SpikePlane::zeros(c, h, w);
            for i in 0..p.len() {
                if rng.chance(0.2) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            p
        })
        .collect()
}

/// Connect with retries: the CI smoke starts the shard processes in
/// the background, so the listeners may lag this coordinator.
fn connect_retry(addr: &str) -> spidr::Result<TcpTransport> {
    let mut last = None;
    for _ in 0..40 {
        match TcpTransport::connect(addr) {
            Ok(t) => return Ok(t),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    Err(last.unwrap())
}

fn print_hops(engine: &DistributedEngine) {
    let net = engine.network();
    for (sm, (alive, total)) in engine
        .stage_metrics()
        .iter()
        .zip(engine.replica_status())
    {
        let layers: Vec<String> = net.layers[sm.layers.0..sm.layers.1]
            .iter()
            .map(|l| l.describe())
            .collect();
        println!(
            "  shard {} ({alive}/{total} replicas alive): [{}] {} frames, \
             wire busy {:?}, stall in/out {:?}/{:?}",
            sm.stage,
            layers.join(" → "),
            sm.steps,
            sm.busy,
            sm.stall_in,
            sm.stall_out,
        );
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

/// `--autotune` mode: a deliberately skewed loopback constellation —
/// the middle hop crosses a throttled, high-latency link while the
/// outer hops stay in-process — served first with the fixed default
/// window, then after stall-driven window retuning
/// (`DistributedEngine::retune_windows`, DESIGN.md §Planner). Asserts
/// the retuned schedule beats the fixed one by >=1.2x on lane-batch
/// wall time with bit-identical outputs (the CI auto-tune smoke's
/// oracle).
fn run_autotune() -> spidr::Result<()> {
    const LANES: usize = 8;
    const REPS: usize = 3;
    let net = demo_pipeline_network(TIMESTEPS)?;
    let links = [
        LinkSpec::loopback(),
        LinkSpec::new(64 << 20, 1_500),
        LinkSpec::loopback(),
    ];
    let cfg = DistributedConfig {
        shards: 3,
        window: 2,
        replicas: 1,
    };

    let clips: Vec<Vec<SpikePlane>> = (0..LANES)
        .map(|i| random_clip(&net, 4000 + i as u64))
        .collect();
    let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
    let mut reference = ReferenceEngine::new(net.clone())?;
    let want: Vec<Vec<i32>> = clips
        .iter()
        .map(|c| reference.infer(c))
        .collect::<spidr::Result<_>>()?;

    let best_batch_us = |engine: &mut DistributedEngine| -> spidr::Result<f64> {
        let mut best = f64::INFINITY;
        for _ in 0..REPS {
            let t0 = Instant::now();
            let got = engine.infer_batch(&refs)?;
            best = best.min(t0.elapsed().as_secs_f64() * 1e6);
            assert_eq!(got, want, "skewed-constellation outputs diverged");
        }
        Ok(best)
    };

    let mut fixed = DistributedEngine::loopback_throttled(net.clone(), &cfg, &links)?;
    println!(
        "skewed constellation (64 MB/s, 1.5 ms middle hop), fixed windows {:?}...",
        fixed.windows()
    );
    let base = best_batch_us(&mut fixed)?;

    let mut tuned = DistributedEngine::loopback_throttled(net.clone(), &cfg, &links)?;
    for round in 0..8 {
        let got = tuned.infer_batch(&refs)?;
        assert_eq!(got, want, "outputs diverged during retune round {round}");
        if !tuned.retune_windows(1, 16) {
            break;
        }
    }
    println!("stall-driven retune settled on windows {:?}", tuned.windows());
    let auto = best_batch_us(&mut tuned)?;

    let speedup = base / auto;
    println!(
        "{LANES}-lane batches x {TIMESTEPS} steps: fixed {base:.0} us vs \
         autotuned {auto:.0} us ({speedup:.2}x)"
    );
    assert!(
        speedup >= 1.2,
        "window autotuning must win >=1.2x on the skewed constellation, got {speedup:.2}x"
    );
    println!("autotune: outputs bit-identical under both schedules: ok");
    Ok(())
}

/// One synthetic DVS burst over the serving-demo clip window.
fn event_burst(seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    (0..180)
        .map(|_| Event {
            y: rng.below(16) as u16,
            x: rng.below(16) as u16,
            polarity: if rng.chance(0.5) {
                Polarity::On
            } else {
                Polarity::Off
            },
            t_us: rng.below(TIMESTEPS as u64 * 1000) as u32,
        })
        .collect()
}

/// `--deadline-us` mode: the streaming server over a self-hosted
/// distributed engine with deadline-bounded lane-batch assembly — the
/// drain loop holds a filling batch up to the deadline for same-length
/// stragglers (DESIGN.md §Planner) — and the per-hop stage counters
/// surfaced in [`spidr::coordinator::Metrics`].
fn run_deadline_demo(deadline_us: u32) -> spidr::Result<()> {
    let net = demo_serving_network(TIMESTEPS)?;
    let cfg = ServerConfig {
        height: 16,
        width: 16,
        timesteps: TIMESTEPS,
        bin_us: 1000,
        queue_depth: 8,
        distributed: Some(DistributedConfig::with_shards(2)),
        deadline_us,
        ..Default::default()
    };
    let requests: Vec<Vec<Event>> = (0..24).map(|i| event_burst(700 + i)).collect();
    let mut engine = FunctionalEngine::from_config(net, cfg.pipeline, cfg.distributed, cfg.batch)?;
    let server = InferenceServer::new(cfg);
    let (responses, metrics) = server.serve(requests, &mut engine)?;
    assert!(
        responses.windows(2).all(|w| w[0].id < w[1].id),
        "deadline assembly must preserve arrival order"
    );
    assert!(
        !metrics.stages.is_empty(),
        "distributed hop metrics must surface in Metrics::stages"
    );
    println!(
        "deadline serve: {} clips under a {deadline_us} us assembly deadline, \
         p50 {} us, wall {:?}",
        responses.len(),
        metrics.percentile_us(50.0),
        metrics.wall
    );
    for sm in &metrics.stages {
        println!(
            "  hop {}: {} frames, occupancy {:.0}%, {} stall samples",
            sm.stage,
            sm.steps,
            sm.occupancy() * 100.0,
            sm.stall_samples
        );
    }
    Ok(())
}

fn main() -> spidr::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--autotune") {
        return run_autotune();
    }
    if let Some(deadline_us) = flag_value(&args, "--deadline-us").and_then(|v| v.parse().ok()) {
        return run_deadline_demo(deadline_us);
    }
    let connect = flag_value(&args, "--connect");
    let trace_out = flag_value(&args, "--trace");
    let metrics_out = flag_value(&args, "--metrics");
    let metrics_server = match flag_value(&args, "--metrics-listen") {
        Some(addr) => {
            let server = spidr::obs::MetricsServer::spawn(&addr, hub())?;
            println!(
                "metrics: live Prometheus endpoint on {} \
                 (scrape with `spidr metrics --connect ...`)",
                server.local_addr()
            );
            Some(server)
        }
        None => None,
    };
    if trace_out.is_some() {
        // Enable before the engine is built: connect-time trace sync
        // (the shard clock-offset estimate) only runs under an enabled
        // tracer (DESIGN.md §Observability).
        tracer().enable(1);
        tracer().set_process_label("coordinator");
    }
    let replicas: usize = flag_value(&args, "--replicas")
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let kill_replica: Option<usize> =
        flag_value(&args, "--kill-replica").and_then(|v| v.parse().ok());
    // Lane-batch phase size: loopback demos always exercise the
    // batched datapath; TCP mode only when --batch is given (the CI
    // lane-batch smoke), so the older scalar smokes stay byte-for-byte
    // the v2 grammar on the wire.
    let batch: usize = flag_value(&args, "--batch")
        .and_then(|v| v.parse().ok())
        .unwrap_or(if connect.is_some() { 0 } else { 64 });

    let net = demo_pipeline_network(TIMESTEPS)?;
    let clips: Vec<Vec<SpikePlane>> = (0..4).map(|i| random_clip(&net, 40 + i)).collect();

    // Oracle: the sequential reference executor on the same clips.
    let mut reference = ReferenceEngine::new(net.clone())?;
    let mut want = Vec::new();
    for clip in &clips {
        want.push(reference.infer(clip)?);
    }

    let mut engine = match &connect {
        // Real shard processes over TCP: consecutive addresses group
        // into hops of `replicas` links, in layer-group order. The
        // shards may start blank — the engine pushes the workload.
        Some(addrs) => {
            let links: Vec<&str> = addrs.split(',').collect();
            if replicas == 0 || links.len() % replicas != 0 {
                return Err(spidr::Error::config(format!(
                    "{} addresses do not group into hops of {replicas} replicas",
                    links.len()
                )));
            }
            let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::new();
            for hop_addrs in links.chunks(replicas) {
                let mut hop: Vec<Box<dyn Transport>> = Vec::new();
                for addr in hop_addrs {
                    hop.push(Box::new(connect_retry(addr)?));
                }
                hops.push(hop);
            }
            println!(
                "coordinator: chaining {} TCP hop(s) x {replicas} replica(s), \
                 provisioning over the wire: {addrs}",
                hops.len()
            );
            DistributedEngine::connect_replicated(net.clone(), hops, 2)?
        }
        // Self-hosted loopback constellation: blank shard threads,
        // weight-pushed, replicated — the same protocol, windowing,
        // reassembly and failover with no sockets.
        None => {
            let reps = if replicas > 1 { replicas } else { 2 };
            println!(
                "coordinator: self-hosting a 3-shard x {reps}-replica loopback \
                 constellation (blank shards, weight-pushed)"
            );
            DistributedEngine::loopback(
                net.clone(),
                &DistributedConfig::replicated(3, reps),
            )?
        }
    };
    println!("layer-group placement: {:?}", engine.groups());

    // Kill a replica halfway through the stream: after an even number
    // of clips the least-loaded tie-break picks replica 0 next, so
    // severing it (or the requested index) guarantees the next clip
    // runs the failover path. Loopback demos always kill; TCP mode
    // kills only when --kill-replica is given (the failover smoke).
    let kill_at = clips.len() / 2;
    let kill = match (&connect, kill_replica) {
        (_, Some(r)) => Some(r),
        (None, None) => Some(0),
        (Some(_), None) => None,
    };
    let replicated = engine.replica_status().iter().all(|&(_, total)| total > 1);
    // Only a replicated constellation can absorb a kill.
    let kill = if replicated { kill } else { None };

    let t0 = Instant::now();
    for (i, clip) in clips.iter().enumerate() {
        if let Some(r) = kill.filter(|_| i == kill_at) {
            println!("killing replica {r} of every hop mid-stream...");
            for hop in 0..engine.groups().len() {
                engine.sever_replica(hop, r)?;
            }
        }
        // One trace per clip: the root "clip" span on this thread and
        // the shard-side spans pulled back over the sideband all carry
        // this id, so Perfetto shows the clip end to end.
        let _bind = trace::bind(tracer().mint());
        let c0 = Instant::now();
        let got = {
            let _span = trace::span("clip");
            engine.infer(clip)?
        };
        hub().observe_us("spidr_clip_latency_us", c0.elapsed().as_micros() as u64);
        assert_eq!(
            got, want[i],
            "distributed output diverged from the reference on clip {i}"
        );
    }
    let wall = t0.elapsed();

    // The reassembled Vmems must match the reference trajectory too.
    let mut state = net.init_state()?;
    net.run(clips.last().unwrap(), &mut state)?;
    for (a, b) in state.vmems.iter().zip(engine.last_vmems()) {
        assert_eq!(a.as_slice(), b.as_slice(), "reassembled Vmems diverged");
    }
    if kill.is_some() {
        assert!(
            engine.failovers() > 0,
            "a replica was killed but no failover was absorbed"
        );
    }

    println!(
        "{} clips × {TIMESTEPS} steps over the wire in {wall:?} — outputs, Vmems and \
         telemetry bit-identical to the reference executor across {} failover(s): ok",
        clips.len(),
        engine.failovers(),
    );

    // Lane-batch phase: pack up to 64 clips into one v3 lane batch per
    // hop and check both the per-lane outputs (against the reference)
    // and the wire-frame amortization counters.
    if batch > 0 {
        // One lane batch's worth of clips; on a v2-pinned
        // constellation (max_batch = 1) they all serve through the
        // scalar fallback instead.
        let lanes = batch.min(MAX_LANES);
        let bclips: Vec<Vec<SpikePlane>> = (0..lanes)
            .map(|i| random_clip(&net, 400 + i as u64))
            .collect();
        let mut bwant = Vec::new();
        for clip in &bclips {
            bwant.push(reference.infer(clip)?);
        }
        let refs: Vec<&[SpikePlane]> = bclips.iter().map(|c| c.as_slice()).collect();
        let (s0, l0) = engine.wire_frames();
        let _bind = trace::bind(tracer().mint());
        let t1 = Instant::now();
        let got = {
            let _span = trace::span("lane_batch");
            engine.infer_batch(&refs)?
        };
        let bwall = t1.elapsed();
        hub().observe_us("spidr_batch_latency_us", bwall.as_micros() as u64);
        assert_eq!(
            got, bwant,
            "batched distributed outputs diverged from the reference"
        );
        let (s1, l1) = engine.wire_frames();
        let hops = engine.groups().len() as u64;
        if engine.lane_batching() {
            assert_eq!(s1, s0, "a lane-batched run sent scalar spike frames");
            assert_eq!(
                l1 - l0,
                (TIMESTEPS as u64 + 2) * hops,
                "lane-frame count off: one batch is open + T frames + drain per hop"
            );
            // What the same clips would have cost as scalar sessions.
            let scalar_cost = (TIMESTEPS as u64 + 1) * hops * lanes as u64;
            println!(
                "lane batch: {lanes} clips × {TIMESTEPS} steps in {bwall:?}, \
                 {} lane frames vs {scalar_cost} scalar frames \
                 ({:.1}x wire amortization): ok",
                l1 - l0,
                scalar_cost as f64 / (l1 - l0) as f64,
            );
        } else {
            // A v2 replica pins the constellation to the scalar
            // grammar; the batched request must still serve correctly.
            assert_eq!(l1, l0, "a v2 constellation sent lane frames");
            assert!(s1 > s0, "scalar fallback sent no frames");
            println!(
                "lane batch: constellation negotiated v{} — {lanes} clips served \
                 by scalar fallback, bit-identical: ok",
                engine.negotiated_version(),
            );
        }
    }
    print_hops(&engine);

    // Observability exports: one Perfetto-loadable trace joining the
    // coordinator "clip" spans, hop spans, failover instants, and the
    // re-based shard spans pulled over the sideband; plus the
    // Prometheus metrics snapshot with the clip-latency histogram.
    if let Some(path) = &trace_out {
        std::fs::write(path, tracer().to_chrome_json())?;
        println!(
            "trace: {} events → {path} (load in https://ui.perfetto.dev)",
            tracer().snapshot_events().len()
        );
    }
    if let Some(path) = &metrics_out {
        std::fs::write(path, hub().render_prometheus())?;
        println!("metrics: Prometheus snapshot → {path}");
    }
    if trace_out.is_some() || metrics_out.is_some() {
        let snap = hub().snapshot();
        if let Some(h) = snap.hists.get("spidr_clip_latency_us") {
            println!(
                "clip latency over {} clips: p50 {} us, p99 {} us (log-bucketed, ±1/16)",
                h.count(),
                h.percentile(50.0),
                h.percentile(99.0),
            );
        }
    }
    if let Some(mut server) = metrics_server {
        // Hold the scrape endpoint open briefly so a `spidr metrics`
        // client (the CI smoke, or a curious operator) can pull the
        // finished-run snapshot before the process exits.
        let linger: u64 = flag_value(&args, "--linger-ms")
            .and_then(|v| v.parse().ok())
            .unwrap_or(3000);
        println!("metrics: endpoint open for {linger} ms more...");
        std::thread::sleep(Duration::from_millis(linger));
        server.stop();
    }
    Ok(())
}
