//! Distributed shard serving, end to end — now with blank-shard
//! provisioning and a kill-a-replica failover demo (DESIGN.md
//! §Distributed).
//!
//! ```text
//! # self-hosted loopback constellation (blank shards, weight-pushed,
//! # 2 replicas per hop, one replica killed mid-stream):
//! cargo run --release --example distributed
//!
//! # against real shard processes (the CI two-process smoke; the
//! # shards start blank — the coordinator provisions them):
//! cargo run --release -- shard --listen 127.0.0.1:7401 --sessions 1 &
//! cargo run --release -- shard --listen 127.0.0.1:7402 --sessions 1 &
//! cargo run --release --example distributed -- --connect 127.0.0.1:7401,127.0.0.1:7402
//!
//! # replicated: consecutive addresses group into hops of --replicas
//! # links; --kill-replica K severs replica K of every hop mid-stream
//! # (the CI three-process failover smoke):
//! cargo run --release --example distributed -- \
//!     --connect 127.0.0.1:7403,127.0.0.1:7404 --replicas 2 --kill-replica 0
//! ```
//!
//! Either way the example acts as the coordinator: it builds the
//! pipeline-demo workload, provisions every shard replica over the
//! wire (the shards need no local artifact), runs the same clips
//! through the sequential reference executor and the distributed
//! engine — killing a replica halfway when the demo is replicated —
//! and **asserts the outputs and Vmems stay bit-identical** (a
//! non-zero exit means the wire path, or the failover replay, diverged
//! — this is the CI smokes' oracle), then prints the shard topology,
//! per-hop wire metrics and failovers absorbed.

use std::time::{Duration, Instant};

use spidr::coordinator::{Engine, ReferenceEngine};
use spidr::net::{DistributedConfig, DistributedEngine, TcpTransport, Transport};
use spidr::prop::SplitMix64;
use spidr::snn::network::{demo_pipeline_network, Network};
use spidr::snn::spikes::SpikePlane;

const TIMESTEPS: usize = 12;

/// Random clip of binned frames for the workload.
fn random_clip(net: &Network, seed: u64) -> Vec<SpikePlane> {
    let (c, h, w) = net.layers[0].in_shape;
    let mut rng = SplitMix64::new(seed);
    (0..TIMESTEPS)
        .map(|_| {
            let mut p = SpikePlane::zeros(c, h, w);
            for i in 0..p.len() {
                if rng.chance(0.2) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            p
        })
        .collect()
}

/// Connect with retries: the CI smoke starts the shard processes in
/// the background, so the listeners may lag this coordinator.
fn connect_retry(addr: &str) -> spidr::Result<TcpTransport> {
    let mut last = None;
    for _ in 0..40 {
        match TcpTransport::connect(addr) {
            Ok(t) => return Ok(t),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    Err(last.unwrap())
}

fn print_hops(engine: &DistributedEngine) {
    let net = engine.network();
    for (sm, (alive, total)) in engine
        .stage_metrics()
        .iter()
        .zip(engine.replica_status())
    {
        let layers: Vec<String> = net.layers[sm.layers.0..sm.layers.1]
            .iter()
            .map(|l| l.describe())
            .collect();
        println!(
            "  shard {} ({alive}/{total} replicas alive): [{}] {} frames, \
             wire busy {:?}, stall in/out {:?}/{:?}",
            sm.stage,
            layers.join(" → "),
            sm.steps,
            sm.busy,
            sm.stall_in,
            sm.stall_out,
        );
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> spidr::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let connect = flag_value(&args, "--connect");
    let replicas: usize = flag_value(&args, "--replicas")
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let kill_replica: Option<usize> =
        flag_value(&args, "--kill-replica").and_then(|v| v.parse().ok());

    let net = demo_pipeline_network(TIMESTEPS)?;
    let clips: Vec<Vec<SpikePlane>> = (0..4).map(|i| random_clip(&net, 40 + i)).collect();

    // Oracle: the sequential reference executor on the same clips.
    let mut reference = ReferenceEngine::new(net.clone())?;
    let mut want = Vec::new();
    for clip in &clips {
        want.push(reference.infer(clip)?);
    }

    let mut engine = match &connect {
        // Real shard processes over TCP: consecutive addresses group
        // into hops of `replicas` links, in layer-group order. The
        // shards may start blank — the engine pushes the workload.
        Some(addrs) => {
            let links: Vec<&str> = addrs.split(',').collect();
            if replicas == 0 || links.len() % replicas != 0 {
                return Err(spidr::Error::config(format!(
                    "{} addresses do not group into hops of {replicas} replicas",
                    links.len()
                )));
            }
            let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::new();
            for hop_addrs in links.chunks(replicas) {
                let mut hop: Vec<Box<dyn Transport>> = Vec::new();
                for addr in hop_addrs {
                    hop.push(Box::new(connect_retry(addr)?));
                }
                hops.push(hop);
            }
            println!(
                "coordinator: chaining {} TCP hop(s) x {replicas} replica(s), \
                 provisioning over the wire: {addrs}",
                hops.len()
            );
            DistributedEngine::connect_replicated(net.clone(), hops, 2)?
        }
        // Self-hosted loopback constellation: blank shard threads,
        // weight-pushed, replicated — the same protocol, windowing,
        // reassembly and failover with no sockets.
        None => {
            let reps = if replicas > 1 { replicas } else { 2 };
            println!(
                "coordinator: self-hosting a 3-shard x {reps}-replica loopback \
                 constellation (blank shards, weight-pushed)"
            );
            DistributedEngine::loopback(
                net.clone(),
                &DistributedConfig::replicated(3, reps),
            )?
        }
    };
    println!("layer-group placement: {:?}", engine.groups());

    // Kill a replica halfway through the stream: after an even number
    // of clips the least-loaded tie-break picks replica 0 next, so
    // severing it (or the requested index) guarantees the next clip
    // runs the failover path. Loopback demos always kill; TCP mode
    // kills only when --kill-replica is given (the failover smoke).
    let kill_at = clips.len() / 2;
    let kill = match (&connect, kill_replica) {
        (_, Some(r)) => Some(r),
        (None, None) => Some(0),
        (Some(_), None) => None,
    };
    let replicated = engine.replica_status().iter().all(|&(_, total)| total > 1);
    // Only a replicated constellation can absorb a kill.
    let kill = if replicated { kill } else { None };

    let t0 = Instant::now();
    for (i, clip) in clips.iter().enumerate() {
        if let Some(r) = kill.filter(|_| i == kill_at) {
            println!("killing replica {r} of every hop mid-stream...");
            for hop in 0..engine.groups().len() {
                engine.sever_replica(hop, r)?;
            }
        }
        let got = engine.infer(clip)?;
        assert_eq!(
            got, want[i],
            "distributed output diverged from the reference on clip {i}"
        );
    }
    let wall = t0.elapsed();

    // The reassembled Vmems must match the reference trajectory too.
    let mut state = net.init_state()?;
    net.run(clips.last().unwrap(), &mut state)?;
    for (a, b) in state.vmems.iter().zip(engine.last_vmems()) {
        assert_eq!(a.as_slice(), b.as_slice(), "reassembled Vmems diverged");
    }
    if kill.is_some() {
        assert!(
            engine.failovers() > 0,
            "a replica was killed but no failover was absorbed"
        );
    }

    println!(
        "{} clips × {TIMESTEPS} steps over the wire in {wall:?} — outputs, Vmems and \
         telemetry bit-identical to the reference executor across {} failover(s): ok",
        clips.len(),
        engine.failovers(),
    );
    print_hops(&engine);
    Ok(())
}
