//! Distributed shard serving, end to end (DESIGN.md §Distributed).
//!
//! ```text
//! # self-hosted loopback constellation (no sockets):
//! cargo run --release --example distributed
//!
//! # against real shard processes (the CI two-process smoke):
//! cargo run --release -- shard --listen 127.0.0.1:7401 --sessions 1 &
//! cargo run --release -- shard --listen 127.0.0.1:7402 --sessions 1 &
//! cargo run --release --example distributed -- --connect 127.0.0.1:7401,127.0.0.1:7402
//! ```
//!
//! Either way the example acts as the coordinator: it builds the
//! pipeline-demo workload, runs the same clips through the sequential
//! reference executor and the distributed engine, **asserts the
//! outputs and Vmems are bit-identical** (a non-zero exit means the
//! wire path diverged — this is the CI smoke's oracle), and prints the
//! shard topology and per-hop wire metrics.

use std::time::{Duration, Instant};

use spidr::coordinator::{Engine, ReferenceEngine};
use spidr::net::{DistributedConfig, DistributedEngine, TcpTransport, Transport};
use spidr::prop::SplitMix64;
use spidr::snn::network::{demo_pipeline_network, Network};
use spidr::snn::spikes::SpikePlane;

const TIMESTEPS: usize = 12;

/// Random clip of binned frames for the workload.
fn random_clip(net: &Network, seed: u64) -> Vec<SpikePlane> {
    let (c, h, w) = net.layers[0].in_shape;
    let mut rng = SplitMix64::new(seed);
    (0..TIMESTEPS)
        .map(|_| {
            let mut p = SpikePlane::zeros(c, h, w);
            for i in 0..p.len() {
                if rng.chance(0.2) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            p
        })
        .collect()
}

/// Connect with retries: the CI smoke starts the shard processes in
/// the background, so the listeners may lag this coordinator.
fn connect_retry(addr: &str) -> spidr::Result<TcpTransport> {
    let mut last = None;
    for _ in 0..40 {
        match TcpTransport::connect(addr) {
            Ok(t) => return Ok(t),
            Err(e) => last = Some(e),
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    Err(last.unwrap())
}

fn print_hops(engine: &DistributedEngine) {
    let net = engine.network();
    for sm in engine.stage_metrics() {
        let layers: Vec<String> = net.layers[sm.layers.0..sm.layers.1]
            .iter()
            .map(|l| l.describe())
            .collect();
        println!(
            "  shard {}: [{}] {} frames, wire busy {:?}, stall in/out {:?}/{:?}",
            sm.stage,
            layers.join(" → "),
            sm.steps,
            sm.busy,
            sm.stall_in,
            sm.stall_out,
        );
    }
}

fn main() -> spidr::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let connect = args
        .iter()
        .position(|a| a == "--connect")
        .and_then(|i| args.get(i + 1).cloned());

    let net = demo_pipeline_network(TIMESTEPS)?;
    let clips: Vec<Vec<SpikePlane>> = (0..4).map(|i| random_clip(&net, 40 + i)).collect();

    // Oracle: the sequential reference executor on the same clips.
    let mut reference = ReferenceEngine::new(net.clone())?;
    let mut want = Vec::new();
    for clip in &clips {
        want.push(reference.infer(clip)?);
    }

    let mut engine = match &connect {
        // Real shard processes over TCP: one link per address, in
        // layer-group order.
        Some(addrs) => {
            let mut links: Vec<Box<dyn Transport>> = Vec::new();
            for addr in addrs.split(',') {
                links.push(Box::new(connect_retry(addr)?));
            }
            println!("coordinator: chaining {} TCP shard(s): {addrs}", links.len());
            DistributedEngine::connect(net.clone(), links, 2)?
        }
        // Self-hosted loopback constellation: the same protocol,
        // windowing and reassembly with no sockets.
        None => {
            println!("coordinator: self-hosting a 3-shard loopback constellation");
            DistributedEngine::loopback(net.clone(), &DistributedConfig::with_shards(3))?
        }
    };
    println!("layer-group placement: {:?}", engine.groups());

    let t0 = Instant::now();
    for (i, clip) in clips.iter().enumerate() {
        let got = engine.infer(clip)?;
        assert_eq!(
            got, want[i],
            "distributed output diverged from the reference on clip {i}"
        );
    }
    let wall = t0.elapsed();

    // The reassembled Vmems must match the reference trajectory too.
    let mut state = net.init_state()?;
    net.run(clips.last().unwrap(), &mut state)?;
    for (a, b) in state.vmems.iter().zip(engine.last_vmems()) {
        assert_eq!(a.as_slice(), b.as_slice(), "reassembled Vmems diverged");
    }

    println!(
        "{} clips × {TIMESTEPS} steps over the wire in {wall:?} — outputs, Vmems and \
         telemetry bit-identical to the reference executor: ok",
        clips.len(),
    );
    print_hops(&engine);
    Ok(())
}
