//! Quickstart: simulate one spiking conv layer on the SpiDR core.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a small Conv(2→12) layer, feeds three random event frames
//! through the cycle-level simulator, and prints the mapping, cycle
//! count, energy breakdown, and derived chip metrics — the minimal
//! end-to-end tour of the public API.

use spidr::coordinator::Mapper;
use spidr::energy::model::Corner;
use spidr::prop::SplitMix64;
use spidr::quant::Precision;
use spidr::sim::{SimConfig, SpidrCore};
use spidr::snn::layer::{Layer, NeuronConfig, ResetMode};
use spidr::snn::spikes::SpikePlane;
use spidr::snn::tensor::Mat;

fn main() -> spidr::Result<()> {
    // 1. A quantized spiking conv layer (weights would normally come
    //    from a trained .swb bundle; here they are synthetic).
    let mut rng = SplitMix64::new(42);
    let mut weights = Mat::zeros(2 * 9, 12);
    for f in 0..18 {
        for k in 0..12 {
            weights.set(f, k, rng.below(15) as i32 - 7);
        }
    }
    let layer = Layer::conv(
        (2, 16, 16), // C,H,W input
        12,          // output channels
        3, 3, 1, 1,  // 3x3, stride 1, pad 1
        weights,
        NeuronConfig { theta: 8, leak: 2, leaky: true, reset: ResetMode::Soft },
        false,
    )?;

    // 2. How does it map onto the core? (paper Fig. 12)
    let mapping = Mapper::new(Precision::W4V7).map_layer(&layer)?;
    println!(
        "mapping: {:?}, rows/CU {:?}, {} tiles, {} pass(es)",
        mapping.mode, mapping.rows_per_cu, mapping.tiles, mapping.passes
    );

    // 3. Three timesteps of random events at ~90 % sparsity.
    let frames: Vec<SpikePlane> = (0..3)
        .map(|t| {
            let mut p = SpikePlane::zeros(2, 16, 16);
            for i in 0..p.len() {
                if rng.chance(0.10) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            println!("frame {t}: {:.1} % sparsity", p.sparsity() * 100.0);
            p
        })
        .collect();

    // 4. Run on the simulated core (functional + cycle/energy exact).
    let core = SpidrCore::new(SimConfig::default());
    let mut vmem_state = Mat::zeros(16 * 16, 12);
    let (outputs, stats) = core.run_layer(&layer, &frames, &mut vmem_state)?;

    let mut run = stats.run;
    run.finalize_leakage(Corner::LOW, &core.cfg.energy);
    println!("\nresults:");
    for (t, o) in outputs.iter().enumerate() {
        println!("  t{t}: {} output spikes", o.count_spikes());
    }
    println!("  cycles          : {}", run.cycles);
    println!("  macro ops       : {}", run.macro_ops);
    println!("  parity switches : {}", run.parity_switches);
    println!("  energy          : {:.2} nJ", run.total_energy_pj(Corner::LOW) / 1e3);
    println!("  CIM share       : {:.1} %", run.energy.cim_share() * 100.0);
    println!("  throughput      : {:.2} GOPS @50 MHz", run.gops(Corner::LOW));
    println!("  efficiency      : {:.2} TOPS/W", run.tops_per_watt(Corner::LOW));
    Ok(())
}
