//! Serving-tier walkthrough: DVS event bursts → streaming server →
//! sharded worker pool → ordered responses.
//!
//! ```text
//! cargo run --release --example serving
//!
//! # observability: export a Chrome-trace JSON of the request path
//! # (ingest → dispatch → worker infer → clip roots; open it in
//! # Perfetto) and hold a live Prometheus scrape endpoint open:
//! cargo run --release --example serving -- \
//!     --trace serving.json --metrics-listen 127.0.0.1:9464
//! ```
//!
//! Demonstrates the L3 request path end to end (DESIGN.md §Serve):
//! a multi-layer spiking network served first by the single-engine
//! pipeline, then by a 4-worker pool with bounded inboxes and work
//! stealing — same outputs, higher throughput — plus the scheduler's
//! layer-group sharding plan and the per-worker metrics.

use spidr::coordinator::{
    InferenceServer, MultiCoreScheduler, PoolConfig, ReferenceEngine, ScheduledEngine,
    ServerConfig,
};
use spidr::dvs::event::{Event, Polarity};
use spidr::obs::{hub, tracer, MetricsServer};
use spidr::prop::SplitMix64;
use spidr::sim::SimConfig;
use spidr::snn::network::demo_serving_network;

/// One synthetic DVS burst over the clip window.
fn burst(seed: u64) -> Vec<Event> {
    let mut rng = SplitMix64::new(seed);
    (0..180)
        .map(|_| Event {
            y: rng.below(16) as u16,
            x: rng.below(16) as u16,
            polarity: if rng.chance(0.5) { Polarity::On } else { Polarity::Off },
            t_us: rng.below(10_000) as u32,
        })
        .collect()
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> spidr::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let trace_out = flag_value(&args, "--trace");
    if trace_out.is_some() {
        tracer().enable(1);
        tracer().set_process_label("serving");
    }
    let metrics_server = match flag_value(&args, "--metrics-listen") {
        Some(addr) => {
            let server = MetricsServer::spawn(&addr, hub())?;
            println!(
                "metrics: live Prometheus endpoint on {} \
                 (scrape with `spidr metrics --connect ...`)",
                server.local_addr()
            );
            Some(server)
        }
        None => None,
    };
    let net = demo_serving_network(10)?;
    let server = InferenceServer::new(ServerConfig {
        height: 16,
        width: 16,
        timesteps: 10,
        bin_us: 1000,
        queue_depth: 4,
        ..Default::default()
    });
    let requests: Vec<Vec<Event>> = (0..24).map(|i| burst(100 + i)).collect();

    // 1. How would the scheduler shard this network's layers across
    //    workers? (the layer-stationary placement; DESIGN.md §Serve)
    let sched = MultiCoreScheduler::new(4, SimConfig::default());
    println!("layer-group plan over 4 workers: {:?}", sched.partition_layer_groups(&net));

    // 2. Baseline: the single-engine three-stage pipeline.
    let mut single = ReferenceEngine::new(net.clone())?;
    let t0 = std::time::Instant::now();
    let (base, _) = server.serve(requests.clone(), &mut single)?;
    let t_single = t0.elapsed();
    println!("single engine : {} responses in {t_single:?}", base.len());

    // 3. The sharded tier: 4 workers, bounded inboxes, work stealing.
    let pool = PoolConfig::with_workers(4);
    let t0 = std::time::Instant::now();
    let (resp, metrics) =
        server.serve_pool(requests.clone(), &pool, |_| ReferenceEngine::new(net.clone()))?;
    let t_pool = t0.elapsed();
    println!("pool x4       : {} responses in {t_pool:?}", resp.len());

    // Ordering guarantee: responses arrive in request order, and the
    // outputs are bit-identical to the single-engine run.
    for (a, b) in base.iter().zip(&resp) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output, b.output);
    }
    println!("ordering + bit-identical outputs: ok");
    println!(
        "latency p50/p99: {} / {} us, pool utilization {:.0}%, {} clips stolen",
        metrics.percentile_us(50.0),
        metrics.percentile_us(99.0),
        metrics.pool_utilization() * 100.0,
        metrics.total_stolen(),
    );
    for w in &metrics.workers {
        println!(
            "  worker {}: {} clips ({} stolen), busy {:?}, idle {:?}, inbox hwm {}",
            w.worker, w.clips, w.stolen, w.busy, w.idle, w.inbox_high_water
        );
    }

    // 4. The same tier with a cycle-level simulated core per worker:
    //    full cycle/energy telemetry on the sharded request path.
    let (sim_resp, _) = server.serve_pool(requests, &PoolConfig::with_workers(2), |_| {
        ScheduledEngine::new(net.clone(), MultiCoreScheduler::new(1, SimConfig::default()))
    })?;
    let first = &sim_resp[0].output;
    println!(
        "simulated pool: clip 0 ran {} cycles, {} synops, {:.2} nJ",
        first.cycles,
        first.run.synops,
        first.run.total_energy_pj(spidr::energy::model::Corner::LOW) / 1e3,
    );

    // Observability exports (DESIGN.md §Observability): the request
    // path above ran with per-clip trace ids minted at ingest, so the
    // Chrome-trace JSON shows ingest → dispatch → worker infer spans
    // per clip; the hub holds the ingest→emit latency histograms the
    // scrape endpoint serves.
    if let Some(path) = &trace_out {
        std::fs::write(path, tracer().to_chrome_json())?;
        println!(
            "trace: {} events → {path} (load in https://ui.perfetto.dev)",
            tracer().snapshot_events().len()
        );
    }
    if let Some(mut server) = metrics_server {
        let snap = hub().snapshot();
        if let Some(h) = snap.hists.get("spidr_clip_latency_us") {
            println!(
                "ingest→emit latency over {} clips: p50 {} us, p99 {} us",
                h.count(),
                h.percentile(50.0),
                h.percentile(99.0),
            );
        }
        // Hold the endpoint open briefly so `spidr metrics` can pull
        // the finished-run snapshot before the process exits.
        let linger: u64 = flag_value(&args, "--linger-ms")
            .and_then(|v| v.parse().ok())
            .unwrap_or(3000);
        println!("metrics: endpoint open for {linger} ms more...");
        std::thread::sleep(std::time::Duration::from_millis(linger));
        server.stop();
    }
    Ok(())
}
