//! Gesture recognition end to end: synthetic DVS gesture events →
//! streaming server (ingest thread + backpressure) → PJRT golden model
//! (the AOT-compiled JAX network) → classification, with the cycle
//! simulator reporting what the SpiDR core would spend.
//!
//! Requires `make artifacts`. Run:
//! ```text
//! cargo run --release --example gesture_recognition
//! ```

use spidr::coordinator::{Engine, InferenceServer, NetworkCompiler, ServerConfig};
use spidr::dvs::binning::unbin_frames;
use spidr::dvs::gesture::{make_gesture, GestureConfig, NUM_GESTURE_CLASSES};
use spidr::energy::model::Corner;
use spidr::error::Result;
use spidr::quant::Precision;
use spidr::runtime::{ArtifactStore, GoldenModel};
use spidr::sim::SimConfig;
use spidr::snn::network::gesture_network;
use spidr::snn::spikes::SpikePlane;
use spidr::snn::WeightBundle;

struct GoldenEngine {
    store: ArtifactStore,
    model: GoldenModel,
}

impl Engine for GoldenEngine {
    type Output = usize;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<usize> {
        self.model.run_clip(&mut self.store, clip)?;
        Ok(self.model.argmax())
    }
}

fn main() -> Result<()> {
    let wb = 4u32;
    let store = ArtifactStore::open("artifacts")?;
    let model = GoldenModel::new(&store, &format!("gesture_w{wb}"))?;
    let (_, h, w) = model.frame_shape();
    let timesteps = model.timesteps;
    println!("artifact gesture_w{wb}: {h}x{w}, {timesteps} timesteps, PJRT CPU");

    // Build the request stream: events (as a DVS would emit them).
    let cfg = GestureConfig { height: h, width: w, timesteps, noise_rate: 0.008 };
    let n_clips = 11;
    let mut labels = Vec::new();
    let requests: Vec<_> = (0..n_clips)
        .map(|i| {
            let label = i % NUM_GESTURE_CLASSES;
            labels.push(label);
            let clip = make_gesture(label, 31_000 + i as u64, &cfg);
            unbin_frames(&clip.frames, 1000)
        })
        .collect();

    // Serve through the pipelined ingest -> infer flow.
    let server = InferenceServer::new(ServerConfig {
        height: h,
        width: w,
        timesteps,
        bin_us: 1000,
        queue_depth: 2,
        ..Default::default()
    });
    let mut engine = GoldenEngine { store, model };
    let (responses, metrics) = server.serve(requests, &mut engine)?;

    let mut correct = 0;
    for (resp, &label) in responses.iter().zip(&labels) {
        let ok = resp.output == label;
        correct += usize::from(ok);
        println!(
            "clip {:2}: label {:2} pred {:2} {} ({} us)",
            resp.id, label, resp.output,
            if ok { "ok " } else { "MISS" },
            resp.latency.as_micros()
        );
    }
    println!(
        "\naccuracy {}/{} ({:.1} %) | mean latency {:.1} ms | p95 {:.1} ms | {:.1} clips/s",
        correct,
        n_clips,
        correct as f64 / n_clips as f64 * 100.0,
        metrics.mean_latency_us() / 1e3,
        metrics.percentile_us(95.0) as f64 / 1e3,
        metrics.clips_per_second()
    );

    // What would the SpiDR core spend? (cycle simulator, same weights)
    let p = Precision::from_weight_bits(wb)?;
    let bundle = WeightBundle::load(format!("artifacts/weights/gesture_w{wb}.swb"))?;
    let net = gesture_network(&bundle, p, h, w, timesteps)?;
    let compiled = NetworkCompiler::compile(net, SimConfig::timing_only(p))?;
    let clip = make_gesture(3, 31_003, &cfg);
    let mut state = compiled.network.init_state()?;
    let report = compiled.run_clip(&clip.frames, &mut state)?;
    println!(
        "simulated core: {:.0} kcycles/clip ({:.2} ms @50 MHz), {:.2} uJ, {:.2} TOPS/W",
        report.total.cycles as f64 / 1e3,
        report.total.seconds(Corner::LOW) * 1e3,
        report.total.total_energy_pj(Corner::LOW) / 1e6,
        report.total.tops_per_watt(Corner::LOW),
    );
    Ok(())
}
