//! Deterministic model checking of the crate's concurrency protocols.
//!
//! Compiled (and meaningful) only under `RUSTFLAGS="--cfg spidr_model"`:
//! the `crate::sync` facade then routes every lock / condvar / channel /
//! atomic operation through the cooperative scheduler in `spidr::check`,
//! and [`explore`] exhaustively interleaves the threads of each model
//! within a preemption bound (DESIGN.md §Correctness).
//!
//! Two kinds of test live here:
//!
//! * **Protocol models** — the real serving-stack protocols (pool
//!   dispatch/retire, bounded-inbox backpressure, pipeline channels,
//!   reorder/failover watermark, loopback pipes, hop-window retune)
//!   driven directly through their public APIs; each must survive
//!   every explored interleaving and explore at least 1 000 of them.
//! * **Seeded-bug self-tests** — deliberately broken protocols
//!   (two-lock deadlock, lost wakeup, racy counter) that the checker
//!   must catch within the default bound and then reproduce
//!   deterministically from the reported schedule via [`replay`].
//!
//! ```text
//! RUSTFLAGS="--cfg spidr_model" cargo test --test model
//! ```
#![cfg(spidr_model)]

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use spidr::check::{explore, model_violation, replay, Config, FailureKind, Report};
use spidr::coordinator::{ClipJob, Dispatch, Fetched, SharedQueue, StealPolicy};
use spidr::net::coordinator::admit_and_forward;
use spidr::net::{Frame, LoopbackTransport, Transport};
use spidr::obs::TraceId;
use spidr::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use spidr::sync::{mpsc, thread, Arc, Condvar, Mutex};
use spidr::{model_assert, model_assert_eq};

/// Exploration config for the protocol models: preemption bound 3
/// (one more than the default — the protocol models are small enough
/// to afford it, and the extra bound multiplies the schedule space
/// well past the 1 000-interleaving acceptance bar), capped at `max`
/// executions so no single model dominates CI wall time. The seeded
/// self-tests use the plain default instead: each bug class must be
/// caught within bound 2.
fn cfg(max: u64) -> Config {
    let mut c = Config::new().with_bound(3);
    c.max_executions = max;
    c
}

/// A protocol model passed: no failure, and the sweep was not trivial
/// (the acceptance bar is ≥1 000 interleavings per model; pruned
/// executions count — they are distinct explored schedules whose
/// continuation was proven equivalent to a visited state).
fn assert_thorough(report: &Report, what: &str) {
    report.assert_ok();
    assert!(
        report.executions >= 1_000,
        "{what}: only {} interleavings explored ({} pruned) — model too small",
        report.executions,
        report.pruned,
    );
}

/// A pool job with no payload (the protocols under test never look at
/// the frames).
fn job(seq: u64) -> ClipJob {
    ClipJob {
        seq,
        t0: Instant::now(),
        trace: TraceId::NONE,
        frames: Vec::new(),
    }
}

/// The worker half of the pool protocol, exactly as `run_pool` drives
/// it: fetch until the queue closes (deregistering on the way out) or
/// the worker retires (already deregistered by `next`).
fn pool_worker(
    q: Arc<SharedQueue>,
    me: usize,
    steal: StealPolicy,
    shrink: Option<(Duration, usize)>,
    got: Arc<AtomicUsize>,
) -> impl FnOnce() + Send + 'static {
    move || loop {
        match q.next(me, steal, shrink) {
            Fetched::Job(_, _) => {
                got.fetch_add(1, Ordering::SeqCst);
            }
            Fetched::Closed => {
                q.worker_exit(me);
                break;
            }
            Fetched::Retired(_) => break,
        }
    }
}

// ---------------------------------------------------------------------------
// Protocol models
// ---------------------------------------------------------------------------

/// Dispatch-vs-retire race (the audit pinned in `SharedQueue::next`):
/// workers may retire at any wait timeout while the dispatcher is
/// placing jobs; the retire invariant (a retiring worker's inbox is
/// provably empty, dispatch re-validates `retired[i]` under the same
/// mutex) must hold in every interleaving — no job may be stranded in
/// a retired inbox. The dispatcher handles [`Dispatch::Grow`] exactly
/// as dynamic sizing does: start a worker, re-dispatch.
#[test]
fn pool_dispatch_vs_retire_never_strands_a_job() {
    let report = explore(cfg(20_000), || {
        let q = Arc::new(SharedQueue::new());
        let got = Arc::new(AtomicUsize::new(0));
        let shrink = Some((Duration::from_millis(1), 1));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let w = q.start_worker();
            handles.push(thread::spawn(pool_worker(
                Arc::clone(&q),
                w,
                StealPolicy::Steal,
                shrink,
                Arc::clone(&got),
            )));
        }
        for seq in 0..2 {
            let mut j = job(seq);
            loop {
                match q.dispatch(1, j, 2) {
                    Dispatch::Placed => break,
                    Dispatch::Grow(back) => {
                        // Dynamic sizing's grow edge: every active
                        // inbox full and a worker slot free.
                        j = back;
                        let w = q.start_worker();
                        handles.push(thread::spawn(pool_worker(
                            Arc::clone(&q),
                            w,
                            StealPolicy::Steal,
                            shrink,
                            Arc::clone(&got),
                        )));
                    }
                    Dispatch::Closed => model_violation("pool closed mid-stream".into()),
                }
            }
        }
        q.close();
        for h in handles {
            h.join().unwrap();
        }
        model_assert_eq!(got.load(Ordering::SeqCst), 2);
    });
    assert_thorough(&report, "pool dispatch-vs-retire");
}

/// Bounded-inbox backpressure: with depth-1 inboxes, one worker, and
/// `grow_limit` already reached, the dispatcher must *block* on a full
/// pool — never drop, never grow — and every job must still come out
/// the other side once the worker drains.
#[test]
fn pool_backpressure_blocks_instead_of_dropping() {
    let report = explore(cfg(20_000), || {
        let q = Arc::new(SharedQueue::new());
        let got = Arc::new(AtomicUsize::new(0));
        let w = q.start_worker();
        let h = thread::spawn(pool_worker(
            Arc::clone(&q),
            w,
            StealPolicy::Pinned,
            None,
            Arc::clone(&got),
        ));
        for seq in 0..3 {
            match q.dispatch(1, job(seq), 1) {
                Dispatch::Placed => {}
                Dispatch::Grow(_) => model_violation("grow past grow_limit".into()),
                Dispatch::Closed => model_violation("pool closed mid-stream".into()),
            }
        }
        q.close();
        h.join().unwrap();
        model_assert_eq!(got.load(Ordering::SeqCst), 3);
    });
    assert_thorough(&report, "pool backpressure");
}

/// Pipeline fill/drain: a two-deep chain of capacity-1 bounded
/// channels (the `stage_loop` shape) must deliver every value in
/// order through every interleaving of producer, stage, and consumer,
/// and terminate cleanly on sender disconnect.
#[test]
fn pipeline_bounded_channels_fill_and_drain_in_order() {
    let report = explore(cfg(20_000), || {
        let (tx, rx) = mpsc::sync_channel::<u32>(1);
        let (tx2, rx2) = mpsc::sync_channel::<u32>(1);
        let stage = thread::spawn(move || {
            for v in rx.iter() {
                if tx2.send(v * 2).is_err() {
                    break;
                }
            }
        });
        for v in 0..3u32 {
            tx.send(v).unwrap();
        }
        drop(tx);
        let out: Vec<u32> = rx2.iter().collect();
        stage.join().unwrap();
        model_assert_eq!(out, vec![0, 2, 4]);
    });
    assert_thorough(&report, "pipeline fill/drain");
}

/// Reorder-buffer ordering under replica skew: two arrival threads
/// deliver interleaved sequence numbers through
/// [`admit_and_forward`]; the watermark/buffer pair must forward
/// 0,1,2,3 in order and drain completely, whichever side runs first.
#[test]
fn reorder_buffer_forwards_in_order_under_skew() {
    type Shared = Mutex<(BTreeMap<u32, u32>, u32, Vec<u32>)>;
    fn deliver(st: &Shared, seq: u32) {
        let mut g = st.lock().unwrap();
        let (reorder, next_fwd, out) = &mut *g;
        admit_and_forward(reorder, next_fwd, seq, seq, |v| {
            out.push(v);
            Ok::<(), ()>(())
        })
        .unwrap();
    }
    let report = explore(cfg(20_000), || {
        let st = Arc::new(Mutex::new((BTreeMap::new(), 0u32, Vec::new())));
        let skewed = {
            let st = Arc::clone(&st);
            thread::spawn(move || {
                for seq in [1u32, 3, 5] {
                    deliver(&st, seq);
                }
            })
        };
        for seq in [0u32, 2, 4] {
            deliver(&st, seq);
        }
        skewed.join().unwrap();
        let g = st.lock().unwrap();
        model_assert_eq!(g.2, vec![0, 1, 2, 3, 4, 5]);
        model_assert!(g.0.is_empty(), "reorder buffer fully drained");
    });
    assert_thorough(&report, "reorder under skew");
}

/// Failover watermark duplicate-drop: after a replica failover the
/// replacement replays from its last watermark, so the reply pump
/// sees overlapping sequence ranges from two sources. The
/// `seq >= next_fwd` admission test must drop the duplicates and
/// forward each sequence exactly once, in order, in every
/// interleaving of original and replayed deliveries.
#[test]
fn failover_watermark_drops_duplicates_exactly_once() {
    type Shared = Mutex<(BTreeMap<u32, u32>, u32, Vec<u32>)>;
    fn deliver(st: &Shared, seq: u32) {
        let mut g = st.lock().unwrap();
        let (reorder, next_fwd, out) = &mut *g;
        admit_and_forward(reorder, next_fwd, seq, seq, |v| {
            out.push(v);
            Ok::<(), ()>(())
        })
        .unwrap();
    }
    let report = explore(cfg(20_000), || {
        let st = Arc::new(Mutex::new((BTreeMap::new(), 0u32, Vec::new())));
        // Original replica delivered 0,1,2 before dying; the failover
        // replacement replays from watermark 1 and delivers 1,2,3.
        let replayer = {
            let st = Arc::clone(&st);
            thread::spawn(move || {
                for seq in [1u32, 2, 3] {
                    deliver(&st, seq);
                }
            })
        };
        for seq in [0u32, 1, 2] {
            deliver(&st, seq);
        }
        replayer.join().unwrap();
        let g = st.lock().unwrap();
        model_assert_eq!(g.2, vec![0, 1, 2, 3]);
        model_assert!(g.0.is_empty(), "no duplicate left buffered");
    });
    assert_thorough(&report, "failover duplicate-drop");
}

/// Loopback pipe, writer blocked on a full buffer vs reader drop: the
/// first frame streams chunk-by-chunk to a live reader; the second is
/// bigger than the pipe capacity, so the writer must wait for drain —
/// and when the reading end drops instead, the writer must wake and
/// fail with a clean error, never hang, at every point the drop can
/// land relative to the partial writes.
#[test]
fn loopback_blocked_writer_observes_reader_drop() {
    let report = explore(cfg(10_000), || {
        let (mut a, mut b) = LoopbackTransport::pair_with_capacity(8);
        let writer = thread::spawn(move || {
            a.send(&Frame::Drain { clip: 1 }).unwrap();
            let big = Frame::Error {
                message: "x".repeat(64),
            };
            model_assert!(
                a.send(&big).is_err(),
                "blocked writer must error once the reader is gone"
            );
        });
        model_assert_eq!(b.recv().unwrap(), Some(Frame::Drain { clip: 1 }));
        drop(b);
        writer.join().unwrap();
    });
    assert_thorough(&report, "loopback writer-vs-reader-drop");
}

/// Loopback pipe, streaming then EOF: a frame larger than the pipe
/// capacity streams chunk-by-chunk to a concurrent reader; after the
/// writer drops, the reader finishes the frame from the residue and
/// then sees a clean EOF (`Ok(None)`), never a truncated frame or a
/// hang.
#[test]
fn loopback_reader_drains_residue_then_clean_eof() {
    let report = explore(cfg(10_000), || {
        let (mut a, mut b) = LoopbackTransport::pair_with_capacity(8);
        let writer = thread::spawn(move || {
            a.send(&Frame::Drain { clip: 7 }).unwrap();
            // `a` drops here: EOF once the buffered bytes drain.
        });
        model_assert_eq!(b.recv().unwrap(), Some(Frame::Drain { clip: 7 }));
        model_assert!(b.recv().unwrap().is_none(), "clean EOF after writer drop");
        writer.join().unwrap();
    });
    assert_thorough(&report, "loopback stream-then-EOF");
}

/// Per-hop window retune mid-flight: the congestion tuner shrinks and
/// grows the hop window while a sender admits frames against it and a
/// receiver acks them. Credit admission must respect the window at
/// admission time, in-flight must never exceed the largest window
/// ever granted, and a shrink below the current in-flight count must
/// drain without deadlock (the checker proves deadlock-freedom
/// directly).
#[test]
fn hop_window_retune_mid_flight_stays_bounded_and_live() {
    struct Hop {
        window: usize,
        inflight: usize,
        peak_window: usize,
    }
    let report = explore(cfg(20_000), || {
        let hop = Arc::new((
            Mutex::new(Hop {
                window: 2,
                inflight: 0,
                peak_window: 2,
            }),
            Condvar::new(),
        ));
        let sender = {
            let hop = Arc::clone(&hop);
            thread::spawn(move || {
                let (m, cv) = &*hop;
                for _ in 0..3 {
                    let mut g = m.lock().unwrap();
                    while g.inflight >= g.window {
                        g = cv.wait(g).unwrap();
                    }
                    g.inflight += 1;
                    model_assert!(
                        g.inflight <= g.peak_window,
                        "in-flight exceeded every window ever granted"
                    );
                    drop(g);
                    cv.notify_all();
                }
            })
        };
        let receiver = {
            let hop = Arc::clone(&hop);
            thread::spawn(move || {
                let (m, cv) = &*hop;
                for _ in 0..3 {
                    let mut g = m.lock().unwrap();
                    while g.inflight == 0 {
                        g = cv.wait(g).unwrap();
                    }
                    g.inflight -= 1;
                    drop(g);
                    cv.notify_all();
                }
            })
        };
        // The tuner retunes concurrently with the transfers: shrink
        // to 1 (possibly below the live in-flight count), then grow.
        {
            let (m, cv) = &*hop;
            let mut g = m.lock().unwrap();
            g.window = 1;
            drop(g);
            cv.notify_all();
            let mut g = m.lock().unwrap();
            g.window = 3;
            g.peak_window = 3;
            drop(g);
            cv.notify_all();
        }
        sender.join().unwrap();
        receiver.join().unwrap();
        let g = hop.0.lock().unwrap();
        model_assert_eq!(g.inflight, 0);
    });
    assert_thorough(&report, "hop window retune");
}

// ---------------------------------------------------------------------------
// Seeded-bug self-tests: the checker must catch each class within the
// default preemption bound and reproduce it from the reported schedule.
// ---------------------------------------------------------------------------

/// ABBA deadlock: two threads take two locks in opposite orders.
fn two_lock_deadlock_body() {
    let a = Arc::new(Mutex::new(0u32));
    let b = Arc::new(Mutex::new(0u32));
    let h = {
        let (a, b) = (Arc::clone(&a), Arc::clone(&b));
        thread::spawn(move || {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        })
    };
    {
        let _gb = b.lock().unwrap();
        let _ga = a.lock().unwrap();
    }
    h.join().unwrap();
}

#[test]
fn seeded_two_lock_deadlock_is_caught_and_replays() {
    let report = explore(Config::new(), two_lock_deadlock_body);
    let failure = report.failure.expect("checker must find the ABBA deadlock");
    assert_eq!(failure.kind, FailureKind::Deadlock, "trace:\n{}", failure.trace);
    let replayed = replay(Config::new(), &failure.schedule, two_lock_deadlock_body)
        .expect("replaying the schedule must reproduce the failure");
    assert_eq!(replayed.kind, FailureKind::Deadlock);
}

/// Lost wakeup: the waiter tests the flag *outside* the lock, so the
/// notify can land in the window between the test and the wait — after
/// which nobody will ever signal again.
fn lost_wakeup_body() {
    let pair = Arc::new((Mutex::new(()), Condvar::new()));
    let flag = Arc::new(AtomicBool::new(false));
    let notifier = {
        let (pair, flag) = (Arc::clone(&pair), Arc::clone(&flag));
        thread::spawn(move || {
            flag.store(true, Ordering::SeqCst);
            pair.1.notify_all();
        })
    };
    if !flag.load(Ordering::SeqCst) {
        // BUG: the flag can flip (and the notify fire) right here.
        let g = pair.0.lock().unwrap();
        let _g = pair.1.wait(g).unwrap();
    }
    notifier.join().unwrap();
}

#[test]
fn seeded_lost_wakeup_is_caught_and_replays() {
    let report = explore(Config::new(), lost_wakeup_body);
    let failure = report.failure.expect("checker must find the lost wakeup");
    assert_eq!(
        failure.kind,
        FailureKind::LostWakeup,
        "trace:\n{}",
        failure.trace
    );
    let replayed = replay(Config::new(), &failure.schedule, lost_wakeup_body)
        .expect("replaying the schedule must reproduce the failure");
    assert_eq!(replayed.kind, FailureKind::LostWakeup);
}

/// Racy counter: a load/store pair is not an atomic increment; two
/// threads can both read 0 and both store 1.
fn racy_counter_body() {
    let c = Arc::new(AtomicUsize::new(0));
    let hs: Vec<_> = (0..2)
        .map(|_| {
            let c = Arc::clone(&c);
            thread::spawn(move || {
                let v = c.load(Ordering::SeqCst);
                c.store(v + 1, Ordering::SeqCst);
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    model_assert_eq!(c.load(Ordering::SeqCst), 2);
}

#[test]
fn seeded_racy_counter_is_caught_and_replays() {
    let report = explore(Config::new(), racy_counter_body);
    let failure = report.failure.expect("checker must find the lost increment");
    assert!(
        matches!(failure.kind, FailureKind::Assertion(_)),
        "expected an assertion failure, got {} — trace:\n{}",
        failure.kind,
        failure.trace
    );
    let replayed = replay(Config::new(), &failure.schedule, racy_counter_body)
        .expect("replaying the schedule must reproduce the failure");
    assert_eq!(replayed.kind, failure.kind);
}
