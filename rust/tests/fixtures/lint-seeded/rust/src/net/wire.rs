//! Seeded `spidr lint` violation (rule 3: decode paths are total).
//! Never compiled.

fn decode(buf: &[u8]) -> u32 {
    u32::from_le_bytes(buf[..4].try_into().unwrap())
}
