//! Seeded `spidr lint` violations (rules 1 and 2). This tree is the
//! CI lint gate's negative control: `spidr lint --root` here must
//! exit nonzero. Never compiled.

use std::sync::mpsc::channel;
use std::sync::{Condvar, Mutex};

fn seeded() {
    let _worker = std::thread::spawn(|| ());
    let _named = std::thread::Builder::new();
    let _t0 = std::time::Instant::now();
}
