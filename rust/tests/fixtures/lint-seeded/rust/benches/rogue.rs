//! Seeded `spidr lint` violation (rule 4: bench output goes through
//! `common::emit`). Never compiled.

fn seeded() {
    let _ = std::fs::File::create("BENCH_rogue.json");
}
