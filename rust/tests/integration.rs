//! Cross-module integration tests.
//!
//! Tests that require AOT artifacts are skipped (with a notice) until
//! `make artifacts` has run; everything else runs standalone.

use spidr::coordinator::{Engine, InferenceServer, NetworkCompiler, ServerConfig};
use spidr::dvs::binning::unbin_frames;
use spidr::dvs::flow_scene::{make_flow_scene, FlowSceneConfig};
use spidr::dvs::gesture::{make_gesture, GestureConfig};
use spidr::error::Result;
use spidr::prop::check;
use spidr::quant::Precision;
use spidr::sim::SimConfig;
use spidr::snn::layer::NeuronConfig;
use spidr::snn::network::{flow_network, gesture_network, NetworkBuilder};
use spidr::snn::spikes::SpikePlane;
use spidr::snn::tensor::Mat;
use spidr::snn::WeightBundle;

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

fn rand_weights(rows: usize, cols: usize, seed: u64, max_abs: i32) -> Mat {
    let mut rng = spidr::prop::SplitMix64::new(seed);
    let mut m = Mat::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            m.set(r, c, rng.below((2 * max_abs + 1) as u64) as i32 - max_abs);
        }
    }
    m
}

/// Simulator == reference executor across random multi-layer networks
/// (property test over topology + inputs).
#[test]
fn sim_equals_reference_over_random_networks() {
    check("sim_vs_ref", 8, |g| {
        let in_ch = 1 + g.index(3);
        let mid_ch = 2 + g.index(6);
        let h = 4 + g.index(5);
        let w = 4 + g.index(5);
        let theta = 2 + g.i32_in(0..=6);
        let leaky = g.chance(0.5);
        let net = NetworkBuilder::new("rand", Precision::W4V7, 3, (in_ch, h, w))
            .conv3x3(
                mid_ch,
                rand_weights(in_ch * 9, mid_ch, g.u64(), 7),
                NeuronConfig { theta, leak: 2, leaky, ..Default::default() },
                false,
            )
            .unwrap()
            .pool(2, 2)
            .fc(
                3,
                rand_weights(mid_ch * (h / 2) * (w / 2), 3, g.u64(), 7),
                NeuronConfig::default(),
                true,
            )
            .unwrap()
            .build()
            .unwrap();

        let frames: Vec<SpikePlane> = (0..3)
            .map(|_| {
                let mut p = SpikePlane::zeros(in_ch, h, w);
                let d = g.f64() * 0.5;
                for i in 0..p.len() {
                    if g.chance(d) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect();

        // reference
        let mut ref_state = net.init_state().unwrap();
        for f in &frames {
            net.step(f, &mut ref_state).unwrap();
        }
        // simulator
        let compiled = NetworkCompiler::compile(net, SimConfig::default()).unwrap();
        let mut sim_state = compiled.network.init_state().unwrap();
        compiled.run_clip(&frames, &mut sim_state).unwrap();

        ref_state
            .vmems
            .iter()
            .zip(&sim_state.vmems)
            .all(|(a, b)| a.as_slice() == b.as_slice())
    });
}

/// Event binning -> server -> engine roundtrip preserves clip content.
#[test]
fn server_roundtrip_preserves_frames() {
    struct Capture(Vec<Vec<SpikePlane>>);
    impl Engine for Capture {
        type Output = u64;
        fn infer(&mut self, clip: &[SpikePlane]) -> Result<u64> {
            self.0.push(clip.to_vec());
            Ok(0)
        }
    }
    let cfg = GestureConfig { height: 16, width: 16, timesteps: 4, noise_rate: 0.02 };
    let clip = make_gesture(2, 5, &cfg);
    let events = unbin_frames(&clip.frames, 1000);
    let server = InferenceServer::new(ServerConfig {
        height: 16,
        width: 16,
        timesteps: 4,
        bin_us: 1000,
        queue_depth: 1,
        ..Default::default()
    });
    let mut engine = Capture(Vec::new());
    server.serve(vec![events], &mut engine).unwrap();
    assert_eq!(engine.0.len(), 1);
    for (a, b) in engine.0[0].iter().zip(&clip.frames) {
        assert_eq!(a.as_slice(), b.as_slice());
    }
}

/// Sparsity monotonicity across the full stack: denser input never
/// costs less energy or fewer cycles.
#[test]
fn energy_monotone_in_density() {
    let net = NetworkBuilder::new("mono", Precision::W4V7, 2, (2, 8, 8))
        .conv3x3(
            8,
            rand_weights(18, 8, 3, 7),
            NeuronConfig { theta: 6, ..Default::default() },
            true,
        )
        .unwrap()
        .build()
        .unwrap();
    let compiled = NetworkCompiler::compile(net, SimConfig::timing_only(Precision::W4V7)).unwrap();
    let mut prev_energy = 0.0;
    let mut prev_cycles = 0;
    for (i, d) in [0.02f64, 0.15, 0.40].iter().enumerate() {
        let frames: Vec<SpikePlane> = (0..2)
            .map(|t| {
                let mut rng = spidr::prop::SplitMix64::new(60 + t);
                let mut p = SpikePlane::zeros(2, 8, 8);
                for j in 0..p.len() {
                    if rng.chance(*d) {
                        p.as_mut_slice()[j] = 1;
                    }
                }
                p
            })
            .collect();
        let mut state = compiled.network.init_state().unwrap();
        let report = compiled.run_clip(&frames, &mut state).unwrap();
        if i > 0 {
            assert!(report.total.energy.total() >= prev_energy);
            assert!(report.total.cycles >= prev_cycles);
        }
        prev_energy = report.total.energy.total();
        prev_cycles = report.total.cycles;
    }
}

/// Golden PJRT model == cycle simulator, bit for bit, on the trained
/// gesture artifact (the end-to-end three-layer contract).
#[test]
fn golden_model_matches_simulator_gesture() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use spidr::runtime::{ArtifactStore, GoldenModel};
    let wb = 4u32;
    let mut store = ArtifactStore::open("artifacts").unwrap();
    let mut golden = GoldenModel::new(&store, "gesture_w4").unwrap();
    let (_, h, w) = golden.frame_shape();
    let cfg = GestureConfig { height: h, width: w, timesteps: 3, noise_rate: 0.01 };
    let clip = make_gesture(5, 77, &cfg);
    golden.run_clip(&mut store, &clip.frames).unwrap();

    let p = Precision::from_weight_bits(wb).unwrap();
    let bundle = WeightBundle::load(store.swb_path("gesture", wb)).unwrap();
    let net = gesture_network(&bundle, p, h, w, 3).unwrap();
    let compiled = NetworkCompiler::compile(net, SimConfig::default()).unwrap();
    let mut state = compiled.network.init_state().unwrap();
    compiled.run_clip(&clip.frames, &mut state).unwrap();

    for (i, sim_vmem) in state.vmems.iter().enumerate() {
        assert_eq!(
            sim_vmem.as_slice(),
            golden.vmem(i),
            "layer {i} Vmem diverged between PJRT golden model and simulator"
        );
    }
}

/// Same contract on the flow artifact at 6-bit.
#[test]
fn golden_model_matches_simulator_flow() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use spidr::runtime::{ArtifactStore, GoldenModel};
    let wb = 6u32;
    let mut store = ArtifactStore::open("artifacts").unwrap();
    let mut golden = GoldenModel::new(&store, "flow_w6").unwrap();
    let (_, h, w) = golden.frame_shape();
    let scene = make_flow_scene(88, &FlowSceneConfig {
        height: h,
        width: w,
        timesteps: 3,
        ..Default::default()
    });
    golden.run_clip(&mut store, &scene.frames).unwrap();

    let p = Precision::from_weight_bits(wb).unwrap();
    let bundle = WeightBundle::load(store.swb_path("flow", wb)).unwrap();
    let net = flow_network(&bundle, p, h, w, 3).unwrap();
    let compiled = NetworkCompiler::compile(net, SimConfig::default()).unwrap();
    let mut state = compiled.network.init_state().unwrap();
    compiled.run_clip(&scene.frames, &mut state).unwrap();

    assert_eq!(
        state.vmems.last().unwrap().as_slice(),
        &golden.out_acc[..],
        "flow output accumulator diverged"
    );
}

/// End-to-end trace constellation (DESIGN.md §Observability): a
/// replicated loopback constellation served under tracing must yield
/// ONE trace in which the coordinator's `clip` spans and the shard
/// hosts' wire-flushed `shard_step` spans carry the same clip trace
/// ids, a severed replica leaves a `failover` instant on the clip that
/// absorbed it, and the Chrome export is well-formed. Also audits the
/// disabled fast path across the whole distributed stack: a serve with
/// tracing off takes zero timestamps.
///
/// Uses the process-global tracer, so all phases stay in this one
/// sequential test; assertions filter by the trace ids minted here
/// (other tests in this binary may mint and record their own).
#[test]
fn distributed_loopback_trace_joins_coordinator_and_shards() {
    use spidr::net::{DistributedConfig, DistributedEngine};
    use spidr::obs::trace::{self, SpanKind};
    use spidr::snn::network::{demo_pipeline_network, Network};

    const TIMESTEPS: usize = 6;
    fn random_clip(net: &Network, seed: u64) -> Vec<SpikePlane> {
        let (c, h, w) = net.layers[0].in_shape;
        let mut rng = spidr::prop::SplitMix64::new(seed);
        (0..TIMESTEPS)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if rng.chance(0.2) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    let tr = trace::tracer();
    let net = demo_pipeline_network(TIMESTEPS).unwrap();
    let clips: Vec<Vec<SpikePlane>> = (0..4).map(|i| random_clip(&net, 90 + i)).collect();

    // Phase 1 — tracing disabled: the full distributed path (connect,
    // relay, drain) takes zero timestamps. No other test in this
    // binary enables the tracer, so the audit counter is quiet.
    tr.disable();
    {
        let mut engine =
            DistributedEngine::loopback(net.clone(), &DistributedConfig::replicated(2, 2))
                .unwrap();
        let stamps0 = tr.stamps();
        engine.infer(&clips[0]).unwrap();
        assert_eq!(
            tr.stamps() - stamps0,
            0,
            "a disabled tracer must take zero timestamps across the distributed serve"
        );
    }

    // Phase 2 — tracing on: connect (trace-sync clock estimate), one
    // trace per clip, replica 0 of every hop severed mid-stream.
    tr.enable(1);
    let mut engine =
        DistributedEngine::loopback(net.clone(), &DistributedConfig::replicated(2, 2)).unwrap();
    let kill_at = clips.len() / 2;
    let mut minted = Vec::new();
    for (i, clip) in clips.iter().enumerate() {
        if i == kill_at {
            for hop in 0..engine.groups().len() {
                engine.sever_replica(hop, 0).unwrap();
            }
        }
        let t = tr.mint();
        minted.push(t);
        let _bind = trace::bind(t);
        let _span = trace::span("clip");
        engine.infer(clip).unwrap();
    }
    assert!(engine.failovers() > 0, "the severed replica must fail over");

    let events = tr.snapshot_events();
    for &t in &minted {
        let mine: Vec<_> = events.iter().filter(|e| e.trace == t.0).collect();
        assert!(
            mine.iter()
                .any(|e| e.name.as_str() == "clip" && e.pid.is_none()),
            "coordinator root span missing for trace {}",
            t.0
        );
        assert!(
            mine.iter()
                .any(|e| e.name.as_str() == "hop" && e.pid.is_none()),
            "coordinator hop span missing for trace {}",
            t.0
        );
        assert!(
            mine.iter().any(|e| {
                e.name.as_str() == "shard_step"
                    && e.pid.as_deref().is_some_and(|p| p.starts_with("shard-"))
            }),
            "shard-process spans missing for trace {} — wire propagation broke",
            t.0
        );
    }
    let failover_clip = minted[kill_at];
    assert!(
        events.iter().any(|e| {
            e.trace == failover_clip.0
                && e.name.as_str() == "failover"
                && e.kind == SpanKind::Instant
        }),
        "the absorbed failover must leave an instant event on clip {}",
        failover_clip.0
    );

    // The export is one well-formed Chrome trace naming both processes.
    let json = tr.to_chrome_json();
    assert!(json.starts_with("{\"traceEvents\":[") && json.ends_with("]}"));
    assert!(json.contains("\"ph\":\"X\"") && json.contains("\"ph\":\"i\""));
    assert!(
        json.contains("\"name\":\"shard-"),
        "export must name the shard processes"
    );

    tr.disable();
}

/// The gesture artifact actually classifies synthetic gestures above
/// chance (end-to-end quality gate; exact accuracy lives in Fig. 16).
#[test]
fn golden_gesture_classifies_above_chance() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    use spidr::runtime::{ArtifactStore, GoldenModel};
    let mut store = ArtifactStore::open("artifacts").unwrap();
    let mut golden = GoldenModel::new(&store, "gesture_w8").unwrap();
    let (_, h, w) = golden.frame_shape();
    let cfg = GestureConfig {
        height: h,
        width: w,
        timesteps: golden.timesteps,
        noise_rate: 0.008,
    };
    let clips = 11usize;
    let mut correct = 0;
    for i in 0..clips {
        let label = i % 11;
        let clip = make_gesture(label, 500_000 + i as u64, &cfg);
        golden.run_clip(&mut store, &clip.frames).unwrap();
        correct += usize::from(golden.argmax() == label);
    }
    // The synthetic-gesture task is hard for this tiny Table-II net
    // (see EXPERIMENTS.md §Fig16); this is a sanity gate, not the
    // accuracy measurement: the model must not be degenerate (all-one-
    // class predictions score 1/11 here by construction).
    assert!(correct >= 1, "accuracy {correct}/{clips}: degenerate model");
}
