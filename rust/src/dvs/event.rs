//! Address-event representation primitives.

/// Event polarity: intensity increase (ON) or decrease (OFF).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Polarity {
    /// Intensity increased.
    On,
    /// Intensity decreased.
    Off,
}

impl Polarity {
    /// Channel index in the 2-channel frame layout (ON = 0, OFF = 1).
    pub fn channel(self) -> usize {
        match self {
            Polarity::On => 0,
            Polarity::Off => 1,
        }
    }

    /// Inverse of [`Polarity::channel`].
    pub fn from_channel(c: usize) -> Self {
        if c == 0 {
            Polarity::On
        } else {
            Polarity::Off
        }
    }
}

/// One DVS event: pixel address, polarity, timestamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Pixel row.
    pub y: u16,
    /// Pixel column.
    pub x: u16,
    /// Polarity.
    pub polarity: Polarity,
    /// Timestamp in microseconds.
    pub t_us: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polarity_channel_roundtrip() {
        assert_eq!(Polarity::On.channel(), 0);
        assert_eq!(Polarity::Off.channel(), 1);
        assert_eq!(Polarity::from_channel(0), Polarity::On);
        assert_eq!(Polarity::from_channel(1), Polarity::Off);
    }
}
