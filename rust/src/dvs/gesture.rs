//! Synthetic DVS gesture generator (mirror of `data.make_gesture`).
//!
//! Eleven parametric motion classes of a bright "arm" segment orbiting
//! the image center; events fire on temporal contrast between rendered
//! sub-frames (ON where intensity rises, OFF where it falls), plus
//! uniform background noise. The same splitmix64 stream as the Python
//! generator, so frames agree across languages (up to last-ulp libm
//! differences at mask boundaries, < 0.1 % of pixels).

use crate::prop::SplitMix64;
use crate::snn::spikes::SpikePlane;

/// Number of gesture classes (mirrors IBM DVS Gesture's 11).
pub const NUM_GESTURE_CLASSES: usize = 11;

/// Generator parameters.
#[derive(Debug, Clone, Copy)]
pub struct GestureConfig {
    /// Frame height.
    pub height: usize,
    /// Frame width.
    pub width: usize,
    /// Timesteps per clip.
    pub timesteps: usize,
    /// Per-pixel background noise probability.
    pub noise_rate: f64,
}

impl Default for GestureConfig {
    fn default() -> Self {
        GestureConfig {
            height: 64,
            width: 64,
            timesteps: 20,
            noise_rate: 0.008,
        }
    }
}

/// One generated clip: frames `(T)` of `(2, H, W)` planes plus label.
#[derive(Debug, Clone)]
pub struct GestureClip {
    /// Event frames, one per timestep.
    pub frames: Vec<SpikePlane>,
    /// Class label in `[0, NUM_GESTURE_CLASSES)`.
    pub label: usize,
}

struct ArmParams {
    cy: f64,
    cx: f64,
    direction: f64,
    omega: f64,
    radius0: f64,
    wobble: f64,
    phase: f64,
    arm_len: f64,
    thickness: f64,
}

fn render(p: &ArmParams, t: f64, h: usize, w: usize, out: &mut [f64]) {
    let ang = p.phase + p.direction * p.omega * t;
    let r = p.radius0 * (1.0 + p.wobble * (0.5 * t + p.phase).sin());
    let bx = p.cx + r * ang.cos();
    let by = p.cy + r * ang.sin();
    let ex = bx + p.arm_len * (ang + 1.2).cos();
    let ey = by + p.arm_len * (ang + 1.2).sin();
    let dx = ex - bx;
    let dy = ey - by;
    let seg_len2 = dx * dx + dy * dy + 1e-9;
    for y in 0..h {
        for x in 0..w {
            let (xf, yf) = (x as f64, y as f64);
            let tproj =
                (((xf - bx) * dx + (yf - by) * dy) / seg_len2).clamp(0.0, 1.0);
            let px = bx + tproj * dx;
            let py = by + tproj * dy;
            let dist = ((xf - px).powi(2) + (yf - py).powi(2)).sqrt();
            out[y * w + x] = if dist < p.thickness { 1.0 } else { 0.0 };
        }
    }
}

/// Generate one clip (same parameterization as the Python generator).
pub fn make_gesture(label: usize, seed: u64, cfg: &GestureConfig) -> GestureClip {
    assert!(label < NUM_GESTURE_CLASSES, "label {label} out of range");
    let (h, w, timesteps) = (cfg.height, cfg.width, cfg.timesteps);
    let mut rng = SplitMix64::new(
        (seed << 8) ^ (label as u64).wrapping_mul(0x9E37) ^ 0xD5,
    );
    // Classes are separable both spatially (class-specific orbit
    // center) and temporally (direction by parity) — mirror of
    // python/compile/data.py.
    let min_hw = h.min(w) as f64;
    let class_ang = 6.28318 * label as f64 / NUM_GESTURE_CLASSES as f64;
    let params = ArmParams {
        cy: h as f64 / 2.0 + 0.26 * min_hw * class_ang.sin(),
        cx: w as f64 / 2.0 + 0.26 * min_hw * class_ang.cos(),
        direction: if label % 2 == 0 { 1.0 } else { -1.0 },
        omega: 0.30 + 0.06 * (label % 3) as f64,
        radius0: 0.14 * min_hw,
        wobble: 0.0,
        phase: rng.uniform(0.0, 6.28318),
        arm_len: 0.22 * min_hw,
        thickness: 2.2,
    };

    let mut frames: Vec<SpikePlane> =
        (0..timesteps).map(|_| SpikePlane::zeros(2, h, w)).collect();
    let mut prev = vec![0.0f64; h * w];
    let mut cur = vec![0.0f64; h * w];
    render(&params, -1.0, h, w, &mut prev);
    for (t, frame) in frames.iter_mut().enumerate() {
        render(&params, t as f64, h, w, &mut cur);
        for y in 0..h {
            for x in 0..w {
                let diff = cur[y * w + x] - prev[y * w + x];
                if diff > 0.5 {
                    frame.set(0, y, x, 1);
                } else if diff < -0.5 {
                    frame.set(1, y, x, 1);
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    // Background noise: identical (t, c, y, x) consumption order.
    for frame in frames.iter_mut() {
        for c in 0..2 {
            for y in 0..h {
                for x in 0..w {
                    if rng.chance(cfg.noise_rate) {
                        frame.set(c, y, x, 1);
                    }
                }
            }
        }
    }
    GestureClip { frames, label }
}

/// Generate a labeled batch with the Python `gesture_batch` seeding.
pub fn gesture_batch(
    num: usize,
    seed: u64,
    cfg: &GestureConfig,
) -> Vec<GestureClip> {
    (0..num)
        .map(|i| {
            let label = (seed as usize + i) % NUM_GESTURE_CLASSES;
            make_gesture(label, seed * 1000 + i as u64, cfg)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> GestureConfig {
        GestureConfig {
            height: 32,
            width: 32,
            timesteps: 6,
            noise_rate: 0.01,
        }
    }

    #[test]
    fn deterministic() {
        let a = make_gesture(3, 11, &small());
        let b = make_gesture(3, 11, &small());
        for (fa, fb) in a.frames.iter().zip(&b.frames) {
            assert_eq!(fa.as_slice(), fb.as_slice());
        }
    }

    #[test]
    fn classes_differ() {
        let a = make_gesture(0, 5, &small());
        let b = make_gesture(1, 5, &small());
        assert!(a
            .frames
            .iter()
            .zip(&b.frames)
            .any(|(x, y)| x.as_slice() != y.as_slice()));
    }

    #[test]
    fn binary_and_sparse() {
        let clip = make_gesture(4, 9, &GestureConfig::default());
        let mut total = 0u64;
        let mut cells = 0u64;
        for f in &clip.frames {
            assert!(f.as_slice().iter().all(|&v| v <= 1));
            total += f.count_spikes();
            cells += f.len() as u64;
        }
        let density = total as f64 / cells as f64;
        assert!(density > 0.001 && density < 0.15, "density {density}");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn label_validated() {
        make_gesture(NUM_GESTURE_CLASSES, 0, &small());
    }

    #[test]
    fn batch_labels_cycle() {
        let batch = gesture_batch(13, 1, &small());
        assert_eq!(batch[0].label, 1);
        assert_eq!(batch[10].label, 0);
        assert_eq!(batch[12].label, 2);
    }
}
