//! Synthetic driving-scene flow generator (mirror of
//! `data.make_flow_scene`): a field of Gaussian blobs under rigid
//! translation plus weak expansion, with analytic dense ground-truth
//! flow. Drives the *low*-sparsity regime of Fig. 5 (the flow net's
//! second layer sees 60–75 % sparsity in the paper).

use crate::prop::SplitMix64;
use crate::snn::spikes::SpikePlane;

/// Generator parameters.
#[derive(Debug, Clone, Copy)]
pub struct FlowSceneConfig {
    /// Frame height.
    pub height: usize,
    /// Frame width.
    pub width: usize,
    /// Timesteps per clip.
    pub timesteps: usize,
    /// Number of Gaussian blobs.
    pub num_blobs: usize,
    /// Per-pixel background noise probability.
    pub noise_rate: f64,
}

impl Default for FlowSceneConfig {
    fn default() -> Self {
        FlowSceneConfig {
            height: 48,
            width: 64,
            timesteps: 10,
            num_blobs: 24,
            noise_rate: 0.005,
        }
    }
}

/// One generated clip with dense ground truth.
#[derive(Debug, Clone)]
pub struct FlowScene {
    /// Event frames, one per timestep.
    pub frames: Vec<SpikePlane>,
    /// Ground-truth flow `u` (x-displacement / timestep), `h*w` row-major.
    pub flow_u: Vec<f32>,
    /// Ground-truth flow `v` (y-displacement / timestep).
    pub flow_v: Vec<f32>,
}

struct Blob {
    by: f64,
    bx: f64,
    sigma: f64,
    amp: f64,
}

#[allow(clippy::too_many_arguments)]
fn render(
    blobs: &[Blob],
    t: f64,
    h: usize,
    w: usize,
    cy: f64,
    cx: f64,
    vx: f64,
    vy: f64,
    expand: f64,
    out: &mut [f64],
) {
    out.fill(0.0);
    let s = 1.0 + expand * t;
    for b in blobs {
        let py = cy + (b.by - cy) * s + vy * t;
        let px = cx + (b.bx - cx) * s + vx * t;
        let inv2s2 = 1.0 / (2.0 * b.sigma * b.sigma);
        for y in 0..h {
            for x in 0..w {
                let d2 = (y as f64 - py).powi(2) + (x as f64 - px).powi(2);
                out[y * w + x] += b.amp * (-d2 * inv2s2).exp();
            }
        }
    }
}

/// Generate one clip (same parameterization as the Python generator).
pub fn make_flow_scene(seed: u64, cfg: &FlowSceneConfig) -> FlowScene {
    let (h, w, timesteps) = (cfg.height, cfg.width, cfg.timesteps);
    let mut rng = SplitMix64::new((seed << 8) ^ 0xF10);
    let vx = rng.uniform(-1.5, 1.5);
    let vy = rng.uniform(-1.0, 1.0);
    let expand = rng.uniform(0.0, 0.008);
    let cy = h as f64 / 2.0;
    let cx = w as f64 / 2.0;
    let blobs: Vec<Blob> = (0..cfg.num_blobs)
        .map(|_| Blob {
            by: rng.uniform(-8.0, h as f64 + 8.0),
            bx: rng.uniform(-8.0, w as f64 + 8.0),
            sigma: rng.uniform(1.2, 3.0),
            amp: rng.uniform(0.5, 1.0),
        })
        .collect();

    let thresh = 0.08;
    let mut frames: Vec<SpikePlane> =
        (0..timesteps).map(|_| SpikePlane::zeros(2, h, w)).collect();
    let mut prev = vec![0.0f64; h * w];
    let mut cur = vec![0.0f64; h * w];
    render(&blobs, -1.0, h, w, cy, cx, vx, vy, expand, &mut prev);
    for (t, frame) in frames.iter_mut().enumerate() {
        render(&blobs, t as f64, h, w, cy, cx, vx, vy, expand, &mut cur);
        for y in 0..h {
            for x in 0..w {
                let diff = cur[y * w + x] - prev[y * w + x];
                if diff > thresh {
                    frame.set(0, y, x, 1);
                } else if diff < -thresh {
                    frame.set(1, y, x, 1);
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    for frame in frames.iter_mut() {
        for c in 0..2 {
            for y in 0..h {
                for x in 0..w {
                    if rng.chance(cfg.noise_rate) {
                        frame.set(c, y, x, 1);
                    }
                }
            }
        }
    }

    let mut flow_u = vec![0.0f32; h * w];
    let mut flow_v = vec![0.0f32; h * w];
    for y in 0..h {
        for x in 0..w {
            flow_u[y * w + x] = (vx + expand * (x as f64 - cx)) as f32;
            flow_v[y * w + x] = (vy + expand * (y as f64 - cy)) as f32;
        }
    }
    FlowScene {
        frames,
        flow_u,
        flow_v,
    }
}

/// Average endpoint error between a predicted flow field and the clip's
/// ground truth (`pred_*` are `h*w` row-major, in pixels/timestep).
pub fn average_endpoint_error(
    scene: &FlowScene,
    pred_u: &[f32],
    pred_v: &[f32],
) -> f64 {
    assert_eq!(pred_u.len(), scene.flow_u.len());
    assert_eq!(pred_v.len(), scene.flow_v.len());
    let n = pred_u.len() as f64;
    scene
        .flow_u
        .iter()
        .zip(&scene.flow_v)
        .zip(pred_u.iter().zip(pred_v))
        .map(|((gu, gv), (pu, pv))| {
            (((gu - pu) as f64).powi(2) + ((gv - pv) as f64).powi(2)).sqrt()
        })
        .sum::<f64>()
        / n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> FlowSceneConfig {
        FlowSceneConfig {
            height: 24,
            width: 32,
            timesteps: 5,
            num_blobs: 12,
            noise_rate: 0.005,
        }
    }

    #[test]
    fn deterministic() {
        let a = make_flow_scene(7, &small());
        let b = make_flow_scene(7, &small());
        for (fa, fb) in a.frames.iter().zip(&b.frames) {
            assert_eq!(fa.as_slice(), fb.as_slice());
        }
        assert_eq!(a.flow_u, b.flow_u);
    }

    #[test]
    fn has_motion_events_and_flow() {
        let s = make_flow_scene(5, &small());
        let spikes: u64 = s.frames[1..].iter().map(|f| f.count_spikes()).sum();
        assert!(spikes > 0);
        let max_mag = s
            .flow_u
            .iter()
            .zip(&s.flow_v)
            .map(|(u, v)| (u * u + v * v).sqrt())
            .fold(0.0f32, f32::max);
        assert!(max_mag > 0.1);
    }

    #[test]
    fn denser_than_gesture() {
        use crate::dvs::gesture::{make_gesture, GestureConfig};
        let f = make_flow_scene(2, &FlowSceneConfig {
            height: 48,
            width: 64,
            timesteps: 10,
            ..Default::default()
        });
        let g = make_gesture(1, 2, &GestureConfig {
            height: 48,
            width: 64,
            timesteps: 10,
            noise_rate: 0.01,
        });
        let fd: f64 = f.frames.iter().map(|p| p.density()).sum::<f64>() / 10.0;
        let gd: f64 = g.frames.iter().map(|p| p.density()).sum::<f64>() / 10.0;
        assert!(fd > gd, "flow density {fd} <= gesture density {gd}");
    }

    #[test]
    fn aee_zero_for_perfect_prediction() {
        let s = make_flow_scene(3, &small());
        let aee = average_endpoint_error(&s, &s.flow_u, &s.flow_v);
        assert!(aee < 1e-9);
    }

    #[test]
    fn aee_positive_for_zero_prediction() {
        let s = make_flow_scene(3, &small());
        let z = vec![0.0f32; s.flow_u.len()];
        assert!(average_endpoint_error(&s, &z, &z) > 0.0);
    }
}
