//! Event-stream to spike-frame binning.
//!
//! A DVS front end delivers an asynchronous event stream; SpiDR's
//! IFmem stores raw (uncompressed) binary frames per timestep. This
//! module bins timestamped events into fixed-width timestep frames —
//! the ingestion step of the streaming coordinator.

use crate::dvs::event::Event;
use crate::snn::spikes::SpikePlane;

/// Bin events into `timesteps` frames of `(2, height, width)`.
///
/// Events with `t_us >= timesteps * bin_us` are dropped (they belong
/// to the next window); multiple events on one (pixel, polarity) in a
/// bin collapse to a single spike, like a real binary frame buffer.
pub fn bin_events(
    events: &[Event],
    height: usize,
    width: usize,
    timesteps: usize,
    bin_us: u32,
) -> Vec<SpikePlane> {
    let mut frames: Vec<SpikePlane> = (0..timesteps)
        .map(|_| SpikePlane::zeros(2, height, width))
        .collect();
    for e in events {
        let t = (e.t_us / bin_us) as usize;
        if t >= timesteps || e.y as usize >= height || e.x as usize >= width {
            continue;
        }
        frames[t].set(e.polarity.channel(), e.y as usize, e.x as usize, 1);
    }
    frames
}

/// Flatten spike frames back into a sorted event stream (one event per
/// set cell, timestamped at the bin start) — used by tests and the AER
/// baseline.
pub fn unbin_frames(frames: &[SpikePlane], bin_us: u32) -> Vec<Event> {
    use crate::dvs::event::Polarity;
    let mut events = Vec::new();
    for (t, f) in frames.iter().enumerate() {
        for c in 0..f.c {
            for y in 0..f.h {
                for x in 0..f.w {
                    if f.get(c, y, x) != 0 {
                        events.push(Event {
                            y: y as u16,
                            x: x as u16,
                            polarity: Polarity::from_channel(c),
                            t_us: t as u32 * bin_us,
                        });
                    }
                }
            }
        }
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dvs::event::Polarity;

    #[test]
    fn bins_by_timestamp() {
        let events = [
            Event { y: 1, x: 2, polarity: Polarity::On, t_us: 0 },
            Event { y: 1, x: 2, polarity: Polarity::Off, t_us: 1500 },
            Event { y: 0, x: 0, polarity: Polarity::On, t_us: 999 },
        ];
        let frames = bin_events(&events, 4, 4, 2, 1000);
        assert_eq!(frames[0].get(0, 1, 2), 1);
        assert_eq!(frames[0].get(0, 0, 0), 1);
        assert_eq!(frames[1].get(1, 1, 2), 1);
        assert_eq!(frames[1].get(0, 1, 2), 0);
    }

    #[test]
    fn duplicate_events_collapse() {
        let events = [
            Event { y: 0, x: 0, polarity: Polarity::On, t_us: 10 },
            Event { y: 0, x: 0, polarity: Polarity::On, t_us: 20 },
        ];
        let frames = bin_events(&events, 2, 2, 1, 1000);
        assert_eq!(frames[0].count_spikes(), 1);
    }

    #[test]
    fn out_of_window_and_bounds_dropped() {
        let events = [
            Event { y: 0, x: 0, polarity: Polarity::On, t_us: 5000 },
            Event { y: 9, x: 0, polarity: Polarity::On, t_us: 0 },
        ];
        let frames = bin_events(&events, 2, 2, 2, 1000);
        assert_eq!(frames[0].count_spikes() + frames[1].count_spikes(), 0);
    }

    #[test]
    fn roundtrip_through_unbin() {
        let events = [
            Event { y: 1, x: 1, polarity: Polarity::On, t_us: 0 },
            Event { y: 0, x: 1, polarity: Polarity::Off, t_us: 1000 },
        ];
        let frames = bin_events(&events, 2, 2, 2, 1000);
        let back = unbin_frames(&frames, 1000);
        assert_eq!(back.len(), 2);
        let frames2 = bin_events(&back, 2, 2, 2, 1000);
        for (a, b) in frames.iter().zip(&frames2) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
    }
}
