//! Address-event representation (AER) codec.
//!
//! The paper's Fig. 4 studies the cost of AER-encoding layer inputs:
//! each event carries an explicit address of `ceil(log2(C·H·W))` bits,
//! so AER beats a raw bitmap only above a sparsity crossover
//! (~94.7 % for the example layer). This module implements the codec
//! and the bit-cost accounting used by the Fig. 4 bench and the AER
//! baseline pipeline.

use crate::snn::spikes::SpikePlane;

/// Bits per AER event address for a `(c, h, w)` layer input.
pub fn aer_address_bits(c: usize, h: usize, w: usize) -> u32 {
    let cells = (c * h * w) as u64;
    if cells <= 1 {
        return 1;
    }
    64 - (cells - 1).leading_zeros()
}

/// Fixed per-event overhead bits (timestamp share + handshake), the
/// "protocol tax" of asynchronous AER links.
pub const AER_BITS_PER_EVENT: u32 = 4;

/// An AER-encoded spike plane: a list of flat cell addresses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AerPacket {
    /// Flat addresses (channel-major, same layout as `SpikePlane`).
    pub addresses: Vec<u32>,
    /// Source plane shape.
    pub shape: (usize, usize, usize),
}

impl AerPacket {
    /// Total encoded size in bits (address + protocol overhead per event).
    pub fn size_bits(&self) -> u64 {
        let (c, h, w) = self.shape;
        self.addresses.len() as u64
            * (aer_address_bits(c, h, w) + AER_BITS_PER_EVENT) as u64
    }

    /// Raw-bitmap size of the same plane in bits.
    pub fn bitmap_bits(&self) -> u64 {
        let (c, h, w) = self.shape;
        (c * h * w) as u64
    }
}

/// Encode a spike plane to AER.
pub fn aer_encode(plane: &SpikePlane) -> AerPacket {
    let mut addresses = Vec::new();
    for (i, &v) in plane.as_slice().iter().enumerate() {
        if v != 0 {
            addresses.push(i as u32);
        }
    }
    AerPacket {
        addresses,
        shape: plane.shape(),
    }
}

/// Decode an AER packet back to a spike plane.
pub fn aer_decode(packet: &AerPacket) -> SpikePlane {
    let (c, h, w) = packet.shape;
    let mut plane = SpikePlane::zeros(c, h, w);
    let buf = plane.as_mut_slice();
    for &a in &packet.addresses {
        buf[a as usize] = 1;
    }
    plane
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    #[test]
    fn address_bits() {
        assert_eq!(aer_address_bits(1, 1, 2), 1);
        assert_eq!(aer_address_bits(2, 16, 16), 9);
        // paper-scale layer: 32ch x 288x384 = 3.5M cells -> 22 bits
        assert_eq!(aer_address_bits(32, 288, 384), 22);
    }

    #[test]
    fn roundtrip() {
        let mut p = SpikePlane::zeros(2, 4, 4);
        p.set(0, 1, 2, 1);
        p.set(1, 3, 3, 1);
        let enc = aer_encode(&p);
        assert_eq!(enc.addresses.len(), 2);
        assert_eq!(aer_decode(&enc), p);
    }

    #[test]
    fn prop_roundtrip_random_planes() {
        check("aer_roundtrip", 50, |g| {
            let (c, h, w) = (1 + g.index(3), 1 + g.index(8), 1 + g.index(8));
            let mut p = SpikePlane::zeros(c, h, w);
            let density = g.f64();
            for i in 0..p.len() {
                if g.chance(density) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            aer_decode(&aer_encode(&p)) == p
        });
    }

    #[test]
    fn crossover_exists() {
        // dense plane: AER bigger than bitmap; very sparse: smaller.
        let mut dense = SpikePlane::zeros(2, 16, 16);
        dense.as_mut_slice().fill(1);
        let e = aer_encode(&dense);
        assert!(e.size_bits() > e.bitmap_bits());

        let mut sparse = SpikePlane::zeros(2, 16, 16);
        sparse.set(0, 0, 0, 1);
        let e = aer_encode(&sparse);
        assert!(e.size_bits() < e.bitmap_bits());
    }
}
