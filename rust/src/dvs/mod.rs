//! Synthetic event-camera workloads and event representations.
//!
//! Substitutes for the paper's IBM DVS Gesture and DSEC-flow datasets
//! (DESIGN.md §2): parametric generators that produce binary ON/OFF
//! event frames with realistic sparsity statistics and ground truth,
//! driven by the same splitmix64 stream as `python/compile/data.py`
//! (frames are byte-identical across the two languages for equal
//! seeds — checked in `rust/tests/cross_language.rs`).

pub mod aer;
pub mod binning;
pub mod event;
pub mod flow_scene;
pub mod gesture;

pub use aer::{aer_decode, aer_encode, AerPacket, AER_BITS_PER_EVENT};
pub use binning::bin_events;
pub use event::{Event, Polarity};
pub use flow_scene::{FlowScene, FlowSceneConfig};
pub use gesture::{GestureClip, GestureConfig, NUM_GESTURE_CLASSES};
