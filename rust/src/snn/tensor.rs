//! Small dense tensor types used across the simulator and coordinator.

use crate::error::{Error, Result};

/// A dense 3-D tensor in `(C, H, W)` channel-major layout, matching the
/// JAX model's frame layout and the input loader's addressing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tensor3<T> {
    /// Channels.
    pub c: usize,
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor3<T> {
    /// Zero-filled tensor.
    pub fn zeros(c: usize, h: usize, w: usize) -> Self {
        Tensor3 {
            c,
            h,
            w,
            data: vec![T::default(); c * h * w],
        }
    }

    /// Build from a flat `(C, H, W)` row-major buffer.
    pub fn from_vec(c: usize, h: usize, w: usize, data: Vec<T>) -> Result<Self> {
        if data.len() != c * h * w {
            return Err(Error::shape(format!(
                "Tensor3 buffer length {} != {c}x{h}x{w}",
                data.len()
            )));
        }
        Ok(Tensor3 { c, h, w, data })
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Immutable element access.
    #[inline(always)]
    pub fn get(&self, c: usize, y: usize, x: usize) -> T {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Mutable element access.
    #[inline(always)]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: T) {
        debug_assert!(c < self.c && y < self.h && x < self.w);
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Flat view of the underlying buffer.
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    /// Mutable flat view.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Shape tuple `(c, h, w)`.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.c, self.h, self.w)
    }
}

/// A dense 2-D `i32` matrix in row-major layout (weights `(F, K)`,
/// Vmem banks `(M, K)`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mat {
    /// Rows.
    pub rows: usize,
    /// Columns.
    pub cols: usize,
    data: Vec<i32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    /// From a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<i32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "Mat buffer length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Immutable element access.
    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> i32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element access.
    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Immutable row view.
    #[inline(always)]
    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row view.
    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [i32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy the rectangular block `rows r0..r1 × cols c0..c1` into a
    /// new matrix using per-row slice copies (§Perf: replaces the
    /// element-wise `get`/`set` loops that used to rebuild CU weight
    /// slices on every pass).
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        debug_assert!(c0 <= c1 && c1 <= self.cols);
        let (rows, cols) = (r1 - r0, c1 - c0);
        let mut data = Vec::with_capacity(rows * cols);
        for r in r0..r1 {
            data.extend_from_slice(&self.row(r)[c0..c1]);
        }
        Mat { rows, cols, data }
    }

    /// Flat view.
    pub fn as_slice(&self) -> &[i32] {
        &self.data
    }

    /// Mutable flat view.
    pub fn as_mut_slice(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor3_indexing_is_chw() {
        let mut t = Tensor3::<u8>::zeros(2, 3, 4);
        t.set(1, 2, 3, 9);
        assert_eq!(t.get(1, 2, 3), 9);
        // channel-major flat layout
        assert_eq!(t.as_slice()[(1 * 3 + 2) * 4 + 3], 9);
    }

    #[test]
    fn tensor3_from_vec_validates() {
        assert!(Tensor3::<u8>::from_vec(1, 2, 2, vec![0; 3]).is_err());
        assert!(Tensor3::<u8>::from_vec(1, 2, 2, vec![0; 4]).is_ok());
    }

    #[test]
    fn mat_rows() {
        let mut m = Mat::zeros(3, 4);
        m.row_mut(1).copy_from_slice(&[1, 2, 3, 4]);
        assert_eq!(m.row(1), &[1, 2, 3, 4]);
        assert_eq!(m.get(1, 2), 3);
    }

    #[test]
    fn mat_from_vec_validates() {
        assert!(Mat::from_vec(2, 2, vec![1, 2, 3]).is_err());
    }

    #[test]
    fn submatrix_copies_block() {
        let mut m = Mat::zeros(4, 5);
        for r in 0..4 {
            for c in 0..5 {
                m.set(r, c, (r * 10 + c) as i32);
            }
        }
        let s = m.submatrix(1, 3, 2, 5);
        assert_eq!(s.rows, 2);
        assert_eq!(s.cols, 3);
        assert_eq!(s.as_slice(), &[12, 13, 14, 22, 23, 24]);
        // degenerate blocks are fine
        let empty = m.submatrix(2, 2, 0, 5);
        assert_eq!(empty.rows, 0);
        assert_eq!(empty.as_slice().len(), 0);
    }
}
