//! `.swb` (SpiDR weight bundle) loader.
//!
//! The bundle is written by `python/compile/aot.py::write_swb` and holds
//! the *same integers* baked into the HLO artifacts, so the cycle-level
//! simulator and the PJRT golden model compute from identical weights.
//!
//! Format (little-endian):
//! ```text
//! u32 magic = 0x53574231 ("SWB1")
//! u32 num_layers
//! per layer: u32 fan_in, u32 k, i32 theta, i32 leak, f64 scale,
//!            i32 weights[fan_in * k]     (row-major W[f][k])
//! ```

use crate::error::{Error, Result};
use crate::snn::tensor::Mat;
use std::path::Path;

/// Magic tag for the bundle format.
pub const SWB_MAGIC: u32 = 0x5357_4231;

/// One layer's parameters from a bundle.
#[derive(Debug, Clone)]
pub struct BundleLayer {
    /// Quantized weights `(F, K)`.
    pub weights: Mat,
    /// Quantized firing threshold.
    pub theta: i32,
    /// Quantized leak magnitude.
    pub leak: i32,
    /// Weight quantization scale.
    pub scale: f64,
}

/// A parsed weight bundle.
#[derive(Debug, Clone)]
pub struct WeightBundle {
    /// Per-stateful-layer parameters, in network order.
    pub layers: Vec<BundleLayer>,
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::artifact(format!(
                "swb truncated at offset {} (need {n} bytes, have {})",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

impl WeightBundle {
    /// Parse a bundle from bytes.
    pub fn parse(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { buf: bytes, pos: 0 };
        let magic = c.u32()?;
        if magic != SWB_MAGIC {
            return Err(Error::artifact(format!(
                "bad swb magic {magic:#010x} (expected {SWB_MAGIC:#010x})"
            )));
        }
        let n = c.u32()? as usize;
        if n == 0 || n > 1024 {
            return Err(Error::artifact(format!("implausible layer count {n}")));
        }
        let mut layers = Vec::with_capacity(n);
        for i in 0..n {
            let fan_in = c.u32()? as usize;
            let k = c.u32()? as usize;
            let theta = c.i32()?;
            let leak = c.i32()?;
            let scale = c.f64()?;
            if fan_in == 0 || k == 0 {
                return Err(Error::artifact(format!(
                    "layer {i}: zero dimension ({fan_in}x{k})"
                )));
            }
            let raw = c.take(4 * fan_in * k)?;
            let data: Vec<i32> = raw
                .chunks_exact(4)
                .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            layers.push(BundleLayer {
                weights: Mat::from_vec(fan_in, k, data)?,
                theta,
                leak,
                scale,
            });
        }
        if c.pos != bytes.len() {
            return Err(Error::artifact(format!(
                "swb trailing bytes: parsed {} of {}",
                c.pos,
                bytes.len()
            )));
        }
        Ok(WeightBundle { layers })
    }

    /// Load a bundle from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())?;
        Self::parse(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(layers: &[(u32, u32, i32, i32, f64, Vec<i32>)]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&SWB_MAGIC.to_le_bytes());
        out.extend_from_slice(&(layers.len() as u32).to_le_bytes());
        for (f, k, th, lk, sc, w) in layers {
            out.extend_from_slice(&f.to_le_bytes());
            out.extend_from_slice(&k.to_le_bytes());
            out.extend_from_slice(&th.to_le_bytes());
            out.extend_from_slice(&lk.to_le_bytes());
            out.extend_from_slice(&sc.to_le_bytes());
            for v in w {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn roundtrip() {
        let bytes = encode(&[
            (2, 3, 5, 1, 0.5, vec![1, 2, 3, 4, 5, 6]),
            (1, 2, 7, 0, 0.25, vec![-1, -2]),
        ]);
        let b = WeightBundle::parse(&bytes).unwrap();
        assert_eq!(b.layers.len(), 2);
        assert_eq!(b.layers[0].weights.get(1, 2), 6);
        assert_eq!(b.layers[0].theta, 5);
        assert_eq!(b.layers[1].scale, 0.25);
        assert_eq!(b.layers[1].weights.get(0, 1), -2);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = encode(&[(1, 1, 1, 0, 1.0, vec![0])]);
        bytes[0] ^= 0xFF;
        assert!(WeightBundle::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = encode(&[(2, 2, 1, 0, 1.0, vec![1, 2, 3, 4])]);
        assert!(WeightBundle::parse(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = encode(&[(1, 1, 1, 0, 1.0, vec![0])]);
        bytes.push(0);
        assert!(WeightBundle::parse(&bytes).is_err());
    }
}
