//! Networks: layer stacks, the Table-II topologies, and a functional
//! reference executor.
//!
//! The reference executor (`Network::step`) is a direct Rust mirror of
//! the JAX model's integer semantics (im2col → wrapped accumulation →
//! neuron update). It serves three purposes:
//!
//! 1. the *functional oracle* the cycle-level simulator is checked
//!    against at any resolution (the PJRT golden model covers the
//!    trained-artifact resolutions),
//! 2. fast layer-activity telemetry for Fig. 5 at full Table-II sizes,
//! 3. the functional backend of the streaming coordinator when PJRT
//!    execution is disabled.

use crate::error::{Error, Result};
use crate::quant::{wrap_to_bits, Precision};
use crate::snn::layer::{Layer, LayerKind, NeuronConfig, ResetMode};
use crate::snn::spikes::{LaneFrame, LanePlane, SpikePlane};
use crate::snn::swb::WeightBundle;
use crate::snn::tensor::Mat;

/// A complete SpiDR workload: layers + precision + timesteps.
#[derive(Debug, Clone)]
pub struct Network {
    /// Human-readable workload name ("gesture", "flow", ...).
    pub name: String,
    /// Layer stack, input to output.
    pub layers: Vec<Layer>,
    /// Precision operating point.
    pub precision: Precision,
    /// Timesteps per inference (Table II).
    pub timesteps: usize,
}

/// Mutable inference state: one Vmem bank per stateful layer.
#[derive(Debug, Clone)]
pub struct NetworkState {
    /// Per-stateful-layer Vmem banks `(M, K)`.
    pub vmems: Vec<Mat>,
}

impl NetworkState {
    /// Zero every Vmem bank in place, making the next clip an
    /// independent inference without reallocating (serving engines
    /// reset between requests; see `coordinator::server`).
    pub fn reset(&mut self) {
        for bank in &mut self.vmems {
            bank.as_mut_slice().fill(0);
        }
    }
}

/// Telemetry from one network step.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepTelemetry {
    /// Input spikes consumed per stateful layer.
    pub layer_input_spikes: Vec<u64>,
    /// Input cells per stateful layer (for sparsity).
    pub layer_input_cells: Vec<u64>,
}

/// One contiguous layer group of a network, in both index spaces: the
/// full layer stack (pool layers included) and the stateful-layer
/// order that [`NetworkState::vmems`] is indexed by. Spans come from
/// [`Network::group_spans`] and are the unit of work of both the
/// sequential per-group executor and the timestep-staged pipeline
/// (`coordinator::pipeline`, DESIGN.md §Pipeline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSpan {
    /// Full-layer index range `[lo, hi)` into [`Network::layers`].
    pub layers: (usize, usize),
    /// Stateful-layer index range `[a, b)` in `stateful_layers()`
    /// order — the group's slice of [`NetworkState::vmems`].
    pub stateful: (usize, usize),
}

impl GroupSpan {
    /// Vmem banks this span owns.
    pub fn banks(&self) -> usize {
        self.stateful.1 - self.stateful.0
    }
}

impl Network {
    /// Initialize zeroed Vmem state.
    pub fn init_state(&self) -> Result<NetworkState> {
        let mut vmems = Vec::new();
        for l in self.layers.iter().filter(|l| l.has_state()) {
            let (m, k) = l.vmem_shape()?;
            vmems.push(Mat::zeros(m, k));
        }
        Ok(NetworkState { vmems })
    }

    /// Stateful layers in order.
    pub fn stateful_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.has_state())
    }

    /// Output accumulator shape `(M, K)` of the final layer.
    pub fn out_shape(&self) -> Result<(usize, usize)> {
        self.layers
            .last()
            .ok_or_else(|| Error::config("empty network"))?
            .vmem_shape()
    }

    /// Dense-equivalent synaptic ops for one timestep (all layers).
    pub fn dense_synops_per_timestep(&self) -> u64 {
        self.layers.iter().map(|l| l.dense_synops()).sum()
    }

    /// The span covering the whole network as one group.
    pub fn full_span(&self) -> GroupSpan {
        GroupSpan {
            layers: (0, self.layers.len()),
            stateful: (0, self.stateful_layers().count()),
        }
    }

    /// Resolve contiguous **stateful-layer** group ranges (as produced
    /// by `MultiCoreScheduler::partition_layer_groups` /
    /// `plan_layer_groups`) into [`GroupSpan`]s over the full layer
    /// stack. Pool layers are attached to the group of the next
    /// stateful layer downstream of them (they run in the loader, in
    /// front of the group's first CIM layer); trailing pool layers —
    /// impossible in built networks, which end in an accumulate layer
    /// — fold into the last group. Groups must be non-empty,
    /// contiguous, and cover every stateful layer.
    pub fn group_spans(&self, groups: &[(usize, usize)]) -> Result<Vec<GroupSpan>> {
        let positions: Vec<usize> = self
            .layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.has_state())
            .map(|(i, _)| i)
            .collect();
        if groups.is_empty() {
            return Err(Error::config("no layer groups"));
        }
        if groups[0].0 != 0 || groups[groups.len() - 1].1 != positions.len() {
            return Err(Error::config(format!(
                "groups {groups:?} must cover stateful layers 0..{}",
                positions.len()
            )));
        }
        for w in groups.windows(2) {
            if w[0].1 != w[1].0 {
                return Err(Error::config(format!(
                    "groups {:?} and {:?} are not contiguous",
                    w[0], w[1]
                )));
            }
        }
        let mut spans = Vec::with_capacity(groups.len());
        let mut lo = 0usize;
        for (gi, &(a, b)) in groups.iter().enumerate() {
            if a >= b {
                return Err(Error::config(format!("empty layer group ({a}, {b})")));
            }
            let hi = if gi + 1 == groups.len() {
                self.layers.len()
            } else {
                positions[b - 1] + 1
            };
            spans.push(GroupSpan {
                layers: (lo, hi),
                stateful: (a, b),
            });
            lo = hi;
        }
        Ok(spans)
    }

    /// Allocate zeroed Vmem banks for one layer-group span — the
    /// shard-local slice of [`Network::init_state`] a remote
    /// [`ShardHost`](crate::net::shard::ShardHost) keeps resident
    /// (layer-stationary placement: weights and state stay pinned to
    /// the compute site; only spike frames cross the wire).
    pub fn span_state(&self, span: &GroupSpan) -> Result<Vec<Mat>> {
        let (lo, hi) = span.layers;
        if lo >= hi || hi > self.layers.len() {
            return Err(Error::config(format!(
                "layer span {lo}..{hi} is invalid for a {}-layer network",
                self.layers.len()
            )));
        }
        let mut vmems = Vec::with_capacity(span.banks());
        for l in self.layers[lo..hi].iter().filter(|l| l.has_state()) {
            let (m, k) = l.vmem_shape()?;
            vmems.push(Mat::zeros(m, k));
        }
        if vmems.len() != span.banks() {
            return Err(Error::config(format!(
                "span {:?} covers {} stateful layers but claims {} banks",
                span.layers,
                vmems.len(),
                span.banks()
            )));
        }
        Ok(vmems)
    }

    /// Run one timestep; returns the output accumulator view and
    /// telemetry. `frame` must match the first layer's input shape.
    pub fn step(
        &self,
        frame: &SpikePlane,
        state: &mut NetworkState,
    ) -> Result<StepTelemetry> {
        let (_, telemetry) = self.step_group(&self.full_span(), frame, &mut state.vmems)?;
        Ok(telemetry)
    }

    /// Run one timestep of one layer group: the shared functional core
    /// of [`Network::step`] (whole network as a single span), the
    /// scheduler's per-group clip executor, and the timestep-staged
    /// pipeline (DESIGN.md §Pipeline).
    ///
    /// `frame` must match the span's first layer's input shape and
    /// `vmems` must hold exactly the span's Vmem banks, in
    /// stateful-layer order. Returns the spike plane the span's last
    /// layer emits (the next group's input; zeros for an accumulate
    /// output layer) plus the span's slice of the step telemetry.
    pub fn step_group(
        &self,
        span: &GroupSpan,
        frame: &SpikePlane,
        vmems: &mut [Mat],
    ) -> Result<(SpikePlane, StepTelemetry)> {
        let (lo, hi) = span.layers;
        if lo >= hi || hi > self.layers.len() {
            return Err(Error::config(format!(
                "layer span {lo}..{hi} is invalid for a {}-layer network",
                self.layers.len()
            )));
        }
        if vmems.len() != span.banks() {
            return Err(Error::config(format!(
                "group state holds {} Vmem banks, span {:?} needs {}",
                vmems.len(),
                span.stateful,
                span.banks()
            )));
        }
        let (c0, h0, w0) = self.layers[lo].in_shape;
        if frame.shape() != (c0, h0, w0) {
            return Err(Error::shape(format!(
                "frame shape {:?} != layer {lo} input {:?}",
                frame.shape(),
                (c0, h0, w0)
            )));
        }
        let vb = self.precision.vmem_bits();
        let mut telemetry = StepTelemetry::default();
        let mut spikes = frame.clone();
        let mut si = 0;
        for layer in &self.layers[lo..hi] {
            match layer.kind {
                LayerKind::Pool => {
                    spikes = pool_step(layer, &spikes);
                }
                LayerKind::Conv | LayerKind::Fc => {
                    telemetry.layer_input_spikes.push(spikes.count_spikes());
                    telemetry.layer_input_cells.push(spikes.len() as u64);
                    spikes = stateful_step(layer, &spikes, &mut vmems[si], vb)?;
                    si += 1;
                }
            }
        }
        Ok((spikes, telemetry))
    }

    /// Run a full clip (frames indexed by timestep). Returns per-step
    /// telemetry; the output lives in the final layer's Vmem bank.
    pub fn run(
        &self,
        frames: &[SpikePlane],
        state: &mut NetworkState,
    ) -> Result<Vec<StepTelemetry>> {
        frames.iter().map(|f| self.step(f, state)).collect()
    }
}

/// im2col patch extraction for one output pixel row: visits the
/// receptive field of output pixel `(oy, ox)` in (c, dy, dx) order —
/// the layout contract shared with `python/compile/model.py`.
#[inline]
pub fn patch_value(
    input: &SpikePlane,
    layer: &Layer,
    oy: usize,
    ox: usize,
    f: usize,
) -> u8 {
    let kh = layer.kh;
    let kw = layer.kw;
    let c = f / (kh * kw);
    let rem = f % (kh * kw);
    let dy = rem / kw;
    let dx = rem % kw;
    let iy = (oy * layer.stride + dy) as isize - layer.pad as isize;
    let ix = (ox * layer.stride + dx) as isize - layer.pad as isize;
    if iy < 0 || ix < 0 || iy >= input.h as isize || ix >= input.w as isize {
        0
    } else {
        input.get(c, iy as usize, ix as usize)
    }
}

fn stateful_step(
    layer: &Layer,
    spikes_in: &SpikePlane,
    vmem: &mut Mat,
    vmem_bits: u32,
) -> Result<SpikePlane> {
    let weights = layer
        .weights
        .as_ref()
        .ok_or_else(|| Error::config("stateful layer without weights"))?;
    let (ko, ho, wo) = layer.out_shape;
    let mut out = SpikePlane::zeros(ko, ho, wo);

    // Neuron ordering contract (same as kernels/ref.py): for LIF layers
    // the leak decays the full Vmem *before* this timestep's partial
    // Vmems are integrated.
    if !layer.accumulate && layer.neuron.leaky {
        apply_leak(vmem, layer.neuron.leak);
    }

    match layer.kind {
        LayerKind::Conv => {
            let fan_in = layer.fan_in();
            for oy in 0..ho {
                for ox in 0..wo {
                    let m = oy * wo + ox;
                    // accumulate all spiking taps of this pixel's field
                    for f in 0..fan_in {
                        if patch_value(spikes_in, layer, oy, ox, f) != 0 {
                            let wrow = weights.row(f);
                            let vrow = vmem.row_mut(m);
                            for k in 0..ko {
                                vrow[k] = wrap_to_bits(vrow[k] + wrow[k], vmem_bits);
                            }
                        }
                    }
                }
            }
        }
        LayerKind::Fc => {
            // flattened (C,H,W) input, fan-in order = channel-major flat
            let flat = spikes_in.as_slice();
            let vrow = vmem.row_mut(0);
            for (f, &s) in flat.iter().enumerate() {
                if s != 0 {
                    let wrow = weights.row(f);
                    for (v, &wv) in vrow.iter_mut().zip(wrow) {
                        *v = wrap_to_bits(*v + wv, vmem_bits);
                    }
                }
            }
        }
        LayerKind::Pool => unreachable!(),
    }

    if layer.accumulate {
        // Non-spiking output layer: Vmem integrates, no spikes emitted.
        return Ok(out);
    }

    apply_fire_reset(layer, vmem, &mut out, vmem_bits);
    Ok(out)
}

fn apply_fire_reset(layer: &Layer, vmem: &mut Mat, out: &mut SpikePlane, vmem_bits: u32) {
    let NeuronConfig { theta, reset, .. } = layer.neuron;
    let (ko, _, wo) = layer.out_shape;
    for m in 0..vmem.rows {
        for k in 0..ko {
            let v = vmem.get(m, k);
            if v >= theta {
                let (y, x) = (m / wo, m % wo);
                out.set(k, y, x, 1);
                let nv = match reset {
                    ResetMode::Hard => 0,
                    ResetMode::Soft => wrap_to_bits(v - theta, vmem_bits),
                };
                vmem.set(m, k, nv.max(-theta));
            } else if v < -theta {
                // digital underflow floor: negative Vmems clamp at -theta
                vmem.set(m, k, -theta);
            }
        }
    }
}

/// Apply the LIF leak to a Vmem bank: an arithmetic-shift decay
/// (`v -= v >> leak`), the digital neuron macro's leak circuit.
pub fn apply_leak(vmem: &mut Mat, leak: i32) {
    if leak <= 0 {
        return;
    }
    let k = leak.clamp(1, 30) as u32;
    for v in vmem.as_mut_slice() {
        *v -= *v >> k;
    }
}

/// Apply a maxpool layer to a spike plane (shared by the reference
/// executor and the coordinator's compiled-network runner).
pub fn pool_step(layer: &Layer, spikes_in: &SpikePlane) -> SpikePlane {
    let (c, _, _) = layer.in_shape;
    let (_, ho, wo) = layer.out_shape;
    let mut out = SpikePlane::zeros(c, ho, wo);
    for ch in 0..c {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut v = 0u8;
                'win: for dy in 0..layer.kh {
                    for dx in 0..layer.kw {
                        let iy = oy * layer.stride + dy;
                        let ix = ox * layer.stride + dx;
                        if iy < spikes_in.h
                            && ix < spikes_in.w
                            && spikes_in.get(ch, iy, ix) != 0
                        {
                            v = 1;
                            break 'win;
                        }
                    }
                }
                out.set(ch, oy, ox, v);
            }
        }
    }
    out
}

/// Apply a maxpool layer to a lane frame: the lane-major mirror of
/// [`pool_step`]. Each `u64` word ORs the window's words, so lane `b`
/// of the result equals `pool_step` of lane `b` — 64 clips pooled in
/// one sweep (DESIGN.md §Perf).
pub fn pool_step_lanes(layer: &Layer, frame: &LaneFrame) -> LaneFrame {
    let input = frame.plane();
    let (c, _, _) = layer.in_shape;
    let (_, ho, wo) = layer.out_shape;
    let mut out = LanePlane::zeros(c, ho, wo);
    for ch in 0..c {
        for oy in 0..ho {
            for ox in 0..wo {
                let mut word = 0u64;
                for dy in 0..layer.kh {
                    for dx in 0..layer.kw {
                        let iy = oy * layer.stride + dy;
                        let ix = ox * layer.stride + dx;
                        if iy < input.h && ix < input.w {
                            word |= input.get(ch, iy, ix);
                        }
                    }
                }
                out.set(ch, oy, ox, word);
            }
        }
    }
    LaneFrame::from_plane(out, frame.lanes())
}

// ---------------------------------------------------------------------------
// Builder + Table-II topologies
// ---------------------------------------------------------------------------

/// Incremental network builder that tracks the flowing shape.
pub struct NetworkBuilder {
    name: String,
    precision: Precision,
    timesteps: usize,
    shape: (usize, usize, usize),
    layers: Vec<Layer>,
}

impl NetworkBuilder {
    /// Start a network from an input shape.
    pub fn new(
        name: impl Into<String>,
        precision: Precision,
        timesteps: usize,
        input_shape: (usize, usize, usize),
    ) -> Self {
        NetworkBuilder {
            name: name.into(),
            precision,
            timesteps,
            shape: input_shape,
            layers: Vec::new(),
        }
    }

    /// Current flowing shape.
    pub fn shape(&self) -> (usize, usize, usize) {
        self.shape
    }

    /// Append a 3x3/s1/p1 conv layer (the Table-II shape).
    pub fn conv3x3(
        mut self,
        out_ch: usize,
        weights: Mat,
        neuron: NeuronConfig,
        accumulate: bool,
    ) -> Result<Self> {
        let l = Layer::conv(self.shape, out_ch, 3, 3, 1, 1, weights, neuron, accumulate)?;
        self.shape = l.out_shape;
        self.layers.push(l);
        Ok(self)
    }

    /// Append a maxpool layer.
    pub fn pool(mut self, size: usize, stride: usize) -> Self {
        let l = Layer::pool(self.shape, size, stride);
        self.shape = l.out_shape;
        self.layers.push(l);
        self
    }

    /// Append an FC layer over the flattened shape.
    pub fn fc(
        mut self,
        out_neurons: usize,
        weights: Mat,
        neuron: NeuronConfig,
        accumulate: bool,
    ) -> Result<Self> {
        let l = Layer::fc(self.shape, out_neurons, weights, neuron, accumulate)?;
        self.shape = l.out_shape;
        self.layers.push(l);
        Ok(self)
    }

    /// Finish building.
    pub fn build(self) -> Result<Network> {
        if self.layers.is_empty() {
            return Err(Error::config("network has no layers"));
        }
        let last = self.layers.last().unwrap();
        if !last.accumulate {
            return Err(Error::config(
                "network must end in an accumulate (non-spiking output) layer",
            ));
        }
        Ok(Network {
            name: self.name,
            layers: self.layers,
            precision: self.precision,
            timesteps: self.timesteps,
        })
    }
}

/// Build the Table-II optical-flow network from a weight bundle at an
/// arbitrary input resolution (paper deploy size: 288x384, 10 steps).
pub fn flow_network(
    bundle: &WeightBundle,
    precision: Precision,
    height: usize,
    width: usize,
    timesteps: usize,
) -> Result<Network> {
    if bundle.layers.len() != 8 {
        return Err(Error::config(format!(
            "flow bundle must have 8 layers, got {}",
            bundle.layers.len()
        )));
    }
    let mut b = NetworkBuilder::new("flow", precision, timesteps, (2, height, width));
    for (i, bl) in bundle.layers.iter().enumerate() {
        let out_ch = bl.weights.cols;
        let neuron = NeuronConfig {
            theta: bl.theta,
            leak: bl.leak,
            leaky: true,
            reset: ResetMode::Soft,
        };
        b = b.conv3x3(out_ch, bl.weights.clone(), neuron, i == 7)?;
        let n = b.layers.len();
        b.layers[n - 1].weight_scale = bl.scale;
    }
    b.build()
}

/// Build the Table-II gesture network from a weight bundle (paper
/// deploy size: 64x64, 20 steps).
pub fn gesture_network(
    bundle: &WeightBundle,
    precision: Precision,
    height: usize,
    width: usize,
    timesteps: usize,
) -> Result<Network> {
    if bundle.layers.len() != 6 {
        return Err(Error::config(format!(
            "gesture bundle must have 6 layers, got {}",
            bundle.layers.len()
        )));
    }
    let mut b = NetworkBuilder::new("gesture", precision, timesteps, (2, height, width));
    for (i, bl) in bundle.layers.iter().take(5).enumerate() {
        let neuron = NeuronConfig {
            theta: bl.theta,
            leak: bl.leak,
            leaky: false,
            reset: ResetMode::Soft,
        };
        b = b.conv3x3(bl.weights.cols, bl.weights.clone(), neuron, false)?;
        let n = b.layers.len();
        b.layers[n - 1].weight_scale = bl.scale;
        // 2x2 maxpool after every two intermediate convs (i = 2, 4).
        if i == 2 || i == 4 {
            b = b.pool(2, 2);
        }
    }
    // readout maxpool (8x8, clamped to the remaining plane) then
    // FC(64, 11) — the same adaptive rule as gesture_topology() in
    // python/compile/model.py. At the Table-II 64x64 input this yields
    // a 2x2x16 = 64-input FC, exactly the paper's FC(64, 11).
    b = b.pool(8, 8);
    let fcl = &bundle.layers[5];
    let neuron = NeuronConfig {
        theta: fcl.theta,
        leak: fcl.leak,
        leaky: false,
        reset: ResetMode::Soft,
    };
    b = b.fc(fcl.weights.cols, fcl.weights.clone(), neuron, true)?;
    let n = b.layers.len();
    b.layers[n - 1].weight_scale = fcl.scale;
    b.build()
}

/// Build the synthetic serving-demo workload shared by the `serving`
/// example and the `serve_pool` bench: Conv(2→12) → pool(2×2) → fc(4)
/// on a 16×16 retina at W4V7 — small enough that one clip takes
/// milliseconds, big enough that per-clip compute dominates thread
/// setup, and with an fc fan-in (12·8·8 = 768) that still maps onto
/// the simulated core in Mode 2.
pub fn demo_serving_network(timesteps: usize) -> Result<Network> {
    let mut rng = crate::prop::SplitMix64::new(0x5E);
    let mut w1 = Mat::zeros(2 * 9, 12);
    for f in 0..18 {
        for k in 0..12 {
            w1.set(f, k, rng.below(15) as i32 - 7);
        }
    }
    let mut w2 = Mat::zeros(12 * 8 * 8, 4);
    for f in 0..(12 * 8 * 8) {
        for k in 0..4 {
            w2.set(f, k, rng.below(15) as i32 - 7);
        }
    }
    NetworkBuilder::new("serving-demo", Precision::W4V7, timesteps, (2, 16, 16))
        .conv3x3(
            12,
            w1,
            NeuronConfig {
                theta: 6,
                leak: 1,
                ..Default::default()
            },
            false,
        )?
        .pool(2, 2)
        .fc(4, w2, NeuronConfig::default(), true)?
        .build()
}

/// Build the synthetic deep workload of the `pipeline` example and the
/// `pipeline_latency` bench: four 3×3 conv stages (2→16, then three
/// 16→16) on a 24×24 retina, a 3×3 maxpool, and an FC(4) readout at
/// W4V7. Five stateful layers with three roughly comparable-cost
/// conv stages in the middle give a staged layer-group pipeline
/// (DESIGN.md §Pipeline) real headroom over sequential stepping, and
/// the FC fan-in (16·8·8 = 1024) still maps onto the simulated core
/// in Mode 2.
pub fn demo_pipeline_network(timesteps: usize) -> Result<Network> {
    let mut rng = crate::prop::SplitMix64::new(0xD1);
    let mut rand_mat = |rows: usize, cols: usize| {
        let mut m = Mat::zeros(rows, cols);
        for f in 0..rows {
            for k in 0..cols {
                m.set(f, k, rng.below(15) as i32 - 7);
            }
        }
        m
    };
    let w1 = rand_mat(2 * 9, 16);
    let w2 = rand_mat(16 * 9, 16);
    let w3 = rand_mat(16 * 9, 16);
    let w4 = rand_mat(16 * 9, 16);
    let w5 = rand_mat(16 * 8 * 8, 4);
    let lif = |theta: i32| NeuronConfig {
        theta,
        leak: 1,
        leaky: true,
        reset: ResetMode::Soft,
    };
    NetworkBuilder::new("pipeline-demo", Precision::W4V7, timesteps, (2, 24, 24))
        .conv3x3(16, w1, lif(5), false)?
        .conv3x3(16, w2, lif(8), false)?
        .conv3x3(16, w3, lif(8), false)?
        .conv3x3(16, w4, lif(8), false)?
        .pool(3, 3)
        .fc(4, w5, NeuronConfig::default(), true)?
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    fn mat_fill(rows: usize, cols: usize, f: impl Fn(usize, usize) -> i32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, f(r, c));
            }
        }
        m
    }

    fn tiny_net(accumulate_theta: i32) -> Network {
        // conv(1->2, 3x3) then fc(2*2*2 -> 3) accumulate, on 2x2 input
        let w1 = mat_fill(9, 2, |f, k| ((f + k) % 3) as i32 - 1);
        let w2 = mat_fill(8, 3, |f, k| ((f * 3 + k) % 5) as i32 - 2);
        NetworkBuilder::new("tiny", Precision::W4V7, 2, (1, 2, 2))
            .conv3x3(
                2,
                w1,
                NeuronConfig {
                    theta: accumulate_theta,
                    ..Default::default()
                },
                false,
            )
            .unwrap()
            .fc(3, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    #[test]
    fn builder_tracks_shapes() {
        let net = tiny_net(1);
        assert_eq!(net.layers[0].out_shape, (2, 2, 2));
        assert_eq!(net.out_shape().unwrap(), (1, 3));
    }

    #[test]
    fn builder_rejects_spiking_output() {
        let w1 = Mat::zeros(9, 2);
        let r = NetworkBuilder::new("bad", Precision::W4V7, 1, (1, 2, 2))
            .conv3x3(2, w1, NeuronConfig::default(), false)
            .unwrap()
            .build();
        assert!(r.is_err());
    }

    #[test]
    fn step_counts_input_spikes() {
        let net = tiny_net(1);
        let mut state = net.init_state().unwrap();
        let mut frame = SpikePlane::zeros(1, 2, 2);
        frame.set(0, 0, 0, 1);
        frame.set(0, 1, 1, 1);
        let t = net.step(&frame, &mut state).unwrap();
        assert_eq!(t.layer_input_spikes[0], 2);
        assert_eq!(t.layer_input_cells[0], 4);
    }

    #[test]
    fn zero_frame_is_inert() {
        let net = tiny_net(1);
        let mut state = net.init_state().unwrap();
        let frame = SpikePlane::zeros(1, 2, 2);
        net.step(&frame, &mut state).unwrap();
        assert!(state.vmems.iter().all(|v| v.as_slice().iter().all(|&x| x == 0)));
    }

    #[test]
    fn wrong_frame_shape_rejected() {
        let net = tiny_net(1);
        let mut state = net.init_state().unwrap();
        let frame = SpikePlane::zeros(1, 3, 3);
        assert!(net.step(&frame, &mut state).is_err());
    }

    #[test]
    fn conv_matches_manual_im2col() {
        // single conv layer, hand-checked receptive field math
        let w = mat_fill(9, 1, |f, _| f as i32);
        let net = NetworkBuilder::new("c", Precision::W8V15, 1, (1, 3, 3))
            .conv3x3(1, w, NeuronConfig { theta: 10_000, ..Default::default() }, true)
            .unwrap()
            .build()
            .unwrap();
        let mut state = net.init_state().unwrap();
        let mut frame = SpikePlane::zeros(1, 3, 3);
        frame.set(0, 1, 1, 1); // center pixel spike
        net.step(&frame, &mut state).unwrap();
        // center output pixel (1,1): tap (dy=1,dx=1) => f=4 => weight 4
        assert_eq!(state.vmems[0].get(4, 0), 4);
        // corner output pixel (0,0): sees center input at (dy=2,dx=2) => f=8
        assert_eq!(state.vmems[0].get(0, 0), 8);
    }

    #[test]
    fn accumulate_layer_integrates_across_steps() {
        let net = tiny_net(1);
        let mut state = net.init_state().unwrap();
        let mut frame = SpikePlane::zeros(1, 2, 2);
        for i in 0..4 {
            frame.set(0, i / 2, i % 2, 1);
        }
        net.step(&frame, &mut state).unwrap();
        let after1: Vec<i32> = state.vmems[1].as_slice().to_vec();
        net.step(&frame, &mut state).unwrap();
        let after2: Vec<i32> = state.vmems[1].as_slice().to_vec();
        // if layer-1 spiked identically, output accumulates monotonically
        assert_ne!(after1, vec![0, 0, 0]);
        assert_ne!(after1, after2);
    }

    #[test]
    fn prop_vmems_stay_in_range() {
        check("vmem_range", 30, |g| {
            let net = tiny_net(1 + g.i32_in(0..=5));
            let mut state = net.init_state().unwrap();
            for _ in 0..3 {
                let mut frame = SpikePlane::zeros(1, 2, 2);
                for i in 0..4 {
                    if g.chance(0.5) {
                        frame.set(0, i / 2, i % 2, 1);
                    }
                }
                net.step(&frame, &mut state).unwrap();
            }
            let p = net.precision;
            state.vmems.iter().all(|v| {
                v.as_slice()
                    .iter()
                    .all(|&x| x >= p.vmem_min() && x <= p.vmem_max())
            })
        });
    }

    #[test]
    fn full_span_covers_everything() {
        let net = tiny_net(1);
        let span = net.full_span();
        assert_eq!(span.layers, (0, 2));
        assert_eq!(span.stateful, (0, 2));
        assert_eq!(span.banks(), 2);
    }

    #[test]
    fn group_spans_attach_pool_layers_downstream() {
        // conv | pool | fc split as [(0,1), (1,2)]: the pool belongs
        // to the fc's group (it feeds the group's first CIM layer).
        let w1 = mat_fill(9, 2, |f, k| (f + k) as i32 % 3 - 1);
        let w2 = mat_fill(2, 3, |f, k| (f * 3 + k) as i32 % 5 - 2);
        let net = NetworkBuilder::new("g", Precision::W4V7, 1, (1, 2, 2))
            .conv3x3(2, w1, NeuronConfig::default(), false)
            .unwrap()
            .pool(2, 2)
            .fc(3, w2, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap();
        let spans = net.group_spans(&[(0, 1), (1, 2)]).unwrap();
        assert_eq!(
            spans,
            vec![
                GroupSpan {
                    layers: (0, 1),
                    stateful: (0, 1)
                },
                GroupSpan {
                    layers: (1, 3),
                    stateful: (1, 2)
                },
            ]
        );
        // single group = the full span
        assert_eq!(net.group_spans(&[(0, 2)]).unwrap(), vec![net.full_span()]);
    }

    #[test]
    fn group_spans_reject_bad_partitions() {
        let net = tiny_net(1);
        assert!(net.group_spans(&[]).is_err());
        assert!(net.group_spans(&[(0, 1)]).is_err(), "must cover all layers");
        assert!(net.group_spans(&[(0, 1), (1, 1), (1, 2)]).is_err(), "empty group");
        assert!(net.group_spans(&[(0, 2), (1, 2)]).is_err(), "overlap");
        assert!(net.group_spans(&[(1, 2)]).is_err(), "must start at 0");
    }

    #[test]
    fn grouped_stepping_matches_monolithic_step() {
        let net = tiny_net(2);
        let spans = net.group_spans(&[(0, 1), (1, 2)]).unwrap();

        let mut whole = net.init_state().unwrap();
        let mut grouped = net.init_state().unwrap();
        let mut rng = crate::prop::SplitMix64::new(77);
        for _ in 0..3 {
            let mut frame = SpikePlane::zeros(1, 2, 2);
            for i in 0..4 {
                if rng.chance(0.5) {
                    frame.set(0, i / 2, i % 2, 1);
                }
            }
            let tel = net.step(&frame, &mut whole).unwrap();

            let (g0, g1) = grouped.vmems.split_at_mut(1);
            let (mid, t0) = net.step_group(&spans[0], &frame, g0).unwrap();
            let (_, t1) = net.step_group(&spans[1], &mid, g1).unwrap();
            assert_eq!(
                tel.layer_input_spikes,
                [t0.layer_input_spikes, t1.layer_input_spikes].concat()
            );
            for (a, b) in whole.vmems.iter().zip(&grouped.vmems) {
                assert_eq!(a.as_slice(), b.as_slice());
            }
        }
    }

    #[test]
    fn span_state_matches_init_state_slices() {
        let net = tiny_net(1);
        let spans = net.group_spans(&[(0, 1), (1, 2)]).unwrap();
        let full = net.init_state().unwrap();
        let mut si = 0;
        for span in &spans {
            let banks = net.span_state(span).unwrap();
            assert_eq!(banks.len(), span.banks());
            for bank in &banks {
                assert_eq!(
                    (bank.rows, bank.cols),
                    (full.vmems[si].rows, full.vmems[si].cols)
                );
                si += 1;
            }
        }
        assert_eq!(si, full.vmems.len());
        // invalid spans are rejected
        let bad = GroupSpan {
            layers: (0, 9),
            stateful: (0, 1),
        };
        assert!(net.span_state(&bad).is_err());
    }

    #[test]
    fn step_group_validates_bank_count_and_shape() {
        let net = tiny_net(1);
        let mut state = net.init_state().unwrap();
        let frame = SpikePlane::zeros(1, 2, 2);
        let span = net.full_span();
        // too few banks for the span
        assert!(net.step_group(&span, &frame, &mut state.vmems[..1]).is_err());
        // wrong input shape for the second group
        let spans = net.group_spans(&[(0, 1), (1, 2)]).unwrap();
        assert!(net
            .step_group(&spans[1], &frame, &mut state.vmems[1..])
            .is_err());
    }

    #[test]
    fn demo_pipeline_network_shape() {
        let net = demo_pipeline_network(4).unwrap();
        assert_eq!(net.stateful_layers().count(), 5);
        assert_eq!(net.out_shape().unwrap(), (1, 4));
        // every layer maps onto the simulated core (Mode 2 cap)
        assert!(net.stateful_layers().all(|l| l.fan_in() <= 1152));
    }

    #[test]
    fn prop_pool_step_lanes_matches_per_lane_pool() {
        check("pool_lanes", 30, |g| {
            let layer = Layer::pool((2, 6, 6), 2, 2);
            let lanes = 1 + g.index(crate::snn::spikes::MAX_LANES);
            let planes: Vec<SpikePlane> = (0..lanes)
                .map(|_| {
                    let density = g.f64() * 0.5;
                    let mut p = SpikePlane::zeros(2, 6, 6);
                    for cell in p.as_mut_slice() {
                        if g.chance(density) {
                            *cell = 1;
                        }
                    }
                    p
                })
                .collect();
            let refs: Vec<&SpikePlane> = planes.iter().collect();
            let frame = LaneFrame::pack(&refs).unwrap();
            let pooled = pool_step_lanes(&layer, &frame);
            pooled.lanes() == lanes
                && (0..lanes).all(|b| pooled.lane(b) == pool_step(&layer, &planes[b]))
        });
    }

    #[test]
    fn reset_zeroes_state_in_place() {
        let net = tiny_net(2);
        let mut state = net.init_state().unwrap();
        for bank in &mut state.vmems {
            for v in bank.as_mut_slice() {
                *v = 5;
            }
        }
        state.reset();
        let fresh = net.init_state().unwrap();
        assert_eq!(state.vmems.len(), fresh.vmems.len());
        for (a, b) in state.vmems.iter().zip(&fresh.vmems) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
    }
}
