//! Binary spike planes and sparsity statistics.

use crate::snn::tensor::Tensor3;

/// A binary spike plane `(C, H, W)` — one timestep of one layer's input
/// or output activity.
pub type SpikePlane = Tensor3<u8>;

impl SpikePlane {
    /// Count of set spikes.
    pub fn count_spikes(&self) -> u64 {
        self.as_slice().iter().map(|&b| b as u64).sum()
    }

    /// Spike density in [0, 1].
    pub fn density(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.count_spikes() as f64 / self.len() as f64
    }

    /// Sparsity in [0, 1] (1 − density) — the paper's x-axis everywhere.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }
}

/// Streaming sparsity statistics across timesteps / layers (Fig. 5).
///
/// Fully streaming, O(1) memory: the min/max band is folded in as
/// observations arrive, so the struct stays constant-size on
/// arbitrarily long serving streams (it used to keep one `f64` per
/// observation, which grew without bound on the request path).
#[derive(Debug, Clone)]
pub struct SparsityStats {
    /// Total cells observed.
    pub cells: u64,
    /// Total spikes observed.
    pub spikes: u64,
    /// Observations folded in so far.
    observations: u64,
    /// Running minimum per-observation sparsity (densest moment).
    min: f64,
    /// Running maximum per-observation sparsity.
    max: f64,
}

impl Default for SparsityStats {
    fn default() -> Self {
        SparsityStats {
            cells: 0,
            spikes: 0,
            observations: 0,
            // fold identities, matching the previous Vec-fold behavior
            // on an empty record set
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl SparsityStats {
    /// Empty statistics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one spike plane.
    pub fn record(&mut self, plane: &SpikePlane) {
        self.record_counts(plane.count_spikes(), plane.len() as u64);
    }

    /// Record raw counts.
    pub fn record_counts(&mut self, spikes: u64, cells: u64) {
        self.spikes += spikes;
        self.cells += cells;
        if cells > 0 {
            let s = 1.0 - spikes as f64 / cells as f64;
            self.min = self.min.min(s);
            self.max = self.max.max(s);
            self.observations += 1;
        }
    }

    /// Mean sparsity over everything recorded.
    pub fn mean_sparsity(&self) -> f64 {
        if self.cells == 0 {
            return 1.0;
        }
        1.0 - self.spikes as f64 / self.cells as f64
    }

    /// Minimum per-observation sparsity (densest moment).
    ///
    /// With zero observations the running minimum is the fold identity
    /// `+inf`, which is not a sparsity and not even valid JSON once a
    /// bench emits it (`Infinity` corrupts `BENCH_*.json`); an empty
    /// band collapses to [`SparsityStats::mean_sparsity`] instead, so
    /// min/mean/max always agree on an empty stream and every band
    /// value is finite in [0, 1].
    pub fn min_sparsity(&self) -> f64 {
        if self.observations == 0 {
            return self.mean_sparsity();
        }
        self.min
    }

    /// Maximum per-observation sparsity.
    ///
    /// Like [`SparsityStats::min_sparsity`], an empty band (zero
    /// observations — the fold identity would be `−inf`) collapses to
    /// the mean-sparsity fallback so the value stays finite.
    pub fn max_sparsity(&self) -> f64 {
        if self.observations == 0 {
            return self.mean_sparsity();
        }
        self.max
    }

    /// Number of observations recorded.
    pub fn observations(&self) -> usize {
        self.observations as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_and_sparsity() {
        let mut p = SpikePlane::zeros(1, 2, 2);
        p.set(0, 0, 0, 1);
        assert_eq!(p.count_spikes(), 1);
        assert!((p.density() - 0.25).abs() < 1e-12);
        assert!((p.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn stats_aggregate() {
        let mut s = SparsityStats::new();
        s.record_counts(10, 100); // 0.90
        s.record_counts(30, 100); // 0.70
        assert!((s.mean_sparsity() - 0.80).abs() < 1e-12);
        assert!((s.min_sparsity() - 0.70).abs() < 1e-12);
        assert!((s.max_sparsity() - 0.90).abs() < 1e-12);
        assert_eq!(s.observations(), 2);
    }

    #[test]
    fn empty_stats_defaults() {
        let s = SparsityStats::new();
        assert_eq!(s.mean_sparsity(), 1.0);
        assert_eq!(s.observations(), 0);
    }

    /// Regression (ISSUE 5 headline bugfix): an empty stream used to
    /// report `min = +inf` / `max = −inf` — the raw fold identities —
    /// which serialized as `Infinity` and silently corrupted the
    /// Fig. 5 `BENCH_*.json` artifact. Empty bands must be finite,
    /// collapse to the mean, and format as strict JSON numbers.
    #[test]
    fn empty_stream_bands_are_finite_and_json_valid() {
        let mut s = SparsityStats::new();
        s.record_counts(0, 0); // zero cells: not an observation
        assert_eq!(s.observations(), 0);
        for v in [s.min_sparsity(), s.mean_sparsity(), s.max_sparsity()] {
            assert!(v.is_finite(), "empty-stream band {v} must be finite");
            assert_eq!(v, 1.0, "empty bands collapse to the mean fallback");
            // `Infinity`/`NaN` are not JSON; a finite f64's `{}` format
            // is — exactly what benches/common::emit writes per line.
            let line = format!("{{\"y\":{v}}}");
            assert!(!line.contains("inf") && !line.contains("NaN"), "{line}");
        }
        // once a real observation lands, the bands are live again
        s.record_counts(25, 100);
        assert_eq!(s.min_sparsity(), 0.75);
        assert_eq!(s.max_sparsity(), 0.75);
    }

    /// The stats stay O(1): a long stream folds into the same bands a
    /// sample vector would have produced, with no per-observation
    /// growth (zero-cell records are ignored, as before).
    #[test]
    fn long_stream_keeps_exact_bands() {
        let mut s = SparsityStats::new();
        s.record_counts(0, 0); // no cells: not an observation
        for i in 0..100_000u64 {
            // sparsity cycles through {0.90, 0.80, 0.70, 0.60}
            s.record_counts(10 + 10 * (i % 4), 100);
        }
        assert_eq!(s.observations(), 100_000);
        assert!((s.min_sparsity() - 0.60).abs() < 1e-12);
        assert!((s.max_sparsity() - 0.90).abs() < 1e-12);
        assert!((s.mean_sparsity() - 0.75).abs() < 1e-12);
    }
}
