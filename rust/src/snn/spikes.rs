//! Binary spike planes, lane-major bit-plane batches, and sparsity
//! statistics.

use crate::error::{Error, Result};
use crate::snn::bitpack;
use crate::snn::tensor::Tensor3;

/// A binary spike plane `(C, H, W)` — one timestep of one layer's input
/// or output activity.
pub type SpikePlane = Tensor3<u8>;

impl SpikePlane {
    /// Count of set spikes, via the packed-representation popcount
    /// ([`bitpack::count_set`] — equivalence-tested against the
    /// byte-wise sum it replaced).
    pub fn count_spikes(&self) -> u64 {
        bitpack::count_set(self.as_slice())
    }

    /// Spike density in [0, 1].
    pub fn density(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.count_spikes() as f64 / self.len() as f64
    }

    /// Sparsity in [0, 1] (1 − density) — the paper's x-axis everywhere.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }
}

/// A lane-major bit-plane tensor `(C, H, W)`: one `u64` word per cell,
/// bit `b` = clip `b`'s spike at that cell. The batched datapath's
/// frame layout (DESIGN.md §Perf): zero-skipping over a whole batch is
/// "skip cells whose word is 0", and per-lane activity is a popcount.
pub type LanePlane = Tensor3<u64>;

/// Maximum clips (bit-lanes) one [`LaneFrame`] can carry — the width
/// of the `u64` lane word.
pub const MAX_LANES: usize = 64;

/// One timestep of up to [`MAX_LANES`] clips, packed lane-major: a
/// [`LanePlane`] plus the number of occupied lanes. Built from per-clip
/// [`SpikePlane`]s via [`LaneFrame::pack`] / [`LaneFrame::pack_clips`];
/// individual lanes unpack back out via [`LaneFrame::lane`]
/// (round-trip property-tested below).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneFrame {
    plane: LanePlane,
    lanes: usize,
}

impl LaneFrame {
    /// Pack one plane per clip (all the same shape, at most
    /// [`MAX_LANES`] of them) into a lane frame; plane `b` lands in
    /// bit-lane `b`. Any nonzero cell normalizes to a set bit, the
    /// same contract as [`bitpack`].
    pub fn pack(planes: &[&SpikePlane]) -> Result<LaneFrame> {
        if planes.is_empty() || planes.len() > MAX_LANES {
            return Err(Error::config(format!(
                "lane frame needs 1..={MAX_LANES} planes, got {}",
                planes.len()
            )));
        }
        let (c, h, w) = planes[0].shape();
        let mut plane = LanePlane::zeros(c, h, w);
        for (b, p) in planes.iter().enumerate() {
            if p.shape() != (c, h, w) {
                return Err(Error::shape(format!(
                    "lane {b} plane shape {:?} != lane 0 shape {:?}",
                    p.shape(),
                    (c, h, w)
                )));
            }
            for (cell, &v) in plane.as_mut_slice().iter_mut().zip(p.as_slice()) {
                if v != 0 {
                    *cell |= 1 << b;
                }
            }
        }
        Ok(LaneFrame {
            plane,
            lanes: planes.len(),
        })
    }

    /// Pack a batch of whole clips (clip `b` → bit-lane `b`) into one
    /// lane frame per timestep. Every clip must have the same number
    /// of timesteps and the same frame shape.
    pub fn pack_clips(clips: &[&[SpikePlane]]) -> Result<Vec<LaneFrame>> {
        if clips.is_empty() || clips.len() > MAX_LANES {
            return Err(Error::config(format!(
                "lane batch needs 1..={MAX_LANES} clips, got {}",
                clips.len()
            )));
        }
        let timesteps = clips[0].len();
        for (b, clip) in clips.iter().enumerate() {
            if clip.len() != timesteps {
                return Err(Error::config(format!(
                    "clip {b} has {} timesteps, clip 0 has {timesteps}",
                    clip.len()
                )));
            }
        }
        (0..timesteps)
            .map(|t| {
                let planes: Vec<&SpikePlane> = clips.iter().map(|clip| &clip[t]).collect();
                LaneFrame::pack(&planes)
            })
            .collect()
    }

    /// Wrap an already lane-major plane (internal constructor for the
    /// sim datapath's outputs; `pack` is the validated public entry).
    pub(crate) fn from_plane(plane: LanePlane, lanes: usize) -> LaneFrame {
        debug_assert!(lanes >= 1 && lanes <= MAX_LANES);
        LaneFrame { plane, lanes }
    }

    /// Validated lane-major constructor for deserialization paths (the
    /// wire codec's v3 lane frames): the lane count must be in
    /// `1..=MAX_LANES` and no cell may carry a spike bit at or above it
    /// — a corrupted frame must not smuggle spikes into lanes that were
    /// never opened.
    pub fn from_plane_checked(plane: LanePlane, lanes: usize) -> Result<LaneFrame> {
        if lanes == 0 || lanes > MAX_LANES {
            return Err(Error::config(format!(
                "lane count {lanes} outside 1..={MAX_LANES}"
            )));
        }
        if lanes < MAX_LANES {
            let stray = !((1u64 << lanes) - 1);
            if plane.as_slice().iter().any(|&w| w & stray != 0) {
                return Err(Error::config(format!(
                    "lane plane carries spike bits at or above lane {lanes}"
                )));
            }
        }
        Ok(LaneFrame { plane, lanes })
    }

    /// Occupied bit-lanes (the batch size).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The underlying lane-major plane.
    pub fn plane(&self) -> &LanePlane {
        &self.plane
    }

    /// Shape tuple `(c, h, w)` of every lane.
    pub fn shape(&self) -> (usize, usize, usize) {
        self.plane.shape()
    }

    /// Unpack one lane back into a per-clip spike plane.
    pub fn lane(&self, b: usize) -> SpikePlane {
        debug_assert!(b < self.lanes);
        let (c, h, w) = self.plane.shape();
        let mut out = SpikePlane::zeros(c, h, w);
        for (cell, &word) in out.as_mut_slice().iter_mut().zip(self.plane.as_slice()) {
            *cell = ((word >> b) & 1) as u8;
        }
        out
    }

    /// The union plane: a cell is set iff *any* lane spikes there —
    /// the batched zero-skipping gate (a cell with word 0 is skipped
    /// for the whole batch).
    pub fn union(&self) -> SpikePlane {
        let (c, h, w) = self.plane.shape();
        let mut out = SpikePlane::zeros(c, h, w);
        for (cell, &word) in out.as_mut_slice().iter_mut().zip(self.plane.as_slice()) {
            *cell = (word != 0) as u8;
        }
        out
    }

    /// Total spikes across all lanes (one popcount per cell).
    pub fn count_spikes(&self) -> u64 {
        self.plane
            .as_slice()
            .iter()
            .map(|w| w.count_ones() as u64)
            .sum()
    }

    /// Per-lane spike counts — lane `b`'s entry equals
    /// `self.lane(b).count_spikes()` without unpacking.
    pub fn lane_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.lanes];
        for &word in self.plane.as_slice() {
            let mut m = word;
            while m != 0 {
                let b = m.trailing_zeros() as usize;
                m &= m - 1;
                counts[b] += 1;
            }
        }
        counts
    }

    /// Mean spike density over all lanes in [0, 1].
    pub fn density(&self) -> f64 {
        let cells = self.plane.len() * self.lanes;
        if cells == 0 {
            return 0.0;
        }
        self.count_spikes() as f64 / cells as f64
    }

    /// Mean sparsity over all lanes (1 − density).
    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }
}

/// Streaming sparsity statistics across timesteps / layers (Fig. 5).
///
/// Fully streaming, O(1) memory: the min/max band is folded in as
/// observations arrive, so the struct stays constant-size on
/// arbitrarily long serving streams (it used to keep one `f64` per
/// observation, which grew without bound on the request path).
#[derive(Debug, Clone)]
pub struct SparsityStats {
    /// Total cells observed.
    pub cells: u64,
    /// Total spikes observed.
    pub spikes: u64,
    /// Observations folded in so far.
    observations: u64,
    /// Running minimum per-observation sparsity (densest moment).
    min: f64,
    /// Running maximum per-observation sparsity.
    max: f64,
}

impl Default for SparsityStats {
    fn default() -> Self {
        SparsityStats {
            cells: 0,
            spikes: 0,
            observations: 0,
            // fold identities, matching the previous Vec-fold behavior
            // on an empty record set
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl SparsityStats {
    /// Empty statistics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one spike plane (counted through the popcount path —
    /// see [`SpikePlane::count_spikes`]).
    pub fn record(&mut self, plane: &SpikePlane) {
        self.record_counts(plane.count_spikes(), plane.len() as u64);
    }

    /// Record raw counts.
    pub fn record_counts(&mut self, spikes: u64, cells: u64) {
        self.spikes += spikes;
        self.cells += cells;
        if cells > 0 {
            let s = 1.0 - spikes as f64 / cells as f64;
            self.min = self.min.min(s);
            self.max = self.max.max(s);
            self.observations += 1;
        }
    }

    /// Mean sparsity over everything recorded.
    pub fn mean_sparsity(&self) -> f64 {
        if self.cells == 0 {
            return 1.0;
        }
        1.0 - self.spikes as f64 / self.cells as f64
    }

    /// Minimum per-observation sparsity (densest moment).
    ///
    /// With zero observations the running minimum is the fold identity
    /// `+inf`, which is not a sparsity and not even valid JSON once a
    /// bench emits it (`Infinity` corrupts `BENCH_*.json`); an empty
    /// band collapses to [`SparsityStats::mean_sparsity`] instead, so
    /// min/mean/max always agree on an empty stream and every band
    /// value is finite in [0, 1].
    pub fn min_sparsity(&self) -> f64 {
        if self.observations == 0 {
            return self.mean_sparsity();
        }
        self.min
    }

    /// Maximum per-observation sparsity.
    ///
    /// Like [`SparsityStats::min_sparsity`], an empty band (zero
    /// observations — the fold identity would be `−inf`) collapses to
    /// the mean-sparsity fallback so the value stays finite.
    pub fn max_sparsity(&self) -> f64 {
        if self.observations == 0 {
            return self.mean_sparsity();
        }
        self.max
    }

    /// Number of observations recorded.
    pub fn observations(&self) -> usize {
        self.observations as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    #[test]
    fn density_and_sparsity() {
        let mut p = SpikePlane::zeros(1, 2, 2);
        p.set(0, 0, 0, 1);
        assert_eq!(p.count_spikes(), 1);
        assert!((p.density() - 0.25).abs() < 1e-12);
        assert!((p.sparsity() - 0.75).abs() < 1e-12);
    }

    /// Satellite (ISSUE 6): the popcount `count_spikes` must equal the
    /// byte-wise sum it replaced, for any plane contents.
    #[test]
    fn prop_count_spikes_popcount_equals_bytewise() {
        check("count_spikes_popcount_equiv", 40, |g| {
            let (c, h, w) = (1 + g.index(3), 1 + g.index(9), 1 + g.index(9));
            let mut p = SpikePlane::zeros(c, h, w);
            for cell in p.as_mut_slice() {
                if g.chance(0.35) {
                    *cell = 1;
                }
            }
            let bytewise: u64 = p.as_slice().iter().map(|&b| b as u64).sum();
            p.count_spikes() == bytewise
        });
    }

    #[test]
    fn stats_aggregate() {
        let mut s = SparsityStats::new();
        s.record_counts(10, 100); // 0.90
        s.record_counts(30, 100); // 0.70
        assert!((s.mean_sparsity() - 0.80).abs() < 1e-12);
        assert!((s.min_sparsity() - 0.70).abs() < 1e-12);
        assert!((s.max_sparsity() - 0.90).abs() < 1e-12);
        assert_eq!(s.observations(), 2);
    }

    #[test]
    fn empty_stats_defaults() {
        let s = SparsityStats::new();
        assert_eq!(s.mean_sparsity(), 1.0);
        assert_eq!(s.observations(), 0);
    }

    /// Regression (ISSUE 5 headline bugfix): an empty stream used to
    /// report `min = +inf` / `max = −inf` — the raw fold identities —
    /// which serialized as `Infinity` and silently corrupted the
    /// Fig. 5 `BENCH_*.json` artifact. Empty bands must be finite,
    /// collapse to the mean, and format as strict JSON numbers.
    #[test]
    fn empty_stream_bands_are_finite_and_json_valid() {
        let mut s = SparsityStats::new();
        s.record_counts(0, 0); // zero cells: not an observation
        assert_eq!(s.observations(), 0);
        for v in [s.min_sparsity(), s.mean_sparsity(), s.max_sparsity()] {
            assert!(v.is_finite(), "empty-stream band {v} must be finite");
            assert_eq!(v, 1.0, "empty bands collapse to the mean fallback");
            // `Infinity`/`NaN` are not JSON; a finite f64's `{}` format
            // is — exactly what benches/common::emit writes per line.
            let line = format!("{{\"y\":{v}}}");
            assert!(!line.contains("inf") && !line.contains("NaN"), "{line}");
        }
        // once a real observation lands, the bands are live again
        s.record_counts(25, 100);
        assert_eq!(s.min_sparsity(), 0.75);
        assert_eq!(s.max_sparsity(), 0.75);
    }

    /// The stats stay O(1): a long stream folds into the same bands a
    /// sample vector would have produced, with no per-observation
    /// growth (zero-cell records are ignored, as before).
    #[test]
    fn long_stream_keeps_exact_bands() {
        let mut s = SparsityStats::new();
        s.record_counts(0, 0); // no cells: not an observation
        for i in 0..100_000u64 {
            // sparsity cycles through {0.90, 0.80, 0.70, 0.60}
            s.record_counts(10 + 10 * (i % 4), 100);
        }
        assert_eq!(s.observations(), 100_000);
        assert!((s.min_sparsity() - 0.60).abs() < 1e-12);
        assert!((s.max_sparsity() - 0.90).abs() < 1e-12);
        assert!((s.mean_sparsity() - 0.75).abs() < 1e-12);
    }

    // -- LaneFrame ---------------------------------------------------

    fn random_plane(g: &mut crate::prop::Gen, c: usize, h: usize, w: usize) -> SpikePlane {
        let density = g.f64() * 0.6;
        let mut p = SpikePlane::zeros(c, h, w);
        for cell in p.as_mut_slice() {
            if g.chance(density) {
                *cell = 1;
            }
        }
        p
    }

    #[test]
    fn prop_lane_pack_unpack_roundtrip() {
        check("lane_pack_roundtrip", 30, |g| {
            let (c, h, w) = (1 + g.index(3), 1 + g.index(6), 1 + g.index(6));
            let lanes = 1 + g.index(MAX_LANES);
            let planes: Vec<SpikePlane> =
                (0..lanes).map(|_| random_plane(g, c, h, w)).collect();
            let refs: Vec<&SpikePlane> = planes.iter().collect();
            let frame = LaneFrame::pack(&refs).unwrap();
            frame.lanes() == lanes
                && (0..lanes).all(|b| frame.lane(b) == planes[b])
        });
    }

    #[test]
    fn prop_lane_counts_and_union_agree_with_lanes() {
        check("lane_counts_union", 30, |g| {
            let (c, h, w) = (1 + g.index(2), 1 + g.index(6), 1 + g.index(6));
            let lanes = 1 + g.index(MAX_LANES);
            let planes: Vec<SpikePlane> =
                (0..lanes).map(|_| random_plane(g, c, h, w)).collect();
            let refs: Vec<&SpikePlane> = planes.iter().collect();
            let frame = LaneFrame::pack(&refs).unwrap();
            let counts = frame.lane_counts();
            let per_lane_ok =
                (0..lanes).all(|b| counts[b] == planes[b].count_spikes());
            let total_ok =
                frame.count_spikes() == counts.iter().sum::<u64>();
            let union = frame.union();
            let union_ok = (0..union.len()).all(|i| {
                let any = planes.iter().any(|p| p.as_slice()[i] != 0);
                (union.as_slice()[i] != 0) == any
            });
            per_lane_ok && total_ok && union_ok
        });
    }

    #[test]
    fn pack_validates_shapes_and_counts() {
        let a = SpikePlane::zeros(1, 2, 2);
        let b = SpikePlane::zeros(1, 3, 2);
        assert!(LaneFrame::pack(&[]).is_err());
        assert!(LaneFrame::pack(&[&a, &b]).is_err());
        let many: Vec<&SpikePlane> = (0..MAX_LANES + 1).map(|_| &a).collect();
        assert!(LaneFrame::pack(&many).is_err());
        assert!(LaneFrame::pack(&[&a, &a]).is_ok());
    }

    #[test]
    fn pack_clips_validates_timesteps() {
        let clip_a = vec![SpikePlane::zeros(1, 2, 2); 3];
        let clip_b = vec![SpikePlane::zeros(1, 2, 2); 2];
        assert!(LaneFrame::pack_clips(&[&clip_a, &clip_b]).is_err());
        let frames = LaneFrame::pack_clips(&[&clip_a, &clip_a]).unwrap();
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[0].lanes(), 2);
    }

    #[test]
    fn all_zero_lane_contributes_nothing() {
        // a fully silent clip packs to clear bits: zero count, absent
        // from the union (the batched path skips it entirely)
        let mut live = SpikePlane::zeros(1, 2, 2);
        live.set(0, 1, 1, 1);
        let silent = SpikePlane::zeros(1, 2, 2);
        let frame = LaneFrame::pack(&[&silent, &live]).unwrap();
        assert_eq!(frame.lane_counts(), vec![0, 1]);
        assert_eq!(frame.lane(0), silent);
        assert_eq!(frame.union().count_spikes(), 1);
        assert!((frame.density() - 1.0 / 8.0).abs() < 1e-12);
    }
}
