//! Layer specifications (mirror of `python/compile/model.py`).

use crate::error::{Error, Result};
use crate::snn::tensor::Mat;

/// Post-fire reset behavior of the neuron macro (paper §II-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResetMode {
    /// Reset Vmem to zero.
    Hard,
    /// Subtract the threshold, retaining residual potential (default —
    /// retains sub-threshold information across timesteps).
    #[default]
    Soft,
}

/// Neuron dynamics configuration held in the neuron macro's parameter
/// rows: IF/LIF selection, threshold, leak and reset mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NeuronConfig {
    /// Firing threshold (Vmem integer domain, >= 1).
    pub theta: i32,
    /// Leak magnitude per timestep (LIF only, >= 0).
    pub leak: i32,
    /// LIF (true) or IF (false).
    pub leaky: bool,
    /// Reset behavior after a spike.
    pub reset: ResetMode,
}

impl Default for NeuronConfig {
    fn default() -> Self {
        NeuronConfig {
            theta: 1,
            leak: 0,
            leaky: false,
            reset: ResetMode::Soft,
        }
    }
}

/// What a layer is, structurally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution (im2col'd to GEMM by the input loader).
    Conv,
    /// Fully-connected.
    Fc,
    /// Maxpool over binary spike planes.
    Pool,
}

/// One layer of a SpiDR network.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Structural kind.
    pub kind: LayerKind,
    /// Input shape `(C, H, W)`.
    pub in_shape: (usize, usize, usize),
    /// Output shape `(C, H, W)`.
    pub out_shape: (usize, usize, usize),
    /// Quantized weights `(F, K)`; `None` for pool layers.
    pub weights: Option<Mat>,
    /// Neuron configuration (ignored for pool layers).
    pub neuron: NeuronConfig,
    /// Non-spiking output layer whose Vmem accumulates across timesteps.
    pub accumulate: bool,
    /// Kernel height (pool window height for pools).
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Stride.
    pub stride: usize,
    /// Zero padding.
    pub pad: usize,
    /// Weight quantization scale (w ≈ w_q · scale).
    pub weight_scale: f64,
}

impl Layer {
    /// Build a conv layer, deriving the output shape.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        in_shape: (usize, usize, usize),
        out_ch: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        weights: Mat,
        neuron: NeuronConfig,
        accumulate: bool,
    ) -> Result<Self> {
        let (c, h, w) = in_shape;
        let f = c * kh * kw;
        if weights.rows != f || weights.cols != out_ch {
            return Err(Error::shape(format!(
                "conv weights {}x{} != fan-in {f} x out_ch {out_ch}",
                weights.rows, weights.cols
            )));
        }
        let ho = (h + 2 * pad - kh) / stride + 1;
        let wo = (w + 2 * pad - kw) / stride + 1;
        Ok(Layer {
            kind: LayerKind::Conv,
            in_shape,
            out_shape: (out_ch, ho, wo),
            weights: Some(weights),
            neuron,
            accumulate,
            kh,
            kw,
            stride,
            pad,
            weight_scale: 1.0,
        })
    }

    /// Build an FC layer over a flattened input.
    pub fn fc(
        in_shape: (usize, usize, usize),
        out_neurons: usize,
        weights: Mat,
        neuron: NeuronConfig,
        accumulate: bool,
    ) -> Result<Self> {
        let (c, h, w) = in_shape;
        let f = c * h * w;
        if weights.rows != f || weights.cols != out_neurons {
            return Err(Error::shape(format!(
                "fc weights {}x{} != fan-in {f} x out {out_neurons}",
                weights.rows, weights.cols
            )));
        }
        Ok(Layer {
            kind: LayerKind::Fc,
            in_shape,
            out_shape: (out_neurons, 1, 1),
            weights: Some(weights),
            neuron,
            accumulate,
            kh: 1,
            kw: 1,
            stride: 1,
            pad: 0,
            weight_scale: 1.0,
        })
    }

    /// Build a maxpool layer (window == stride, floor division, the
    /// same adaptive clamping as the Python model).
    pub fn pool(in_shape: (usize, usize, usize), size: usize, stride: usize) -> Self {
        let (c, h, w) = in_shape;
        let size = size.min(h).min(w);
        let stride = stride.min(size);
        Layer {
            kind: LayerKind::Pool,
            in_shape,
            out_shape: (c, h / stride, w / stride),
            weights: None,
            neuron: NeuronConfig::default(),
            accumulate: false,
            kh: size,
            kw: size,
            stride,
            pad: 0,
            weight_scale: 1.0,
        }
    }

    /// Attach the weight quantization scale.
    pub fn with_scale(mut self, scale: f64) -> Self {
        self.weight_scale = scale;
        self
    }

    /// True for layers that carry Vmem state (conv/fc).
    pub fn has_state(&self) -> bool {
        matches!(self.kind, LayerKind::Conv | LayerKind::Fc)
    }

    /// Vmem state shape `(M, K)`.
    pub fn vmem_shape(&self) -> Result<(usize, usize)> {
        match self.kind {
            LayerKind::Conv => {
                let (k, h, w) = self.out_shape;
                Ok((h * w, k))
            }
            LayerKind::Fc => Ok((1, self.out_shape.0)),
            LayerKind::Pool => Err(Error::config("pool layer has no Vmem")),
        }
    }

    /// Fan-in per output neuron (`R·S·C` for conv, inputs for FC).
    pub fn fan_in(&self) -> usize {
        match self.kind {
            LayerKind::Conv => self.in_shape.0 * self.kh * self.kw,
            LayerKind::Fc => self.in_shape.0 * self.in_shape.1 * self.in_shape.2,
            LayerKind::Pool => 0,
        }
    }

    /// Synaptic ops triggered by one input spike (= output channels hit).
    pub fn synops_per_spike(&self) -> usize {
        self.out_shape.0
    }

    /// One-line human-readable summary ("conv 2→16@24x24",
    /// "pool 3x3", "fc 1024→4") for stage-topology printouts
    /// (`examples/pipeline.rs`, DESIGN.md §Pipeline).
    pub fn describe(&self) -> String {
        match self.kind {
            LayerKind::Conv => format!(
                "conv {}→{}@{}x{}",
                self.in_shape.0, self.out_shape.0, self.out_shape.1, self.out_shape.2
            ),
            LayerKind::Fc => format!("fc {}→{}", self.fan_in(), self.out_shape.0),
            LayerKind::Pool => format!("pool {}x{}", self.kh, self.kw),
        }
    }

    /// Dense-equivalent synaptic operations for one full timestep
    /// (every input position × every mapped output): the denominator
    /// of the paper's effective-GOPS numbers.
    pub fn dense_synops(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                let (_, ho, wo) = self.out_shape;
                (ho * wo) as u64 * self.fan_in() as u64 * self.out_shape.0 as u64
            }
            LayerKind::Fc => self.fan_in() as u64 * self.out_shape.0 as u64,
            LayerKind::Pool => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(f: usize, k: usize) -> Mat {
        Mat::zeros(f, k)
    }

    #[test]
    fn conv_shapes() {
        let l = Layer::conv((2, 8, 8), 4, 3, 3, 1, 1, w(18, 4), NeuronConfig::default(), false)
            .unwrap();
        assert_eq!(l.out_shape, (4, 8, 8));
        assert_eq!(l.vmem_shape().unwrap(), (64, 4));
        assert_eq!(l.fan_in(), 18);
        assert_eq!(l.dense_synops(), 64 * 18 * 4);
        assert_eq!(l.describe(), "conv 2→4@8x8");
    }

    #[test]
    fn conv_stride_shapes() {
        let l = Layer::conv((1, 9, 9), 2, 3, 3, 2, 1, w(9, 2), NeuronConfig::default(), false)
            .unwrap();
        assert_eq!(l.out_shape, (2, 5, 5));
    }

    #[test]
    fn conv_rejects_bad_weights() {
        let r = Layer::conv((2, 8, 8), 4, 3, 3, 1, 1, w(17, 4), NeuronConfig::default(), false);
        assert!(r.is_err());
    }

    #[test]
    fn fc_shapes() {
        let l = Layer::fc((16, 2, 2), 11, w(64, 11), NeuronConfig::default(), true).unwrap();
        assert_eq!(l.out_shape, (11, 1, 1));
        assert_eq!(l.vmem_shape().unwrap(), (1, 11));
        assert_eq!(l.fan_in(), 64);
        assert_eq!(l.describe(), "fc 64→11");
    }

    #[test]
    fn pool_adapts_window() {
        let l = Layer::pool((16, 4, 4), 8, 8);
        assert_eq!(l.kh, 4); // clamped to remaining spatial size
        assert_eq!(l.out_shape, (16, 1, 1));
        assert!(l.vmem_shape().is_err());
        assert!(!l.has_state());
        assert_eq!(l.describe(), "pool 4x4");
    }
}
