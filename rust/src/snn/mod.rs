//! SNN data structures: spike tensors, layer specs, Table-II networks,
//! and the `.swb` weight-bundle loader shared with the Python AOT path.

pub mod bitpack;
pub mod layer;
pub mod network;
pub mod spikes;
pub mod swb;
pub mod tensor;

pub use layer::{Layer, LayerKind, NeuronConfig, ResetMode};
pub use network::{Network, NetworkBuilder};
pub use spikes::{LaneFrame, LanePlane, SparsityStats, SpikePlane, MAX_LANES};
pub use swb::WeightBundle;
pub use tensor::Tensor3;
