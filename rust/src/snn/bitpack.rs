//! The single bit-layout definition for binary spike cells.
//!
//! Two subsystems pack spike planes into bits: the wire codec
//! (`net/wire.rs`, 8 cells per byte on a shard link) and the lane-major
//! batch tensor ([`LaneFrame`](crate::snn::spikes::LaneFrame), 64 clips
//! per `u64` word). Both must agree on one layout — **LSB-first**: cell
//! `i` maps to bit `i % width` of word `i / width`, and any nonzero
//! cell normalizes to a set bit (planes are binary by contract). This
//! module is that layout's only definition; round-trip property tests
//! below pin it.

/// Pack binary cells into bytes, 8 cells per byte, LSB-first. Any
/// nonzero cell becomes a set bit. The last byte is zero-padded when
/// `cells.len()` is not a multiple of 8.
pub fn pack_bytes(cells: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(cells.len().div_ceil(8));
    let mut byte = 0u8;
    for (i, &v) in cells.iter().enumerate() {
        if v != 0 {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if cells.len() % 8 != 0 {
        out.push(byte);
    }
    out
}

/// Unpack `cells` bits from an LSB-first packed buffer back into one
/// byte per cell (0 or 1). `packed` must hold at least
/// `cells.div_ceil(8)` bytes.
pub fn unpack_bytes(packed: &[u8], cells: usize) -> Vec<u8> {
    debug_assert!(packed.len() >= cells.div_ceil(8));
    let mut out = vec![0u8; cells];
    for (i, cell) in out.iter_mut().enumerate() {
        *cell = (packed[i / 8] >> (i % 8)) & 1;
    }
    out
}

/// Pack lane words into a contiguous LSB-first bitstream, `lanes` bits
/// per word: cell `i` occupies bits `i*lanes .. (i+1)*lanes` of the
/// stream, low lane first. Bits at or above `lanes` are masked off
/// (lane words are `lanes`-bit by contract). This is the wire layout
/// for a lane frame (`net/wire.rs` v3): at `lanes = 64` a cell costs
/// exactly one `u64`, at `lanes = 1` the stream degenerates to
/// [`pack_bytes`] of the single lane.
pub fn pack_words(words: &[u64], lanes: usize) -> Vec<u8> {
    assert!((1..=64).contains(&lanes), "lane width {lanes} outside 1..=64");
    let total_bits = words.len() * lanes;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mask = lane_mask(lanes);
    for (i, &w) in words.iter().enumerate() {
        let bit = i * lanes;
        let (byte, off) = (bit / 8, bit % 8);
        let last = (bit + lanes - 1) / 8;
        // off <= 7 and lanes <= 64, so the shifted value spans at most
        // 71 bits — a u128 holds it with room to spare
        let mut chunk = ((w & mask) as u128) << off;
        for slot in out[byte..=last].iter_mut() {
            *slot |= (chunk & 0xff) as u8;
            chunk >>= 8;
        }
    }
    out
}

/// Unpack `cells` lane words of `lanes` bits each from an LSB-first
/// bitstream produced by [`pack_words`]. `packed` must hold at least
/// `(cells * lanes).div_ceil(8)` bytes; bits above `lanes` in each
/// output word are always clear.
pub fn unpack_words(packed: &[u8], cells: usize, lanes: usize) -> Vec<u64> {
    assert!((1..=64).contains(&lanes), "lane width {lanes} outside 1..=64");
    debug_assert!(packed.len() >= (cells * lanes).div_ceil(8));
    let mask = lane_mask(lanes);
    let mut out = vec![0u64; cells];
    for (i, w) in out.iter_mut().enumerate() {
        let bit = i * lanes;
        let (byte, off) = (bit / 8, bit % 8);
        let last = (bit + lanes - 1) / 8;
        let mut chunk: u128 = 0;
        for (j, &b) in packed[byte..=last].iter().enumerate() {
            chunk |= (b as u128) << (8 * j);
        }
        *w = ((chunk >> off) as u64) & mask;
    }
    out
}

fn lane_mask(lanes: usize) -> u64 {
    if lanes == 64 {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

/// Count nonzero cells through the packed representation: fold 64
/// cells at a time into a `u64` and popcount it — the hot-path
/// replacement for the byte-at-a-time sum (§Perf), equivalence-tested
/// below.
pub fn count_set(cells: &[u8]) -> u64 {
    let mut total = 0u64;
    for chunk in cells.chunks(64) {
        let mut word = 0u64;
        for (b, &v) in chunk.iter().enumerate() {
            word |= ((v != 0) as u64) << b;
        }
        total += word.count_ones() as u64;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    #[test]
    fn pack_is_lsb_first() {
        // cell 0 -> bit 0, cell 9 -> byte 1 bit 1
        let mut cells = vec![0u8; 10];
        cells[0] = 1;
        cells[9] = 1;
        assert_eq!(pack_bytes(&cells), vec![0b0000_0001, 0b0000_0010]);
    }

    #[test]
    fn nonzero_cells_normalize_to_set_bits() {
        assert_eq!(pack_bytes(&[0, 3, 0, 255]), vec![0b0000_1010]);
    }

    #[test]
    fn empty_and_exact_multiples() {
        assert!(pack_bytes(&[]).is_empty());
        assert_eq!(pack_bytes(&[1; 8]).len(), 1);
        assert_eq!(pack_bytes(&[1; 9]).len(), 2);
    }

    #[test]
    fn prop_pack_unpack_roundtrip() {
        check("bitpack_roundtrip", 50, |g| {
            let n = g.index(300);
            let cells: Vec<u8> = (0..n).map(|_| g.chance(0.3) as u8).collect();
            unpack_bytes(&pack_bytes(&cells), n) == cells
        });
    }

    #[test]
    fn prop_unpack_pack_roundtrip() {
        // packed -> cells -> packed is identity when the pad bits are
        // clear (the only buffers pack_bytes ever produces)
        check("bitpack_repack", 50, |g| {
            let n = g.index(300);
            let cells: Vec<u8> = (0..n).map(|_| g.chance(0.5) as u8).collect();
            let packed = pack_bytes(&cells);
            pack_bytes(&unpack_bytes(&packed, n)) == packed
        });
    }

    /// Satellite (ISSUE 7): the lane bitstream must round-trip for
    /// every lane width, including the dense 64-lane case and widths
    /// that straddle byte boundaries.
    #[test]
    fn prop_pack_unpack_words_roundtrip() {
        check("bitpack_words_roundtrip", 50, |g| {
            let lanes = 1 + g.index(64);
            let n = g.index(200);
            let mask = super::lane_mask(lanes);
            let words: Vec<u64> = (0..n).map(|_| g.u64() & mask).collect();
            let packed = pack_words(&words, lanes);
            packed.len() == (n * lanes).div_ceil(8) && unpack_words(&packed, n, lanes) == words
        });
    }

    /// Bits at or above the lane width never survive the wire: they are
    /// masked on pack, so a round trip normalizes them away.
    #[test]
    fn prop_pack_words_masks_stray_high_bits() {
        check("bitpack_words_mask", 50, |g| {
            let lanes = 1 + g.index(63); // leave headroom for stray bits
            let n = 1 + g.index(100);
            let words: Vec<u64> = (0..n).map(|_| g.u64()).collect();
            let mask = super::lane_mask(lanes);
            let want: Vec<u64> = words.iter().map(|&w| w & mask).collect();
            unpack_words(&pack_words(&words, lanes), n, lanes) == want
        });
    }

    /// At one lane the word stream is exactly the byte stream: the two
    /// codecs share a single LSB-first layout.
    #[test]
    fn prop_one_lane_matches_byte_packing() {
        check("bitpack_words_vs_bytes", 50, |g| {
            let n = g.index(300);
            let cells: Vec<u8> = (0..n).map(|_| g.chance(0.3) as u8).collect();
            let words: Vec<u64> = cells.iter().map(|&c| c as u64).collect();
            pack_words(&words, 1) == pack_bytes(&cells)
        });
    }

    /// Satellite (ISSUE 6): the popcount path must agree with the
    /// byte-wise sum for any cell buffer, including non-0/1 values.
    #[test]
    fn prop_count_set_equals_bytewise() {
        check("bitpack_popcount_equiv", 50, |g| {
            let n = g.index(500);
            let cells: Vec<u8> = (0..n)
                .map(|_| if g.chance(0.4) { 1 + g.index(255) as u8 } else { 0 })
                .collect();
            let bytewise: u64 = cells.iter().map(|&b| (b != 0) as u64).sum();
            count_set(&cells) == bytewise
        });
    }
}
