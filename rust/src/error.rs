//! Unified error type for the SpiDR library.
//!
//! Hand-rolled `Display`/`Error`/`From` impls instead of `thiserror`:
//! the default build carries zero external dependencies so `cargo test`
//! is hermetic in registry-less environments (DESIGN.md §3).

use std::fmt;

/// Errors surfaced by the SpiDR library.
#[derive(Debug)]
pub enum Error {
    /// A layer/network/mapping configuration is invalid.
    Config(String),

    /// A workload does not fit the selected operating mode / core.
    Mapping(String),

    /// Artifact files (HLO text, weight bundles, manifests) are
    /// missing or malformed.
    Artifact(String),

    /// PJRT / XLA runtime failures (or the runtime being compiled out;
    /// see the `pjrt` cargo feature).
    Runtime(String),

    /// Shape or dimension mismatch between tensors.
    Shape(String),

    /// Wire-protocol failures on the distributed shard path: malformed
    /// or truncated frames, checksum mismatches, version skew, or a
    /// peer violating the session protocol (see `net::wire`).
    Protocol(String),

    /// I/O failures while loading artifacts or traces.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "configuration error: {m}"),
            Error::Mapping(m) => write!(f, "mapping error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            // transparent, matching the previous `#[error(transparent)]`
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Shorthand constructor for mapping errors.
    pub fn mapping(msg: impl Into<String>) -> Self {
        Error::Mapping(msg.into())
    }

    /// Shorthand constructor for artifact errors.
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }

    /// Shorthand constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }

    /// Shorthand constructor for wire-protocol errors.
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::config("x").to_string(), "configuration error: x");
        assert_eq!(Error::mapping("x").to_string(), "mapping error: x");
        assert_eq!(Error::artifact("x").to_string(), "artifact error: x");
        assert_eq!(Error::shape("x").to_string(), "shape error: x");
        assert_eq!(Error::protocol("x").to_string(), "protocol error: x");
        assert_eq!(Error::Runtime("x".into()).to_string(), "runtime error: x");
    }

    #[test]
    fn io_is_transparent_with_source() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let msg = io.to_string();
        let e: Error = io.into();
        assert_eq!(e.to_string(), msg);
        assert!(e.source().is_some());
    }
}
