//! Unified error type for the SpiDR library.

use thiserror::Error;

/// Errors surfaced by the SpiDR library.
#[derive(Error, Debug)]
pub enum Error {
    /// A layer/network/mapping configuration is invalid.
    #[error("configuration error: {0}")]
    Config(String),

    /// A workload does not fit the selected operating mode / core.
    #[error("mapping error: {0}")]
    Mapping(String),

    /// Artifact files (HLO text, weight bundles, manifests) are
    /// missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Shape or dimension mismatch between tensors.
    #[error("shape error: {0}")]
    Shape(String),

    /// I/O failures while loading artifacts or traces.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Shorthand constructor for mapping errors.
    pub fn mapping(msg: impl Into<String>) -> Self {
        Error::Mapping(msg.into())
    }

    /// Shorthand constructor for artifact errors.
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }

    /// Shorthand constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}
