//! Frame transports: how [`Frame`]s move between a coordinator and a
//! shard host (DESIGN.md §Distributed).
//!
//! The [`Transport`] trait is the narrow waist — blocking, ordered,
//! reliable frame delivery in both directions. Two implementations:
//!
//! * [`TcpTransport`] over `std::net` for real multi-process /
//!   multi-host topologies (the `spidr shard` mode and the CI
//!   two-process smoke run on it), and
//! * [`LoopbackTransport`], a pair of **bounded in-process byte
//!   pipes**, so every distributed test and the loopback constellation
//!   run deterministically with no sockets, while still exercising the
//!   exact same codec, flow control (a full pipe blocks the writer,
//!   like a full TCP send buffer) and EOF semantics.

use crate::sync::{Arc, Condvar, Mutex};
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::net::wire::Frame;

/// Blocking, ordered, reliable frame delivery to one peer.
///
/// `send` delivers one frame (blocking while the link is congested —
/// the wire analogue of a full handshaking FIFO stalling its
/// producer); `recv` blocks for the next frame and returns `Ok(None)`
/// when the peer closed the link cleanly between frames.
///
/// The versioned pair is the negotiation surface (wire v3): `send`
/// stamps each frame at its kind's own dialect
/// ([`Frame::wire_version`] — v2 for the scalar grammar, v3 for lane
/// messages), and `recv_versioned` surfaces the header version a frame
/// arrived under, which is how a coordinator learns whether its peer
/// can take lane batches (the shard's `Hello` reply is stamped at the
/// highest version the shard speaks).
pub trait Transport: Send {
    /// Deliver one frame stamped with an explicit header version,
    /// blocking on link backpressure.
    fn send_versioned(&mut self, frame: &Frame, version: u16) -> Result<()>;

    /// Receive the next frame plus the header version it arrived
    /// under; `Ok(None)` means the peer closed the link cleanly at a
    /// frame boundary.
    fn recv_versioned(&mut self) -> Result<Option<(Frame, u16)>>;

    /// Deliver one frame, stamped at the kind's own
    /// [`Frame::wire_version`] (so scalar traffic stays v2 on the wire
    /// and v2 peers interoperate by construction).
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.send_versioned(frame, frame.wire_version())
    }

    /// Receive the next frame; `Ok(None)` means the peer closed the
    /// link cleanly at a frame boundary.
    fn recv(&mut self) -> Result<Option<Frame>> {
        Ok(self.recv_versioned()?.map(|(frame, _)| frame))
    }
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

/// [`Transport`] over a TCP stream.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Connect to a listening shard (or coordinator).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(Self::from_stream(TcpStream::connect(addr)?))
    }

    /// Wrap an accepted stream. Disables Nagle coalescing — the
    /// protocol is request/reply per timestep, so latency beats
    /// batching here.
    pub fn from_stream(stream: TcpStream) -> Self {
        let _ = stream.set_nodelay(true);
        TcpTransport { stream }
    }
}

impl Transport for TcpTransport {
    fn send_versioned(&mut self, frame: &Frame, version: u16) -> Result<()> {
        frame.write_to_versioned(&mut self.stream, version)
    }

    fn recv_versioned(&mut self) -> Result<Option<(Frame, u16)>> {
        Frame::read_versioned_from(&mut self.stream)
    }
}

// ---------------------------------------------------------------------------
// Loopback byte pipes
// ---------------------------------------------------------------------------

/// Default per-direction pipe capacity (matches the ballpark of an OS
/// TCP send buffer, so loopback runs see the same flow-control shape
/// as socket runs).
pub const DEFAULT_PIPE_CAPACITY: usize = 256 * 1024;

/// Delay-line model of a finite link (DESIGN.md §Planner): bytes
/// **serialize** onto the wire at `bandwidth_bytes_per_s` (the
/// serialization frontier `busy_until` advances by `bytes/bandwidth`
/// per chunk, so back-to-back writes queue behind each other) and then
/// **propagate** for `latency` before the reader may consume them.
/// Because each chunk's delivery time is stamped at *write* time,
/// propagation delays overlap across in-flight frames — exactly why a
/// larger protocol window hides a long round trip, and what a naive
/// sleep-per-frame throttle would fail to model.
struct ThrottleState {
    bandwidth_bytes_per_s: u64,
    latency: Duration,
    /// Time origin shared by both stamps below.
    origin: Instant,
    /// Serialization frontier: when the wire finishes transmitting
    /// everything written so far (relative to `origin`).
    busy_until: Duration,
    /// Per-chunk `(len, ready_at)` delivery stamps, in write order
    /// (`ready_at` is monotone, relative to `origin`). Lengths sum to
    /// `data.len()` of the owning pipe.
    chunks: VecDeque<(usize, Duration)>,
}

impl ThrottleState {
    fn new(bandwidth_bytes_per_s: u64, latency: Duration) -> Self {
        ThrottleState {
            bandwidth_bytes_per_s: bandwidth_bytes_per_s.max(1),
            latency,
            origin: Instant::now(), // lint: wall-clock
            busy_until: Duration::ZERO,
            chunks: VecDeque::new(),
        }
    }

    /// Stamp `len` freshly written bytes with their delivery time.
    fn stamp(&mut self, len: usize) {
        let now = self.origin.elapsed();
        let tx = Duration::from_secs_f64(len as f64 / self.bandwidth_bytes_per_s as f64);
        self.busy_until = self.busy_until.max(now) + tx;
        let ready_at = self.busy_until + self.latency;
        self.chunks.push_back((len, ready_at));
    }

    /// How many queued bytes have already arrived, plus (when none
    /// have) how long until the head chunk lands.
    fn arrived(&self) -> (usize, Option<Duration>) {
        let now = self.origin.elapsed();
        let mut ready = 0;
        for &(len, at) in &self.chunks {
            if at <= now {
                ready += len;
            } else if ready == 0 {
                return (0, Some(at - now));
            } else {
                break;
            }
        }
        (ready, None)
    }

    /// Account `n` bytes consumed by the reader.
    fn consume(&mut self, mut n: usize) {
        while n > 0 {
            let (len, at) = self.chunks[0];
            if len <= n {
                n -= len;
                self.chunks.pop_front();
            } else {
                self.chunks[0] = (len - n, at);
                n = 0;
            }
        }
    }
}

/// One bounded unidirectional byte queue.
struct PipeState {
    data: VecDeque<u8>,
    capacity: usize,
    write_closed: bool,
    read_closed: bool,
    /// `Some` puts a modeled finite link on this direction; `None`
    /// (every pre-existing pipe) adds no overhead to the data path.
    throttle: Option<ThrottleState>,
}

struct Pipe {
    state: Mutex<PipeState>,
    /// Signaled when bytes arrive or the writer closes.
    readable: Condvar,
    /// Signaled when space frees or the reader closes.
    writable: Condvar,
}

fn byte_pipe_inner(capacity: usize, throttle: Option<ThrottleState>) -> (PipeWriter, PipeReader) {
    let pipe = Arc::new(Pipe {
        state: Mutex::new(PipeState {
            data: VecDeque::new(),
            capacity: capacity.max(1),
            write_closed: false,
            read_closed: false,
            throttle,
        }),
        readable: Condvar::new(),
        writable: Condvar::new(),
    });
    (
        PipeWriter {
            pipe: Arc::clone(&pipe),
        },
        PipeReader { pipe },
    )
}

fn byte_pipe(capacity: usize) -> (PipeWriter, PipeReader) {
    byte_pipe_inner(capacity, None)
}

/// Write half of a bounded in-process byte pipe. A full pipe blocks
/// the writer until the reader drains it; dropping the writer is a
/// clean EOF for the reader.
pub struct PipeWriter {
    pipe: Arc<Pipe>,
}

impl Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut st = self.pipe.state.lock().unwrap();
        loop {
            if st.read_closed {
                return Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "loopback peer closed",
                ));
            }
            let free = st.capacity - st.data.len();
            if free > 0 {
                let n = free.min(buf.len());
                st.data.extend(&buf[..n]);
                if let Some(t) = &mut st.throttle {
                    t.stamp(n);
                }
                self.pipe.readable.notify_all();
                return Ok(n);
            }
            st = self.pipe.writable.wait(st).unwrap();
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        let mut st = self.pipe.state.lock().unwrap();
        st.write_closed = true;
        drop(st);
        self.pipe.readable.notify_all();
    }
}

/// Read half of a bounded in-process byte pipe. Reads block until
/// bytes arrive; once the writer drops, remaining bytes drain and then
/// reads return `Ok(0)` (EOF).
pub struct PipeReader {
    pipe: Arc<Pipe>,
}

impl Read for PipeReader {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        let mut st = self.pipe.state.lock().unwrap();
        loop {
            if !st.data.is_empty() {
                // On a throttled pipe only bytes whose modeled delivery
                // time has passed are visible; queued-but-in-flight
                // bytes keep the reader waiting out the residual delay.
                let (visible, eta) = match &st.throttle {
                    None => (st.data.len(), None),
                    Some(t) => {
                        let (ready, eta) = t.arrived();
                        // Every byte is stamped under the same lock
                        // that queued it, so `ready == 0` without an
                        // ETA cannot happen while data is queued; fall
                        // back to full visibility rather than spin.
                        if ready == 0 && eta.is_none() {
                            (st.data.len(), None)
                        } else {
                            (ready, eta)
                        }
                    }
                };
                if visible > 0 {
                    let n = buf.len().min(visible);
                    for (dst, b) in buf.iter_mut().zip(st.data.drain(..n)) {
                        *dst = b;
                    }
                    if let Some(t) = &mut st.throttle {
                        t.consume(n);
                    }
                    self.pipe.writable.notify_all();
                    return Ok(n);
                }
                if let Some(wait) = eta {
                    let (guard, _) = self
                        .pipe
                        .readable
                        .wait_timeout(st, wait)
                        .unwrap();
                    st = guard;
                    continue;
                }
            }
            if st.data.is_empty() && st.write_closed {
                return Ok(0);
            }
            if st.data.is_empty() {
                st = self.pipe.readable.wait(st).unwrap();
            }
        }
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        let mut st = self.pipe.state.lock().unwrap();
        st.read_closed = true;
        drop(st);
        self.pipe.writable.notify_all();
    }
}

/// In-process [`Transport`]: one end of a pair of bounded byte pipes.
///
/// [`LoopbackTransport::pair`] returns two connected ends; frames
/// written to one are read by the other, through the same codec and
/// the same bounded-buffer flow control as a socket. Dropping an end
/// closes both of its pipe halves: the peer's next `recv` sees a clean
/// EOF and its next `send` fails — identical to a TCP hangup.
pub struct LoopbackTransport {
    tx: PipeWriter,
    rx: PipeReader,
}

impl LoopbackTransport {
    /// A connected pair with [`DEFAULT_PIPE_CAPACITY`] per direction.
    pub fn pair() -> (Self, Self) {
        Self::pair_with_capacity(DEFAULT_PIPE_CAPACITY)
    }

    /// A connected pair with an explicit per-direction byte capacity
    /// (small capacities make the backpressure observable in tests).
    pub fn pair_with_capacity(capacity: usize) -> (Self, Self) {
        let (a_tx, b_rx) = byte_pipe(capacity);
        let (b_tx, a_rx) = byte_pipe(capacity);
        (
            LoopbackTransport { tx: a_tx, rx: a_rx },
            LoopbackTransport { tx: b_tx, rx: b_rx },
        )
    }

    /// A connected pair over a **modeled finite link**: both directions
    /// serialize at `bandwidth_bytes_per_s` and each byte arrives
    /// `latency` after it finishes serializing (a delay line, not a
    /// sleep per frame — in-flight frames overlap their propagation
    /// delays, so protocol windows hide the round trip exactly as they
    /// would on a real long link). This is how the skewed-constellation
    /// auto-tune bench and CI smoke build their deliberately slow hop
    /// without sockets (DESIGN.md §Planner).
    pub fn pair_throttled(bandwidth_bytes_per_s: u64, latency: Duration) -> (Self, Self) {
        let (a_tx, b_rx) = byte_pipe_inner(
            DEFAULT_PIPE_CAPACITY,
            Some(ThrottleState::new(bandwidth_bytes_per_s, latency)),
        );
        let (b_tx, a_rx) = byte_pipe_inner(
            DEFAULT_PIPE_CAPACITY,
            Some(ThrottleState::new(bandwidth_bytes_per_s, latency)),
        );
        (
            LoopbackTransport { tx: a_tx, rx: a_rx },
            LoopbackTransport { tx: b_tx, rx: b_rx },
        )
    }
}

impl Transport for LoopbackTransport {
    fn send_versioned(&mut self, frame: &Frame, version: u16) -> Result<()> {
        frame.write_to_versioned(&mut self.tx, version)
    }

    fn recv_versioned(&mut self) -> Result<Option<(Frame, u16)>> {
        Frame::read_versioned_from(&mut self.rx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::atomic::{AtomicBool, Ordering};
    use std::net::TcpListener;
    use std::time::Duration;

    fn ping(clip: u64) -> Frame {
        Frame::Drain { clip }
    }

    #[test]
    fn loopback_roundtrips_both_directions() {
        let (mut a, mut b) = LoopbackTransport::pair();
        a.send(&ping(1)).unwrap();
        b.send(&ping(2)).unwrap();
        assert_eq!(b.recv().unwrap(), Some(ping(1)));
        assert_eq!(a.recv().unwrap(), Some(ping(2)));
    }

    /// The default `send` stamps each kind at its own dialect, and the
    /// receiver sees exactly that stamp — the negotiation surface
    /// (ISSUE 7).
    #[test]
    fn frames_carry_their_wire_version_end_to_end() {
        use crate::net::wire::{MIN_VERSION, VERSION};
        let (mut a, mut b) = LoopbackTransport::pair();
        a.send(&ping(1)).unwrap();
        assert_eq!(b.recv_versioned().unwrap(), Some((ping(1), MIN_VERSION)));
        // an explicit stamp (the Hello negotiation path) also survives
        a.send_versioned(&ping(2), VERSION).unwrap();
        assert_eq!(b.recv_versioned().unwrap(), Some((ping(2), VERSION)));
    }

    #[test]
    fn dropping_an_end_is_clean_eof_for_the_peer() {
        let (a, mut b) = LoopbackTransport::pair();
        drop(a);
        assert_eq!(b.recv().unwrap(), None);
        assert!(b.send(&ping(9)).is_err());
    }

    /// A frame larger than the pipe capacity streams through chunk by
    /// chunk while the peer reads concurrently — writes block on the
    /// bounded buffer instead of failing.
    #[test]
    fn bounded_pipe_streams_oversized_frames() {
        let (mut a, mut b) = LoopbackTransport::pair_with_capacity(16);
        let big = Frame::Error {
            message: "x".repeat(1000),
        };
        let want = big.clone();
        let t = crate::sync::thread::spawn(move || {
            a.send(&big).unwrap();
            a
        });
        assert_eq!(b.recv().unwrap(), Some(want));
        t.join().unwrap();
    }

    /// The writer genuinely blocks while the pipe is full (the
    /// backpressure edge), resuming only once the reader drains.
    #[test]
    fn full_pipe_blocks_the_writer_until_drained() {
        let (mut a, mut b) = LoopbackTransport::pair_with_capacity(8);
        let sent = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&sent);
        let t = crate::sync::thread::spawn(move || {
            a.send(&Frame::Error {
                message: "y".repeat(64),
            })
            .unwrap();
            flag.store(true, Ordering::SeqCst);
            a
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!sent.load(Ordering::SeqCst), "writer must stall on a full pipe");
        assert!(b.recv().unwrap().is_some());
        t.join().unwrap();
        assert!(sent.load(Ordering::SeqCst));
    }

    /// A throttled pair delivers no earlier than the modeled
    /// serialization + propagation delay.
    #[test]
    fn throttled_pipe_delays_delivery_by_the_link_latency() {
        let latency = Duration::from_millis(40);
        let (mut a, mut b) = LoopbackTransport::pair_throttled(100 << 20, latency);
        let t0 = Instant::now();
        a.send(&ping(1)).unwrap();
        assert_eq!(b.recv().unwrap(), Some(ping(1)));
        assert!(
            t0.elapsed() >= latency,
            "frame arrived in {:?}, before the modeled {latency:?} latency",
            t0.elapsed()
        );
    }

    /// The delay line is not a sleep per frame: N frames written
    /// back-to-back overlap their propagation delays, so the batch
    /// drains in roughly one latency, not N of them. This is the
    /// property that makes protocol windows worth widening over a long
    /// link (DESIGN.md §Planner).
    #[test]
    fn throttled_pipe_overlaps_latency_across_inflight_frames() {
        let latency = Duration::from_millis(60);
        let (mut a, mut b) = LoopbackTransport::pair_throttled(100 << 20, latency);
        let t0 = Instant::now();
        for clip in 0..4 {
            a.send(&ping(clip)).unwrap();
        }
        for clip in 0..4 {
            assert_eq!(b.recv().unwrap(), Some(ping(clip)));
        }
        let wall = t0.elapsed();
        assert!(wall >= latency, "4 frames in {wall:?}: beat the link latency");
        assert!(
            wall < 3 * latency,
            "4 overlapped frames took {wall:?} (≥ 3×{latency:?}): \
             the throttle serialized propagation delays"
        );
    }

    /// Serialization is modeled too: a large frame over a thin pipe is
    /// paced by bytes/bandwidth, well past the (zero) latency.
    #[test]
    fn throttled_pipe_paces_bytes_at_the_link_bandwidth() {
        // ~1KB payload over 20 KB/s ≈ 50ms of serialization.
        let (mut a, mut b) = LoopbackTransport::pair_throttled(20_000, Duration::ZERO);
        let big = Frame::Error {
            message: "z".repeat(1000),
        };
        let want = big.clone();
        let t0 = Instant::now();
        a.send(&big).unwrap();
        assert_eq!(b.recv().unwrap(), Some(want));
        assert!(
            t0.elapsed() >= Duration::from_millis(40),
            "1KB over a 20KB/s link arrived in {:?}",
            t0.elapsed()
        );
    }

    /// Dropping a throttled end is still a clean EOF once the bytes in
    /// flight have landed.
    #[test]
    fn throttled_pipe_drains_then_eofs_after_hangup() {
        let (mut a, mut b) =
            LoopbackTransport::pair_throttled(100 << 20, Duration::from_millis(10));
        a.send(&ping(5)).unwrap();
        drop(a);
        assert_eq!(b.recv().unwrap(), Some(ping(5)));
        assert_eq!(b.recv().unwrap(), None);
    }

    #[test]
    fn tcp_transport_roundtrips_over_localhost() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = crate::sync::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::from_stream(stream);
            while let Some(frame) = t.recv().unwrap() {
                t.send(&frame).unwrap(); // echo
            }
        });
        let mut c = TcpTransport::connect(addr).unwrap();
        for clip in 0..4 {
            c.send(&ping(clip)).unwrap();
            assert_eq!(c.recv().unwrap(), Some(ping(clip)));
        }
        drop(c);
        server.join().unwrap();
    }
}
