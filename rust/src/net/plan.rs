//! Topology-aware deployment planning for the distributed tier
//! (DESIGN.md §Planner).
//!
//! The distributed engine chains layer-group shards behind per-hop
//! protocol windows, but until now placement assumed uniform links and
//! every hop got the same static window. This module adds the missing
//! model: each candidate shard endpoint carries a [`LinkSpec`]
//! (bandwidth + latency — the constant-bandwidth link model), each
//! layer group carries a compute demand from
//! `plan_layer_group_costs`, and [`plan_deployment`] searches group
//! counts, placements, replica spread, and per-hop windows to minimize
//! the **modeled clip makespan**:
//!
//! ```text
//! serv_h   = max(compute_h, tx_in_h, tx_out_h) + overhead
//! rtt_h    = tx_in_h + tx_out_h + 2·latency_h + compute_h + overhead
//! t_h(W)   = max(serv_h, rtt_h / W_h)          (steady-state interval)
//! T_clip   ≈ Σ_h rtt_h  +  (T − 1) · max_h t_h(W_h)
//! ```
//!
//! which extends DESIGN.md §Pipeline's fill/drain model
//! (`T_clip ≈ (G−1)·t_stage + T·t_stage`) with wire terms: at zero
//! wire cost `rtt_h = serv_h = t_stage` and the two formulas coincide.
//! The planned window `W_h = ⌈rtt_h / serv_h⌉` (clamped) is the
//! bandwidth-delay product in frames — exactly enough in-flight frames
//! to hide the round trip without inflating memory.
//!
//! Frame sizes are **measured, not estimated**: a zero frame is
//! stepped through the group spans and each hop's request/reply
//! `Frame::SpikeFrame` is encoded through the real codec (spike planes
//! are bit-packed, so size depends only on shape). Compute and
//! per-frame overhead come from a [`CostModel`], calibrated from two
//! cheap measurements ([`CostModel::calibrate`]).
//!
//! The plan is advice, not magic: the runtime closes the loop with
//! `DistributedEngine::retune_windows`, which reads the measured
//! per-hop `StageMetrics` stall split and widens/narrows windows
//! within bounds (the simulate-vs-measured bench in
//! `benches/distributed_serve.rs` keeps the model honest).

use std::time::Duration;

use crate::coordinator::scheduler::{plan_layer_group_costs, plan_layer_groups};
use crate::error::{Error, Result};
use crate::net::wire::Frame;
use crate::snn::network::Network;
use crate::snn::spikes::SpikePlane;

/// Modeled properties of one coordinator→shard link: the
/// constant-bandwidth model (serialization at `bandwidth_bytes_per_s`,
/// propagation of `latency_us` each way). The same numbers drive the
/// loopback delay-line throttle
/// (`LoopbackTransport::pair_throttled`), so a modeled topology can be
/// *instantiated* and measured against its own prediction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkSpec {
    /// Serialization rate in bytes per second (shared by both
    /// directions; each direction has the full rate).
    pub bandwidth_bytes_per_s: u64,
    /// One-way propagation delay in microseconds.
    pub latency_us: u64,
}

impl LinkSpec {
    /// A link with the given bandwidth and one-way latency.
    pub const fn new(bandwidth_bytes_per_s: u64, latency_us: u64) -> Self {
        LinkSpec {
            bandwidth_bytes_per_s,
            latency_us,
        }
    }

    /// An effectively free in-process link: memory-bus bandwidth, no
    /// propagation delay. Modeling a plain loopback constellation with
    /// these reduces the makespan formula to the §Pipeline model.
    pub const fn loopback() -> Self {
        LinkSpec::new(8 << 30, 0)
    }

    /// One-way propagation delay as a [`Duration`].
    pub fn latency(&self) -> Duration {
        Duration::from_micros(self.latency_us)
    }

    /// Microseconds to serialize `bytes` onto this link.
    pub fn tx_us(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bandwidth_bytes_per_s.max(1) as f64 * 1e6
    }
}

/// Calibrated scalar costs the planner multiplies its structural
/// knowledge (synop counts, frame bytes) by. Two knobs only, both
/// recoverable from cheap measurements — everything else in the model
/// is measured or specified exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Microseconds per dense-equivalent synaptic op of the functional
    /// executor on this machine.
    pub per_synop_us: f64,
    /// Fixed per-frame, per-hop overhead in microseconds: codec,
    /// scheduling, and channel hand-off — everything a wire frame
    /// costs beyond bandwidth and compute.
    pub per_frame_overhead_us: f64,
}

impl CostModel {
    /// A rough machine-independent prior for planning before any
    /// measurement: ~1 GHz of effective synop throughput and a few
    /// microseconds of per-frame overhead.
    pub fn uncalibrated() -> Self {
        CostModel {
            per_synop_us: 1e-3,
            per_frame_overhead_us: 5.0,
        }
    }

    /// Calibrate from two measurements on the target machine:
    /// `reference_clip_us` (one clip through the sequential reference
    /// executor — pins `per_synop_us`) and `loopback_clip_us` (the same
    /// clip through a **1-shard plain loopback** constellation, whose
    /// modeled makespan is `T·(compute + overhead)` — the difference
    /// pins `per_frame_overhead_us`).
    pub fn calibrate(network: &Network, reference_clip_us: f64, loopback_clip_us: f64) -> Self {
        let t = network.timesteps.max(1) as f64;
        let synops = network.dense_synops_per_timestep().max(1) as f64;
        let compute_per_step = reference_clip_us / t;
        let overhead = (loopback_clip_us / t - compute_per_step).max(0.05);
        CostModel {
            per_synop_us: (compute_per_step / synops).max(1e-9),
            per_frame_overhead_us: overhead,
        }
    }
}

/// One hop of a [`DeploymentPlan`]: which site hosts which layer
/// group, how many replicas back it, the planned protocol window, and
/// the modeled cost terms behind those choices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HopPlan {
    /// Index into the candidate-site slice passed to
    /// [`plan_deployment`].
    pub site: usize,
    /// Replicas provisioned for this hop (≥ 1; leftover sites are
    /// spent on the makespan-critical hops, which have the least
    /// headroom to mask a failover replay).
    pub replicas: usize,
    /// Planned protocol window: the bandwidth-delay product in frames,
    /// clamped to the planner's bounds.
    pub window: usize,
    /// Stateful-layer range `[a, b)` of the group this hop serves.
    pub group: (usize, usize),
    /// Modeled per-timestep compute on this hop, microseconds.
    pub compute_us: f64,
    /// Encoded request `SpikeFrame` size toward this hop, bytes.
    pub in_bytes: u64,
    /// Encoded reply `SpikeFrame` size from this hop, bytes.
    pub out_bytes: u64,
    /// Modeled steady-state service time per frame, microseconds.
    pub serv_us: f64,
    /// Modeled per-frame round trip, microseconds.
    pub rtt_us: f64,
    /// Modeled steady-state inter-frame interval under the planned
    /// window: `max(serv, rtt / window)`, microseconds.
    pub steady_us: f64,
}

/// What [`plan_deployment`] decides: the layer-group partition, one
/// [`HopPlan`] per hop, and the modeled clip makespan those choices
/// achieve.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// Contiguous stateful-layer groups, one per hop (the
    /// `plan_layer_groups` partition at the chosen group count).
    pub groups: Vec<(usize, usize)>,
    /// Per-hop placement, replicas, window, and modeled cost terms.
    pub hops: Vec<HopPlan>,
    /// Modeled end-to-end clip makespan, microseconds.
    pub modeled_clip_us: f64,
}

impl DeploymentPlan {
    /// The per-hop window schedule (hand to
    /// `DistributedEngine::set_windows`).
    pub fn windows(&self) -> Vec<usize> {
        self.hops.iter().map(|h| h.window).collect()
    }

    /// The [`LinkSpec`] each hop was planned onto, in hop order.
    pub fn links(&self, sites: &[LinkSpec]) -> Vec<LinkSpec> {
        self.hops.iter().map(|h| sites[h.site]).collect()
    }
}

/// Planner knobs: window bounds and the cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlannerConfig {
    /// Inclusive `(min, max)` bounds on planned (and retuned) per-hop
    /// windows. The max also bounds in-flight frame memory per hop.
    pub window_bounds: (usize, usize),
    /// Calibrated scalar costs ([`CostModel::calibrate`]).
    pub cost: CostModel,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            window_bounds: (1, 32),
            cost: CostModel::uncalibrated(),
        }
    }
}

/// Clamp a planned or retuned window into `bounds`.
pub fn clamp_window(window: usize, bounds: (usize, usize)) -> usize {
    window.clamp(bounds.0.max(1), bounds.1.max(bounds.0).max(1))
}

/// The bandwidth-delay window for a hop: just enough in-flight frames
/// that waiting on the round trip never gates throughput
/// (`rtt / W ≤ serv`), clamped into `bounds`.
pub fn planned_window(rtt_us: f64, serv_us: f64, bounds: (usize, usize)) -> usize {
    let need = (rtt_us / serv_us.max(1e-9)).ceil() as usize;
    clamp_window(need.max(1), bounds)
}

/// Measured request/reply `SpikeFrame` sizes per hop for a group
/// partition: a zero frame is stepped through the spans (spike planes
/// are bit-packed, so encoded size depends only on shape) and each
/// boundary's frame is encoded through the real codec. Returns one
/// `(request_bytes, reply_bytes)` pair per hop.
pub fn hop_frame_bytes(network: &Network, groups: &[(usize, usize)]) -> Result<Vec<(u64, u64)>> {
    let spans = network.group_spans(groups)?;
    let (c0, h0, w0) = network
        .layers
        .first()
        .ok_or_else(|| Error::config("empty network"))?
        .in_shape;
    let mut state = network.init_state()?;
    let mut plane = SpikePlane::zeros(c0, h0, w0);
    let mut sizes = Vec::with_capacity(spans.len());
    let mut si = 0usize;
    for span in &spans {
        let banks = span.banks();
        let in_bytes = frame_bytes(&plane);
        let (out, _) = network.step_group(span, &plane, &mut state.vmems[si..si + banks])?;
        sizes.push((in_bytes, frame_bytes(&out)));
        plane = out;
        si += banks;
    }
    Ok(sizes)
}

fn frame_bytes(plane: &SpikePlane) -> u64 {
    let (c, h, w) = plane.shape();
    Frame::SpikeFrame {
        clip: 0,
        seq: 0,
        plane: SpikePlane::zeros(c, h, w),
    }
    .to_bytes()
    .len() as u64
}

/// Modeled cost terms of one hop on one link.
fn hop_terms(
    compute_us: f64,
    bytes: (u64, u64),
    link: &LinkSpec,
    cost: &CostModel,
) -> (f64, f64) {
    let tx_in = link.tx_us(bytes.0);
    let tx_out = link.tx_us(bytes.1);
    let ovh = cost.per_frame_overhead_us;
    let serv = compute_us.max(tx_in).max(tx_out) + ovh;
    let rtt = tx_in + tx_out + 2.0 * link.latency_us as f64 + compute_us + ovh;
    (serv, rtt)
}

/// Modeled end-to-end clip makespan (microseconds) of an
/// **instantiated** topology: `groups` layer groups on hops with the
/// given `links` and per-hop `windows`. This is the formula the
/// simulate-vs-measured bench holds against real runs; see the module
/// docs for its derivation.
pub fn modeled_clip_us(
    network: &Network,
    groups: &[(usize, usize)],
    links: &[LinkSpec],
    windows: &[usize],
    cost: &CostModel,
) -> Result<f64> {
    if groups.len() != links.len() || groups.len() != windows.len() {
        return Err(Error::config(format!(
            "{} groups, {} links, {} windows: the topology vectors must align",
            groups.len(),
            links.len(),
            windows.len()
        )));
    }
    let demands = plan_layer_group_costs(network, groups);
    let bytes = hop_frame_bytes(network, groups)?;
    let t = network.timesteps.max(1) as f64;
    let mut fill = 0.0f64;
    let mut t_step = 0.0f64;
    for h in 0..groups.len() {
        let compute = demands[h] as f64 * cost.per_synop_us;
        let (serv, rtt) = hop_terms(compute, bytes[h], &links[h], cost);
        fill += rtt;
        t_step = t_step.max(serv.max(rtt / windows[h].max(1) as f64));
    }
    Ok(fill + (t - 1.0) * t_step)
}

/// Choose a deployment for `network` over `sites` (one candidate shard
/// endpoint per [`LinkSpec`]): the group count `G ∈ 1..=min(|sites|,
/// stateful layers)`, a placement of the `plan_layer_groups` partition
/// onto `G` of the sites, per-hop bandwidth-delay windows, and a
/// replica spread of the leftover sites — minimizing the modeled clip
/// makespan.
///
/// Placement is greedy-bottleneck: hops are considered in descending
/// compute demand and each takes the free site minimizing its
/// steady-state interval (ties toward lower round trip) — heavy groups
/// get fast links, and a slow link ends up with the lightest group and
/// a wide window rather than gating the whole chain.
pub fn plan_deployment(
    network: &Network,
    sites: &[LinkSpec],
    cfg: &PlannerConfig,
) -> Result<DeploymentPlan> {
    if sites.is_empty() {
        return Err(Error::config("no candidate sites to plan onto"));
    }
    let stateful = network.stateful_layers().count();
    if stateful == 0 {
        return Err(Error::config("network has no stateful layers to place"));
    }
    let t = network.timesteps.max(1) as f64;
    let mut best: Option<DeploymentPlan> = None;
    for g in 1..=stateful.min(sites.len()) {
        let groups = plan_layer_groups(network, g);
        let demands = plan_layer_group_costs(network, &groups);
        let bytes = hop_frame_bytes(network, &groups)?;

        // Greedy-bottleneck assignment: heaviest hop first, each onto
        // the free site with the smallest achievable steady interval.
        let mut order: Vec<usize> = (0..groups.len()).collect();
        order.sort_by(|&a, &b| demands[b].cmp(&demands[a]).then(a.cmp(&b)));
        let mut taken = vec![false; sites.len()];
        let mut hops: Vec<Option<HopPlan>> = vec![None; groups.len()];
        for &h in &order {
            let compute = demands[h] as f64 * cfg.cost.per_synop_us;
            let mut pick: Option<(usize, f64, f64, f64)> = None;
            for (s, spec) in sites.iter().enumerate() {
                if taken[s] {
                    continue;
                }
                let (serv, rtt) = hop_terms(compute, bytes[h], spec, &cfg.cost);
                let w = planned_window(rtt, serv, cfg.window_bounds);
                let steady = serv.max(rtt / w as f64);
                let better = match &pick {
                    None => true,
                    Some(&(_, ps, prtt, _)) => {
                        steady < ps - 1e-12 || ((steady - ps).abs() <= 1e-12 && rtt < prtt)
                    }
                };
                if better {
                    pick = Some((s, steady, rtt, serv));
                }
            }
            let (site, steady, rtt, serv) =
                pick.expect("g <= sites.len(), so a free site always remains");
            taken[site] = true;
            hops[h] = Some(HopPlan {
                site,
                replicas: 1,
                window: planned_window(rtt, serv, cfg.window_bounds),
                group: groups[h],
                compute_us: compute,
                in_bytes: bytes[h].0,
                out_bytes: bytes[h].1,
                serv_us: serv,
                rtt_us: rtt,
                steady_us: steady,
            });
        }
        let mut hops: Vec<HopPlan> = hops.into_iter().map(|h| h.unwrap()).collect();

        // Spend leftover sites as replicas on the makespan-critical
        // hops (highest steady interval first): those have the least
        // slack to absorb a failover re-push + replay.
        let spare = sites.len() - groups.len();
        if spare > 0 {
            let mut crit: Vec<usize> = (0..hops.len()).collect();
            crit.sort_by(|&a, &b| {
                hops[b]
                    .steady_us
                    .partial_cmp(&hops[a].steady_us)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            for i in 0..spare {
                hops[crit[i % crit.len()]].replicas += 1;
            }
        }

        let fill: f64 = hops.iter().map(|h| h.rtt_us).sum();
        let t_step = hops.iter().map(|h| h.steady_us).fold(0.0f64, f64::max);
        let modeled = fill + (t - 1.0) * t_step;
        let improves = match &best {
            None => true,
            Some(b) => modeled < b.modeled_clip_us - 1e-9,
        };
        if improves {
            best = Some(DeploymentPlan {
                groups,
                hops,
                modeled_clip_us: modeled,
            });
        }
    }
    Ok(best.expect("at least one group count was evaluated"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::network::demo_pipeline_network;

    fn net() -> Network {
        demo_pipeline_network(12).unwrap()
    }

    #[test]
    fn frame_bytes_follow_the_group_boundaries() {
        let n = net();
        let groups = plan_layer_groups(&n, 3);
        let bytes = hop_frame_bytes(&n, &groups).unwrap();
        assert_eq!(bytes.len(), groups.len());
        // chained hops: each reply shape is the next request shape
        for w in bytes.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        // bit-packed planes: all sizes are modest but non-zero
        assert!(bytes.iter().all(|&(i, o)| i > 0 && o > 0));
    }

    #[test]
    fn calibration_recovers_the_two_knobs() {
        let n = net();
        let t = n.timesteps as f64;
        let synops = n.dense_synops_per_timestep() as f64;
        // reference: 1 us/step/synop-unit; loopback adds 3 us/frame
        let m = CostModel::calibrate(&n, t * synops * 1e-3, t * (synops * 1e-3 + 3.0));
        assert!((m.per_synop_us - 1e-3).abs() < 1e-9);
        assert!((m.per_frame_overhead_us - 3.0).abs() < 1e-6);
    }

    #[test]
    fn planned_window_is_the_bandwidth_delay_product() {
        assert_eq!(planned_window(100.0, 100.0, (1, 32)), 1);
        assert_eq!(planned_window(1000.0, 100.0, (1, 32)), 10);
        assert_eq!(planned_window(1001.0, 100.0, (1, 32)), 11);
        assert_eq!(planned_window(1e6, 1.0, (1, 32)), 32); // clamped
        assert_eq!(planned_window(0.0, 100.0, (2, 32)), 2); // floor
    }

    #[test]
    fn free_links_reduce_to_the_pipeline_model() {
        let n = net();
        let cost = CostModel {
            per_synop_us: 1e-3,
            per_frame_overhead_us: 0.0,
        };
        let groups = plan_layer_groups(&n, 2);
        let demands = plan_layer_group_costs(&n, &groups);
        let links = vec![LinkSpec::loopback(); 2];
        let modeled = modeled_clip_us(&n, &groups, &links, &[1, 1], &cost).unwrap();
        let c: Vec<f64> = demands.iter().map(|&d| d as f64 * 1e-3).collect();
        let want = c.iter().sum::<f64>() + (n.timesteps as f64 - 1.0) * c[0].max(c[1]);
        // only the (negligible) tx terms separate the two formulas
        assert!(
            (modeled - want).abs() / want < 1e-3,
            "modeled {modeled} vs pipeline-model {want}"
        );
    }

    #[test]
    fn planner_gives_the_slow_site_the_lightest_group_and_a_wide_window() {
        let n = net();
        let sites = [
            LinkSpec::loopback(),
            LinkSpec::new(64 << 20, 2_000), // the slow, distant site
            LinkSpec::loopback(),
        ];
        let cfg = PlannerConfig::default();
        let plan = plan_deployment(&n, &sites, &cfg).unwrap();
        assert_eq!(plan.hops.len(), plan.groups.len());
        assert!(plan.modeled_clip_us > 0.0);
        if let Some(slow) = plan.hops.iter().find(|h| h.site == 1) {
            // the slow link's window must open far enough to hide its
            // round trip; free links need almost nothing
            let fast_max = plan
                .hops
                .iter()
                .filter(|h| h.site != 1)
                .map(|h| h.window)
                .max()
                .unwrap();
            assert!(
                slow.window > fast_max,
                "slow hop window {} vs fast max {fast_max}",
                slow.window
            );
            // and it hosts no more compute than any other hop
            assert!(plan
                .hops
                .iter()
                .all(|h| h.site == 1 || h.compute_us >= slow.compute_us - 1e-9));
        }
    }

    #[test]
    fn spare_sites_become_replicas_on_the_critical_hop() {
        let n = net();
        let stateful = n.stateful_layers().count();
        // more sites than stateful layers: the plan must spend the
        // spares as replicas, keeping every count >= 1
        let sites = vec![LinkSpec::loopback(); stateful + 2];
        let plan = plan_deployment(&n, &sites, &PlannerConfig::default()).unwrap();
        let total: usize = plan.hops.iter().map(|h| h.replicas).sum();
        assert_eq!(total, plan.hops.len() + 2);
        assert!(plan.hops.iter().all(|h| h.replicas >= 1));
        // the extra replicas sit on the highest modeled steady interval
        let crit = plan
            .hops
            .iter()
            .max_by(|a, b| a.steady_us.partial_cmp(&b.steady_us).unwrap())
            .unwrap();
        assert!(crit.replicas >= 2);
    }

    #[test]
    fn single_site_collapses_to_one_hop() {
        let n = net();
        let plan = plan_deployment(&n, &[LinkSpec::loopback()], &PlannerConfig::default()).unwrap();
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.hops[0].replicas, 1);
        assert_eq!(plan.windows(), vec![plan.hops[0].window]);
    }

    #[test]
    fn topology_vectors_must_align() {
        let n = net();
        let groups = plan_layer_groups(&n, 2);
        let cost = CostModel::uncalibrated();
        assert!(modeled_clip_us(&n, &groups, &[LinkSpec::loopback()], &[2, 2], &cost).is_err());
        assert!(plan_deployment(&n, &[], &PlannerConfig::default()).is_err());
    }
}
