//! The distributed coordinator: chain shard hosts into one serving
//! engine (DESIGN.md §Distributed).
//!
//! [`DistributedEngine`] owns one [`Transport`] link per layer group
//! and relays spike frames along the shard chain, one hop thread per
//! link:
//!
//! ```text
//! frames ─► hop 0 ═link═ shard 0      hop g feeds its shard over the
//!             │                       wire (≤ `window` frames in
//!             ▼ bounded channel       flight), reorders replies by
//!           hop 1 ═link═ shard 1      seq, and hands each output
//!             │                       plane to hop g+1 — so shard g
//!             ▼                       steps timestep `t` while shard
//!            ...                      g−1 steps `t+1`, the pipeline
//! ```
//!
//! The discipline is `coordinator/pipeline.rs` lifted across address
//! spaces: bounded in-process channels between hop threads plus the
//! per-link protocol window bound how far any shard can run ahead
//! (backpressure propagates through the wire — frames are never
//! dropped), and the per-hop reorder buffer is the pool's
//! sequence-number emission discipline applied to reply frames. Every
//! shard runs the same `Network::step_group` core, so the engine is
//! **bit-identical** to `ReferenceEngine`
//! (`prop_distributed_bit_identical_to_reference`).

use std::collections::BTreeMap;
use std::fmt;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::metrics::StageMetrics;
use crate::coordinator::scheduler::plan_layer_groups;
use crate::coordinator::server::Engine;
use crate::error::{Error, Result};
use crate::net::shard::{ShardHost, ShardReport};
use crate::net::transport::{LoopbackTransport, Transport};
use crate::net::wire::{Frame, Role};
use crate::snn::network::{GroupSpan, Network, StepTelemetry};
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

/// Configuration of the distributed shard engine, sibling of
/// `PipelineConfig` (`ServerConfig::distributed` /
/// `PoolConfig::distributed` select it on the serving tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DistributedConfig {
    /// Desired shard count; clamped to the network's stateful-layer
    /// count (`plan_layer_groups` never returns an empty group).
    pub shards: usize,
    /// Per-link protocol window: how many spike frames may be in
    /// flight toward one shard before its hop blocks on the reply
    /// stream (the handshaking FIFO depth of the wire).
    pub window: usize,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            shards: 2,
            window: 2,
        }
    }
}

impl DistributedConfig {
    /// A constellation of `shards` shards with the default window.
    pub fn with_shards(shards: usize) -> Self {
        DistributedConfig {
            shards,
            ..DistributedConfig::default()
        }
    }
}

/// Compact frame label for protocol-error messages (full `Debug`
/// output would dump whole spike planes).
fn frame_name(f: &Option<Frame>) -> &'static str {
    match f {
        None => "end of stream",
        Some(Frame::Hello { .. }) => "Hello",
        Some(Frame::LoadGroup { .. }) => "LoadGroup",
        Some(Frame::SpikeFrame { .. }) => "SpikeFrame",
        Some(Frame::Telemetry { .. }) => "Telemetry",
        Some(Frame::Drain { .. }) => "Drain",
        Some(Frame::Error { .. }) => "Error",
    }
}

/// Secondary error a hop reports when a neighbour exited early and
/// tore the inter-hop channel down; the parent prefers the
/// neighbour's primary error over this one.
fn hop_torn_down(hop: usize, dir: &str) -> Error {
    Error::Runtime(format!(
        "distributed hop {hop}: {dir} hop channel closed early"
    ))
}

fn is_hop_teardown(e: &Error) -> bool {
    matches!(e, Error::Runtime(m) if m.contains("hop channel closed early"))
}

/// What one hop thread hands back when its clip share completes.
struct HopOutcome {
    /// The shard's telemetry fragments, one per timestep.
    telemetry: Vec<StepTelemetry>,
    /// The shard's Vmem banks after the clip.
    vmems: Vec<Mat>,
    metrics: StageMetrics,
    finished_at: std::time::Duration,
}

/// Receive one reply from the shard and forward any now-in-order
/// output planes downstream (the reorder-buffer discipline applied to
/// reply frames).
fn pump_reply(
    link: &mut dyn Transport,
    hop: usize,
    clip_id: u64,
    reorder: &mut BTreeMap<u32, SpikePlane>,
    next_fwd: &mut u32,
    tx: &Option<SyncSender<SpikePlane>>,
    sm: &mut StageMetrics,
) -> Result<()> {
    let wait0 = Instant::now();
    let reply = link.recv()?;
    sm.busy += wait0.elapsed();
    match reply {
        Some(Frame::SpikeFrame { clip, seq, plane }) if clip == clip_id => {
            reorder.insert(seq, plane);
        }
        Some(Frame::SpikeFrame { clip, .. }) => {
            return Err(Error::protocol(format!(
                "hop {hop}: reply for clip {clip} while clip {clip_id} is in flight"
            )));
        }
        Some(Frame::Error { message }) => return Err(Error::Protocol(message)),
        other => {
            return Err(Error::protocol(format!(
                "hop {hop}: expected a spike-frame reply, got {}",
                frame_name(&other)
            )));
        }
    }
    while let Some(plane) = reorder.remove(next_fwd) {
        *next_fwd += 1;
        if let Some(tx) = tx {
            let send0 = Instant::now();
            tx.send(plane)
                .map_err(|_| hop_torn_down(hop, "downstream"))?;
            sm.stall_out += send0.elapsed();
        }
    }
    Ok(())
}

/// Body of one hop thread: relay this clip's frames to one shard,
/// keeping at most `window` frames in flight, and hand ordered output
/// planes to the next hop.
#[allow(clippy::too_many_arguments)]
fn hop_loop(
    link: &mut dyn Transport,
    span: &GroupSpan,
    hop: usize,
    frames: &[SpikePlane],
    clip_id: u64,
    window: usize,
    rx: Option<Receiver<SpikePlane>>,
    tx: Option<SyncSender<SpikePlane>>,
    epoch: Instant,
) -> Result<HopOutcome> {
    let mut sm = StageMetrics::new(hop, span.layers);
    let t_total = frames.len();
    let mut reorder: BTreeMap<u32, SpikePlane> = BTreeMap::new();
    let mut next_fwd: u32 = 0;
    let mut inflight = 0usize;
    for (t, clip_frame) in frames.iter().enumerate() {
        let owned;
        let plane = match &rx {
            None => clip_frame,
            Some(rx) => {
                let wait0 = Instant::now();
                owned = rx.recv().map_err(|_| hop_torn_down(hop, "upstream"))?;
                sm.stall_in += wait0.elapsed();
                &owned
            }
        };
        if t == 0 {
            sm.fill = epoch.elapsed();
        }
        if inflight == window {
            pump_reply(link, hop, clip_id, &mut reorder, &mut next_fwd, &tx, &mut sm)?;
            inflight -= 1;
        }
        let send0 = Instant::now();
        link.send(&Frame::SpikeFrame {
            clip: clip_id,
            seq: t as u32,
            plane: plane.clone(),
        })?;
        sm.busy += send0.elapsed();
        sm.steps += 1;
        inflight += 1;
    }
    while inflight > 0 {
        pump_reply(link, hop, clip_id, &mut reorder, &mut next_fwd, &tx, &mut sm)?;
        inflight -= 1;
    }
    link.send(&Frame::Drain { clip: clip_id })?;
    let wait0 = Instant::now();
    let reply = link.recv()?;
    sm.busy += wait0.elapsed();
    let (telemetry, vmems) = match reply {
        Some(Frame::Telemetry { clip, steps, vmems }) if clip == clip_id => (steps, vmems),
        Some(Frame::Telemetry { clip, .. }) => {
            return Err(Error::protocol(format!(
                "hop {hop}: drained clip {clip} while clip {clip_id} is in flight"
            )));
        }
        Some(Frame::Error { message }) => return Err(Error::Protocol(message)),
        other => {
            return Err(Error::protocol(format!(
                "hop {hop}: expected drained telemetry, got {}",
                frame_name(&other)
            )));
        }
    };
    if telemetry.len() != t_total {
        return Err(Error::protocol(format!(
            "hop {hop}: shard drained {} timesteps for a {t_total}-frame clip",
            telemetry.len()
        )));
    }
    Ok(HopOutcome {
        telemetry,
        vmems,
        metrics: sm,
        finished_at: epoch.elapsed(),
    })
}

/// The distributed serving engine: layer groups execute on shard
/// hosts in other threads/processes/hosts, chained over [`Transport`]
/// links, bit-identical in output and telemetry to `ReferenceEngine`.
///
/// Built either against already-connected links
/// ([`DistributedEngine::connect`] — the real multi-process topology,
/// see the `spidr shard` CLI mode) or as a self-hosted in-process
/// constellation over loopback pipes
/// ([`DistributedEngine::loopback`] — what
/// `ServerConfig::distributed` / `PoolConfig::distributed` select via
/// `FunctionalEngine::from_config`).
///
/// After a transport or shard error the engine is poisoned (remote
/// Vmem state and sequence counters are no longer trustworthy) and
/// every later `infer` fails; build a fresh engine to recover.
pub struct DistributedEngine {
    network: Network,
    groups: Vec<(usize, usize)>,
    spans: Vec<GroupSpan>,
    links: Vec<Box<dyn Transport>>,
    window: usize,
    next_clip: u64,
    poisoned: bool,
    stages: Vec<StageMetrics>,
    last_telemetry: Vec<StepTelemetry>,
    last_vmems: Vec<Mat>,
    /// Self-hosted loopback shard threads (empty for `connect`); they
    /// exit when the links drop at engine drop.
    hosts: Vec<JoinHandle<Result<ShardReport>>>,
}

impl fmt::Debug for DistributedEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DistributedEngine")
            .field("network", &self.network.name)
            .field("groups", &self.groups)
            .field("window", &self.window)
            .field("next_clip", &self.next_clip)
            .field("poisoned", &self.poisoned)
            .field("self_hosted_shards", &self.hosts.len())
            .finish()
    }
}

impl DistributedEngine {
    /// Chain already-connected shard links into an engine: plan one
    /// layer group per link, then handshake (`Hello`) and place
    /// (`LoadGroup`) each shard, validating that every shard resolved
    /// the span the coordinator planned.
    pub fn connect(
        network: Network,
        mut links: Vec<Box<dyn Transport>>,
        window: usize,
    ) -> Result<Self> {
        if links.is_empty() {
            return Err(Error::config("distributed engine needs at least one shard link"));
        }
        let groups = plan_layer_groups(&network, links.len());
        if groups.len() != links.len() {
            return Err(Error::config(format!(
                "{} shard links but the network shards into at most {} layer groups",
                links.len(),
                groups.len()
            )));
        }
        let spans = network.group_spans(&groups)?;
        let wire_groups: Vec<(u32, u32)> =
            groups.iter().map(|&(a, b)| (a as u32, b as u32)).collect();
        for (i, link) in links.iter_mut().enumerate() {
            link.send(&Frame::Hello {
                role: Role::Coordinator,
                name: network.name.clone(),
            })?;
            match link.recv()? {
                Some(Frame::Hello { role: Role::Shard, .. }) => {}
                Some(Frame::Error { message }) => return Err(Error::Protocol(message)),
                other => {
                    return Err(Error::protocol(format!(
                        "shard {i}: expected a hello, got {}",
                        frame_name(&other)
                    )));
                }
            }
            link.send(&Frame::LoadGroup {
                shard: i as u32,
                groups: wire_groups.clone(),
                span: None,
            })?;
            match link.recv()? {
                Some(Frame::LoadGroup { span: Some(span), .. }) => {
                    if span != spans[i] {
                        return Err(Error::protocol(format!(
                            "shard {i} resolved span {span:?}, coordinator planned {:?}",
                            spans[i]
                        )));
                    }
                }
                Some(Frame::Error { message }) => return Err(Error::Protocol(message)),
                other => {
                    return Err(Error::protocol(format!(
                        "shard {i}: expected a load-group ack, got {}",
                        frame_name(&other)
                    )));
                }
            }
        }
        let stages = spans
            .iter()
            .enumerate()
            .map(|(i, s)| StageMetrics::new(i, s.layers))
            .collect();
        Ok(DistributedEngine {
            network,
            groups,
            spans,
            links,
            window: window.max(1),
            next_clip: 0,
            poisoned: false,
            stages,
            last_telemetry: Vec::new(),
            last_vmems: Vec::new(),
            hosts: Vec::new(),
        })
    }

    /// Self-host a constellation: spawn one [`ShardHost`] thread per
    /// layer group, paired to the engine over [`LoopbackTransport`]
    /// byte pipes — the whole distributed path (codec, windowing,
    /// reorder, drain) with no sockets, deterministic enough for
    /// tests. The shard threads exit when the engine (and with it the
    /// pipes) drops.
    pub fn loopback(network: Network, cfg: &DistributedConfig) -> Result<Self> {
        let groups = plan_layer_groups(&network, cfg.shards.max(1));
        if groups.is_empty() {
            return Err(Error::config("network has no stateful layers to shard"));
        }
        let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(groups.len());
        let mut hosts = Vec::with_capacity(groups.len());
        for i in 0..groups.len() {
            let (coord_end, mut shard_end) = LoopbackTransport::pair();
            let net = network.clone();
            let handle = std::thread::Builder::new()
                .name(format!("spidr-shard-{i}"))
                .spawn(move || ShardHost::new(net).serve(&mut shard_end))?;
            links.push(Box::new(coord_end));
            hosts.push(handle);
        }
        let mut engine = Self::connect(network, links, cfg.window)?;
        engine.hosts = hosts;
        Ok(engine)
    }

    /// The workload this engine serves.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// The stateful-layer group placed on each shard.
    pub fn groups(&self) -> &[(usize, usize)] {
        &self.groups
    }

    /// Per-hop counters accumulated over every clip served so far
    /// (`busy` is wire round-trip time — remote compute plus codec —
    /// `stall_in`/`stall_out` are inter-hop channel waits).
    pub fn stage_metrics(&self) -> &[StageMetrics] {
        &self.stages
    }

    /// The last served clip's merged per-timestep telemetry, in layer
    /// order (the shard fragments reassembled).
    pub fn last_telemetry(&self) -> &[StepTelemetry] {
        &self.last_telemetry
    }

    /// The last served clip's final Vmem banks, in stateful-layer
    /// order (the shard banks reassembled — bit-comparable to
    /// `NetworkState::vmems` after `Network::run`).
    pub fn last_vmems(&self) -> &[Mat] {
        &self.last_vmems
    }

    /// Drive one clip through the shard chain, filling
    /// `last_telemetry` / `last_vmems` and absorbing hop metrics.
    fn run_clip(&mut self, clip: &[SpikePlane]) -> Result<()> {
        if self.poisoned {
            return Err(Error::Runtime(
                "distributed engine is poisoned by an earlier error; rebuild it".into(),
            ));
        }
        let (c0, h0, w0) = self
            .network
            .layers
            .first()
            .ok_or_else(|| Error::config("empty network"))?
            .in_shape;
        for f in clip {
            if f.shape() != (c0, h0, w0) {
                return Err(Error::shape(format!(
                    "frame shape {:?} != network input {:?}",
                    f.shape(),
                    (c0, h0, w0)
                )));
            }
        }
        let clip_id = self.next_clip;
        self.next_clip += 1;
        let window = self.window;
        let hops = self.links.len();
        let epoch = Instant::now();
        let results: Vec<Result<HopOutcome>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(hops);
            let mut prev_rx: Option<Receiver<SpikePlane>> = None;
            for (gi, (link, span)) in self.links.iter_mut().zip(self.spans.iter()).enumerate() {
                let rx = prev_rx.take();
                let tx = if gi + 1 < hops {
                    let (tx, next_rx) = sync_channel(window);
                    prev_rx = Some(next_rx);
                    Some(tx)
                } else {
                    None
                };
                handles.push(scope.spawn(move || {
                    hop_loop(&mut **link, span, gi, clip, clip_id, window, rx, tx, epoch)
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("distributed hop panicked"))
                .collect()
        });
        let wall = epoch.elapsed();

        // Prefer a hop's own failure over the secondary channel-teardown
        // errors its neighbours observe.
        let mut teardown: Option<Error> = None;
        let mut outcomes = Vec::with_capacity(hops);
        for r in results {
            match r {
                Ok(o) => outcomes.push(o),
                Err(e) if is_hop_teardown(&e) => {
                    if teardown.is_none() {
                        teardown = Some(e);
                    }
                }
                Err(e) => {
                    self.poisoned = true;
                    return Err(e);
                }
            }
        }
        if let Some(e) = teardown {
            self.poisoned = true;
            return Err(e);
        }

        let mut merged: Vec<StepTelemetry> =
            (0..clip.len()).map(|_| StepTelemetry::default()).collect();
        let mut vmems = Vec::new();
        for (o, acc) in outcomes.into_iter().zip(&mut self.stages) {
            for (t, frag) in o.telemetry.into_iter().enumerate() {
                merged[t].layer_input_spikes.extend(frag.layer_input_spikes);
                merged[t].layer_input_cells.extend(frag.layer_input_cells);
            }
            let mut sm = o.metrics;
            sm.drain = wall.saturating_sub(o.finished_at);
            acc.absorb(&sm);
            vmems.extend(o.vmems);
        }
        self.last_telemetry = merged;
        self.last_vmems = vmems;
        Ok(())
    }
}

impl Engine for DistributedEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        self.run_clip(clip)?;
        Ok(self
            .last_vmems
            .last()
            .map(|m| m.as_slice().to_vec())
            .unwrap_or_default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ReferenceEngine;
    use crate::net::transport::TcpTransport;
    use crate::prop::{check, Gen, SplitMix64};
    use crate::quant::Precision;
    use crate::sim::config::SimConfig;
    use crate::snn::layer::{NeuronConfig, ResetMode};
    use crate::snn::network::{demo_pipeline_network, demo_serving_network, NetworkBuilder};

    fn demo_clip(seed: u64, t: usize, c: usize, h: usize, w: usize) -> Vec<SpikePlane> {
        let mut rng = SplitMix64::new(seed);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if rng.chance(0.2) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn loopback_engine_matches_reference_and_resets_between_clips() {
        let net = demo_serving_network(6).unwrap();
        let clip = demo_clip(9, 6, 2, 16, 16);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();

        let mut e = DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        assert_eq!(e.groups().len(), 2);
        let a = e.infer(&clip).unwrap();
        let b = e.infer(&clip).unwrap();
        assert_eq!(a, want, "distributed output != reference output");
        assert_eq!(a, b, "shard banks must reset between clips");
        // hop counters accumulated over both clips
        assert!(e.stage_metrics().iter().all(|s| s.steps == 12));
        assert_eq!(e.last_telemetry().len(), 6);
    }

    #[test]
    fn empty_clip_is_a_noop() {
        let net = demo_serving_network(4).unwrap();
        let mut e = DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        let out = e.infer(&[]).unwrap();
        assert!(out.iter().all(|&v| v == 0));
        assert!(e.last_telemetry().is_empty());
        assert!(e.stage_metrics().iter().all(|s| s.steps == 0));
    }

    #[test]
    fn more_links_than_layer_groups_is_rejected() {
        // 2 stateful layers cannot feed 3 links
        let net = demo_serving_network(4).unwrap();
        let links: Vec<Box<dyn Transport>> = (0..3)
            .map(|_| Box::new(LoopbackTransport::pair().0) as Box<dyn Transport>)
            .collect();
        assert!(DistributedEngine::connect(net, links, 2).is_err());
    }

    #[test]
    fn bad_frame_shape_is_rejected_without_poisoning() {
        let net = demo_serving_network(4).unwrap();
        let mut e = DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        let wrong = vec![SpikePlane::zeros(2, 8, 8)];
        assert!(e.infer(&wrong).is_err());
        // shape validation happens before any frame leaves, so the
        // engine stays serviceable
        let ok = demo_clip(3, 4, 2, 16, 16);
        assert!(e.infer(&ok).is_ok());
    }

    /// The real multi-process shape, in-process: two shard hosts behind
    /// TCP sockets on localhost, chained by the coordinator — output
    /// and Vmems bit-identical to the reference executor.
    #[test]
    fn tcp_constellation_matches_reference() {
        let net = demo_pipeline_network(5).unwrap();
        let clip = demo_clip(21, 5, 2, 24, 24);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();

        let mut links: Vec<Box<dyn Transport>> = Vec::new();
        let mut hosts = Vec::new();
        for _ in 0..2 {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let shard_net = net.clone();
            hosts.push(std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                let mut link = TcpTransport::from_stream(stream);
                ShardHost::new(shard_net).serve(&mut link)
            }));
            links.push(Box::new(TcpTransport::connect(addr).unwrap()));
        }
        let mut e = DistributedEngine::connect(net, links, 2).unwrap();
        let got = e.infer(&clip).unwrap();
        assert_eq!(got, want, "TCP-distributed output != reference output");
        drop(e); // closes the sockets; shard sessions end cleanly
        for h in hosts {
            let report = h.join().unwrap().unwrap();
            assert_eq!(report.clips, 1);
            assert_eq!(report.frames, 5);
        }
    }

    fn rand_mat(g: &mut Gen, rows: usize, cols: usize) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, g.i32_in(-7..=7));
            }
        }
        m
    }

    /// A random spiking network: 1–3 hidden conv layers (random
    /// channels, thresholds, leaks, reset modes), an optional pool,
    /// and an accumulate FC readout (mirrors the pipeline prop test).
    fn random_network(g: &mut Gen) -> crate::snn::network::Network {
        let in_ch = 1 + g.index(2);
        let h = 4 + 2 * g.index(3);
        let w = 4 + 2 * g.index(3);
        let hidden = 1 + g.index(3);
        let pool_after = g.index(hidden + 1); // == hidden means "none"
        let mut b = NetworkBuilder::new("prop-dist", Precision::W4V7, 3, (in_ch, h, w));
        for i in 0..hidden {
            let (c, _, _) = b.shape();
            let out_ch = 2 + g.index(5);
            let neuron = NeuronConfig {
                theta: 1 + g.i32_in(0..=6),
                leak: g.i32_in(0..=2),
                leaky: g.chance(0.5),
                reset: if g.chance(0.5) {
                    ResetMode::Soft
                } else {
                    ResetMode::Hard
                },
            };
            let wm = rand_mat(g, c * 9, out_ch);
            b = b.conv3x3(out_ch, wm, neuron, false).unwrap();
            if i == pool_after {
                b = b.pool(2, 2);
            }
        }
        let (c, hh, ww) = b.shape();
        let out = 2 + g.index(3);
        let wm = rand_mat(g, c * hh * ww, out);
        b.fc(out, wm, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    /// Acceptance: over random networks, shard counts and windows, the
    /// loopback constellation's Vmems *and* telemetry are bit-identical
    /// to `Network::run` — and the scheduler's cycle-level path agrees,
    /// so all three executors stay pinned to one functional core.
    #[test]
    fn prop_distributed_bit_identical_to_reference() {
        check("distributed_bit_identical", 10, |g| {
            let net = random_network(g);
            let t = 1 + g.index(4);
            let (c, h, w) = net.layers[0].in_shape;
            let density = 0.1 + g.f64() * 0.4;
            let frames: Vec<SpikePlane> = (0..t)
                .map(|_| {
                    let mut p = SpikePlane::zeros(c, h, w);
                    for i in 0..p.len() {
                        if g.chance(density) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect();
            let stateful = net.stateful_layers().count();
            let cfg = DistributedConfig {
                shards: 1 + g.index(stateful + 2), // may exceed the layer count
                window: 1 + g.index(3),
            };

            // sequential reference
            let mut ref_state = net.init_state().unwrap();
            let ref_tel = net.run(&frames, &mut ref_state).unwrap();

            // distributed constellation
            let mut e = DistributedEngine::loopback(net.clone(), &cfg).unwrap();
            e.infer(&frames).unwrap();

            // cycle-level scheduler path as a cross-check
            let sched =
                crate::coordinator::scheduler::MultiCoreScheduler::new(2, SimConfig::default());
            let mut sim_state = net.init_state().unwrap();
            sched.run_network_clip(&net, &frames, &mut sim_state).unwrap();

            e.last_telemetry() == &ref_tel[..]
                && ref_state
                    .vmems
                    .iter()
                    .zip(e.last_vmems())
                    .all(|(a, b)| a.as_slice() == b.as_slice())
                && ref_state
                    .vmems
                    .iter()
                    .zip(&sim_state.vmems)
                    .all(|(a, b)| a.as_slice() == b.as_slice())
        });
    }
}
