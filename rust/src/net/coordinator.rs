//! The distributed coordinator: chain shard hosts into one serving
//! engine (DESIGN.md §Distributed).
//!
//! [`DistributedEngine`] owns one or more [`Transport`] replica links
//! per layer group and relays spike frames along the shard chain, one
//! hop thread per group:
//!
//! ```text
//! frames ─► hop 0 ═link═ shard 0a │ 0b  hop g feeds one replica of
//!             │                        its group over the wire (≤
//!             ▼ bounded channel        `window` frames in flight),
//!           hop 1 ═link═ shard 1a │ 1b reorders replies by seq, and
//!             │                        hands each output plane to
//!             ▼                        hop g+1 — so shard g steps
//!            ...                       timestep `t` while shard g−1
//!                                      steps `t+1`, the pipeline
//! ```
//!
//! The discipline is `coordinator/pipeline.rs` lifted across address
//! spaces: bounded in-process channels between hop threads plus the
//! per-link protocol window bound how far any shard can run ahead
//! (backpressure propagates through the wire — frames are never
//! dropped), and the per-hop reorder buffer is the pool's
//! sequence-number emission discipline applied to reply frames. Every
//! shard runs the same `Network::step_group` core, so the engine is
//! **bit-identical** to `ReferenceEngine`
//! (`prop_distributed_bit_identical_to_reference`).
//!
//! **Provisioning**: at session start the coordinator pushes the
//! serialized workload ([`crate::net::wire::encode_network`]) to every
//! replica inside its first `LoadGroup`, so shards can start blank
//! (`spidr shard --listen` with no `--workload`) — weights cross the
//! wire once and stay pinned.
//!
//! **Failover**: with `DistributedConfig::replicas > 1`, each hop fans
//! clips across its replicas with the pool's least-loaded discipline.
//! When the active replica's transport or protocol fails mid-clip, the
//! hop re-pushes the group to a surviving replica (a weightless
//! `LoadGroup`, which resets its banks) and **replays** the clip's
//! frames from its per-clip log; replies whose `seq` is below the
//! already-forwarded watermark are regenerated bit-identically (the
//! executor is deterministic) and dropped, so downstream hops see each
//! output plane exactly once. Only a hop with **zero survivors**
//! degrades to the old fail-fast behavior and poisons the engine.
//!
//! **Windows**: each hop has its own protocol window — how many frames
//! may be in flight on its link before the hop blocks on replies.
//! `DistributedConfig::window` seeds a uniform schedule;
//! [`DistributedEngine::set_windows`] pins an explicit per-hop one and
//! [`DistributedEngine::retune_windows`] closes the loop at runtime,
//! widening the wire-bound hop and narrowing idle ones from the
//! hops' own stall counters (DESIGN.md §Planner). Windows bound
//! in-flight frames, never what is computed, so outputs stay
//! bit-identical under any schedule
//! (`prop_window_schedule_invariant`).

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use crate::sync::thread::JoinHandle;
use std::collections::BTreeMap;
use std::fmt;
use std::time::Instant;

use crate::coordinator::metrics::StageMetrics;
use crate::coordinator::scheduler::plan_layer_groups;
use crate::coordinator::server::Engine;
use crate::error::{Error, Result};
use crate::net::plan::LinkSpec;
use crate::net::shard::{ShardHost, ShardReport};
use crate::net::transport::{LoopbackTransport, Transport};
use crate::net::wire::{
    encode_network, Frame, LaneReport, Role, LANE_VERSION, MAX_PAYLOAD, VERSION,
};
use crate::obs::trace::{self, TraceId};
use crate::snn::network::{GroupSpan, Network, StepTelemetry};
use crate::snn::spikes::{LaneFrame, SpikePlane, MAX_LANES};
use crate::snn::tensor::Mat;

/// Configuration of the distributed shard engine, sibling of
/// `PipelineConfig` (`ServerConfig::distributed` /
/// `PoolConfig::distributed` select it on the serving tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DistributedConfig {
    /// Desired shard count; clamped to the network's stateful-layer
    /// count (`plan_layer_groups` never returns an empty group).
    pub shards: usize,
    /// Per-link protocol window: how many spike frames may be in
    /// flight toward one shard before its hop blocks on the reply
    /// stream (the handshaking FIFO depth of the wire). This seeds a
    /// **uniform** per-hop schedule; `DistributedEngine::set_windows`
    /// and `DistributedEngine::retune_windows` respecialize individual
    /// hops at runtime.
    pub window: usize,
    /// Replica links per shard hop (≥ 1). With more than one, a hop
    /// fans clips across its replicas least-loaded-first and fails
    /// over — re-push + replay — when the active replica dies; the
    /// engine only fails once a hop has zero survivors.
    pub replicas: usize,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            shards: 2,
            window: 2,
            replicas: 1,
        }
    }
}

impl DistributedConfig {
    /// A constellation of `shards` shards with the default window and
    /// no replication.
    pub fn with_shards(shards: usize) -> Self {
        DistributedConfig {
            shards,
            ..DistributedConfig::default()
        }
    }

    /// A fault-tolerant constellation: `shards` hops with `replicas`
    /// links each.
    pub fn replicated(shards: usize, replicas: usize) -> Self {
        DistributedConfig {
            shards,
            replicas,
            ..DistributedConfig::default()
        }
    }
}

/// Compact frame label for protocol-error messages (full `Debug`
/// output would dump whole spike planes).
fn frame_name(f: &Option<Frame>) -> &'static str {
    match f {
        None => "end of stream",
        Some(Frame::Hello { .. }) => "Hello",
        Some(Frame::LoadGroup { .. }) => "LoadGroup",
        Some(Frame::SpikeFrame { .. }) => "SpikeFrame",
        Some(Frame::Telemetry { .. }) => "Telemetry",
        Some(Frame::Drain { .. }) => "Drain",
        Some(Frame::Error { .. }) => "Error",
        Some(Frame::LaneBatchOpen { .. }) => "LaneBatchOpen",
        Some(Frame::LaneFrame { .. }) => "LaneFrame",
        Some(Frame::LaneTelemetry { .. }) => "LaneTelemetry",
        Some(Frame::TraceSync { .. }) => "TraceSync",
        Some(Frame::TraceCtx { .. }) => "TraceCtx",
        Some(Frame::TraceFlush) => "TraceFlush",
        Some(Frame::TraceSpans { .. }) => "TraceSpans",
    }
}

/// Secondary error a hop reports when a neighbour exited early and
/// tore the inter-hop channel down; the parent prefers the
/// neighbour's primary error over this one.
fn hop_torn_down(hop: usize, dir: &str) -> Error {
    Error::Runtime(format!(
        "distributed hop {hop}: {dir} hop channel closed early"
    ))
}

fn is_hop_teardown(e: &Error) -> bool {
    matches!(e, Error::Runtime(m) if m.contains("hop channel closed early"))
}

/// One replica link of a hop, with its failover state and the
/// clips-served counter the least-loaded pick balances on.
struct Replica {
    link: Box<dyn Transport>,
    /// False once a transport/protocol failure was observed on this
    /// link; dead replicas are never picked again.
    alive: bool,
    /// Clips this replica served (the least-loaded dispatch key, the
    /// pool's discipline applied to replica links; every lane of a
    /// batch counts).
    clips: u64,
    /// Protocol dialect the replica's `Hello` ack was stamped with,
    /// capped at this build's [`VERSION`] — the negotiation input for
    /// [`DistributedEngine::negotiated_version`].
    version: u16,
    /// Estimated shard-clock minus coordinator-clock offset in µs,
    /// measured by a `TraceSync` ping at connect time (0 when tracing
    /// was disabled or the replica is pre-v3). Feeds
    /// [`Tracer::inject`](crate::obs::trace::Tracer::inject) so the
    /// shard's flushed spans land on the coordinator timeline.
    trace_offset_us: i64,
}

/// How one relay attempt on a replica failed.
enum HopFailure {
    /// The active replica's link or shard failed — mark it dead and
    /// fail over to a survivor.
    Replica(Error),
    /// A neighbouring hop tore the in-process channel down (or the
    /// run is otherwise unrecoverable); no replica can fix this.
    Fatal(Error),
}

/// What one hop thread hands back when its clip share completes.
struct HopOutcome {
    /// The shard's telemetry fragments, one per timestep.
    telemetry: Vec<StepTelemetry>,
    /// The shard's Vmem banks after the clip.
    vmems: Vec<Mat>,
    metrics: StageMetrics,
    finished_at: std::time::Duration,
}

/// What one hop thread hands back when its lane-batch share completes.
struct LaneHopOutcome {
    /// One drain report per lane: this span's telemetry fragments and
    /// Vmem banks for that lane's clip.
    reports: Vec<LaneReport>,
    metrics: StageMetrics,
    finished_at: std::time::Duration,
}

/// Least-loaded alive replica (ties break toward the lowest index).
fn pick_replica(replicas: &[Replica]) -> Option<usize> {
    replicas
        .iter()
        .enumerate()
        .filter(|(_, r)| r.alive)
        .min_by_key(|(i, r)| (r.clips, *i))
        .map(|(i, _)| i)
}

/// Receive from an inter-hop channel, sampling the stall timer only on
/// the blocking path: a `try_recv` probe first — when the frame is
/// already there (the fast path under load) **no timestamp is taken**
/// — then, only if the channel was empty, a blocking `recv` bracketed
/// by one `Instant::now()` pair that lands in `stall_in` and bumps
/// `stall_samples`. `Err(())` is upstream teardown.
fn timed_recv<T>(rx: &Receiver<T>, sm: &mut StageMetrics) -> std::result::Result<T, ()> {
    match rx.try_recv() {
        Ok(v) => Ok(v),
        Err(TryRecvError::Disconnected) => Err(()),
        Err(TryRecvError::Empty) => {
            let wait0 = Instant::now(); // lint: wall-clock
            let got = rx.recv();
            sm.stall_in += wait0.elapsed();
            sm.stall_samples += 1;
            got.map_err(|_| ())
        }
    }
}

/// [`timed_recv`]'s send twin: `try_send` first (fast path, no
/// timestamp), and only a full downstream channel pays the
/// `Instant::now()` pair — into `stall_out`, counted in
/// `stall_samples`. `Err(())` is downstream teardown.
fn timed_send<T>(
    tx: &SyncSender<T>,
    value: T,
    sm: &mut StageMetrics,
) -> std::result::Result<(), ()> {
    match tx.try_send(value) {
        Ok(()) => Ok(()),
        Err(TrySendError::Disconnected(_)) => Err(()),
        Err(TrySendError::Full(value)) => {
            let send0 = Instant::now(); // lint: wall-clock
            let sent = tx.send(value);
            sm.stall_out += send0.elapsed();
            sm.stall_samples += 1;
            sent.map_err(|_| ())
        }
    }
}

/// Send one spike frame to the shard.
fn send_frame(
    link: &mut dyn Transport,
    clip_id: u64,
    seq: usize,
    plane: &SpikePlane,
    sm: &mut StageMetrics,
) -> std::result::Result<(), HopFailure> {
    let send0 = Instant::now(); // lint: wall-clock
    link.send(&Frame::SpikeFrame {
        clip: clip_id,
        seq: seq as u32,
        plane: plane.clone(),
    })
    .map_err(HopFailure::Replica)?;
    sm.busy += send0.elapsed();
    Ok(())
}

/// The reorder-buffer watermark discipline, factored out of
/// [`pump_reply`] / [`pump_lane_reply`] so `tests/model.rs` can
/// model-check it without a transport: admit `item` at `seq` into the
/// reorder buffer — a `seq` below the already-forwarded watermark is a
/// failover-replay regeneration (bit-identical by determinism) and is
/// dropped so downstream sees each frame once — then drain every
/// now-in-order item through `forward`, advancing the watermark.
pub fn admit_and_forward<T, E>(
    reorder: &mut BTreeMap<u32, T>,
    next_fwd: &mut u32,
    seq: u32,
    item: T,
    mut forward: impl FnMut(T) -> std::result::Result<(), E>,
) -> std::result::Result<(), E> {
    if seq >= *next_fwd {
        reorder.insert(seq, item);
    }
    while let Some(item) = reorder.remove(next_fwd) {
        *next_fwd += 1;
        forward(item)?;
    }
    Ok(())
}

/// Receive one reply from the shard and forward any now-in-order
/// output planes downstream (the reorder-buffer discipline applied to
/// reply frames). Replies whose `seq` is below the already-forwarded
/// watermark are failover-replay regenerations — bit-identical by
/// determinism — and are dropped so downstream sees each plane once.
fn pump_reply(
    link: &mut dyn Transport,
    hop: usize,
    clip_id: u64,
    reorder: &mut BTreeMap<u32, SpikePlane>,
    next_fwd: &mut u32,
    tx: Option<&SyncSender<SpikePlane>>,
    sm: &mut StageMetrics,
) -> std::result::Result<(), HopFailure> {
    let wait0 = Instant::now(); // lint: wall-clock
    let reply = link.recv().map_err(HopFailure::Replica)?;
    sm.busy += wait0.elapsed();
    let (seq, plane) = match reply {
        Some(Frame::SpikeFrame { clip, seq, plane }) if clip == clip_id => (seq, plane),
        Some(Frame::SpikeFrame { clip, .. }) => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: reply for clip {clip} while clip {clip_id} is in flight"
            ))));
        }
        Some(Frame::Error { message }) => {
            return Err(HopFailure::Replica(Error::Protocol(message)));
        }
        other => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: expected a spike-frame reply, got {}",
                frame_name(&other)
            ))));
        }
    };
    admit_and_forward(reorder, next_fwd, seq, plane, |plane| {
        if let Some(tx) = tx {
            timed_send(tx, plane, sm)
                .map_err(|()| HopFailure::Fatal(hop_torn_down(hop, "downstream")))?;
        }
        Ok(())
    })
}

/// One relay attempt of a clip on one replica: optionally re-push the
/// group (failover entry — resets the replica's banks and seq
/// expectation), replay the `relayed` frames already consumed by
/// earlier attempts, then relay live frames, drain, and return the
/// shard's telemetry + Vmems.
///
/// The replay source is the caller's own clip slice for the first hop
/// (its frames are resident for the clip's lifetime — no copies) and
/// the `sent` log for upstream-fed hops. `log` keeps that log; it is
/// off for single-replica hops (failover is unreachable there — a
/// dead replica means zero survivors), which keeps the old zero-copy
/// relay on that path.
#[allow(clippy::too_many_arguments)]
fn serve_on_replica(
    link: &mut dyn Transport,
    span: &GroupSpan,
    wire_groups: &[(u32, u32)],
    hop: usize,
    frames: &[SpikePlane],
    clip_id: u64,
    window: usize,
    rx: Option<&Receiver<SpikePlane>>,
    tx: Option<&SyncSender<SpikePlane>>,
    log: bool,
    sent: &mut Vec<SpikePlane>,
    relayed: &mut usize,
    next_fwd: &mut u32,
    sm: &mut StageMetrics,
    epoch: Instant,
    reprovision: bool,
    trace_ctx: Option<u64>,
) -> std::result::Result<(Vec<StepTelemetry>, Vec<Mat>), HopFailure> {
    let t_total = frames.len();
    if reprovision {
        // Weightless re-push: the survivor was provisioned at session
        // start, so only the group assignment travels; the shard
        // resets its banks/telemetry/seq for the replay.
        link.send(&Frame::LoadGroup {
            shard: hop as u32,
            groups: wire_groups.to_vec(),
            span: None,
            workload: None,
        })
        .map_err(HopFailure::Replica)?;
        match link.recv().map_err(HopFailure::Replica)? {
            Some(Frame::LoadGroup { span: Some(s), .. }) if s == *span => {}
            Some(Frame::Error { message }) => {
                return Err(HopFailure::Replica(Error::Protocol(message)));
            }
            other => {
                return Err(HopFailure::Replica(Error::protocol(format!(
                    "hop {hop}: failover re-push expected a load-group ack, got {}",
                    frame_name(&other)
                ))));
            }
        }
    }
    // Trace sideband: bind this clip to its trace on the shard so its
    // spans join the coordinator timeline. Fire-and-forget (no ack);
    // re-sent on every failover attempt since a survivor never saw it.
    if let Some(trace) = trace_ctx {
        link.send(&Frame::TraceCtx {
            trace,
            clip: clip_id,
        })
        .map_err(HopFailure::Replica)?;
    }
    let mut reorder: BTreeMap<u32, SpikePlane> = BTreeMap::new();
    let mut inflight = 0usize;
    // Replay the frames earlier attempts already consumed (no-op on
    // the first attempt). The first hop replays straight from the
    // caller's clip slice; upstream hops replay their log. `steps` is
    // not re-counted: replays are recovery traffic, not new timesteps.
    let replay: &[SpikePlane] = match rx {
        None => &frames[..*relayed],
        Some(_) => &sent[..*relayed],
    };
    for (t, plane) in replay.iter().enumerate() {
        if inflight == window {
            pump_reply(link, hop, clip_id, &mut reorder, next_fwd, tx, sm)?;
            inflight -= 1;
        }
        send_frame(link, clip_id, t, plane, sm)?;
        inflight += 1;
    }
    // Live frames: pull from upstream (or the clip source), log, send.
    let mut t = *relayed;
    while t < t_total {
        let mut owned: Option<SpikePlane> = None;
        if let Some(rx) = rx {
            // The first-frame wait is the fill front (`fill`, below),
            // not starvation — only steady-state pulls run the stall
            // timer (same split as the local pipeline's stage loop).
            let p = if t == 0 {
                rx.recv().map_err(|_| ())
            } else {
                timed_recv(rx, sm)
            }
            .map_err(|()| HopFailure::Fatal(hop_torn_down(hop, "upstream")))?;
            owned = Some(p);
        }
        if t == 0 {
            sm.fill = epoch.elapsed();
        }
        // Commit the plane to the replay source *before* anything can
        // fail: a pump/send error below must never drop a plane
        // already consumed from the upstream channel — the failover
        // retry could not regenerate it and would wedge on a short
        // channel. (First-hop planes live in `frames`; only the
        // cursor moves.)
        if log {
            if let Some(p) = owned.take() {
                sent.push(p);
            }
        }
        *relayed = t + 1;
        if inflight == window {
            pump_reply(link, hop, clip_id, &mut reorder, next_fwd, tx, sm)?;
            inflight -= 1;
        }
        let plane: &SpikePlane = if rx.is_none() {
            &frames[t]
        } else if log {
            &sent[t]
        } else {
            // single-replica upstream hop: no retry is possible, so
            // the plane is relayed without ever touching a log
            owned.as_ref().expect("upstream plane is resident")
        };
        send_frame(link, clip_id, t, plane, sm)?;
        sm.steps += 1;
        inflight += 1;
        t += 1;
    }
    while inflight > 0 {
        pump_reply(link, hop, clip_id, &mut reorder, next_fwd, tx, sm)?;
        inflight -= 1;
    }
    link.send(&Frame::Drain { clip: clip_id })
        .map_err(HopFailure::Replica)?;
    let wait0 = Instant::now(); // lint: wall-clock
    let reply = link.recv().map_err(HopFailure::Replica)?;
    sm.busy += wait0.elapsed();
    let (telemetry, vmems) = match reply {
        Some(Frame::Telemetry { clip, steps, vmems }) if clip == clip_id => (steps, vmems),
        Some(Frame::Telemetry { clip, .. }) => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: drained clip {clip} while clip {clip_id} is in flight"
            ))));
        }
        Some(Frame::Error { message }) => {
            return Err(HopFailure::Replica(Error::Protocol(message)));
        }
        other => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: expected drained telemetry, got {}",
                frame_name(&other)
            ))));
        }
    };
    if telemetry.len() != t_total {
        return Err(HopFailure::Replica(Error::protocol(format!(
            "hop {hop}: shard drained {} timesteps for a {t_total}-frame clip",
            telemetry.len()
        ))));
    }
    Ok((telemetry, vmems))
}

/// Body of one hop thread: relay this clip's frames to the hop's
/// least-loaded replica, failing over — re-push + replay — on replica
/// death until the clip completes or no survivor remains. Each
/// absorbed failover bumps the shared engine counter immediately, so
/// the count survives even when the clip ultimately errors.
#[allow(clippy::too_many_arguments)]
fn relay_clip(
    replicas: &mut [Replica],
    span: &GroupSpan,
    wire_groups: &[(u32, u32)],
    hop: usize,
    frames: &[SpikePlane],
    clip_id: u64,
    window: usize,
    rx: Option<Receiver<SpikePlane>>,
    tx: Option<SyncSender<SpikePlane>>,
    epoch: Instant,
    failovers: &AtomicU64,
) -> Result<HopOutcome> {
    let mut sm = StageMetrics::new(hop, span.layers);
    // Per-clip replay state + forwarded watermark: the clip/seq
    // identity that lets a survivor resume exactly where the dead
    // replica left. The first hop replays from the caller's clip
    // slice (only the `relayed` cursor moves); upstream hops keep the
    // `sent` log. Single-replica hops skip the log entirely — no
    // survivor could replay it.
    let log = replicas.len() > 1 && rx.is_some();
    let mut sent: Vec<SpikePlane> = Vec::new();
    let mut relayed = 0usize;
    let mut next_fwd: u32 = 0;
    let mut attempt = 0usize;
    // Sampled clips carry their trace id to v3 replicas (the hop
    // thread runs under the clip's trace binding); unsampled clips
    // put nothing trace-related on the wire.
    let clip_trace = trace::current();
    let sampled = trace::tracer().should_sample(clip_trace);
    loop {
        let Some(ri) = pick_replica(replicas) else {
            return Err(Error::Runtime(format!(
                "distributed hop {hop}: zero surviving replicas"
            )));
        };
        let trace_ctx =
            (sampled && replicas[ri].version >= LANE_VERSION).then_some(clip_trace.0);
        let reprovision = attempt > 0;
        attempt += 1;
        match serve_on_replica(
            &mut *replicas[ri].link,
            span,
            wire_groups,
            hop,
            frames,
            clip_id,
            window,
            rx.as_ref(),
            tx.as_ref(),
            log,
            &mut sent,
            &mut relayed,
            &mut next_fwd,
            &mut sm,
            epoch,
            reprovision,
            trace_ctx,
        ) {
            Ok((telemetry, vmems)) => {
                replicas[ri].clips += 1;
                return Ok(HopOutcome {
                    telemetry,
                    vmems,
                    metrics: sm,
                    finished_at: epoch.elapsed(),
                });
            }
            Err(HopFailure::Fatal(e)) => return Err(e),
            Err(HopFailure::Replica(e)) => {
                replicas[ri].alive = false;
                if !replicas.iter().any(|r| r.alive) {
                    // Zero survivors: degrade to fail-fast with the
                    // last replica's primary error.
                    return Err(e);
                }
                // A survivor remains: count the absorbed failover
                // (immediately — it must survive a later clip error)
                // and loop around to re-push + replay.
                failovers.fetch_add(1, Ordering::Relaxed);
                trace::instant("failover");
            }
        }
    }
}

/// Send one lane frame — one timestep of the whole batch, `lanes` bits
/// per cell on the wire.
fn send_lane_frame(
    link: &mut dyn Transport,
    batch_id: u64,
    seq: usize,
    frame: &LaneFrame,
    sm: &mut StageMetrics,
) -> std::result::Result<(), HopFailure> {
    let send0 = Instant::now(); // lint: wall-clock
    link.send(&Frame::LaneFrame {
        batch: batch_id,
        seq: seq as u32,
        frame: frame.clone(),
    })
    .map_err(HopFailure::Replica)?;
    sm.busy += send0.elapsed();
    Ok(())
}

/// [`pump_reply`] for lane batches: receive one lane-frame reply,
/// reorder by seq, forward in-order frames downstream. The watermark
/// discipline is per *batch* — a dropped duplicate drops that seq's
/// reply for **every lane at once**, which is exactly the per-lane
/// drop (all 64 lanes regenerate bit-identically together).
#[allow(clippy::too_many_arguments)]
fn pump_lane_reply(
    link: &mut dyn Transport,
    hop: usize,
    batch_id: u64,
    lanes: usize,
    reorder: &mut BTreeMap<u32, LaneFrame>,
    next_fwd: &mut u32,
    tx: Option<&SyncSender<LaneFrame>>,
    sm: &mut StageMetrics,
) -> std::result::Result<(), HopFailure> {
    let wait0 = Instant::now(); // lint: wall-clock
    let reply = link.recv().map_err(HopFailure::Replica)?;
    sm.busy += wait0.elapsed();
    let (seq, frame) = match reply {
        Some(Frame::LaneFrame { batch, seq, frame }) if batch == batch_id => {
            if frame.lanes() != lanes {
                return Err(HopFailure::Replica(Error::protocol(format!(
                    "hop {hop}: reply carries {} lanes for a {lanes}-lane batch",
                    frame.lanes()
                ))));
            }
            (seq, frame)
        }
        Some(Frame::LaneFrame { batch, .. }) => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: reply for batch {batch} while batch {batch_id} is in flight"
            ))));
        }
        Some(Frame::Error { message }) => {
            return Err(HopFailure::Replica(Error::Protocol(message)));
        }
        other => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: expected a lane-frame reply, got {}",
                frame_name(&other)
            ))));
        }
    };
    admit_and_forward(reorder, next_fwd, seq, frame, |frame| {
        if let Some(tx) = tx {
            timed_send(tx, frame, sm)
                .map_err(|()| HopFailure::Fatal(hop_torn_down(hop, "downstream")))?;
        }
        Ok(())
    })
}

/// One relay attempt of a lane batch on one replica:
/// [`serve_on_replica`] lifted to lane frames. Every attempt opens the
/// batch (`LaneBatchOpen` + ack) because a failover's weightless
/// `LoadGroup` re-push also clears the shard's lane session; the
/// replay then re-sends the `relayed` lane frames earlier attempts
/// consumed, regenerating all lanes bit-identically, and the batch
/// watermark drops duplicate replies for every lane at once.
#[allow(clippy::too_many_arguments)]
fn serve_batch_on_replica(
    link: &mut dyn Transport,
    span: &GroupSpan,
    wire_groups: &[(u32, u32)],
    hop: usize,
    frames: &[LaneFrame],
    batch_id: u64,
    clip_ids: &[u64],
    window: usize,
    rx: Option<&Receiver<LaneFrame>>,
    tx: Option<&SyncSender<LaneFrame>>,
    log: bool,
    sent: &mut Vec<LaneFrame>,
    relayed: &mut usize,
    next_fwd: &mut u32,
    sm: &mut StageMetrics,
    epoch: Instant,
    reprovision: bool,
    trace_ctx: Option<u64>,
) -> std::result::Result<Vec<LaneReport>, HopFailure> {
    let t_total = frames.len();
    let lanes = clip_ids.len();
    if reprovision {
        link.send(&Frame::LoadGroup {
            shard: hop as u32,
            groups: wire_groups.to_vec(),
            span: None,
            workload: None,
        })
        .map_err(HopFailure::Replica)?;
        match link.recv().map_err(HopFailure::Replica)? {
            Some(Frame::LoadGroup { span: Some(s), .. }) if s == *span => {}
            Some(Frame::Error { message }) => {
                return Err(HopFailure::Replica(Error::Protocol(message)));
            }
            other => {
                return Err(HopFailure::Replica(Error::protocol(format!(
                    "hop {hop}: failover re-push expected a load-group ack, got {}",
                    frame_name(&other)
                ))));
            }
        }
    }
    // Trace sideband: the batch is anchored on its first lane's clip
    // id (mirrors the shard's first-traced-lane anchor); re-sent per
    // failover attempt.
    if let Some(trace) = trace_ctx {
        link.send(&Frame::TraceCtx {
            trace,
            clip: clip_ids[0],
        })
        .map_err(HopFailure::Replica)?;
    }
    link.send(&Frame::LaneBatchOpen {
        batch: batch_id,
        clips: clip_ids.to_vec(),
    })
    .map_err(HopFailure::Replica)?;
    match link.recv().map_err(HopFailure::Replica)? {
        Some(Frame::LaneBatchOpen { batch, clips })
            if batch == batch_id && clips == clip_ids =>
        {
        }
        Some(Frame::Error { message }) => {
            return Err(HopFailure::Replica(Error::Protocol(message)));
        }
        other => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: expected a lane-batch-open ack, got {}",
                frame_name(&other)
            ))));
        }
    }
    let mut reorder: BTreeMap<u32, LaneFrame> = BTreeMap::new();
    let mut inflight = 0usize;
    let replay: &[LaneFrame] = match rx {
        None => &frames[..*relayed],
        Some(_) => &sent[..*relayed],
    };
    for (t, frame) in replay.iter().enumerate() {
        if inflight == window {
            pump_lane_reply(link, hop, batch_id, lanes, &mut reorder, next_fwd, tx, sm)?;
            inflight -= 1;
        }
        send_lane_frame(link, batch_id, t, frame, sm)?;
        inflight += 1;
    }
    let mut t = *relayed;
    while t < t_total {
        let mut owned: Option<LaneFrame> = None;
        if let Some(rx) = rx {
            // Fill front, not starvation: first pull skips the stall
            // timer (see the scalar hop loop).
            let f = if t == 0 {
                rx.recv().map_err(|_| ())
            } else {
                timed_recv(rx, sm)
            }
            .map_err(|()| HopFailure::Fatal(hop_torn_down(hop, "upstream")))?;
            owned = Some(f);
        }
        if t == 0 {
            sm.fill = epoch.elapsed();
        }
        // Same commit-before-fallible-ops rule as the scalar path: a
        // plane pulled off the upstream channel must reach the replay
        // log before any send/pump can fail.
        if log {
            if let Some(f) = owned.take() {
                sent.push(f);
            }
        }
        *relayed = t + 1;
        if inflight == window {
            pump_lane_reply(link, hop, batch_id, lanes, &mut reorder, next_fwd, tx, sm)?;
            inflight -= 1;
        }
        let frame: &LaneFrame = if rx.is_none() {
            &frames[t]
        } else if log {
            &sent[t]
        } else {
            owned.as_ref().expect("upstream lane frame is resident")
        };
        send_lane_frame(link, batch_id, t, frame, sm)?;
        sm.steps += 1;
        inflight += 1;
        t += 1;
    }
    while inflight > 0 {
        pump_lane_reply(link, hop, batch_id, lanes, &mut reorder, next_fwd, tx, sm)?;
        inflight -= 1;
    }
    link.send(&Frame::Drain { clip: batch_id })
        .map_err(HopFailure::Replica)?;
    let wait0 = Instant::now(); // lint: wall-clock
    let reply = link.recv().map_err(HopFailure::Replica)?;
    sm.busy += wait0.elapsed();
    let reports = match reply {
        Some(Frame::LaneTelemetry { batch, lanes: reports }) if batch == batch_id => reports,
        Some(Frame::LaneTelemetry { batch, .. }) => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: drained batch {batch} while batch {batch_id} is in flight"
            ))));
        }
        Some(Frame::Error { message }) => {
            return Err(HopFailure::Replica(Error::Protocol(message)));
        }
        other => {
            return Err(HopFailure::Replica(Error::protocol(format!(
                "hop {hop}: expected drained lane telemetry, got {}",
                frame_name(&other)
            ))));
        }
    };
    if reports.len() != lanes {
        return Err(HopFailure::Replica(Error::protocol(format!(
            "hop {hop}: shard drained {} lanes for a {lanes}-lane batch",
            reports.len()
        ))));
    }
    if let Some(r) = reports.iter().find(|r| r.steps.len() != t_total) {
        return Err(HopFailure::Replica(Error::protocol(format!(
            "hop {hop}: shard drained {} timesteps for a {t_total}-frame batch",
            r.steps.len()
        ))));
    }
    Ok(reports)
}

/// Body of one hop thread serving a lane batch: [`relay_clip`] with
/// whole batches as the replay unit — on replica death the survivor is
/// re-pushed, the batch re-opened, and the already-consumed lane
/// frames replayed, so every lane regenerates bit-identically.
#[allow(clippy::too_many_arguments)]
fn relay_lane_batch(
    replicas: &mut [Replica],
    span: &GroupSpan,
    wire_groups: &[(u32, u32)],
    hop: usize,
    frames: &[LaneFrame],
    batch_id: u64,
    clip_ids: &[u64],
    window: usize,
    rx: Option<Receiver<LaneFrame>>,
    tx: Option<SyncSender<LaneFrame>>,
    epoch: Instant,
    failovers: &AtomicU64,
) -> Result<LaneHopOutcome> {
    let mut sm = StageMetrics::new(hop, span.layers);
    let log = replicas.len() > 1 && rx.is_some();
    let mut sent: Vec<LaneFrame> = Vec::new();
    let mut relayed = 0usize;
    let mut next_fwd: u32 = 0;
    let mut attempt = 0usize;
    let batch_trace = trace::current();
    let sampled = trace::tracer().should_sample(batch_trace);
    loop {
        let Some(ri) = pick_replica(replicas) else {
            return Err(Error::Runtime(format!(
                "distributed hop {hop}: zero surviving replicas"
            )));
        };
        let trace_ctx =
            (sampled && replicas[ri].version >= LANE_VERSION).then_some(batch_trace.0);
        let reprovision = attempt > 0;
        attempt += 1;
        match serve_batch_on_replica(
            &mut *replicas[ri].link,
            span,
            wire_groups,
            hop,
            frames,
            batch_id,
            clip_ids,
            window,
            rx.as_ref(),
            tx.as_ref(),
            log,
            &mut sent,
            &mut relayed,
            &mut next_fwd,
            &mut sm,
            epoch,
            reprovision,
            trace_ctx,
        ) {
            Ok(reports) => {
                replicas[ri].clips += clip_ids.len() as u64;
                return Ok(LaneHopOutcome {
                    reports,
                    metrics: sm,
                    finished_at: epoch.elapsed(),
                });
            }
            Err(HopFailure::Fatal(e)) => return Err(e),
            Err(HopFailure::Replica(e)) => {
                replicas[ri].alive = false;
                if !replicas.iter().any(|r| r.alive) {
                    return Err(e);
                }
                failovers.fetch_add(1, Ordering::Relaxed);
                trace::instant("failover");
            }
        }
    }
}

/// The distributed serving engine: layer groups execute on shard
/// hosts in other threads/processes/hosts, chained over [`Transport`]
/// links, bit-identical in output and telemetry to `ReferenceEngine`.
///
/// Built either against already-connected links
/// ([`DistributedEngine::connect`] /
/// [`DistributedEngine::connect_replicated`] — the real multi-process
/// topology, see the `spidr shard` CLI mode) or as a self-hosted
/// in-process constellation over loopback pipes
/// ([`DistributedEngine::loopback`] — what
/// `ServerConfig::distributed` / `PoolConfig::distributed` select via
/// `FunctionalEngine::from_config`). Either way the coordinator
/// **provisions every replica over the wire** at session start
/// (weight push), so shard hosts can start blank.
///
/// With replicated hops, a replica's transport or protocol failure is
/// absorbed: the hop re-pushes the group to a survivor and replays the
/// in-flight clip from its log ([`DistributedEngine::failovers`]
/// counts these). Only when a hop has zero survivors — or on a
/// non-replica failure — is the engine poisoned (remote Vmem state and
/// sequence counters are no longer trustworthy) and every later
/// `infer` fails; build a fresh engine to recover.
pub struct DistributedEngine {
    network: Network,
    groups: Vec<(usize, usize)>,
    wire_groups: Vec<(u32, u32)>,
    spans: Vec<GroupSpan>,
    hops: Vec<Vec<Replica>>,
    /// Per-hop protocol windows (index = hop). Seeded uniform from the
    /// connect-time `window`, respecialized by `set_windows` /
    /// `retune_windows`; read per clip/batch, so a retune between runs
    /// is structurally safe.
    windows: Vec<usize>,
    next_clip: u64,
    poisoned: bool,
    failovers: u64,
    stages: Vec<StageMetrics>,
    /// Snapshot of `stages` at the last `retune_windows` call — the
    /// retuner reacts to the *delta* since then, not lifetime totals.
    retune_mark: Vec<StageMetrics>,
    last_telemetry: Vec<StepTelemetry>,
    last_vmems: Vec<Mat>,
    last_lane_telemetry: Vec<Vec<StepTelemetry>>,
    last_lane_vmems: Vec<Vec<Mat>>,
    scalar_frames: u64,
    lane_frames: u64,
    /// Self-hosted loopback shard threads (empty for `connect`); they
    /// exit when the links drop at engine drop.
    hosts: Vec<JoinHandle<Result<ShardReport>>>,
}

impl fmt::Debug for DistributedEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DistributedEngine")
            .field("network", &self.network.name)
            .field("groups", &self.groups)
            .field("windows", &self.windows)
            .field("replicas", &self.hops.iter().map(|h| h.len()).collect::<Vec<_>>())
            .field("next_clip", &self.next_clip)
            .field("poisoned", &self.poisoned)
            .field("failovers", &self.failovers)
            .field("self_hosted_shards", &self.hosts.len())
            .finish()
    }
}

impl DistributedEngine {
    /// Chain already-connected shard links into an engine, one replica
    /// per hop (see [`DistributedEngine::connect_replicated`]).
    pub fn connect(
        network: Network,
        links: Vec<Box<dyn Transport>>,
        window: usize,
    ) -> Result<Self> {
        Self::connect_replicated(network, links.into_iter().map(|l| vec![l]).collect(), window)
    }

    /// Chain already-connected shard links into an engine with
    /// `hops[g]` holding group `g`'s replica links: plan one layer
    /// group per hop, then handshake (`Hello`) and provision
    /// (`LoadGroup` carrying the serialized workload — the weight
    /// push) every replica, validating that each resolved the span the
    /// coordinator planned. Shards may be blank or pre-loaded; the
    /// push makes both serve identical weights.
    pub fn connect_replicated(
        network: Network,
        hops: Vec<Vec<Box<dyn Transport>>>,
        window: usize,
    ) -> Result<Self> {
        if hops.is_empty() {
            return Err(Error::config("distributed engine needs at least one shard hop"));
        }
        if hops.iter().any(|h| h.is_empty()) {
            return Err(Error::config(
                "every distributed hop needs at least one replica link",
            ));
        }
        let groups = plan_layer_groups(&network, hops.len());
        if groups.len() != hops.len() {
            return Err(Error::config(format!(
                "{} shard hops but the network shards into at most {} layer groups",
                hops.len(),
                groups.len()
            )));
        }
        let spans = network.group_spans(&groups)?;
        let wire_groups: Vec<(u32, u32)> =
            groups.iter().map(|&(a, b)| (a as u32, b as u32)).collect();
        let workload = encode_network(&network);
        // Surface oversized workloads here, with the real reason —
        // otherwise the push dies shard-side as an opaque
        // "length prefix exceeds the cap" protocol error. The envelope
        // is the rest of the LoadGroup payload around the bundle:
        // shard + groups count + span/workload flags + workload length
        // prefix (14 bytes) plus 8 bytes per group range.
        let envelope = 14 + 8 * wire_groups.len() as u64;
        if workload.len() as u64 + envelope > MAX_PAYLOAD as u64 {
            return Err(Error::config(format!(
                "serialized workload is {} bytes — too large for the \
                 {MAX_PAYLOAD}-byte frame cap, cannot provision shards over the wire",
                workload.len()
            )));
        }
        let mut replica_hops: Vec<Vec<Replica>> = Vec::with_capacity(hops.len());
        for (i, links) in hops.into_iter().enumerate() {
            let mut reps = Vec::with_capacity(links.len());
            for (ri, mut link) in links.into_iter().enumerate() {
                link.send(&Frame::Hello {
                    role: Role::Coordinator,
                    name: network.name.clone(),
                })?;
                // Version negotiation: the shard stamps its Hello ack
                // at the highest dialect it speaks; the constellation's
                // minimum decides whether lane batching is available.
                let version = match link.recv_versioned()? {
                    Some((Frame::Hello { role: Role::Shard, .. }, ver)) => ver.min(VERSION),
                    Some((Frame::Error { message }, _)) => return Err(Error::Protocol(message)),
                    other => {
                        return Err(Error::protocol(format!(
                            "shard {i} replica {ri}: expected a hello, got {}",
                            frame_name(&other.map(|(f, _)| f))
                        )));
                    }
                };
                link.send(&Frame::LoadGroup {
                    shard: i as u32,
                    groups: wire_groups.clone(),
                    span: None,
                    workload: Some(workload.clone()),
                })?;
                match link.recv()? {
                    Some(Frame::LoadGroup { span: Some(span), .. }) => {
                        if span != spans[i] {
                            return Err(Error::protocol(format!(
                                "shard {i} replica {ri} resolved span {span:?}, \
                                 coordinator planned {:?}",
                                spans[i]
                            )));
                        }
                    }
                    Some(Frame::Error { message }) => return Err(Error::Protocol(message)),
                    other => {
                        return Err(Error::protocol(format!(
                            "shard {i} replica {ri}: expected a load-group ack, got {}",
                            frame_name(&other)
                        )));
                    }
                }
                // Trace sideband clock sync (only when tracing is on
                // and the replica speaks v3): one ping/echo estimates
                // the shard-clock offset under a symmetric-delay
                // assumption, so flushed shard spans can be re-based
                // onto the coordinator timeline.
                let tr = trace::tracer();
                let mut trace_offset_us = 0i64;
                if tr.enabled() && version >= LANE_VERSION {
                    let t0 = tr.now_us();
                    link.send(&Frame::TraceSync { t0_us: t0, peer_us: 0 })?;
                    match link.recv()? {
                        Some(Frame::TraceSync { t0_us, peer_us }) if t0_us == t0 => {
                            let t1 = tr.now_us();
                            trace_offset_us = peer_us as i64 - ((t0 + t1) / 2) as i64;
                        }
                        Some(Frame::Error { message }) => return Err(Error::Protocol(message)),
                        other => {
                            return Err(Error::protocol(format!(
                                "shard {i} replica {ri}: expected a trace-sync echo, got {}",
                                frame_name(&other)
                            )));
                        }
                    }
                }
                reps.push(Replica {
                    link,
                    alive: true,
                    clips: 0,
                    version,
                    trace_offset_us,
                });
            }
            replica_hops.push(reps);
        }
        let stages: Vec<StageMetrics> = spans
            .iter()
            .enumerate()
            .map(|(i, s)| StageMetrics::new(i, s.layers))
            .collect();
        let retune_mark = stages.clone();
        let windows = vec![window.max(1); replica_hops.len()];
        Ok(DistributedEngine {
            network,
            groups,
            wire_groups,
            spans,
            hops: replica_hops,
            windows,
            next_clip: 0,
            poisoned: false,
            failovers: 0,
            stages,
            retune_mark,
            last_telemetry: Vec::new(),
            last_vmems: Vec::new(),
            last_lane_telemetry: Vec::new(),
            last_lane_vmems: Vec::new(),
            scalar_frames: 0,
            lane_frames: 0,
            hosts: Vec::new(),
        })
    }

    /// Self-host a constellation: spawn `shards × replicas` **blank**
    /// [`ShardHost`] threads, paired to the engine over
    /// [`LoopbackTransport`] byte pipes, then provision them all over
    /// the wire — the whole distributed path (codec, weight push,
    /// windowing, reorder, drain, failover) with no sockets,
    /// deterministic enough for tests. The shard threads exit when the
    /// engine (and with it the pipes) drops.
    pub fn loopback(network: Network, cfg: &DistributedConfig) -> Result<Self> {
        let groups = plan_layer_groups(&network, cfg.shards.max(1));
        if groups.is_empty() {
            return Err(Error::config("network has no stateful layers to shard"));
        }
        let replicas = cfg.replicas.max(1);
        let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::with_capacity(groups.len());
        let mut hosts = Vec::with_capacity(groups.len() * replicas);
        for i in 0..groups.len() {
            let mut links: Vec<Box<dyn Transport>> = Vec::with_capacity(replicas);
            for r in 0..replicas {
                let (coord_end, mut shard_end) = LoopbackTransport::pair();
                let handle =
                    crate::sync::thread::spawn_named(&format!("spidr-shard-{i}-{r}"), move || {
                        ShardHost::blank(format!("shard-{i}.{r}")).serve(&mut shard_end)
                    })?;
                links.push(Box::new(coord_end));
                hosts.push(handle);
            }
            hops.push(links);
        }
        let mut engine = Self::connect_replicated(network, hops, cfg.window)?;
        engine.hosts = hosts;
        Ok(engine)
    }

    /// [`DistributedEngine::loopback`] over **throttled** pipes: hop
    /// `i`'s replica links all model `links[i]` — a finite bandwidth
    /// and a propagation latency
    /// ([`LoopbackTransport::pair_throttled`]) — so a deliberately
    /// skewed constellation can be built in-process. This is the
    /// retuner's test rig and the planner's calibration target: the
    /// modeled wire terms of [`crate::net::plan`] correspond to real
    /// waits here. Needs one [`LinkSpec`] per planned layer group.
    pub fn loopback_throttled(
        network: Network,
        cfg: &DistributedConfig,
        links: &[LinkSpec],
    ) -> Result<Self> {
        let groups = plan_layer_groups(&network, cfg.shards.max(1));
        if groups.is_empty() {
            return Err(Error::config("network has no stateful layers to shard"));
        }
        if links.len() != groups.len() {
            return Err(Error::config(format!(
                "{} link specs for a constellation of {} shard hops",
                links.len(),
                groups.len()
            )));
        }
        let replicas = cfg.replicas.max(1);
        let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::with_capacity(groups.len());
        let mut hosts = Vec::with_capacity(groups.len() * replicas);
        for (i, spec) in links.iter().enumerate() {
            let mut reps: Vec<Box<dyn Transport>> = Vec::with_capacity(replicas);
            for r in 0..replicas {
                let (coord_end, mut shard_end) =
                    LoopbackTransport::pair_throttled(spec.bandwidth_bytes_per_s, spec.latency());
                let handle =
                    crate::sync::thread::spawn_named(&format!("spidr-shard-{i}-{r}"), move || {
                        ShardHost::blank(format!("shard-{i}.{r}")).serve(&mut shard_end)
                    })?;
                reps.push(Box::new(coord_end));
                hosts.push(handle);
            }
            hops.push(reps);
        }
        let mut engine = Self::connect_replicated(network, hops, cfg.window)?;
        engine.hosts = hosts;
        Ok(engine)
    }

    /// The workload this engine serves.
    pub fn network(&self) -> &Network {
        &self.network
    }

    /// The stateful-layer group placed on each shard.
    pub fn groups(&self) -> &[(usize, usize)] {
        &self.groups
    }

    /// Per-hop counters accumulated over every clip served so far
    /// (`busy` is wire round-trip time — remote compute plus codec —
    /// `stall_in`/`stall_out` are inter-hop channel waits).
    pub fn stage_metrics(&self) -> &[StageMetrics] {
        &self.stages
    }

    /// The per-hop protocol window schedule currently in force
    /// (index = hop).
    pub fn windows(&self) -> &[usize] {
        &self.windows
    }

    /// Pin an explicit per-hop window schedule: one entry per hop,
    /// each ≥ 1 (a planner's
    /// [`DeploymentPlan::windows`](crate::net::plan::DeploymentPlan::windows),
    /// say). Takes effect at the next clip/batch — windows are read
    /// per run and inter-hop channels are created per run, so retuning
    /// between runs is structurally safe, and windows bound in-flight
    /// frames without touching what is computed, so outputs stay
    /// bit-identical under any schedule
    /// (`prop_window_schedule_invariant`).
    pub fn set_windows(&mut self, windows: &[usize]) -> Result<()> {
        if windows.len() != self.hops.len() {
            return Err(Error::config(format!(
                "{} windows for a constellation of {} hops",
                windows.len(),
                self.hops.len()
            )));
        }
        if windows.contains(&0) {
            return Err(Error::config("protocol windows must be ≥ 1"));
        }
        self.windows = windows.to_vec();
        Ok(())
    }

    /// Stall-driven window retune (DESIGN.md §Planner): look at each
    /// hop's counters accumulated **since the previous retune**, rank
    /// hops by per-step wire wait (`busy` here is link round trips —
    /// remote compute plus codec plus propagation — while
    /// `stall_in`/`stall_out` are inter-hop channel waits; a starved
    /// or backpressured hop is some *other* hop's problem and scores
    /// low), then double the window of every hop within 2× of the
    /// bottleneck, clamped to `max`, and halve hops below a quarter of
    /// it, clamped to `min`. Returns `true` while the schedule moved —
    /// serve a clip or batch between calls and loop until it returns
    /// `false` (the bottleneck's window doubles per round, so
    /// convergence is O(log `max`) rounds). Retunes never change what
    /// is computed, only how much is in flight, so outputs stay
    /// bit-identical across them.
    pub fn retune_windows(&mut self, min: usize, max: usize) -> bool {
        let min = min.max(1);
        let max = max.max(min);
        let mut rates = Vec::with_capacity(self.stages.len());
        for (s, prev) in self.stages.iter().zip(&self.retune_mark) {
            let steps = s.steps.saturating_sub(prev.steps);
            let wait = s.busy.saturating_sub(prev.busy);
            rates.push(if steps == 0 {
                0.0
            } else {
                wait.as_secs_f64() / steps as f64
            });
        }
        self.retune_mark = self.stages.clone();
        let peak = rates.iter().copied().fold(0.0f64, f64::max);
        if peak <= 0.0 {
            return false;
        }
        let mut moved = false;
        for (i, &rate) in rates.iter().enumerate() {
            let w = self.windows[i].clamp(min, max);
            let next = if rate >= peak * 0.5 {
                (w * 2).min(max)
            } else if rate < peak * 0.25 {
                (w / 2).max(min)
            } else {
                w
            };
            if next != self.windows[i] {
                self.windows[i] = next;
                moved = true;
            }
        }
        moved
    }

    /// Replica failovers absorbed so far across all hops (each one is
    /// a re-push + replay that kept the run alive).
    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// `(alive, total)` replica counts per hop — how degraded the
    /// constellation is.
    pub fn replica_status(&self) -> Vec<(usize, usize)> {
        self.hops
            .iter()
            .map(|h| (h.iter().filter(|r| r.alive).count(), h.len()))
            .collect()
    }

    /// Fault injection for tests, demos and the failover bench: sever
    /// one replica's link by swapping in a transport whose peer is
    /// already closed — the next use fails exactly like a crashed
    /// shard process or a dropped connection. The engine does *not*
    /// learn about the kill here; it discovers the failure through the
    /// protocol and fails over, which is the behavior under test.
    /// (The old link drops, so a live shard behind it sees a clean
    /// EOF and ends its session.)
    pub fn sever_replica(&mut self, hop: usize, replica: usize) -> Result<()> {
        let r = self
            .hops
            .get_mut(hop)
            .and_then(|h| h.get_mut(replica))
            .ok_or_else(|| {
                Error::config(format!("no replica {replica} on hop {hop} to sever"))
            })?;
        let (dead, gone) = LoopbackTransport::pair();
        drop(gone);
        r.link = Box::new(dead);
        Ok(())
    }

    /// The last served clip's merged per-timestep telemetry, in layer
    /// order (the shard fragments reassembled).
    pub fn last_telemetry(&self) -> &[StepTelemetry] {
        &self.last_telemetry
    }

    /// The last served clip's final Vmem banks, in stateful-layer
    /// order (the shard banks reassembled — bit-comparable to
    /// `NetworkState::vmems` after `Network::run`).
    pub fn last_vmems(&self) -> &[Mat] {
        &self.last_vmems
    }

    /// The protocol dialect the whole constellation can speak: the
    /// minimum of every replica's `Hello` version (capped at this
    /// build's [`VERSION`]). Lane batching needs all of them —
    /// failover may move any batch to any replica of a hop.
    pub fn negotiated_version(&self) -> u16 {
        self.hops
            .iter()
            .flatten()
            .map(|r| r.version)
            .min()
            .unwrap_or(VERSION)
    }

    /// True when every replica speaks at least [`LANE_VERSION`], so
    /// [`DistributedEngine::infer_lanes`] is available; otherwise
    /// `infer_batch` falls back to scalar spike frames.
    pub fn lane_batching(&self) -> bool {
        self.negotiated_version() >= LANE_VERSION
    }

    /// `(scalar, lane)` spike-carrying serving frames sent so far
    /// (spike/lane frames plus their drains; handshake, provisioning
    /// and failover replays excluded). The bench's
    /// `wire_amortization_ratio` is `scalar / lane` at equal clip
    /// counts.
    pub fn wire_frames(&self) -> (u64, u64) {
        (self.scalar_frames, self.lane_frames)
    }

    /// The last served lane batch's per-lane merged telemetry: entry
    /// `b` holds lane `b`'s per-timestep fragments reassembled across
    /// hops — bit-identical to what [`Network::run`] reports for that
    /// lane's clip alone.
    pub fn last_lane_telemetry(&self) -> &[Vec<StepTelemetry>] {
        &self.last_lane_telemetry
    }

    /// The last served lane batch's per-lane final Vmem banks, in
    /// stateful-layer order — entry `b` is bit-comparable to
    /// `NetworkState::vmems` after running lane `b`'s clip alone.
    pub fn last_lane_vmems(&self) -> &[Vec<Mat>] {
        &self.last_lane_vmems
    }

    /// Run one lane batch (clip `b` → bit-lane `b`) through the shard
    /// chain: one `LaneBatchOpen` + one lane frame per timestep per
    /// hop instead of per clip, amortizing protocol overhead across up
    /// to [`MAX_LANES`] clips. Output `b` is lane `b`'s final
    /// accumulator bank, bit-identical to a per-clip run
    /// (`prop_distributed_batched_bit_identical_per_lane`); per-lane
    /// telemetry and Vmems land in [`Self::last_lane_telemetry`] /
    /// [`Self::last_lane_vmems`]. Requires a fully v3 constellation
    /// ([`Self::lane_batching`]) — on mixed constellations use
    /// `infer_batch`, which falls back to scalar frames.
    pub fn infer_lanes(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Vec<i32>>> {
        if self.poisoned {
            return Err(Error::Runtime(
                "distributed engine is poisoned by an earlier error; rebuild it".into(),
            ));
        }
        if !self.lane_batching() {
            return Err(Error::config(format!(
                "lane batching requires protocol v{LANE_VERSION} on every replica; \
                 this constellation negotiated v{}",
                self.negotiated_version()
            )));
        }
        if clips.is_empty() || clips.len() > MAX_LANES {
            return Err(Error::config(format!(
                "lane batch needs 1..={MAX_LANES} clips, got {}",
                clips.len()
            )));
        }
        let (c0, h0, w0) = self
            .network
            .layers
            .first()
            .ok_or_else(|| Error::config("empty network"))?
            .in_shape;
        for clip in clips {
            for f in *clip {
                if f.shape() != (c0, h0, w0) {
                    return Err(Error::shape(format!(
                        "frame shape {:?} != network input {:?}",
                        f.shape(),
                        (c0, h0, w0)
                    )));
                }
            }
        }
        let frames = LaneFrame::pack_clips(clips)?;
        let lanes = clips.len();
        let t_total = frames.len();
        let batch_id = self.next_clip;
        let clip_ids: Vec<u64> = (0..lanes as u64).map(|i| batch_id + i).collect();
        self.next_clip += lanes as u64;
        let windows = self.windows.clone();
        let hop_count = self.hops.len();
        let wire_groups = &self.wire_groups;
        let epoch = Instant::now(); // lint: wall-clock
        let failovers = AtomicU64::new(0);
        let frames_ref = &frames;
        let clip_ids_ref = &clip_ids;
        // The batch's trace travels to the scoped hop threads via an
        // explicit re-bind (thread bindings don't inherit).
        let batch_trace = trace::current();
        let results: Vec<Result<LaneHopOutcome>> = crate::sync::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(hop_count);
            let mut prev_rx: Option<Receiver<LaneFrame>> = None;
            for (gi, (replicas, span)) in
                self.hops.iter_mut().zip(self.spans.iter()).enumerate()
            {
                let rx = prev_rx.take();
                // The inter-hop channel's depth follows the consuming
                // hop's window: a wide downstream window needs that
                // much lookahead buffered ahead of it.
                let tx = if gi + 1 < hop_count {
                    let (tx, next_rx) = sync_channel(windows[gi + 1]);
                    prev_rx = Some(next_rx);
                    Some(tx)
                } else {
                    None
                };
                let window = windows[gi];
                let failovers = &failovers;
                handles.push(scope.spawn(move || {
                    let _tbind = trace::bind(batch_trace);
                    let _tspan = trace::span("hop");
                    relay_lane_batch(
                        replicas,
                        span,
                        wire_groups,
                        gi,
                        frames_ref,
                        batch_id,
                        clip_ids_ref,
                        window,
                        rx,
                        tx,
                        epoch,
                        failovers,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("distributed lane hop panicked"))
                .collect()
        });
        let wall = epoch.elapsed();
        self.failovers += failovers.into_inner();

        let mut teardown: Option<Error> = None;
        let mut outcomes = Vec::with_capacity(hop_count);
        for r in results {
            match r {
                Ok(o) => outcomes.push(o),
                Err(e) if is_hop_teardown(&e) => {
                    if teardown.is_none() {
                        teardown = Some(e);
                    }
                }
                Err(e) => {
                    self.poisoned = true;
                    return Err(e);
                }
            }
        }
        if let Some(e) = teardown {
            self.poisoned = true;
            return Err(e);
        }

        // Demux per lane, merging hop fragments in layer order — the
        // scalar merge applied once per lane.
        let mut lane_tel: Vec<Vec<StepTelemetry>> =
            vec![vec![StepTelemetry::default(); t_total]; lanes];
        let mut lane_vmems: Vec<Vec<Mat>> = vec![Vec::new(); lanes];
        for (o, acc) in outcomes.into_iter().zip(&mut self.stages) {
            for (b, report) in o.reports.into_iter().enumerate() {
                for (t, frag) in report.steps.into_iter().enumerate() {
                    lane_tel[b][t]
                        .layer_input_spikes
                        .extend(frag.layer_input_spikes);
                    lane_tel[b][t]
                        .layer_input_cells
                        .extend(frag.layer_input_cells);
                }
                lane_vmems[b].extend(report.vmems);
            }
            let mut sm = o.metrics;
            sm.drain = wall.saturating_sub(o.finished_at);
            acc.absorb(&sm);
        }
        // Serving frames this batch put on the wire: open + one lane
        // frame per timestep + drain, per hop (replays excluded — they
        // are recovery traffic).
        self.lane_frames += (t_total as u64 + 2) * hop_count as u64;
        self.flush_remote_spans(batch_trace);
        let outputs = lane_vmems
            .iter()
            .map(|banks| {
                banks
                    .last()
                    .map(|m| m.as_slice().to_vec())
                    .unwrap_or_default()
            })
            .collect();
        self.last_lane_telemetry = lane_tel;
        self.last_lane_vmems = lane_vmems;
        Ok(outputs)
    }

    /// Drive one clip through the shard chain, filling
    /// `last_telemetry` / `last_vmems` and absorbing hop metrics.
    fn run_clip(&mut self, clip: &[SpikePlane]) -> Result<()> {
        if self.poisoned {
            return Err(Error::Runtime(
                "distributed engine is poisoned by an earlier error; rebuild it".into(),
            ));
        }
        let (c0, h0, w0) = self
            .network
            .layers
            .first()
            .ok_or_else(|| Error::config("empty network"))?
            .in_shape;
        for f in clip {
            if f.shape() != (c0, h0, w0) {
                return Err(Error::shape(format!(
                    "frame shape {:?} != network input {:?}",
                    f.shape(),
                    (c0, h0, w0)
                )));
            }
        }
        let clip_id = self.next_clip;
        self.next_clip += 1;
        let windows = self.windows.clone();
        let hop_count = self.hops.len();
        let wire_groups = &self.wire_groups;
        let epoch = Instant::now(); // lint: wall-clock
        let failovers = AtomicU64::new(0);
        // The clip's trace travels to the scoped hop threads via an
        // explicit re-bind (thread bindings don't inherit).
        let clip_trace = trace::current();
        let results: Vec<Result<HopOutcome>> = crate::sync::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(hop_count);
            let mut prev_rx: Option<Receiver<SpikePlane>> = None;
            for (gi, (replicas, span)) in
                self.hops.iter_mut().zip(self.spans.iter()).enumerate()
            {
                let rx = prev_rx.take();
                // Channel depth follows the consuming hop's window.
                let tx = if gi + 1 < hop_count {
                    let (tx, next_rx) = sync_channel(windows[gi + 1]);
                    prev_rx = Some(next_rx);
                    Some(tx)
                } else {
                    None
                };
                let window = windows[gi];
                let failovers = &failovers;
                handles.push(scope.spawn(move || {
                    let _tbind = trace::bind(clip_trace);
                    let _tspan = trace::span("hop");
                    relay_clip(
                        replicas, span, wire_groups, gi, clip, clip_id, window, rx, tx,
                        epoch, failovers,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("distributed hop panicked"))
                .collect()
        });
        let wall = epoch.elapsed();
        // Absorbed failovers count even when the clip ultimately
        // errors below — a replica demonstrably died either way.
        self.failovers += failovers.into_inner();

        // Prefer a hop's own failure over the secondary channel-teardown
        // errors its neighbours observe.
        let mut teardown: Option<Error> = None;
        let mut outcomes = Vec::with_capacity(hop_count);
        for r in results {
            match r {
                Ok(o) => outcomes.push(o),
                Err(e) if is_hop_teardown(&e) => {
                    if teardown.is_none() {
                        teardown = Some(e);
                    }
                }
                Err(e) => {
                    self.poisoned = true;
                    return Err(e);
                }
            }
        }
        if let Some(e) = teardown {
            self.poisoned = true;
            return Err(e);
        }

        let mut merged: Vec<StepTelemetry> =
            (0..clip.len()).map(|_| StepTelemetry::default()).collect();
        let mut vmems = Vec::new();
        for (o, acc) in outcomes.into_iter().zip(&mut self.stages) {
            for (t, frag) in o.telemetry.into_iter().enumerate() {
                merged[t].layer_input_spikes.extend(frag.layer_input_spikes);
                merged[t].layer_input_cells.extend(frag.layer_input_cells);
            }
            let mut sm = o.metrics;
            sm.drain = wall.saturating_sub(o.finished_at);
            acc.absorb(&sm);
            vmems.extend(o.vmems);
        }
        self.last_telemetry = merged;
        self.last_vmems = vmems;
        // Serving frames this clip put on the wire: one spike frame
        // per timestep + drain, per hop (replays excluded).
        self.scalar_frames += (clip.len() as u64 + 1) * hop_count as u64;
        self.flush_remote_spans(clip_trace);
        Ok(())
    }

    /// After a sampled clip/batch completes, pull every v3 replica's
    /// buffered spans (`TraceFlush` → `TraceSpans`) and inject them
    /// into the local tracer under a per-replica process label, shifted
    /// by the connect-time clock-offset estimate. Best-effort: a
    /// replica that fails here is left for the next clip's relay to
    /// discover (the links are quiescent between runs, so the only
    /// in-order reply is the flush's own). A no-op unless the given
    /// trace is sampled — unsampled runs put nothing on the wire, so
    /// there is nothing to pull.
    fn flush_remote_spans(&mut self, trace: TraceId) {
        let tr = trace::tracer();
        if !tr.should_sample(trace) {
            return;
        }
        for (hi, hop) in self.hops.iter_mut().enumerate() {
            for (ri, rep) in hop.iter_mut().enumerate() {
                if !rep.alive || rep.version < LANE_VERSION {
                    continue;
                }
                if rep.link.send(&Frame::TraceFlush).is_err() {
                    continue;
                }
                if let Ok(Some(Frame::TraceSpans { spans })) = rep.link.recv() {
                    if !spans.is_empty() {
                        tr.inject(&format!("shard-{hi}.{ri}"), spans, rep.trace_offset_us);
                    }
                }
            }
        }
    }
}

impl Engine for DistributedEngine {
    type Output = Vec<i32>;

    fn infer(&mut self, clip: &[SpikePlane]) -> Result<Vec<i32>> {
        self.run_clip(clip)?;
        Ok(self
            .last_vmems
            .last()
            .map(|m| m.as_slice().to_vec())
            .unwrap_or_default())
    }

    fn max_batch(&self) -> usize {
        if self.lane_batching() {
            MAX_LANES
        } else {
            1
        }
    }

    /// Greedy lane packing: consecutive clips with equal timestep
    /// counts coalesce into lane batches of up to [`MAX_LANES`];
    /// singleton runs — and every clip on a constellation with a v2
    /// replica ([`DistributedEngine::max_batch`] is 1 there) — fall
    /// back to the scalar spike-frame path. Either way each clip's
    /// result is bit-identical to `infer` serving it alone.
    fn infer_batch(&mut self, clips: &[&[SpikePlane]]) -> Result<Vec<Vec<i32>>> {
        let mut out = Vec::with_capacity(clips.len());
        let mut i = 0;
        while i < clips.len() {
            let t = clips[i].len();
            let mut j = i + 1;
            while j < clips.len() && j - i < self.max_batch() && clips[j].len() == t {
                j += 1;
            }
            if j - i == 1 {
                out.push(self.infer(clips[i])?);
            } else {
                out.extend(self.infer_lanes(&clips[i..j])?);
            }
            i = j;
        }
        Ok(out)
    }

    /// Per-hop wire/stall counters, so `serve`/`serve_pool` surface
    /// distributed hop telemetry in `Metrics::stages` automatically.
    fn stage_metrics(&self) -> Vec<StageMetrics> {
        self.stages.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ReferenceEngine;
    use crate::net::transport::TcpTransport;
    use crate::prop::{check, Gen, SplitMix64};
    use crate::quant::Precision;
    use crate::sim::config::SimConfig;
    use crate::snn::layer::{NeuronConfig, ResetMode};
    use crate::snn::network::{demo_pipeline_network, demo_serving_network, NetworkBuilder};

    fn demo_clip(seed: u64, t: usize, c: usize, h: usize, w: usize) -> Vec<SpikePlane> {
        let mut rng = SplitMix64::new(seed);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if rng.chance(0.2) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn loopback_engine_matches_reference_and_resets_between_clips() {
        let net = demo_serving_network(6).unwrap();
        let clip = demo_clip(9, 6, 2, 16, 16);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();

        let mut e = DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        assert_eq!(e.groups().len(), 2);
        let a = e.infer(&clip).unwrap();
        let b = e.infer(&clip).unwrap();
        assert_eq!(a, want, "distributed output != reference output");
        assert_eq!(a, b, "shard banks must reset between clips");
        // hop counters accumulated over both clips
        assert!(e.stage_metrics().iter().all(|s| s.steps == 12));
        assert_eq!(e.last_telemetry().len(), 6);
        assert_eq!(e.failovers(), 0);
    }

    #[test]
    fn empty_clip_is_a_noop() {
        let net = demo_serving_network(4).unwrap();
        let mut e = DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        let out = e.infer(&[]).unwrap();
        assert!(out.iter().all(|&v| v == 0));
        assert!(e.last_telemetry().is_empty());
        assert!(e.stage_metrics().iter().all(|s| s.steps == 0));
    }

    #[test]
    fn more_links_than_layer_groups_is_rejected() {
        // 2 stateful layers cannot feed 3 links
        let net = demo_serving_network(4).unwrap();
        let links: Vec<Box<dyn Transport>> = (0..3)
            .map(|_| Box::new(LoopbackTransport::pair().0) as Box<dyn Transport>)
            .collect();
        assert!(DistributedEngine::connect(net, links, 2).is_err());
    }

    #[test]
    fn empty_replica_set_is_rejected() {
        let net = demo_serving_network(4).unwrap();
        let hops: Vec<Vec<Box<dyn Transport>>> = vec![vec![], vec![]];
        assert!(DistributedEngine::connect_replicated(net, hops, 2).is_err());
    }

    #[test]
    fn bad_frame_shape_is_rejected_without_poisoning() {
        let net = demo_serving_network(4).unwrap();
        let mut e = DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        let wrong = vec![SpikePlane::zeros(2, 8, 8)];
        assert!(e.infer(&wrong).is_err());
        // shape validation happens before any frame leaves, so the
        // engine stays serviceable
        let ok = demo_clip(3, 4, 2, 16, 16);
        assert!(e.infer(&ok).is_ok());
    }

    /// Tentpole acceptance: killing a replica mid-stream loses zero
    /// clips — the hop re-pushes the group to the survivor, replays,
    /// and the outputs (Vmems + telemetry) stay bit-identical to the
    /// reference across the failover.
    #[test]
    fn replica_killed_between_clips_fails_over_bit_identically() {
        let net = demo_serving_network(6).unwrap();
        let clip = demo_clip(11, 6, 2, 16, 16);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();
        let ref_tel = {
            let mut state = net.init_state().unwrap();
            net.run(&clip, &mut state).unwrap()
        };

        let mut e =
            DistributedEngine::loopback(net, &DistributedConfig::replicated(2, 2)).unwrap();
        assert_eq!(e.infer(&clip).unwrap(), want);
        assert_eq!(e.failovers(), 0);

        // Clip 0 went to replica 0 of each hop (least-loaded tie →
        // lowest index), so clip 1 will pick replica 1 — sever exactly
        // that target on every hop to force the failover path.
        for hop in 0..e.groups().len() {
            e.sever_replica(hop, 1).unwrap();
        }
        let got = e.infer(&clip).unwrap();
        assert_eq!(got, want, "failover clip diverged from the reference");
        assert_eq!(e.last_telemetry(), &ref_tel[..], "telemetry diverged");
        assert_eq!(e.failovers(), e.groups().len() as u64);
        for (alive, total) in e.replica_status() {
            assert_eq!((alive, total), (1, 2));
        }

        // degraded but alive: later clips keep serving on the survivor
        assert_eq!(e.infer(&clip).unwrap(), want);
    }

    /// A transport that delivers the first `good_sends` sends /
    /// `good_recvs` recvs and then fails that operation forever — a
    /// shard that dies mid-clip with frames already relayed and
    /// replies already forwarded, the hardest replay case (the
    /// survivor must regenerate planes the coordinator already
    /// forwarded downstream, and the hop must drop those duplicates).
    struct FailAfter {
        inner: LoopbackTransport,
        good_sends: usize,
        good_recvs: usize,
    }

    impl Transport for FailAfter {
        fn send_versioned(&mut self, frame: &Frame, version: u16) -> Result<()> {
            if self.good_sends == 0 {
                return Err(Error::Runtime("injected mid-clip link failure".into()));
            }
            self.good_sends -= 1;
            self.inner.send_versioned(frame, version)
        }

        fn recv_versioned(&mut self) -> Result<Option<(Frame, u16)>> {
            if self.good_recvs == 0 {
                return Err(Error::Runtime("injected mid-clip reply failure".into()));
            }
            self.good_recvs -= 1;
            self.inner.recv_versioned()
        }
    }

    /// Tentpole acceptance: replicas that die *mid-clip* — after
    /// relaying some frames and forwarding some replies — are replaced
    /// by survivors that replay from the per-clip state, and the final
    /// output is still bit-identical to the reference. Hop 0's primary
    /// dies on a *send* (replay resumes from the caller's clip slice);
    /// hop 1's primary dies on a *reply recv with the window full*,
    /// right after consuming a plane from the upstream channel — the
    /// consumed plane must already sit in the replay log or the
    /// survivor would wedge waiting for a frame upstream can never
    /// resend.
    #[test]
    fn replica_dying_mid_clip_replays_on_survivor() {
        let net = demo_pipeline_network(8).unwrap();
        let clip = demo_clip(23, 8, 2, 24, 24);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();

        // Two hops × two replicas, all blank + weight-pushed; each
        // hop's primary is flaky, each standby healthy.
        let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::new();
        let mut hosts = Vec::new();
        for hop in 0..2 {
            let mut links: Vec<Box<dyn Transport>> = Vec::new();
            for r in 0..2 {
                let (coord_end, mut shard_end) = LoopbackTransport::pair();
                hosts.push(crate::sync::thread::spawn(move || {
                    let _ = ShardHost::blank("t").serve(&mut shard_end);
                }));
                links.push(match (hop, r) {
                    // Hello + LoadGroup + 4 spike frames succeed, the
                    // 5th frame *send* fails mid-clip.
                    (0, 0) => Box::new(FailAfter {
                        inner: coord_end,
                        good_sends: 2 + 4,
                        good_recvs: usize::MAX,
                    }),
                    // Hello ack + LoadGroup ack + 1 reply succeed, the
                    // next reply *recv* fails — with window 2 that
                    // lands mid-clip, immediately after a plane was
                    // pulled off the inter-hop channel.
                    (1, 0) => Box::new(FailAfter {
                        inner: coord_end,
                        good_sends: usize::MAX,
                        good_recvs: 2 + 1,
                    }),
                    _ => Box::new(coord_end) as Box<dyn Transport>,
                });
            }
            hops.push(links);
        }
        let mut e = DistributedEngine::connect_replicated(net, hops, 2).unwrap();
        let got = e.infer(&clip).unwrap();
        assert_eq!(got, want, "mid-clip failover diverged from the reference");
        assert_eq!(e.failovers(), 2);
        assert_eq!(e.replica_status()[0], (1, 2));
        assert_eq!(e.replica_status()[1], (1, 2));
        drop(e);
        for h in hosts {
            h.join().unwrap();
        }
    }

    /// The zero-survivor rule: when every replica of a hop is dead the
    /// engine degrades to the old fail-fast behavior — the clip fails
    /// and the engine poisons.
    #[test]
    fn zero_survivors_fail_fast_and_poison() {
        let net = demo_serving_network(4).unwrap();
        let clip = demo_clip(5, 4, 2, 16, 16);
        let mut e =
            DistributedEngine::loopback(net, &DistributedConfig::replicated(2, 2)).unwrap();
        assert!(e.infer(&clip).is_ok());
        e.sever_replica(0, 0).unwrap();
        e.sever_replica(0, 1).unwrap();
        assert!(e.infer(&clip).is_err(), "no survivor on hop 0 must fail");
        // poisoned: even though hop 1 is healthy, state is gone
        assert!(e.infer(&clip).is_err(), "a poisoned engine must stay failed");
    }

    /// The real multi-process shape, in-process: two shard hosts behind
    /// TCP sockets on localhost, chained by the coordinator — output
    /// and Vmems bit-identical to the reference executor. The hosts
    /// are **blank**: provisioning happens over the TCP link.
    #[test]
    fn tcp_constellation_matches_reference() {
        let net = demo_pipeline_network(5).unwrap();
        let clip = demo_clip(21, 5, 2, 24, 24);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();

        let mut links: Vec<Box<dyn Transport>> = Vec::new();
        let mut hosts = Vec::new();
        for _ in 0..2 {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            hosts.push(crate::sync::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                let mut link = TcpTransport::from_stream(stream);
                ShardHost::blank("tcp-blank").serve(&mut link)
            }));
            links.push(Box::new(TcpTransport::connect(addr).unwrap()));
        }
        let mut e = DistributedEngine::connect(net, links, 2).unwrap();
        let got = e.infer(&clip).unwrap();
        assert_eq!(got, want, "TCP-distributed output != reference output");
        drop(e); // closes the sockets; shard sessions end cleanly
        for h in hosts {
            let report = h.join().unwrap().unwrap();
            assert_eq!(report.clips, 1);
            assert_eq!(report.frames, 5);
        }
    }

    fn rand_mat(g: &mut Gen, rows: usize, cols: usize) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, g.i32_in(-7..=7));
            }
        }
        m
    }

    /// A random spiking network: 1–3 hidden conv layers (random
    /// channels, thresholds, leaks, reset modes), an optional pool,
    /// and an accumulate FC readout (mirrors the pipeline prop test).
    fn random_network(g: &mut Gen) -> crate::snn::network::Network {
        let in_ch = 1 + g.index(2);
        let h = 4 + 2 * g.index(3);
        let w = 4 + 2 * g.index(3);
        let hidden = 1 + g.index(3);
        let pool_after = g.index(hidden + 1); // == hidden means "none"
        let mut b = NetworkBuilder::new("prop-dist", Precision::W4V7, 3, (in_ch, h, w));
        for i in 0..hidden {
            let (c, _, _) = b.shape();
            let out_ch = 2 + g.index(5);
            let neuron = NeuronConfig {
                theta: 1 + g.i32_in(0..=6),
                leak: g.i32_in(0..=2),
                leaky: g.chance(0.5),
                reset: if g.chance(0.5) {
                    ResetMode::Soft
                } else {
                    ResetMode::Hard
                },
            };
            let wm = rand_mat(g, c * 9, out_ch);
            b = b.conv3x3(out_ch, wm, neuron, false).unwrap();
            if i == pool_after {
                b = b.pool(2, 2);
            }
        }
        let (c, hh, ww) = b.shape();
        let out = 2 + g.index(3);
        let wm = rand_mat(g, c * hh * ww, out);
        b.fc(out, wm, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    /// Acceptance: over random networks, shard counts, windows and
    /// replica counts, the loopback constellation's Vmems *and*
    /// telemetry are bit-identical to `Network::run` — including
    /// across a mid-stream replica kill when replication is on — and
    /// the scheduler's cycle-level path agrees, so all executors stay
    /// pinned to one functional core.
    #[test]
    fn prop_distributed_bit_identical_to_reference() {
        check("distributed_bit_identical", 10, |g| {
            let net = random_network(g);
            let t = 1 + g.index(4);
            let (c, h, w) = net.layers[0].in_shape;
            let density = 0.1 + g.f64() * 0.4;
            let frames: Vec<SpikePlane> = (0..t)
                .map(|_| {
                    let mut p = SpikePlane::zeros(c, h, w);
                    for i in 0..p.len() {
                        if g.chance(density) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect();
            let stateful = net.stateful_layers().count();
            let cfg = DistributedConfig {
                shards: 1 + g.index(stateful + 2), // may exceed the layer count
                window: 1 + g.index(3),
                replicas: 1 + g.index(2),
            };

            // sequential reference
            let mut ref_state = net.init_state().unwrap();
            let ref_tel = net.run(&frames, &mut ref_state).unwrap();

            // distributed constellation (blank shards, weight-pushed)
            let mut e = DistributedEngine::loopback(net.clone(), &cfg).unwrap();
            e.infer(&frames).unwrap();
            let first_ok = e.last_telemetry() == &ref_tel[..]
                && ref_state
                    .vmems
                    .iter()
                    .zip(e.last_vmems())
                    .all(|(a, b)| a.as_slice() == b.as_slice());

            // with replication: kill a random replica and serve the
            // clip again — still bit-identical, zero clips lost
            let failover_ok = if cfg.replicas > 1 {
                let hop = g.index(e.groups().len());
                let replica = g.index(cfg.replicas);
                e.sever_replica(hop, replica).unwrap();
                e.infer(&frames).unwrap();
                e.last_telemetry() == &ref_tel[..]
                    && ref_state
                        .vmems
                        .iter()
                        .zip(e.last_vmems())
                        .all(|(a, b)| a.as_slice() == b.as_slice())
            } else {
                true
            };

            // cycle-level scheduler path as a cross-check
            let sched =
                crate::coordinator::scheduler::MultiCoreScheduler::new(2, SimConfig::default());
            let mut sim_state = net.init_state().unwrap();
            sched.run_network_clip(&net, &frames, &mut sim_state).unwrap();

            first_ok
                && failover_ok
                && ref_state
                    .vmems
                    .iter()
                    .zip(&sim_state.vmems)
                    .all(|(a, b)| a.as_slice() == b.as_slice())
        });
    }

    /// Satellite (ISSUE 7): every lane of a batched distributed run —
    /// outputs, per-lane telemetry, and per-lane Vmems — is
    /// bit-identical to `Network::run` of that lane's clip alone,
    /// across random networks, lane counts `1..=64`, shard counts,
    /// windows, and replica counts.
    #[test]
    fn prop_distributed_batched_bit_identical_per_lane() {
        check("distributed_batched_per_lane", 6, |g| {
            let net = random_network(g);
            let t = 1 + g.index(3);
            let lanes = 1 + g.index(MAX_LANES);
            let (c, h, w) = net.layers[0].in_shape;
            let clips: Vec<Vec<SpikePlane>> = (0..lanes)
                .map(|_| {
                    let density = if g.chance(0.1) { 0.0 } else { 0.1 + g.f64() * 0.4 };
                    (0..t)
                        .map(|_| {
                            let mut p = SpikePlane::zeros(c, h, w);
                            for i in 0..p.len() {
                                if g.chance(density) {
                                    p.as_mut_slice()[i] = 1;
                                }
                            }
                            p
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
            let stateful = net.stateful_layers().count();
            let cfg = DistributedConfig {
                shards: 1 + g.index(stateful + 1),
                window: 1 + g.index(3),
                replicas: 1 + g.index(2),
            };
            let mut e = DistributedEngine::loopback(net.clone(), &cfg).unwrap();
            assert!(e.lane_batching(), "loopback hosts speak v3");
            let outs = e.infer_lanes(&refs).unwrap();
            assert_eq!(outs.len(), lanes);
            for (b, clip) in clips.iter().enumerate() {
                let mut state = net.init_state().unwrap();
                let tel = net.run(clip, &mut state).unwrap();
                let want: Vec<i32> = state.vmems.last().unwrap().as_slice().to_vec();
                if outs[b] != want {
                    return false;
                }
                if e.last_lane_telemetry()[b] != tel {
                    return false;
                }
                if !state
                    .vmems
                    .iter()
                    .zip(&e.last_lane_vmems()[b])
                    .all(|(a, b)| a.as_slice() == b.as_slice())
                {
                    return false;
                }
            }
            true
        });
    }

    /// Tentpole acceptance: a full 64-lane batch served across a
    /// replica kill between batches — the hop re-pushes the group,
    /// re-opens the batch, and all 64 lanes come back bit-identical.
    #[test]
    fn replica_killed_between_lane_batches_fails_over_bit_identically() {
        let net = demo_serving_network(6).unwrap();
        let clips: Vec<Vec<SpikePlane>> = (0..MAX_LANES)
            .map(|b| demo_clip(100 + b as u64, 4, 2, 16, 16))
            .collect();
        let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let wants: Vec<Vec<i32>> = clips.iter().map(|c| reference.infer(c).unwrap()).collect();

        let mut e =
            DistributedEngine::loopback(net, &DistributedConfig::replicated(2, 2)).unwrap();
        assert_eq!(e.infer_lanes(&refs).unwrap(), wants);
        assert_eq!(e.failovers(), 0);

        // Batch 0 went to replica 0 of each hop (least-loaded tie →
        // lowest index), so the next batch picks replica 1 — sever
        // exactly that target on every hop.
        for hop in 0..e.groups().len() {
            e.sever_replica(hop, 1).unwrap();
        }
        let got = e.infer_lanes(&refs).unwrap();
        assert_eq!(got, wants, "failover batch diverged from the reference");
        assert_eq!(e.failovers(), e.groups().len() as u64);
        for (alive, total) in e.replica_status() {
            assert_eq!((alive, total), (1, 2));
        }
        // degraded but alive: the survivor keeps serving batches
        assert_eq!(e.infer_lanes(&refs).unwrap(), wants);
    }

    /// Tentpole acceptance: replicas that die *mid-batch* — hop 0's on
    /// a lane-frame send with frames already relayed, hop 1's on a
    /// reply recv right after consuming a lane frame from the upstream
    /// channel — are replaced by survivors that replay the whole batch
    /// from the per-batch log; every lane regenerates bit-identically
    /// and replayed replies below the per-batch watermark are dropped,
    /// so outputs, telemetry, and Vmems still match the reference per
    /// lane.
    #[test]
    fn replica_dying_mid_lane_batch_replays_on_survivor() {
        let net = demo_pipeline_network(8).unwrap();
        let lanes = 5usize;
        let clips: Vec<Vec<SpikePlane>> = (0..lanes)
            .map(|b| demo_clip(40 + b as u64, 8, 2, 24, 24))
            .collect();
        let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let wants: Vec<Vec<i32>> = clips.iter().map(|c| reference.infer(c).unwrap()).collect();

        let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::new();
        let mut hosts = Vec::new();
        for hop in 0..2 {
            let mut links: Vec<Box<dyn Transport>> = Vec::new();
            for r in 0..2 {
                let (coord_end, mut shard_end) = LoopbackTransport::pair();
                hosts.push(crate::sync::thread::spawn(move || {
                    let _ = ShardHost::blank("t").serve(&mut shard_end);
                }));
                links.push(match (hop, r) {
                    // Hello + LoadGroup + LaneBatchOpen + 4 lane frames
                    // succeed, the 5th lane-frame *send* fails mid-batch.
                    (0, 0) => Box::new(FailAfter {
                        inner: coord_end,
                        good_sends: 2 + 1 + 4,
                        good_recvs: usize::MAX,
                    }),
                    // Hello ack + LoadGroup ack + open ack + 1 reply
                    // succeed, the next reply *recv* fails — with
                    // window 2 that lands mid-batch, right after a lane
                    // frame was pulled off the inter-hop channel.
                    (1, 0) => Box::new(FailAfter {
                        inner: coord_end,
                        good_sends: usize::MAX,
                        good_recvs: 2 + 1 + 1,
                    }),
                    _ => Box::new(coord_end) as Box<dyn Transport>,
                });
            }
            hops.push(links);
        }
        let mut e = DistributedEngine::connect_replicated(net.clone(), hops, 2).unwrap();
        assert!(e.lane_batching());
        let got = e.infer_lanes(&refs).unwrap();
        assert_eq!(got, wants, "mid-batch failover diverged from the reference");
        assert_eq!(e.failovers(), 2);
        assert_eq!(e.replica_status()[0], (1, 2));
        assert_eq!(e.replica_status()[1], (1, 2));
        for (b, clip) in clips.iter().enumerate() {
            let mut state = net.init_state().unwrap();
            let tel = net.run(clip, &mut state).unwrap();
            assert_eq!(e.last_lane_telemetry()[b], tel, "lane {b} telemetry diverged");
            assert!(
                state
                    .vmems
                    .iter()
                    .zip(&e.last_lane_vmems()[b])
                    .all(|(a, v)| a.as_slice() == v.as_slice()),
                "lane {b} Vmems diverged"
            );
        }
        drop(e);
        for h in hosts {
            h.join().unwrap();
        }
    }

    /// Satellite (version negotiation): one v2 replica anywhere in the
    /// constellation pins the negotiated dialect to v2 — `infer_lanes`
    /// rejects with a typed error (no grammar desync, the engine stays
    /// serviceable) and `infer_batch` falls back to scalar spike
    /// frames, bit-identical per clip.
    #[test]
    fn v2_shard_negotiates_scalar_fallback() {
        let net = demo_serving_network(4).unwrap();
        let mut hops: Vec<Vec<Box<dyn Transport>>> = Vec::new();
        let mut hosts = Vec::new();
        for hop in 0..2u16 {
            let (coord_end, mut shard_end) = LoopbackTransport::pair();
            let protocol = if hop == 1 { 2 } else { 3 };
            hosts.push(crate::sync::thread::spawn(move || {
                let _ = ShardHost::blank("nego")
                    .with_protocol(protocol)
                    .serve(&mut shard_end);
            }));
            hops.push(vec![Box::new(coord_end) as Box<dyn Transport>]);
        }
        let mut e = DistributedEngine::connect_replicated(net.clone(), hops, 2).unwrap();
        assert_eq!(e.negotiated_version(), 2);
        assert!(!e.lane_batching());
        assert_eq!(e.max_batch(), 1);

        let clips: Vec<Vec<SpikePlane>> =
            (0..3).map(|b| demo_clip(60 + b, 4, 2, 16, 16)).collect();
        let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();

        // batching explicitly required → typed error, engine healthy
        let err = e.infer_lanes(&refs).unwrap_err();
        assert!(
            err.to_string().contains("lane batching requires protocol v"),
            "want a typed negotiation error, got: {err}"
        );

        // infer_batch falls back to scalar frames, bit-identical
        let outs = e.infer_batch(&refs).unwrap();
        let mut reference = ReferenceEngine::new(net).unwrap();
        for (b, clip) in clips.iter().enumerate() {
            assert_eq!(outs[b], reference.infer(clip).unwrap(), "clip {b}");
        }
        let (scalar, lane) = e.wire_frames();
        assert_eq!(lane, 0, "no lane frame may reach a v2 constellation");
        assert_eq!(scalar, 3 * (4 + 1) * 2);
        drop(e);
        for h in hosts {
            h.join().unwrap();
        }
    }

    /// The amortization contract the bench reports: one 64-clip batch
    /// costs `T + 2` serving frames per hop where 64 scalar clips cost
    /// `64 × (T + 1)` — and `infer_batch` coalesces equal-length clips
    /// into exactly that batch, bit-identical to serving each scalar.
    #[test]
    fn lane_batching_amortizes_wire_frames() {
        let net = demo_serving_network(4).unwrap();
        let clips: Vec<Vec<SpikePlane>> = (0..MAX_LANES)
            .map(|b| demo_clip(b as u64, 4, 2, 16, 16))
            .collect();
        let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
        let mut e =
            DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        assert_eq!(e.max_batch(), MAX_LANES);

        let batched = e.infer_batch(&refs).unwrap();
        let (s0, l0) = e.wire_frames();
        assert_eq!(s0, 0, "a full batch must not fall back to scalar frames");
        assert_eq!(l0, (4 + 2) * 2);

        for (b, clip) in refs.iter().enumerate() {
            assert_eq!(e.infer(clip).unwrap(), batched[b], "lane {b} != scalar run");
        }
        let (s1, l1) = e.wire_frames();
        assert_eq!((s1, l1), ((4 + 1) * 2 * MAX_LANES as u64, l0));
        assert!(
            s1 / l1 >= 40,
            "wire amortization collapsed: {s1} scalar / {l1} lane frames"
        );
    }

    #[test]
    fn window_schedules_are_validated() {
        let net = demo_serving_network(4).unwrap();
        let mut e =
            DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        assert_eq!(e.windows(), &[2, 2], "the config seeds a uniform schedule");
        assert!(e.set_windows(&[1]).is_err(), "wrong arity must be rejected");
        assert!(e.set_windows(&[0, 3]).is_err(), "a zero window must be rejected");
        e.set_windows(&[1, 4]).unwrap();
        assert_eq!(e.windows(), &[1, 4]);
        // the throttled constructor needs one link spec per hop
        assert!(DistributedEngine::loopback_throttled(
            demo_serving_network(4).unwrap(),
            &DistributedConfig::with_shards(2),
            &[LinkSpec::loopback()],
        )
        .is_err());
    }

    /// Satellite (ISSUE 8): stall timers are sampled only on the
    /// blocking path. A channel operation that completes on the
    /// `try_*` probe — the steady-state case under load — takes no
    /// `Instant::now()` pair and bumps no counter; only an operation
    /// that actually waited is timed and counted.
    #[test]
    fn timed_stall_sampling_skips_the_fast_path() {
        use std::time::Duration;

        let mut sm = StageMetrics::new(0, (0, 1));
        let (tx, rx) = sync_channel::<u32>(1);
        timed_send(&tx, 7, &mut sm).unwrap();
        assert_eq!(timed_recv(&rx, &mut sm).unwrap(), 7);
        assert_eq!(sm.stall_samples, 0, "ready channel ops must not be timed");
        assert_eq!(sm.stall_in, Duration::ZERO);
        assert_eq!(sm.stall_out, Duration::ZERO);

        // Blocking send: the capacity-1 buffer is already full, a
        // helper drains it after a delay — the send must wait, and
        // exactly that wait gets sampled.
        let (tx2, rx2) = sync_channel::<u32>(1);
        timed_send(&tx2, 1, &mut sm).unwrap();
        assert_eq!(sm.stall_samples, 0);
        let drainer = crate::sync::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            (rx2.recv().unwrap(), rx2.recv().unwrap())
        });
        timed_send(&tx2, 2, &mut sm).unwrap();
        assert_eq!(sm.stall_samples, 1, "a blocked send is one sample");
        assert!(sm.stall_out >= Duration::from_millis(5), "the wait was timed");
        assert_eq!(drainer.join().unwrap(), (1, 2));

        // Blocking recv: nothing queued until a helper sends.
        let (tx3, rx3) = sync_channel::<u32>(1);
        let sender = crate::sync::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx3.send(9).unwrap();
        });
        assert_eq!(timed_recv(&rx3, &mut sm).unwrap(), 9);
        sender.join().unwrap();
        assert_eq!(sm.stall_samples, 2, "a blocked recv is one sample");
        assert!(sm.stall_in >= Duration::from_millis(5));

        // Teardown surfaces as Err on either path.
        let (tx4, rx4) = sync_channel::<u32>(1);
        drop(tx4);
        assert!(timed_recv(&rx4, &mut sm).is_err());
        let (tx5, rx5) = sync_channel::<u32>(1);
        drop(rx5);
        assert!(timed_send(&tx5, 0, &mut sm).is_err());

        // End to end: a served clip's samples are bounded by blocking
        // events (at most one per channel op), never by frame count
        // alone.
        let net = demo_serving_network(6).unwrap();
        let clip = demo_clip(17, 6, 2, 16, 16);
        let mut e =
            DistributedEngine::loopback(net, &DistributedConfig::with_shards(2)).unwrap();
        e.infer(&clip).unwrap();
        for s in e.stage_metrics() {
            assert!(
                s.stall_samples <= 2 * s.steps,
                "hop {} took {} stall samples over {} steps",
                s.stage,
                s.stall_samples,
                s.steps
            );
        }
    }

    /// Tentpole acceptance (ISSUE 8): outputs, telemetry, and Vmems
    /// stay bit-identical to the reference under **any** per-hop
    /// window schedule — the window=1 degenerate included — and across
    /// mid-session `set_windows`, stall-driven `retune_windows`, a
    /// retune applied right before a replica failover, and lane
    /// batches under yet another schedule. Windows bound in-flight
    /// frames; they never touch what is computed.
    #[test]
    fn prop_window_schedule_invariant() {
        check("window_schedule_invariant", 8, |g| {
            let net = random_network(g);
            let t = 1 + g.index(4);
            let (c, h, w) = net.layers[0].in_shape;
            let density = 0.1 + g.f64() * 0.4;
            let frames: Vec<SpikePlane> = (0..t)
                .map(|_| {
                    let mut p = SpikePlane::zeros(c, h, w);
                    for i in 0..p.len() {
                        if g.chance(density) {
                            p.as_mut_slice()[i] = 1;
                        }
                    }
                    p
                })
                .collect();
            let stateful = net.stateful_layers().count();
            let cfg = DistributedConfig {
                shards: 1 + g.index(stateful + 1),
                window: 1 + g.index(3),
                replicas: 1 + g.index(2),
            };

            let mut ref_state = net.init_state().unwrap();
            let ref_tel = net.run(&frames, &mut ref_state).unwrap();

            let mut e = DistributedEngine::loopback(net.clone(), &cfg).unwrap();
            let hops = e.groups().len();
            for round in 0..3 {
                let schedule: Vec<usize> = (0..hops).map(|_| 1 + g.index(4)).collect();
                e.set_windows(&schedule).unwrap();
                if round == 1 {
                    // a stall-driven retune mid-session
                    e.retune_windows(1, 8);
                }
                if round == 2 && cfg.replicas > 1 {
                    // retune-then-failover: the survivor serves under
                    // whatever schedule is pinned
                    e.sever_replica(g.index(hops), g.index(cfg.replicas)).unwrap();
                }
                e.infer(&frames).unwrap();
                let ok = e.last_telemetry() == &ref_tel[..]
                    && ref_state
                        .vmems
                        .iter()
                        .zip(e.last_vmems())
                        .all(|(a, b)| a.as_slice() == b.as_slice());
                if !ok {
                    return false;
                }
            }
            // lane batches obey the schedule invariance too
            let schedule: Vec<usize> = (0..hops).map(|_| 1 + g.index(4)).collect();
            e.set_windows(&schedule).unwrap();
            let outs = e.infer_lanes(&[&frames, &frames]).unwrap();
            let want: Vec<i32> = ref_state.vmems.last().unwrap().as_slice().to_vec();
            outs.iter().all(|o| *o == want)
                && (0..2).all(|b| e.last_lane_telemetry()[b] == ref_tel)
        });
    }

    /// Tentpole acceptance (ISSUE 8): on a deliberately skewed
    /// constellation — one hop behind a high-latency link — the
    /// retuner widens exactly the wire-bound hop's window, narrows the
    /// idle ones, converges in O(log max) rounds, and the retuned
    /// engine keeps serving bit-identically.
    #[test]
    fn retune_widens_the_congested_hop_and_narrows_idle_ones() {
        let net = demo_serving_network(6).unwrap();
        let clip = demo_clip(31, 6, 2, 16, 16);
        let mut reference = ReferenceEngine::new(net.clone()).unwrap();
        let want = reference.infer(&clip).unwrap();

        // hop 1 sits behind 2 ms of propagation latency; hop 0 is free
        let links = [LinkSpec::loopback(), LinkSpec::new(1 << 30, 2_000)];
        let mut e = DistributedEngine::loopback_throttled(
            net,
            &DistributedConfig {
                shards: 2,
                window: 2,
                replicas: 1,
            },
            &links,
        )
        .unwrap();
        assert_eq!(e.windows(), &[2, 2]);
        assert!(!e.retune_windows(1, 16), "no traffic yet — nothing to retune");

        assert_eq!(e.infer(&clip).unwrap(), want);
        assert!(e.retune_windows(1, 16), "a skewed constellation must retune");
        assert!(
            e.windows()[1] > 2,
            "the latency-bound hop must widen: {:?}",
            e.windows()
        );
        assert!(
            e.windows()[0] <= 2,
            "the free hop must not widen: {:?}",
            e.windows()
        );

        // serve-retune rounds converge to a stable schedule
        for _ in 0..8 {
            assert_eq!(e.infer(&clip).unwrap(), want);
            if !e.retune_windows(1, 16) {
                break;
            }
        }
        assert_eq!(
            e.infer(&clip).unwrap(),
            want,
            "retuned serving must stay bit-identical"
        );
    }
}
