//! Distributed shard serving: layer-group execution across
//! processes/hosts over a binary wire protocol (DESIGN.md
//! §Distributed).
//!
//! The serving tier's next scale step after in-process pipelining
//! (`coordinator::pipeline`): a deep network's layer groups can
//! outgrow one address space, so each group moves to a **shard host**
//! that keeps its weights and Vmem banks resident (layer-stationary
//! placement) while spike frames — the only data that is small per
//! timestep — travel over a versioned, checksummed binary protocol.
//!
//! * [`wire`] — the frame codec (`Hello`, `LoadGroup`, `SpikeFrame`,
//!   `Telemetry`, `Drain`, `Error`, plus the v3 lane-batch messages
//!   `LaneBatchOpen`/`LaneFrame`/`LaneTelemetry` — up to 64 clips per
//!   checksummed frame), length-prefixed + checksummed, total on
//!   decode; `LoadGroup` can carry a serialized workload
//!   ([`wire::encode_network`]) so the coordinator provisions blank
//!   shards over the wire (weight push).
//! * [`transport`] — the [`Transport`](transport::Transport) narrow
//!   waist: TCP for real topologies, bounded in-process byte pipes
//!   (loopback) for deterministic sockets-free tests; the throttled
//!   pair models a finite link (bandwidth + latency) as a delay line.
//! * [`plan`] — the topology-aware deployment planner: per-link
//!   [`LinkSpec`](plan::LinkSpec)s plus per-group compute costs feed a
//!   wire-extended fill/drain makespan model that places layer groups,
//!   spreads replicas, and opens per-hop protocol windows to the
//!   bandwidth-delay product (DESIGN.md §Planner); the runtime closes
//!   the loop with `DistributedEngine::retune_windows`.
//! * [`shard`] — [`ShardHost`](shard::ShardHost), the remote half:
//!   owns one layer-group span, services frames through
//!   `Network::step_group`.
//! * [`coordinator`] —
//!   [`DistributedEngine`](coordinator::DistributedEngine), the local
//!   half: chains shards, windows frames over each link, reassembles
//!   telemetry/Vmems; a serving `Engine`, bit-identical to the
//!   reference executor. With `DistributedConfig::replicas > 1` each
//!   hop holds N replica links and fails over — re-push + replay —
//!   when one dies, failing fast only at zero survivors.

pub mod coordinator;
pub mod plan;
pub mod shard;
pub mod transport;
pub mod wire;

pub use coordinator::{DistributedConfig, DistributedEngine};
pub use plan::{plan_deployment, CostModel, DeploymentPlan, LinkSpec, PlannerConfig};
pub use shard::{ShardHost, ShardReport};
pub use transport::{LoopbackTransport, TcpTransport, Transport};
pub use wire::{decode_network, encode_network, Frame, LaneReport, Role};
