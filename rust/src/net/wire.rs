//! Versioned binary wire codec for the distributed shard protocol
//! (DESIGN.md §Distributed).
//!
//! Every message on a shard link is one [`Frame`], encoded as
//!
//! ```text
//! ┌───────┬─────────┬──────┬─────────┬───────────────┬─────────┐
//! │ magic │ version │ kind │ payload │    payload    │ checksum│
//! │ SPDR  │   u16   │  u8  │ len u32 │  (len bytes)  │   u32   │
//! └───────┴─────────┴──────┴─────────┴───────────────┴─────────┘
//! ```
//!
//! — length-prefixed framing (all integers little-endian) with an
//! FNV-1a checksum over the payload, so a receiver can resynchronize
//! detectably instead of misinterpreting a corrupt stream. Decoding is
//! total: truncated buffers, bad magic, version skew, oversized length
//! prefixes, checksum mismatches and malformed payloads all come back
//! as [`Error::Protocol`] values — never a panic, never an
//! out-of-bounds allocation (the length prefix is validated against
//! [`MAX_PAYLOAD`] *before* any buffer is sized from it).
//!
//! The payload grammar round-trips the simulator's own types —
//! [`SpikePlane`] (bit-packed, 8 cells per byte: planes are binary by
//! contract), [`GroupSpan`], [`StepTelemetry`] and Vmem [`Mat`] banks
//! — through [`Frame::to_bytes`] / [`Frame::from_bytes`], property
//! tested in `prop_frame_roundtrip`.

use std::io::{Read, Write};

use crate::error::{Error, Result};
use crate::snn::network::{GroupSpan, StepTelemetry};
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

/// Frame magic, the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"SPDR";

/// Wire-protocol version carried in every frame header; receivers
/// reject frames from any other version.
pub const VERSION: u16 = 1;

/// Hard cap on the payload length prefix (64 MiB) — anything larger is
/// rejected before allocation, bounding what a corrupt or adversarial
/// peer can make a receiver reserve.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Frame header bytes ahead of the payload (magic + version + kind +
/// payload length).
const HEADER_LEN: usize = 11;

/// Who is speaking on a shard link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// The engine driving clips through the shard chain.
    Coordinator,
    /// A shard host owning one layer-group span.
    Shard,
}

/// One protocol message (DESIGN.md §Distributed has the session
/// grammar: `Hello → LoadGroup → (SpikeFrame* Drain)*`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// Session opener, echoed by the shard: version negotiation is the
    /// frame header; `name` identifies the workload/host for logs.
    Hello {
        /// Speaker role.
        role: Role,
        /// Workload (coordinator) or host (shard) name, for logs.
        name: String,
    },
    /// Assign a layer group: the full stateful-layer group plan plus
    /// which slot this shard serves. The shard resolves its
    /// [`GroupSpan`], pins that span's Vmem banks locally
    /// (layer-stationary placement — weights never cross the wire) and
    /// echoes the frame with `span` filled in as the acknowledgement.
    LoadGroup {
        /// Index of the group this shard owns.
        shard: u32,
        /// Contiguous stateful-layer group ranges, the whole plan.
        groups: Vec<(u32, u32)>,
        /// Resolved span — `None` in the request, `Some` in the echo.
        span: Option<GroupSpan>,
    },
    /// One timestep of spikes for `clip`, sequence-numbered so the
    /// receiver can enforce (and the sender's reorder buffer restore)
    /// timestep order. The shard replies with the output plane its
    /// layer group emits, under the same `(clip, seq)`.
    SpikeFrame {
        /// Clip id (monotonic per session).
        clip: u64,
        /// Timestep index within the clip.
        seq: u32,
        /// The binary spike plane (bit-packed on the wire).
        plane: SpikePlane,
    },
    /// Shard → coordinator at clip end (the reply to [`Frame::Drain`]):
    /// the group's per-timestep telemetry fragments and its final Vmem
    /// banks for the clip.
    Telemetry {
        /// Clip id these results belong to.
        clip: u64,
        /// One telemetry fragment per timestep served.
        steps: Vec<StepTelemetry>,
        /// The span's Vmem banks after the clip's last timestep.
        vmems: Vec<Mat>,
    },
    /// Coordinator → shard: the clip is complete — flush telemetry +
    /// Vmems back and reset the banks for the next clip.
    Drain {
        /// Clip id to drain.
        clip: u64,
    },
    /// A peer reporting failure; the session is over.
    Error {
        /// Human-readable failure description.
        message: String,
    },
}

/// FNV-1a 32-bit checksum (zero-dependency; collision resistance is
/// not a goal — this detects truncation and bit corruption, the
/// transports below it provide integrity).
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// ---------------------------------------------------------------------------
// Encode / decode primitives
// ---------------------------------------------------------------------------

/// Little-endian payload writer.
struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    fn new() -> Self {
        Wr { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn plane(&mut self, p: &SpikePlane) {
        let (c, h, w) = p.shape();
        self.u32(c as u32);
        self.u32(h as u32);
        self.u32(w as u32);
        // bit-packed, LSB-first within each byte; planes are binary by
        // contract (any nonzero cell normalizes to a set bit)
        let mut byte = 0u8;
        for (i, &v) in p.as_slice().iter().enumerate() {
            if v != 0 {
                byte |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                self.buf.push(byte);
                byte = 0;
            }
        }
        if p.len() % 8 != 0 {
            self.buf.push(byte);
        }
    }

    fn mat(&mut self, m: &Mat) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        for &v in m.as_slice() {
            self.i32(v);
        }
    }

    fn telemetry(&mut self, t: &StepTelemetry) {
        self.u32(t.layer_input_spikes.len() as u32);
        for &v in &t.layer_input_spikes {
            self.u64(v);
        }
        self.u32(t.layer_input_cells.len() as u32);
        for &v in &t.layer_input_cells {
            self.u64(v);
        }
    }

    fn span(&mut self, s: &GroupSpan) {
        self.u32(s.layers.0 as u32);
        self.u32(s.layers.1 as u32);
        self.u32(s.stateful.0 as u32);
        self.u32(s.stateful.1 as u32);
    }
}

/// Little-endian payload reader over a borrowed buffer; every accessor
/// fails with a protocol error instead of panicking.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::protocol("truncated payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// A length prefix that must still fit in the remaining buffer when
    /// multiplied by `elem_bytes` — rejects absurd counts before any
    /// allocation is sized from them.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        match n.checked_mul(elem_bytes.max(1)) {
            Some(bytes) if bytes <= remaining => Ok(n),
            _ => Err(Error::protocol(format!(
                "length prefix {n} exceeds remaining payload ({remaining} bytes)"
            ))),
        }
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::protocol("string field is not valid UTF-8"))
    }

    fn plane(&mut self) -> Result<SpikePlane> {
        let c = self.u32()? as u64;
        let h = self.u32()? as u64;
        let w = self.u32()? as u64;
        // cap the unpacked size at MAX_PAYLOAD too, so a crafted shape
        // cannot amplify a small payload into a huge allocation
        let cells = c
            .checked_mul(h)
            .and_then(|v| v.checked_mul(w))
            .filter(|&v| v <= MAX_PAYLOAD as u64)
            .ok_or_else(|| Error::protocol("oversized spike plane"))?
            as usize;
        let packed = self.take(cells.div_ceil(8))?;
        let mut data = vec![0u8; cells];
        for (i, cell) in data.iter_mut().enumerate() {
            *cell = (packed[i / 8] >> (i % 8)) & 1;
        }
        SpikePlane::from_vec(c as usize, h as usize, w as usize, data)
            .map_err(|e| Error::protocol(format!("bad spike plane: {e}")))
    }

    fn mat(&mut self) -> Result<Mat> {
        let rows = self.u32()? as u64;
        let cols = self.u32()? as u64;
        // like len_prefix: the claimed element data must actually be
        // present in the remaining payload before anything is sized
        // from the count
        let remaining = (self.buf.len() - self.pos) as u64;
        let cells = rows
            .checked_mul(cols)
            .filter(|&v| v.checked_mul(4).is_some_and(|bytes| bytes <= remaining))
            .ok_or_else(|| Error::protocol("oversized matrix"))?
            as usize;
        let mut data = Vec::with_capacity(cells);
        for _ in 0..cells {
            data.push(self.i32()?);
        }
        Mat::from_vec(rows as usize, cols as usize, data)
            .map_err(|e| Error::protocol(format!("bad matrix: {e}")))
    }

    fn telemetry(&mut self) -> Result<StepTelemetry> {
        let ns = self.len_prefix(8)?;
        let mut layer_input_spikes = Vec::with_capacity(ns);
        for _ in 0..ns {
            layer_input_spikes.push(self.u64()?);
        }
        let nc = self.len_prefix(8)?;
        let mut layer_input_cells = Vec::with_capacity(nc);
        for _ in 0..nc {
            layer_input_cells.push(self.u64()?);
        }
        Ok(StepTelemetry {
            layer_input_spikes,
            layer_input_cells,
        })
    }

    fn span(&mut self) -> Result<GroupSpan> {
        Ok(GroupSpan {
            layers: (self.u32()? as usize, self.u32()? as usize),
            stateful: (self.u32()? as usize, self.u32()? as usize),
        })
    }

    /// Decoding must consume the payload exactly — trailing bytes mean
    /// a malformed (or differently-versioned) frame.
    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::protocol(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

impl Frame {
    /// Wire kind tag of this frame.
    fn kind(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::LoadGroup { .. } => 2,
            Frame::SpikeFrame { .. } => 3,
            Frame::Telemetry { .. } => 4,
            Frame::Drain { .. } => 5,
            Frame::Error { .. } => 6,
        }
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut w = Wr::new();
        match self {
            Frame::Hello { role, name } => {
                w.u8(match role {
                    Role::Coordinator => 0,
                    Role::Shard => 1,
                });
                w.str(name);
            }
            Frame::LoadGroup { shard, groups, span } => {
                w.u32(*shard);
                w.u32(groups.len() as u32);
                for &(a, b) in groups {
                    w.u32(a);
                    w.u32(b);
                }
                match span {
                    None => w.u8(0),
                    Some(s) => {
                        w.u8(1);
                        w.span(s);
                    }
                }
            }
            Frame::SpikeFrame { clip, seq, plane } => {
                w.u64(*clip);
                w.u32(*seq);
                w.plane(plane);
            }
            Frame::Telemetry { clip, steps, vmems } => {
                w.u64(*clip);
                w.u32(steps.len() as u32);
                for t in steps {
                    w.telemetry(t);
                }
                w.u32(vmems.len() as u32);
                for m in vmems {
                    w.mat(m);
                }
            }
            Frame::Drain { clip } => w.u64(*clip),
            Frame::Error { message } => w.str(message),
        }
        w.buf
    }

    fn decode_payload(kind: u8, payload: &[u8]) -> Result<Frame> {
        let mut r = Rd::new(payload);
        let frame = match kind {
            1 => Frame::Hello {
                role: match r.u8()? {
                    0 => Role::Coordinator,
                    1 => Role::Shard,
                    other => {
                        return Err(Error::protocol(format!("unknown role {other}")));
                    }
                },
                name: r.str()?,
            },
            2 => {
                let shard = r.u32()?;
                let n = r.len_prefix(8)?;
                let mut groups = Vec::with_capacity(n);
                for _ in 0..n {
                    groups.push((r.u32()?, r.u32()?));
                }
                let span = match r.u8()? {
                    0 => None,
                    1 => Some(r.span()?),
                    other => {
                        return Err(Error::protocol(format!("bad span flag {other}")));
                    }
                };
                Frame::LoadGroup {
                    shard,
                    groups,
                    span,
                }
            }
            3 => Frame::SpikeFrame {
                clip: r.u64()?,
                seq: r.u32()?,
                plane: r.plane()?,
            },
            4 => {
                let clip = r.u64()?;
                let n = r.len_prefix(8)?;
                let mut steps = Vec::with_capacity(n);
                for _ in 0..n {
                    steps.push(r.telemetry()?);
                }
                let nm = r.len_prefix(8)?;
                let mut vmems = Vec::with_capacity(nm);
                for _ in 0..nm {
                    vmems.push(r.mat()?);
                }
                Frame::Telemetry { clip, steps, vmems }
            }
            5 => Frame::Drain { clip: r.u64()? },
            6 => Frame::Error { message: r.str()? },
            other => {
                return Err(Error::protocol(format!("unknown frame kind {other}")));
            }
        };
        r.finish()?;
        Ok(frame)
    }

    /// Encode the frame into one contiguous wire buffer (header +
    /// payload + checksum).
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut buf = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.push(self.kind());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&payload);
        buf.extend_from_slice(&checksum(&payload).to_le_bytes());
        buf
    }

    /// Decode one frame from the front of `buf`; returns the frame and
    /// the bytes consumed. Every malformation — truncation (of header,
    /// payload or checksum), bad magic, version skew, an oversized
    /// length prefix, a checksum mismatch, an unknown kind, or a
    /// malformed payload — is an [`Error::Protocol`]; decoding never
    /// panics.
    pub fn from_bytes(buf: &[u8]) -> Result<(Frame, usize)> {
        if buf.len() < HEADER_LEN {
            return Err(Error::protocol(format!(
                "truncated frame header: {} of {HEADER_LEN} bytes",
                buf.len()
            )));
        }
        let len = parse_header(buf[..HEADER_LEN].try_into().unwrap())?;
        let total = HEADER_LEN + len + 4;
        if buf.len() < total {
            return Err(Error::protocol(format!(
                "truncated frame: {} of {total} bytes",
                buf.len()
            )));
        }
        let payload = &buf[HEADER_LEN..HEADER_LEN + len];
        let want = u32::from_le_bytes(buf[HEADER_LEN + len..total].try_into().unwrap());
        if checksum(payload) != want {
            return Err(Error::protocol("frame checksum mismatch"));
        }
        let frame = Frame::decode_payload(buf[6], payload)?;
        Ok((frame, total))
    }

    /// Read one frame from a byte stream. Returns `Ok(None)` on a
    /// clean end-of-stream (the peer closed between frames); EOF
    /// *inside* a frame is a protocol error.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Option<Frame>> {
        let mut header = [0u8; HEADER_LEN];
        // Peek the first byte separately to distinguish a clean close
        // from a mid-frame truncation.
        loop {
            match r.read(&mut header[..1]) {
                Ok(0) => return Ok(None),
                Ok(_) => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(Error::Io(e)),
            }
        }
        read_exact(r, &mut header[1..])?;
        let len = parse_header(&header)?;
        let mut rest = vec![0u8; len + 4];
        read_exact(r, &mut rest)?;
        let payload = &rest[..len];
        let want = u32::from_le_bytes(rest[len..].try_into().unwrap());
        if checksum(payload) != want {
            return Err(Error::protocol("frame checksum mismatch"));
        }
        Ok(Some(Frame::decode_payload(header[6], payload)?))
    }

    /// Write the frame to a byte stream (one contiguous write, then
    /// flush).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(&self.to_bytes())?;
        w.flush()?;
        Ok(())
    }
}

/// Validate a frame header and return the payload length.
fn parse_header(header: &[u8; HEADER_LEN]) -> Result<usize> {
    if header[..4] != MAGIC {
        return Err(Error::protocol(format!(
            "bad frame magic {:02x?}",
            &header[..4]
        )));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(Error::protocol(format!(
            "unsupported protocol version {version} (host speaks {VERSION})"
        )));
    }
    let len = u32::from_le_bytes(header[7..11].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(Error::protocol(format!(
            "oversized frame: {len}-byte payload exceeds the {MAX_PAYLOAD}-byte cap"
        )));
    }
    Ok(len as usize)
}

/// `Read::read_exact` with mid-frame EOF mapped to a protocol error.
fn read_exact<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::protocol("connection closed mid-frame")
        } else {
            Error::Io(e)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Gen};

    fn sample_frames() -> Vec<Frame> {
        let mut plane = SpikePlane::zeros(2, 3, 5);
        plane.set(0, 0, 0, 1);
        plane.set(1, 2, 4, 1);
        plane.set(0, 1, 3, 1);
        let mut vmem = Mat::zeros(2, 3);
        vmem.set(0, 1, -7);
        vmem.set(1, 2, 123);
        vec![
            Frame::Hello {
                role: Role::Coordinator,
                name: "flow".into(),
            },
            Frame::Hello {
                role: Role::Shard,
                name: String::new(),
            },
            Frame::LoadGroup {
                shard: 1,
                groups: vec![(0, 2), (2, 5)],
                span: None,
            },
            Frame::LoadGroup {
                shard: 0,
                groups: vec![(0, 1)],
                span: Some(GroupSpan {
                    layers: (0, 3),
                    stateful: (0, 2),
                }),
            },
            Frame::SpikeFrame {
                clip: 7,
                seq: 3,
                plane,
            },
            Frame::Telemetry {
                clip: 7,
                steps: vec![
                    StepTelemetry {
                        layer_input_spikes: vec![4, 0, 9],
                        layer_input_cells: vec![64, 64, 16],
                    },
                    StepTelemetry::default(),
                ],
                vmems: vec![vmem, Mat::zeros(1, 4)],
            },
            Frame::Drain { clip: 7 },
            Frame::Error {
                message: "boom".into(),
            },
        ]
    }

    #[test]
    fn every_variant_roundtrips() {
        for frame in sample_frames() {
            let bytes = frame.to_bytes();
            let (back, used) = Frame::from_bytes(&bytes).unwrap();
            assert_eq!(back, frame);
            assert_eq!(used, bytes.len());
        }
    }

    #[test]
    fn concatenated_frames_decode_in_sequence() {
        let frames = sample_frames();
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&f.to_bytes());
        }
        let mut at = 0;
        for f in &frames {
            let (back, used) = Frame::from_bytes(&stream[at..]).unwrap();
            assert_eq!(&back, f);
            at += used;
        }
        assert_eq!(at, stream.len());
    }

    #[test]
    fn stream_reader_matches_buffer_decoder() {
        let frames = sample_frames();
        let mut stream = Vec::new();
        for f in &frames {
            f.write_to(&mut stream).unwrap();
        }
        let mut r = &stream[..];
        for f in &frames {
            assert_eq!(Frame::read_from(&mut r).unwrap().as_ref(), Some(f));
        }
        // clean end-of-stream
        assert!(Frame::read_from(&mut r).unwrap().is_none());
    }

    fn rand_plane(g: &mut Gen) -> SpikePlane {
        let (c, h, w) = (1 + g.index(3), 1 + g.index(6), 1 + g.index(6));
        let mut p = SpikePlane::zeros(c, h, w);
        for i in 0..p.len() {
            if g.chance(0.3) {
                p.as_mut_slice()[i] = 1;
            }
        }
        p
    }

    fn rand_telemetry(g: &mut Gen) -> StepTelemetry {
        StepTelemetry {
            layer_input_spikes: g.vec_of(0, 4, |g| g.u64()),
            layer_input_cells: g.vec_of(0, 4, |g| g.u64()),
        }
    }

    fn rand_mat(g: &mut Gen) -> Mat {
        let (rows, cols) = (1 + g.index(5), 1 + g.index(5));
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, g.i32_in(i32::MIN..=i32::MAX));
            }
        }
        m
    }

    /// Satellite: random planes, spans, telemetry and Vmem banks
    /// survive the codec bit-exactly.
    #[test]
    fn prop_frame_roundtrip() {
        check("frame_roundtrip", 60, |g| {
            let frame = match g.index(6) {
                0 => Frame::Hello {
                    role: *g.choose(&[Role::Coordinator, Role::Shard]),
                    name: "shard-α ".repeat(g.index(4)),
                },
                1 => Frame::LoadGroup {
                    shard: g.u64_in(0..=u32::MAX as u64) as u32,
                    groups: g.vec_of(0, 5, |g| {
                        (g.u64_in(0..=99) as u32, g.u64_in(0..=99) as u32)
                    }),
                    span: g.chance(0.5).then(|| GroupSpan {
                        layers: (g.index(9), g.index(9)),
                        stateful: (g.index(9), g.index(9)),
                    }),
                },
                2 => Frame::SpikeFrame {
                    clip: g.u64(),
                    seq: g.u64_in(0..=u32::MAX as u64) as u32,
                    plane: rand_plane(g),
                },
                3 => Frame::Telemetry {
                    clip: g.u64(),
                    steps: g.vec_of(0, 3, rand_telemetry),
                    vmems: g.vec_of(0, 3, rand_mat),
                },
                4 => Frame::Drain { clip: g.u64() },
                _ => Frame::Error {
                    message: "e".repeat(g.index(40)),
                },
            };
            let bytes = frame.to_bytes();
            matches!(Frame::from_bytes(&bytes), Ok((back, used))
                if back == frame && used == bytes.len())
        });
    }

    /// Satellite: adversarial decodes — every truncation point, bad
    /// magic, version skew, oversized length, flipped payload bits and
    /// unknown kinds must all come back as `Error` values, never
    /// panics.
    #[test]
    fn adversarial_decodes_error_cleanly() {
        let frame = Frame::SpikeFrame {
            clip: 3,
            seq: 1,
            plane: SpikePlane::zeros(2, 4, 4),
        };
        let good = frame.to_bytes();

        // truncation at every possible length
        for n in 0..good.len() {
            assert!(Frame::from_bytes(&good[..n]).is_err(), "prefix {n}");
        }

        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("magic")));

        // version skew
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("version")));

        // oversized length prefix must be rejected before allocation
        let mut bad = good.clone();
        bad[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("oversized")));

        // corrupt payload: the checksum catches it
        let mut bad = good.clone();
        bad[HEADER_LEN] ^= 0xff;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("checksum")));

        // corrupt checksum itself
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("checksum")));

        // unknown kind with a valid checksum
        let mut bad = good.clone();
        bad[6] = 42;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("kind")));

        // trailing garbage inside a correctly-checksummed payload
        let mut w = Frame::Drain { clip: 1 }.encode_payload();
        w.push(0xEE);
        let mut evil = Vec::new();
        evil.extend_from_slice(&MAGIC);
        evil.extend_from_slice(&VERSION.to_le_bytes());
        evil.push(5);
        evil.extend_from_slice(&(w.len() as u32).to_le_bytes());
        evil.extend_from_slice(&w);
        evil.extend_from_slice(&checksum(&w).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&evil), Err(Error::Protocol(m))
            if m.contains("trailing")));

        // matrix dims whose element data cannot be present are
        // rejected before any allocation is sized from the count
        let mut w = Wr::new();
        w.u64(9); // clip
        w.u32(0); // no steps
        w.u32(1); // one matrix…
        w.u32(4096);
        w.u32(4096); // …claiming 16M cells with no bytes behind them
        let mut evil = Vec::new();
        evil.extend_from_slice(&MAGIC);
        evil.extend_from_slice(&VERSION.to_le_bytes());
        evil.push(4);
        evil.extend_from_slice(&(w.buf.len() as u32).to_le_bytes());
        evil.extend_from_slice(&w.buf);
        evil.extend_from_slice(&checksum(&w.buf).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&evil), Err(Error::Protocol(m))
            if m.contains("oversized matrix")));

        // absurd inner length prefix (vec count) caps before allocating
        let mut w = Wr::new();
        w.u64(9); // clip
        w.u32(u32::MAX); // steps count: would be 32 GiB of telemetry
        let mut evil = Vec::new();
        evil.extend_from_slice(&MAGIC);
        evil.extend_from_slice(&VERSION.to_le_bytes());
        evil.push(4);
        evil.extend_from_slice(&(w.buf.len() as u32).to_le_bytes());
        evil.extend_from_slice(&w.buf);
        evil.extend_from_slice(&checksum(&w.buf).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&evil), Err(Error::Protocol(m))
            if m.contains("length prefix")));

        // the pristine frame still decodes (the cases above were real)
        assert!(Frame::from_bytes(&good).is_ok());
    }

    #[test]
    fn mid_stream_eof_is_a_protocol_error_not_a_clean_close() {
        let bytes = Frame::Drain { clip: 5 }.to_bytes();
        let mut r = &bytes[..bytes.len() - 2];
        assert!(matches!(
            Frame::read_from(&mut r),
            Err(Error::Protocol(m)) if m.contains("mid-frame")
        ));
    }

    #[test]
    fn plane_bit_packing_is_compact() {
        let frame = Frame::SpikeFrame {
            clip: 0,
            seq: 0,
            plane: SpikePlane::zeros(2, 16, 16),
        };
        // 512 cells pack into 64 bytes (+ shape/ids/framing), far under
        // the 512 bytes a raw u8 encoding would need.
        assert!(frame.to_bytes().len() < 2 * 16 * 16 / 8 + 64);
    }
}
