//! Versioned binary wire codec for the distributed shard protocol
//! (DESIGN.md §Distributed).
//!
//! Every message on a shard link is one [`Frame`], encoded as
//!
//! ```text
//! ┌───────┬─────────┬──────┬─────────┬───────────────┬─────────┐
//! │ magic │ version │ kind │ payload │    payload    │ checksum│
//! │ SPDR  │   u16   │  u8  │ len u32 │  (len bytes)  │   u32   │
//! └───────┴─────────┴──────┴─────────┴───────────────┴─────────┘
//! ```
//!
//! — length-prefixed framing (all integers little-endian) with an
//! FNV-1a checksum over the payload, so a receiver can resynchronize
//! detectably instead of misinterpreting a corrupt stream. Decoding is
//! total: truncated buffers, bad magic, version skew, oversized length
//! prefixes, checksum mismatches and malformed payloads all come back
//! as [`Error::Protocol`] values — never a panic, never an
//! out-of-bounds allocation (the length prefix is validated against
//! [`MAX_PAYLOAD`] *before* any buffer is sized from it).
//!
//! The payload grammar round-trips the simulator's own types —
//! [`SpikePlane`] (bit-packed through the shared
//! [`bitpack`](crate::snn::bitpack) layout, 8 cells per byte: planes
//! are binary by contract), lane-major [`LaneFrame`]s (v3: `lanes`
//! bits per cell, up to 64 clips in one checksummed frame),
//! [`GroupSpan`], [`StepTelemetry`], Vmem
//! [`Mat`] banks and
//! whole [`Network`] workloads ([`encode_network`] /
//! [`decode_network`], the `LoadGroup` weight-push payload) — through
//! [`Frame::to_bytes`] / [`Frame::from_bytes`], property tested in
//! `prop_frame_roundtrip` and `prop_network_roundtrips_bit_exactly`.
//!
//! **Version negotiation.** Receivers accept header versions
//! [`MIN_VERSION`]`..=`[`VERSION`] and every frame kind knows the
//! lowest dialect it exists in ([`Frame::wire_version`]): senders
//! stamp each frame at that version, so the v2 grammar stays
//! byte-identical on the wire and a v2 peer never sees a v3 header
//! unless lane traffic — which it cannot service — is addressed to it.
//! A v3-only kind under a v2 header is rejected as version skew; a
//! host's `Hello` is stamped at the highest version it speaks, which
//! is how the coordinator learns whether a shard can take lane
//! batches.

use std::io::{Read, Write};

use crate::error::{Error, Result};
use crate::obs::trace::WireSpan;
use crate::quant::Precision;
use crate::snn::bitpack;
use crate::snn::layer::{Layer, LayerKind, NeuronConfig, ResetMode};
use crate::snn::network::{GroupSpan, Network, StepTelemetry};
use crate::snn::spikes::{LaneFrame, LanePlane, SpikePlane, MAX_LANES};
use crate::snn::tensor::Mat;

/// Frame magic, the first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"SPDR";

/// Highest wire-protocol version this build speaks; receivers accept
/// [`MIN_VERSION`]`..=VERSION` in the frame header. Version 2 added
/// the [`Frame::LoadGroup`] `workload` field (over-the-wire weight
/// push, so shards can start blank); version 3 added the lane-batch
/// messages ([`Frame::LaneBatchOpen`] / [`Frame::LaneFrame`] /
/// [`Frame::LaneTelemetry`] — up to 64 clips per frame) and the
/// observability sideband ([`Frame::TraceSync`] / [`Frame::TraceCtx`]
/// / [`Frame::TraceFlush`] / [`Frame::TraceSpans`], only ever sent
/// when tracing is enabled).
pub const VERSION: u16 = 3;

/// Lowest wire-protocol version this build still decodes. The v2
/// grammar (every pre-lane frame kind) is encoded byte-identically by
/// this build, stamped at v2 ([`Frame::wire_version`]), so v2 peers
/// interoperate for scalar traffic.
pub const MIN_VERSION: u16 = 2;

/// The version that introduced lane batching — a peer whose `Hello`
/// header carries at least this version can service
/// [`Frame::LaneBatchOpen`] / [`Frame::LaneFrame`] streams.
pub const LANE_VERSION: u16 = 3;

/// Hard cap on the payload length prefix (64 MiB) — anything larger is
/// rejected before allocation, bounding what a corrupt or adversarial
/// peer can make a receiver reserve.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Frame header bytes ahead of the payload (magic + version + kind +
/// payload length).
const HEADER_LEN: usize = 11;

/// Who is speaking on a shard link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// The engine driving clips through the shard chain.
    Coordinator,
    /// A shard host owning one layer-group span.
    Shard,
}

/// One protocol message (DESIGN.md §Distributed has the session
/// grammar: `Hello → LoadGroup[+workload] → (LoadGroup | SpikeFrame*
/// Drain | LaneBatchOpen LaneFrame* Drain)*` — the first `LoadGroup`
/// may push the serialized workload, later ones re-assign/reset for
/// failover replay; the lane-batch production is protocol v3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// Session opener, echoed by the shard: version negotiation is the
    /// frame header; `name` identifies the workload/host for logs.
    Hello {
        /// Speaker role.
        role: Role,
        /// Workload (coordinator) or host (shard) name, for logs.
        name: String,
    },
    /// Assign a layer group: the full stateful-layer group plan plus
    /// which slot this shard serves. The shard resolves its
    /// [`GroupSpan`], pins that span's Vmem banks locally
    /// (layer-stationary placement) and echoes the frame with `span`
    /// filled in as the acknowledgement.
    ///
    /// With `workload` set, the frame additionally *provisions* the
    /// shard: the bytes are a serialized weight bundle
    /// ([`encode_network`] — layer topology, quantized weight
    /// matrices, precision and neuron config, checksummed like every
    /// frame) that the shard installs before resolving the span, so a
    /// blank `spidr shard --listen` needs no local artifact. Weights
    /// cross the wire once at session start and stay pinned after
    /// that; the echo never carries them back.
    LoadGroup {
        /// Index of the group this shard owns.
        shard: u32,
        /// Contiguous stateful-layer group ranges, the whole plan.
        groups: Vec<(u32, u32)>,
        /// Resolved span — `None` in the request, `Some` in the echo.
        span: Option<GroupSpan>,
        /// Serialized workload ([`encode_network`]) to install before
        /// resolving the span — `Some` when the coordinator pushes
        /// weights (blank-shard provisioning), `None` on re-pushes
        /// (failover replay resets) and in the echo.
        workload: Option<Vec<u8>>,
    },
    /// One timestep of spikes for `clip`, sequence-numbered so the
    /// receiver can enforce (and the sender's reorder buffer restore)
    /// timestep order. The shard replies with the output plane its
    /// layer group emits, under the same `(clip, seq)`.
    SpikeFrame {
        /// Clip id (monotonic per session).
        clip: u64,
        /// Timestep index within the clip.
        seq: u32,
        /// The binary spike plane (bit-packed on the wire).
        plane: SpikePlane,
    },
    /// Shard → coordinator at clip end (the reply to [`Frame::Drain`]):
    /// the group's per-timestep telemetry fragments and its final Vmem
    /// banks for the clip.
    Telemetry {
        /// Clip id these results belong to.
        clip: u64,
        /// One telemetry fragment per timestep served.
        steps: Vec<StepTelemetry>,
        /// The span's Vmem banks after the clip's last timestep.
        vmems: Vec<Mat>,
    },
    /// Coordinator → shard: the clip is complete — flush telemetry +
    /// Vmems back and reset the banks for the next clip.
    Drain {
        /// Clip id to drain.
        clip: u64,
    },
    /// A peer reporting failure; the session is over.
    Error {
        /// Human-readable failure description.
        message: String,
    },
    /// v3: open a lane batch — up to [`MAX_LANES`] clips ride one
    /// [`Frame::LaneFrame`] stream, clip `clips[b]` in bit-lane `b`.
    /// The shard allocates per-span lane Vmem banks sized to
    /// `clips.len()` lanes and echoes the frame as the
    /// acknowledgement. A lane batch and a scalar clip are mutually
    /// exclusive on a link until drained.
    LaneBatchOpen {
        /// Batch id (the first lane's clip id; monotonic per session).
        batch: u64,
        /// Per-lane clip ids, one per occupied bit-lane (1..=64).
        clips: Vec<u64>,
    },
    /// v3: one timestep of spikes for a whole lane batch — the
    /// lane-major plane bit-packs `frame.lanes()` bits per cell, so 64
    /// clips' spikes cross the wire in one checksummed frame. The
    /// shard replies with the output lane frame its layer group emits,
    /// under the same `(batch, seq)`.
    LaneFrame {
        /// Batch id this timestep belongs to.
        batch: u64,
        /// Timestep index within the batch.
        seq: u32,
        /// The lane-major spike plane (`lanes` bits per cell on the
        /// wire).
        frame: LaneFrame,
    },
    /// v3: shard → coordinator at lane-batch end (the reply to
    /// [`Frame::Drain`] with the batch id): per-lane telemetry and
    /// final Vmem banks, demuxed lane-by-lane at the coordinator.
    LaneTelemetry {
        /// Batch id these results belong to.
        batch: u64,
        /// One report per lane, in lane order.
        lanes: Vec<LaneReport>,
    },
    /// v3 (observability sideband): clock-sync ping/echo for
    /// cross-process trace alignment. The coordinator sends its local
    /// µs clock in `t0_us` with `peer_us` 0; the shard echoes the
    /// frame with `peer_us` set to its own µs clock. Reading the echo
    /// at local time `t1`, the coordinator estimates the shard-clock
    /// offset as `peer_us − (t0_us + t1)/2` (symmetric-delay
    /// assumption — good to one RTT/2, enough to join span timelines).
    /// Sent only when tracing is enabled, never on the clip hot path.
    TraceSync {
        /// Requester's local µs clock at send, echoed back verbatim.
        t0_us: u64,
        /// Responder's local µs clock (0 in the request).
        peer_us: u64,
    },
    /// v3 (observability sideband): bind a session clip id to a
    /// coordinator-minted trace id, so the shard attributes its spans
    /// for that clip to the coordinator's trace (one frame per lane
    /// for a lane batch). Sent only when tracing is enabled.
    TraceCtx {
        /// Coordinator-minted trace id.
        trace: u64,
        /// Session clip id the trace covers.
        clip: u64,
    },
    /// v3 (observability sideband): ask the shard to flush its
    /// buffered spans; the reply is a [`Frame::TraceSpans`].
    TraceFlush,
    /// v3 (observability sideband): the shard's buffered spans since
    /// the last flush, timestamps in the **shard's** clock —
    /// [`Tracer::inject`](crate::obs::trace::Tracer::inject) shifts
    /// them onto the coordinator timeline using the
    /// [`Frame::TraceSync`] offset estimate.
    TraceSpans {
        /// Buffered spans, oldest first.
        spans: Vec<WireSpan>,
    },
}

/// One lane's drain report inside [`Frame::LaneTelemetry`]: exactly
/// what a scalar [`Frame::Telemetry`] would have carried had the
/// lane's clip been served alone — the per-lane bit-identity contract.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct LaneReport {
    /// One telemetry fragment per timestep served, for this lane.
    pub steps: Vec<StepTelemetry>,
    /// The span's Vmem banks after the batch's last timestep, for this
    /// lane.
    pub vmems: Vec<Mat>,
}

/// FNV-1a 32-bit checksum (zero-dependency; collision resistance is
/// not a goal — this detects truncation and bit corruption, the
/// transports below it provide integrity).
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// ---------------------------------------------------------------------------
// Encode / decode primitives
// ---------------------------------------------------------------------------

/// Little-endian payload writer.
struct Wr {
    buf: Vec<u8>,
}

impl Wr {
    fn new() -> Self {
        Wr { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn plane(&mut self, p: &SpikePlane) {
        let (c, h, w) = p.shape();
        self.u32(c as u32);
        self.u32(h as u32);
        self.u32(w as u32);
        // the shared LSB-first layout (snn::bitpack) — one definition
        // for the wire codec and the lane-major batch tensor
        self.buf.extend_from_slice(&bitpack::pack_bytes(p.as_slice()));
    }

    fn lane_plane(&mut self, f: &LaneFrame) {
        let (c, h, w) = f.shape();
        self.u8(f.lanes() as u8);
        self.u32(c as u32);
        self.u32(h as u32);
        self.u32(w as u32);
        // the shared LSB-first lane bitstream: `lanes` bits per cell,
        // so a 64-clip batch costs one u64 per cell — not 64 planes
        self.buf
            .extend_from_slice(&bitpack::pack_words(f.plane().as_slice(), f.lanes()));
    }

    fn mat(&mut self, m: &Mat) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        for &v in m.as_slice() {
            self.i32(v);
        }
    }

    fn telemetry(&mut self, t: &StepTelemetry) {
        self.u32(t.layer_input_spikes.len() as u32);
        for &v in &t.layer_input_spikes {
            self.u64(v);
        }
        self.u32(t.layer_input_cells.len() as u32);
        for &v in &t.layer_input_cells {
            self.u64(v);
        }
    }

    fn span(&mut self, s: &GroupSpan) {
        self.u32(s.layers.0 as u32);
        self.u32(s.layers.1 as u32);
        self.u32(s.stateful.0 as u32);
        self.u32(s.stateful.1 as u32);
    }
}

/// A slice as a fixed-size array, failing with a protocol error —
/// never a panic — on length mismatch. Every fixed-width read in the
/// decode path goes through here (`spidr lint` rule 3).
fn fixed<const N: usize>(s: &[u8]) -> Result<[u8; N]> {
    s.try_into()
        .map_err(|_| Error::protocol(format!("expected {N} bytes, got {}", s.len())))
}

/// Little-endian payload reader over a borrowed buffer; every accessor
/// fails with a protocol error instead of panicking.
struct Rd<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::protocol("truncated payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// `take(N)` as a fixed array — the total form of
    /// `slice.try_into().unwrap()` (`spidr lint` rule 3: decode paths
    /// never panic, even if a bounds invariant is later broken).
    fn arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        fixed(self.take(N)?)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.arr()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.arr()?))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.arr()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.arr()?))
    }

    /// A length prefix that must still fit in the remaining buffer when
    /// multiplied by `elem_bytes` — rejects absurd counts before any
    /// allocation is sized from them.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        match n.checked_mul(elem_bytes.max(1)) {
            Some(bytes) if bytes <= remaining => Ok(n),
            _ => Err(Error::protocol(format!(
                "length prefix {n} exceeds remaining payload ({remaining} bytes)"
            ))),
        }
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::protocol("string field is not valid UTF-8"))
    }

    fn plane(&mut self) -> Result<SpikePlane> {
        let c = self.u32()? as u64;
        let h = self.u32()? as u64;
        let w = self.u32()? as u64;
        // cap the unpacked size at MAX_PAYLOAD too, so a crafted shape
        // cannot amplify a small payload into a huge allocation
        let cells = c
            .checked_mul(h)
            .and_then(|v| v.checked_mul(w))
            .filter(|&v| v <= MAX_PAYLOAD as u64)
            .ok_or_else(|| Error::protocol("oversized spike plane"))?
            as usize;
        let packed = self.take(cells.div_ceil(8))?;
        let data = bitpack::unpack_bytes(packed, cells);
        SpikePlane::from_vec(c as usize, h as usize, w as usize, data)
            .map_err(|e| Error::protocol(format!("bad spike plane: {e}")))
    }

    /// The v3 lane-count byte, validated before anything is sized from
    /// it: 0 lanes and more than [`MAX_LANES`] are both malformed.
    fn lane_count(&mut self) -> Result<usize> {
        let lanes = self.u8()? as usize;
        if lanes == 0 || lanes > MAX_LANES {
            return Err(Error::protocol(format!(
                "lane count {lanes} outside 1..={MAX_LANES}"
            )));
        }
        Ok(lanes)
    }

    fn lane_plane(&mut self) -> Result<LaneFrame> {
        let lanes = self.lane_count()?;
        let c = self.u32()? as u64;
        let h = self.u32()? as u64;
        let w = self.u32()? as u64;
        // cap the unpacked size before allocation: a lane plane costs
        // 8 bytes per cell in memory, so bound cells*8 by MAX_PAYLOAD —
        // a crafted shape cannot amplify a small payload into a huge
        // allocation
        let cells = c
            .checked_mul(h)
            .and_then(|v| v.checked_mul(w))
            .filter(|&v| v.checked_mul(8).is_some_and(|b| b <= MAX_PAYLOAD as u64))
            .ok_or_else(|| Error::protocol("oversized lane plane"))?
            as usize;
        let packed = self.take((cells * lanes).div_ceil(8))?;
        let data = bitpack::unpack_words(packed, cells, lanes);
        let plane = LanePlane::from_vec(c as usize, h as usize, w as usize, data)
            .map_err(|e| Error::protocol(format!("bad lane plane: {e}")))?;
        // unpack_words masks to `lanes` bits, so the stray-bit check
        // cannot fire here — but the constructor stays the validated
        // entry for any future decode path
        LaneFrame::from_plane_checked(plane, lanes)
            .map_err(|e| Error::protocol(format!("bad lane plane: {e}")))
    }

    fn mat(&mut self) -> Result<Mat> {
        let rows = self.u32()? as u64;
        let cols = self.u32()? as u64;
        // like len_prefix: the claimed element data must actually be
        // present in the remaining payload before anything is sized
        // from the count
        let remaining = (self.buf.len() - self.pos) as u64;
        let cells = rows
            .checked_mul(cols)
            .filter(|&v| v.checked_mul(4).is_some_and(|bytes| bytes <= remaining))
            .ok_or_else(|| Error::protocol("oversized matrix"))?
            as usize;
        let mut data = Vec::with_capacity(cells);
        for _ in 0..cells {
            data.push(self.i32()?);
        }
        Mat::from_vec(rows as usize, cols as usize, data)
            .map_err(|e| Error::protocol(format!("bad matrix: {e}")))
    }

    fn telemetry(&mut self) -> Result<StepTelemetry> {
        let ns = self.len_prefix(8)?;
        let mut layer_input_spikes = Vec::with_capacity(ns);
        for _ in 0..ns {
            layer_input_spikes.push(self.u64()?);
        }
        let nc = self.len_prefix(8)?;
        let mut layer_input_cells = Vec::with_capacity(nc);
        for _ in 0..nc {
            layer_input_cells.push(self.u64()?);
        }
        Ok(StepTelemetry {
            layer_input_spikes,
            layer_input_cells,
        })
    }

    fn span(&mut self) -> Result<GroupSpan> {
        Ok(GroupSpan {
            layers: (self.u32()? as usize, self.u32()? as usize),
            stateful: (self.u32()? as usize, self.u32()? as usize),
        })
    }

    /// Decoding must consume the payload exactly — trailing bytes mean
    /// a malformed (or differently-versioned) frame.
    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::protocol(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

impl Frame {
    /// Wire kind tag of this frame.
    fn kind(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 1,
            Frame::LoadGroup { .. } => 2,
            Frame::SpikeFrame { .. } => 3,
            Frame::Telemetry { .. } => 4,
            Frame::Drain { .. } => 5,
            Frame::Error { .. } => 6,
            Frame::LaneBatchOpen { .. } => 7,
            Frame::LaneFrame { .. } => 8,
            Frame::LaneTelemetry { .. } => 9,
            Frame::TraceSync { .. } => 10,
            Frame::TraceCtx { .. } => 11,
            Frame::TraceFlush => 12,
            Frame::TraceSpans { .. } => 13,
        }
    }

    /// The lowest header version this frame's kind is defined at: lane
    /// messages and the observability sideband are v3, everything else
    /// decodes at v2. Senders stamp each frame at this version
    /// ([`Frame::to_bytes`]), so the v2 grammar stays byte-identical
    /// on the wire and a v2 peer only ever receives headers it can
    /// parse — unless lane traffic, which it cannot service, is
    /// addressed to it (a typed rejection, not a desync). Trace frames
    /// are additionally only ever *sent* to peers that negotiated v3.
    pub fn wire_version(&self) -> u16 {
        match self {
            Frame::LaneBatchOpen { .. }
            | Frame::LaneFrame { .. }
            | Frame::LaneTelemetry { .. }
            | Frame::TraceSync { .. }
            | Frame::TraceCtx { .. }
            | Frame::TraceFlush
            | Frame::TraceSpans { .. } => LANE_VERSION,
            _ => MIN_VERSION,
        }
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut w = Wr::new();
        match self {
            Frame::Hello { role, name } => {
                w.u8(match role {
                    Role::Coordinator => 0,
                    Role::Shard => 1,
                });
                w.str(name);
            }
            Frame::LoadGroup {
                shard,
                groups,
                span,
                workload,
            } => {
                w.u32(*shard);
                w.u32(groups.len() as u32);
                for &(a, b) in groups {
                    w.u32(a);
                    w.u32(b);
                }
                match span {
                    None => w.u8(0),
                    Some(s) => {
                        w.u8(1);
                        w.span(s);
                    }
                }
                match workload {
                    None => w.u8(0),
                    Some(bytes) => {
                        w.u8(1);
                        w.u32(bytes.len() as u32);
                        w.buf.extend_from_slice(bytes);
                    }
                }
            }
            Frame::SpikeFrame { clip, seq, plane } => {
                w.u64(*clip);
                w.u32(*seq);
                w.plane(plane);
            }
            Frame::Telemetry { clip, steps, vmems } => {
                w.u64(*clip);
                w.u32(steps.len() as u32);
                for t in steps {
                    w.telemetry(t);
                }
                w.u32(vmems.len() as u32);
                for m in vmems {
                    w.mat(m);
                }
            }
            Frame::Drain { clip } => w.u64(*clip),
            Frame::Error { message } => w.str(message),
            Frame::LaneBatchOpen { batch, clips } => {
                w.u64(*batch);
                w.u8(clips.len() as u8);
                for &c in clips {
                    w.u64(c);
                }
            }
            Frame::LaneFrame { batch, seq, frame } => {
                w.u64(*batch);
                w.u32(*seq);
                w.lane_plane(frame);
            }
            Frame::LaneTelemetry { batch, lanes } => {
                w.u64(*batch);
                w.u8(lanes.len() as u8);
                for lane in lanes {
                    w.u32(lane.steps.len() as u32);
                    for t in &lane.steps {
                        w.telemetry(t);
                    }
                    w.u32(lane.vmems.len() as u32);
                    for m in &lane.vmems {
                        w.mat(m);
                    }
                }
            }
            Frame::TraceSync { t0_us, peer_us } => {
                w.u64(*t0_us);
                w.u64(*peer_us);
            }
            Frame::TraceCtx { trace, clip } => {
                w.u64(*trace);
                w.u64(*clip);
            }
            Frame::TraceFlush => {}
            Frame::TraceSpans { spans } => {
                w.u32(spans.len() as u32);
                for s in spans {
                    w.u64(s.trace);
                    w.str(&s.name);
                    w.u64(s.start_us);
                    w.u64(s.dur_us);
                    w.u8(s.instant as u8);
                    w.u64(s.tid);
                }
            }
        }
        w.buf
    }

    fn decode_payload(kind: u8, version: u16, payload: &[u8]) -> Result<Frame> {
        if (7..=13).contains(&kind) && version < LANE_VERSION {
            return Err(Error::protocol(format!(
                "version skew: v{LANE_VERSION} frame kind {kind} under a v{version} header"
            )));
        }
        let mut r = Rd::new(payload);
        let frame = match kind {
            1 => Frame::Hello {
                role: match r.u8()? {
                    0 => Role::Coordinator,
                    1 => Role::Shard,
                    other => {
                        return Err(Error::protocol(format!("unknown role {other}")));
                    }
                },
                name: r.str()?,
            },
            2 => {
                let shard = r.u32()?;
                let n = r.len_prefix(8)?;
                let mut groups = Vec::with_capacity(n);
                for _ in 0..n {
                    groups.push((r.u32()?, r.u32()?));
                }
                let span = match r.u8()? {
                    0 => None,
                    1 => Some(r.span()?),
                    other => {
                        return Err(Error::protocol(format!("bad span flag {other}")));
                    }
                };
                let workload = match r.u8()? {
                    0 => None,
                    1 => {
                        let n = r.len_prefix(1)?;
                        Some(r.take(n)?.to_vec())
                    }
                    other => {
                        return Err(Error::protocol(format!("bad workload flag {other}")));
                    }
                };
                Frame::LoadGroup {
                    shard,
                    groups,
                    span,
                    workload,
                }
            }
            3 => Frame::SpikeFrame {
                clip: r.u64()?,
                seq: r.u32()?,
                plane: r.plane()?,
            },
            4 => {
                let clip = r.u64()?;
                let n = r.len_prefix(8)?;
                let mut steps = Vec::with_capacity(n);
                for _ in 0..n {
                    steps.push(r.telemetry()?);
                }
                let nm = r.len_prefix(8)?;
                let mut vmems = Vec::with_capacity(nm);
                for _ in 0..nm {
                    vmems.push(r.mat()?);
                }
                Frame::Telemetry { clip, steps, vmems }
            }
            5 => Frame::Drain { clip: r.u64()? },
            6 => Frame::Error { message: r.str()? },
            7 => {
                let batch = r.u64()?;
                let lanes = r.lane_count()?;
                let mut clips = Vec::with_capacity(lanes);
                for _ in 0..lanes {
                    clips.push(r.u64()?);
                }
                Frame::LaneBatchOpen { batch, clips }
            }
            8 => Frame::LaneFrame {
                batch: r.u64()?,
                seq: r.u32()?,
                frame: r.lane_plane()?,
            },
            9 => {
                let batch = r.u64()?;
                let n = r.lane_count()?;
                let mut lanes = Vec::with_capacity(n);
                for _ in 0..n {
                    let ns = r.len_prefix(8)?;
                    let mut steps = Vec::with_capacity(ns);
                    for _ in 0..ns {
                        steps.push(r.telemetry()?);
                    }
                    let nm = r.len_prefix(8)?;
                    let mut vmems = Vec::with_capacity(nm);
                    for _ in 0..nm {
                        vmems.push(r.mat()?);
                    }
                    lanes.push(LaneReport { steps, vmems });
                }
                Frame::LaneTelemetry { batch, lanes }
            }
            10 => Frame::TraceSync {
                t0_us: r.u64()?,
                peer_us: r.u64()?,
            },
            11 => Frame::TraceCtx {
                trace: r.u64()?,
                clip: r.u64()?,
            },
            12 => Frame::TraceFlush,
            13 => {
                // u64 trace + (u32 len + name ≥ 0) + u64 start + u64
                // dur + u8 instant + u64 tid — 37 bytes minimum each.
                let n = r.len_prefix(37)?;
                let mut spans = Vec::with_capacity(n);
                for _ in 0..n {
                    spans.push(WireSpan {
                        trace: r.u64()?,
                        name: r.str()?,
                        start_us: r.u64()?,
                        dur_us: r.u64()?,
                        instant: match r.u8()? {
                            0 => false,
                            1 => true,
                            other => {
                                return Err(Error::protocol(format!("bad instant flag {other}")));
                            }
                        },
                        tid: r.u64()?,
                    });
                }
                Frame::TraceSpans { spans }
            }
            other => {
                return Err(Error::protocol(format!("unknown frame kind {other}")));
            }
        };
        r.finish()?;
        Ok(frame)
    }

    /// Encode the frame into one contiguous wire buffer (header +
    /// payload + checksum), stamped at the kind's own
    /// [`Frame::wire_version`].
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_bytes_versioned(self.wire_version())
    }

    /// Encode the frame stamped with an explicit header `version` —
    /// the negotiation escape hatch (a host's `Hello` is stamped at
    /// the highest version it speaks, not the kind's minimum).
    pub fn to_bytes_versioned(&self, version: u16) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut buf = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&version.to_le_bytes());
        buf.push(self.kind());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&payload);
        buf.extend_from_slice(&checksum(&payload).to_le_bytes());
        buf
    }

    /// Decode one frame from the front of `buf`; returns the frame and
    /// the bytes consumed. Every malformation — truncation (of header,
    /// payload or checksum), bad magic, version skew, an oversized
    /// length prefix, a checksum mismatch, an unknown kind, or a
    /// malformed payload — is an [`Error::Protocol`]; decoding never
    /// panics.
    pub fn from_bytes(buf: &[u8]) -> Result<(Frame, usize)> {
        let (frame, _, used) = Frame::from_bytes_versioned(buf)?;
        Ok((frame, used))
    }

    /// [`Frame::from_bytes`] that also surfaces the header version the
    /// frame arrived under (within [`MIN_VERSION`]`..=`[`VERSION`]) —
    /// how a receiver learns which dialect its peer speaks.
    pub fn from_bytes_versioned(buf: &[u8]) -> Result<(Frame, u16, usize)> {
        if buf.len() < HEADER_LEN {
            return Err(Error::protocol(format!(
                "truncated frame header: {} of {HEADER_LEN} bytes",
                buf.len()
            )));
        }
        let (version, len) = parse_header(&fixed(&buf[..HEADER_LEN])?)?;
        let total = HEADER_LEN + len + 4;
        if buf.len() < total {
            return Err(Error::protocol(format!(
                "truncated frame: {} of {total} bytes",
                buf.len()
            )));
        }
        let payload = &buf[HEADER_LEN..HEADER_LEN + len];
        let want = u32::from_le_bytes(fixed(&buf[HEADER_LEN + len..total])?);
        if checksum(payload) != want {
            return Err(Error::protocol("frame checksum mismatch"));
        }
        let frame = Frame::decode_payload(buf[6], version, payload)?;
        Ok((frame, version, total))
    }

    /// Read one frame from a byte stream. Returns `Ok(None)` on a
    /// clean end-of-stream (the peer closed between frames); EOF
    /// *inside* a frame is a protocol error.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Option<Frame>> {
        Ok(Frame::read_versioned_from(r)?.map(|(f, _)| f))
    }

    /// [`Frame::read_from`] that also surfaces the header version the
    /// frame arrived under.
    pub fn read_versioned_from<R: Read>(r: &mut R) -> Result<Option<(Frame, u16)>> {
        let mut header = [0u8; HEADER_LEN];
        // Peek the first byte separately to distinguish a clean close
        // from a mid-frame truncation.
        loop {
            match r.read(&mut header[..1]) {
                Ok(0) => return Ok(None),
                Ok(_) => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(Error::Io(e)),
            }
        }
        read_exact(r, &mut header[1..])?;
        let (version, len) = parse_header(&header)?;
        let mut rest = vec![0u8; len + 4];
        read_exact(r, &mut rest)?;
        let payload = &rest[..len];
        let want = u32::from_le_bytes(fixed(&rest[len..])?);
        if checksum(payload) != want {
            return Err(Error::protocol("frame checksum mismatch"));
        }
        Ok(Some((
            Frame::decode_payload(header[6], version, payload)?,
            version,
        )))
    }

    /// Write the frame to a byte stream (one contiguous write, then
    /// flush), stamped at the kind's own [`Frame::wire_version`].
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        self.write_to_versioned(w, self.wire_version())
    }

    /// [`Frame::write_to`] with an explicit header version stamp.
    pub fn write_to_versioned<W: Write>(&self, w: &mut W, version: u16) -> Result<()> {
        w.write_all(&self.to_bytes_versioned(version))?;
        w.flush()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Workload codec — the LoadGroup weight-push payload
// ---------------------------------------------------------------------------

/// Hard cap on the layer count of a pushed workload (the `.swb`
/// loader's plausibility bound, applied to the wire too).
const MAX_WORKLOAD_LAYERS: usize = 1024;

/// Sane cap on kernel/stride/pad geometry of a pushed layer —
/// generous for any Table-II shape, tight enough that a crafted
/// geometry cannot blow up downstream output-shape arithmetic.
const MAX_GEOMETRY: u64 = 512;

/// Serialize a whole workload — layer topology, quantized weight
/// matrices, neuron configuration, precision, timesteps — into the
/// byte payload a [`Frame::LoadGroup`] pushes to a blank shard.
/// Deterministic and bit-exact: [`decode_network`] rebuilds a network
/// whose executors (the shard's `Network::step_group` included)
/// produce bit-identical Vmems and telemetry to the original.
pub fn encode_network(net: &Network) -> Vec<u8> {
    let mut w = Wr::new();
    w.str(&net.name);
    w.u8(net.precision.weight_bits() as u8);
    w.u32(net.timesteps as u32);
    let (c, h, ww) = net
        .layers
        .first()
        .map(|l| l.in_shape)
        .unwrap_or((0, 0, 0));
    w.u32(c as u32);
    w.u32(h as u32);
    w.u32(ww as u32);
    w.u32(net.layers.len() as u32);
    for l in &net.layers {
        match l.kind {
            LayerKind::Conv => {
                w.u8(0);
                w.u32(l.out_shape.0 as u32);
                w.u32(l.kh as u32);
                w.u32(l.kw as u32);
                w.u32(l.stride as u32);
                w.u32(l.pad as u32);
                encode_layer_params(&mut w, l);
            }
            LayerKind::Fc => {
                w.u8(1);
                w.u32(l.out_shape.0 as u32);
                encode_layer_params(&mut w, l);
            }
            LayerKind::Pool => {
                w.u8(2);
                w.u32(l.kh as u32);
                w.u32(l.stride as u32);
            }
        }
    }
    w.buf
}

/// Shared tail of a stateful layer's encoding: neuron config,
/// accumulate flag, quantization scale, weights.
fn encode_layer_params(w: &mut Wr, l: &Layer) {
    w.i32(l.neuron.theta);
    w.i32(l.neuron.leak);
    w.u8(u8::from(l.neuron.leaky));
    w.u8(match l.neuron.reset {
        ResetMode::Hard => 0,
        ResetMode::Soft => 1,
    });
    w.u8(u8::from(l.accumulate));
    w.f64(l.weight_scale);
    // stateful layers always carry weights; a zero matrix is the
    // (unreachable) total fallback
    match &l.weights {
        Some(m) => w.mat(m),
        None => w.mat(&Mat::zeros(0, 0)),
    }
}

/// Decode the tail of a stateful layer (see [`encode_layer_params`]).
fn decode_layer_params(r: &mut Rd) -> Result<(NeuronConfig, bool, f64, Mat)> {
    let theta = r.i32()?;
    let leak = r.i32()?;
    let leaky = match r.u8()? {
        0 => false,
        1 => true,
        other => return Err(Error::protocol(format!("bad leaky flag {other}"))),
    };
    let reset = match r.u8()? {
        0 => ResetMode::Hard,
        1 => ResetMode::Soft,
        other => return Err(Error::protocol(format!("bad reset mode {other}"))),
    };
    let accumulate = match r.u8()? {
        0 => false,
        1 => true,
        other => return Err(Error::protocol(format!("bad accumulate flag {other}"))),
    };
    let scale = r.f64()?;
    let weights = r.mat()?;
    Ok((
        NeuronConfig {
            theta,
            leak,
            leaky,
            reset,
        },
        accumulate,
        scale,
        weights,
    ))
}

/// Rebuild a workload pushed by [`encode_network`]. Decoding is total,
/// like the frame codec: truncation, malformed flags, implausible
/// geometry (kernel/stride/pad beyond [`MAX_GEOMETRY`], output planes
/// beyond [`MAX_PAYLOAD`] cells), weight matrices that don't match
/// the flowing shape, and trailing bytes all return
/// [`Error::Protocol`] — never a panic, never an unbounded allocation
/// (weight data is validated against the remaining payload before any
/// buffer is sized from it).
pub fn decode_network(bytes: &[u8]) -> Result<Network> {
    let mut r = Rd::new(bytes);
    let name = r.str()?;
    let precision = Precision::from_weight_bits(r.u8()? as u32)
        .map_err(|e| Error::protocol(format!("bad workload precision: {e}")))?;
    let timesteps = r.u32()? as usize;
    let (c, h, w) = (r.u32()? as u64, r.u32()? as u64, r.u32()? as u64);
    c.checked_mul(h)
        .and_then(|v| v.checked_mul(w))
        .filter(|&v| v >= 1 && v <= MAX_PAYLOAD as u64)
        .ok_or_else(|| Error::protocol("implausible workload input shape"))?;
    let n = r.u32()? as usize;
    if n == 0 || n > MAX_WORKLOAD_LAYERS {
        return Err(Error::protocol(format!(
            "implausible workload layer count {n}"
        )));
    }
    let mut shape = (c as usize, h as usize, w as usize);
    let mut layers = Vec::with_capacity(n.min(64));
    for i in 0..n {
        let bad = |m: String| Error::protocol(format!("workload layer {i}: {m}"));
        let layer = match r.u8()? {
            0 => {
                let out_ch = r.u32()? as u64;
                let kh = r.u32()? as u64;
                let kw = r.u32()? as u64;
                let stride = r.u32()? as u64;
                let pad = r.u32()? as u64;
                if !(1..=MAX_GEOMETRY).contains(&kh)
                    || !(1..=MAX_GEOMETRY).contains(&kw)
                    || !(1..=MAX_GEOMETRY).contains(&stride)
                    || pad > MAX_GEOMETRY
                {
                    return Err(bad(format!(
                        "implausible conv geometry {kh}x{kw}/s{stride}/p{pad}"
                    )));
                }
                let (_, ih, iw) = shape;
                let span_h = (ih as u64) + 2 * pad;
                let span_w = (iw as u64) + 2 * pad;
                if span_h < kh || span_w < kw {
                    return Err(bad(format!(
                        "kernel {kh}x{kw} exceeds padded input {span_h}x{span_w}"
                    )));
                }
                let ho = (span_h - kh) / stride + 1;
                let wo = (span_w - kw) / stride + 1;
                out_ch
                    .checked_mul(ho)
                    .and_then(|v| v.checked_mul(wo))
                    .filter(|&v| v >= 1 && v <= MAX_PAYLOAD as u64)
                    .ok_or_else(|| bad("implausible conv output plane".into()))?;
                let (neuron, accumulate, scale, weights) = decode_layer_params(&mut r)?;
                Layer::conv(
                    shape,
                    out_ch as usize,
                    kh as usize,
                    kw as usize,
                    stride as usize,
                    pad as usize,
                    weights,
                    neuron,
                    accumulate,
                )
                .map_err(|e| bad(e.to_string()))?
                .with_scale(scale)
            }
            1 => {
                let out = r.u32()? as usize;
                if out == 0 || out as u64 > MAX_PAYLOAD as u64 {
                    return Err(bad(format!("implausible fc width {out}")));
                }
                let (neuron, accumulate, scale, weights) = decode_layer_params(&mut r)?;
                Layer::fc(shape, out, weights, neuron, accumulate)
                    .map_err(|e| bad(e.to_string()))?
                    .with_scale(scale)
            }
            2 => {
                let size = r.u32()? as u64;
                let stride = r.u32()? as u64;
                if !(1..=MAX_GEOMETRY).contains(&size)
                    || !(1..=MAX_GEOMETRY).contains(&stride)
                {
                    return Err(bad(format!("implausible pool geometry {size}/{stride}")));
                }
                Layer::pool(shape, size as usize, stride as usize)
            }
            other => return Err(bad(format!("unknown layer kind {other}"))),
        };
        shape = layer.out_shape;
        layers.push(layer);
    }
    r.finish()?;
    if !layers.last().is_some_and(|l| l.accumulate) {
        return Err(Error::protocol(
            "workload must end in an accumulate output layer",
        ));
    }
    Ok(Network {
        name,
        layers,
        precision,
        timesteps,
    })
}

/// Validate a frame header and return the header version and payload
/// length. Versions outside [`MIN_VERSION`]`..=`[`VERSION`] are
/// rejected here, before any payload is read.
fn parse_header(header: &[u8; HEADER_LEN]) -> Result<(u16, usize)> {
    if header[..4] != MAGIC {
        return Err(Error::protocol(format!(
            "bad frame magic {:02x?}",
            &header[..4]
        )));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(Error::protocol(format!(
            "unsupported protocol version {version} (host speaks {MIN_VERSION}..={VERSION})"
        )));
    }
    let len = u32::from_le_bytes([header[7], header[8], header[9], header[10]]);
    if len > MAX_PAYLOAD {
        return Err(Error::protocol(format!(
            "oversized frame: {len}-byte payload exceeds the {MAX_PAYLOAD}-byte cap"
        )));
    }
    Ok((version, len as usize))
}

/// `Read::read_exact` with mid-frame EOF mapped to a protocol error.
fn read_exact<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Error::protocol("connection closed mid-frame")
        } else {
            Error::Io(e)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, Gen};

    fn sample_frames() -> Vec<Frame> {
        let mut plane = SpikePlane::zeros(2, 3, 5);
        plane.set(0, 0, 0, 1);
        plane.set(1, 2, 4, 1);
        plane.set(0, 1, 3, 1);
        let mut vmem = Mat::zeros(2, 3);
        vmem.set(0, 1, -7);
        vmem.set(1, 2, 123);
        vec![
            Frame::Hello {
                role: Role::Coordinator,
                name: "flow".into(),
            },
            Frame::Hello {
                role: Role::Shard,
                name: String::new(),
            },
            Frame::LoadGroup {
                shard: 1,
                groups: vec![(0, 2), (2, 5)],
                span: None,
                workload: None,
            },
            Frame::LoadGroup {
                shard: 0,
                groups: vec![(0, 1)],
                span: Some(GroupSpan {
                    layers: (0, 3),
                    stateful: (0, 2),
                }),
                workload: None,
            },
            Frame::LoadGroup {
                shard: 2,
                groups: vec![(0, 3)],
                span: None,
                workload: Some(vec![0xde, 0xad, 0xbe, 0xef, 0x00]),
            },
            Frame::SpikeFrame {
                clip: 7,
                seq: 3,
                plane,
            },
            Frame::Telemetry {
                clip: 7,
                steps: vec![
                    StepTelemetry {
                        layer_input_spikes: vec![4, 0, 9],
                        layer_input_cells: vec![64, 64, 16],
                    },
                    StepTelemetry::default(),
                ],
                vmems: vec![vmem, Mat::zeros(1, 4)],
            },
            Frame::Drain { clip: 7 },
            Frame::Error {
                message: "boom".into(),
            },
            Frame::LaneBatchOpen {
                batch: 64,
                clips: (64..64 + 5).collect(),
            },
            Frame::LaneFrame {
                batch: 64,
                seq: 2,
                frame: sample_lane_frame(5),
            },
            Frame::LaneTelemetry {
                batch: 64,
                lanes: vec![
                    LaneReport {
                        steps: vec![StepTelemetry {
                            layer_input_spikes: vec![3, 1],
                            layer_input_cells: vec![48, 48],
                        }],
                        vmems: vec![Mat::zeros(2, 2)],
                    },
                    LaneReport::default(),
                ],
            },
            Frame::TraceSync {
                t0_us: 1_234_567,
                peer_us: 0,
            },
            Frame::TraceCtx { trace: 9, clip: 64 },
            Frame::TraceFlush,
            Frame::TraceSpans {
                spans: vec![
                    WireSpan {
                        trace: 9,
                        name: "shard_step".into(),
                        start_us: 100,
                        dur_us: 40,
                        instant: false,
                        tid: 3,
                    },
                    WireSpan {
                        trace: 9,
                        name: String::new(),
                        start_us: 150,
                        dur_us: 0,
                        instant: true,
                        tid: 3,
                    },
                ],
            },
            Frame::TraceSpans { spans: Vec::new() },
        ]
    }

    fn sample_lane_frame(lanes: usize) -> LaneFrame {
        let planes: Vec<SpikePlane> = (0..lanes)
            .map(|b| {
                let mut p = SpikePlane::zeros(2, 3, 4);
                p.set(0, b % 3, b % 4, 1);
                p.set(1, (b + 1) % 3, (2 * b) % 4, 1);
                p
            })
            .collect();
        let refs: Vec<&SpikePlane> = planes.iter().collect();
        LaneFrame::pack(&refs).unwrap()
    }

    #[test]
    fn every_variant_roundtrips() {
        for frame in sample_frames() {
            let bytes = frame.to_bytes();
            let (back, used) = Frame::from_bytes(&bytes).unwrap();
            assert_eq!(back, frame);
            assert_eq!(used, bytes.len());
        }
    }

    #[test]
    fn concatenated_frames_decode_in_sequence() {
        let frames = sample_frames();
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&f.to_bytes());
        }
        let mut at = 0;
        for f in &frames {
            let (back, used) = Frame::from_bytes(&stream[at..]).unwrap();
            assert_eq!(&back, f);
            at += used;
        }
        assert_eq!(at, stream.len());
    }

    #[test]
    fn stream_reader_matches_buffer_decoder() {
        let frames = sample_frames();
        let mut stream = Vec::new();
        for f in &frames {
            f.write_to(&mut stream).unwrap();
        }
        let mut r = &stream[..];
        for f in &frames {
            assert_eq!(Frame::read_from(&mut r).unwrap().as_ref(), Some(f));
        }
        // clean end-of-stream
        assert!(Frame::read_from(&mut r).unwrap().is_none());
    }

    fn rand_plane(g: &mut Gen) -> SpikePlane {
        let (c, h, w) = (1 + g.index(3), 1 + g.index(6), 1 + g.index(6));
        let mut p = SpikePlane::zeros(c, h, w);
        for i in 0..p.len() {
            if g.chance(0.3) {
                p.as_mut_slice()[i] = 1;
            }
        }
        p
    }

    fn rand_telemetry(g: &mut Gen) -> StepTelemetry {
        StepTelemetry {
            layer_input_spikes: g.vec_of(0, 4, |g| g.u64()),
            layer_input_cells: g.vec_of(0, 4, |g| g.u64()),
        }
    }

    fn rand_mat(g: &mut Gen) -> Mat {
        let (rows, cols) = (1 + g.index(5), 1 + g.index(5));
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, g.i32_in(i32::MIN..=i32::MAX));
            }
        }
        m
    }

    /// Random lane frame: one shape shared by 1..=64 lanes, each lane
    /// an independent sparse plane.
    fn rand_lane_frame(g: &mut Gen) -> LaneFrame {
        let lanes = 1 + g.index(MAX_LANES);
        let (c, h, w) = (1 + g.index(3), 1 + g.index(5), 1 + g.index(5));
        let planes: Vec<SpikePlane> = (0..lanes)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if g.chance(0.3) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect();
        let refs: Vec<&SpikePlane> = planes.iter().collect();
        LaneFrame::pack(&refs).unwrap()
    }

    fn rand_lane_report(g: &mut Gen) -> LaneReport {
        LaneReport {
            steps: g.vec_of(0, 3, rand_telemetry),
            vmems: g.vec_of(0, 3, rand_mat),
        }
    }

    fn rand_wire_span(g: &mut Gen) -> WireSpan {
        WireSpan {
            trace: g.u64(),
            name: "s".repeat(g.index(12)),
            start_us: g.u64(),
            dur_us: g.u64(),
            instant: g.chance(0.3),
            tid: g.u64(),
        }
    }

    /// Satellite: random planes, lane frames, spans, telemetry and
    /// Vmem banks survive the codec bit-exactly (ISSUE 7 extended the
    /// sweep over the v3 lane variants, ISSUE 9 over the trace
    /// sideband).
    #[test]
    fn prop_frame_roundtrip() {
        check("frame_roundtrip", 60, |g| {
            let frame = match g.index(12) {
                0 => Frame::Hello {
                    role: *g.choose(&[Role::Coordinator, Role::Shard]),
                    name: "shard-α ".repeat(g.index(4)),
                },
                1 => Frame::LoadGroup {
                    shard: g.u64_in(0..=u32::MAX as u64) as u32,
                    groups: g.vec_of(0, 5, |g| {
                        (g.u64_in(0..=99) as u32, g.u64_in(0..=99) as u32)
                    }),
                    span: g.chance(0.5).then(|| GroupSpan {
                        layers: (g.index(9), g.index(9)),
                        stateful: (g.index(9), g.index(9)),
                    }),
                    workload: g
                        .chance(0.5)
                        .then(|| g.vec_of(0, 64, |g| g.u64_in(0..=255) as u8)),
                },
                2 => Frame::SpikeFrame {
                    clip: g.u64(),
                    seq: g.u64_in(0..=u32::MAX as u64) as u32,
                    plane: rand_plane(g),
                },
                3 => Frame::Telemetry {
                    clip: g.u64(),
                    steps: g.vec_of(0, 3, rand_telemetry),
                    vmems: g.vec_of(0, 3, rand_mat),
                },
                4 => Frame::Drain { clip: g.u64() },
                5 => Frame::Error {
                    message: "e".repeat(g.index(40)),
                },
                6 => {
                    let lanes = 1 + g.index(MAX_LANES);
                    Frame::LaneBatchOpen {
                        batch: g.u64(),
                        clips: (0..lanes).map(|_| g.u64()).collect(),
                    }
                }
                7 => Frame::LaneFrame {
                    batch: g.u64(),
                    seq: g.u64_in(0..=u32::MAX as u64) as u32,
                    frame: rand_lane_frame(g),
                },
                8 => Frame::LaneTelemetry {
                    batch: g.u64(),
                    lanes: g.vec_of(1, 4, rand_lane_report),
                },
                9 => Frame::TraceSync {
                    t0_us: g.u64(),
                    peer_us: g.u64(),
                },
                10 => Frame::TraceCtx {
                    trace: g.u64(),
                    clip: g.u64(),
                },
                _ => Frame::TraceSpans {
                    spans: g.vec_of(0, 5, rand_wire_span),
                },
            };
            let bytes = frame.to_bytes();
            // the stamp is the kind's own dialect: v3 only for lane
            // kinds, so v2 peers keep parsing scalar traffic
            let stamped = u16::from_le_bytes([bytes[4], bytes[5]]);
            if stamped != frame.wire_version() {
                return false;
            }
            matches!(Frame::from_bytes(&bytes), Ok((back, used))
                if back == frame && used == bytes.len())
        });
    }

    /// Satellite: adversarial decodes — every truncation point, bad
    /// magic, version skew, oversized length, flipped payload bits and
    /// unknown kinds must all come back as `Error` values, never
    /// panics.
    #[test]
    fn adversarial_decodes_error_cleanly() {
        let frame = Frame::SpikeFrame {
            clip: 3,
            seq: 1,
            plane: SpikePlane::zeros(2, 4, 4),
        };
        let good = frame.to_bytes();

        // truncation at every possible length
        for n in 0..good.len() {
            assert!(Frame::from_bytes(&good[..n]).is_err(), "prefix {n}");
        }

        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("magic")));

        // version skew
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("version")));

        // oversized length prefix must be rejected before allocation
        let mut bad = good.clone();
        bad[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("oversized")));

        // corrupt payload: the checksum catches it
        let mut bad = good.clone();
        bad[HEADER_LEN] ^= 0xff;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("checksum")));

        // corrupt checksum itself
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("checksum")));

        // unknown kind with a valid checksum
        let mut bad = good.clone();
        bad[6] = 42;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("kind")));

        // trailing garbage inside a correctly-checksummed payload
        let mut w = Frame::Drain { clip: 1 }.encode_payload();
        w.push(0xEE);
        let mut evil = Vec::new();
        evil.extend_from_slice(&MAGIC);
        evil.extend_from_slice(&VERSION.to_le_bytes());
        evil.push(5);
        evil.extend_from_slice(&(w.len() as u32).to_le_bytes());
        evil.extend_from_slice(&w);
        evil.extend_from_slice(&checksum(&w).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&evil), Err(Error::Protocol(m))
            if m.contains("trailing")));

        // matrix dims whose element data cannot be present are
        // rejected before any allocation is sized from the count
        let mut w = Wr::new();
        w.u64(9); // clip
        w.u32(0); // no steps
        w.u32(1); // one matrix…
        w.u32(4096);
        w.u32(4096); // …claiming 16M cells with no bytes behind them
        let mut evil = Vec::new();
        evil.extend_from_slice(&MAGIC);
        evil.extend_from_slice(&VERSION.to_le_bytes());
        evil.push(4);
        evil.extend_from_slice(&(w.buf.len() as u32).to_le_bytes());
        evil.extend_from_slice(&w.buf);
        evil.extend_from_slice(&checksum(&w.buf).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&evil), Err(Error::Protocol(m))
            if m.contains("oversized matrix")));

        // absurd inner length prefix (vec count) caps before allocating
        let mut w = Wr::new();
        w.u64(9); // clip
        w.u32(u32::MAX); // steps count: would be 32 GiB of telemetry
        let mut evil = Vec::new();
        evil.extend_from_slice(&MAGIC);
        evil.extend_from_slice(&VERSION.to_le_bytes());
        evil.push(4);
        evil.extend_from_slice(&(w.buf.len() as u32).to_le_bytes());
        evil.extend_from_slice(&w.buf);
        evil.extend_from_slice(&checksum(&w.buf).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&evil), Err(Error::Protocol(m))
            if m.contains("length prefix")));

        // the pristine frame still decodes (the cases above were real)
        assert!(Frame::from_bytes(&good).is_ok());
    }

    /// Satellite: adversarial decodes of the weight-push `LoadGroup` —
    /// truncation at every prefix, checksum flips, a bad workload flag
    /// and an oversized inner workload length must all come back as
    /// `Error::Protocol`, never a panic or an unbounded allocation.
    #[test]
    fn adversarial_load_group_decodes_error_cleanly() {
        let frame = Frame::LoadGroup {
            shard: 1,
            groups: vec![(0, 2), (2, 4)],
            span: None,
            workload: Some(vec![7u8; 96]),
        };
        let good = frame.to_bytes();

        // truncation at every possible length
        for n in 0..good.len() {
            assert!(Frame::from_bytes(&good[..n]).is_err(), "prefix {n}");
        }

        // flipped payload bits: the checksum catches every position
        for i in HEADER_LEN..good.len() - 4 {
            let mut bad = good.clone();
            bad[i] ^= 0x10;
            assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
                if m.contains("checksum")));
        }

        // bad workload flag, behind a valid checksum
        let mut w = Wr::new();
        w.u32(0); // shard
        w.u32(0); // no groups
        w.u8(0); // no span
        w.u8(9); // bad workload flag
        let reframe = |payload: &[u8]| {
            let mut evil = Vec::new();
            evil.extend_from_slice(&MAGIC);
            evil.extend_from_slice(&VERSION.to_le_bytes());
            evil.push(2); // LoadGroup
            evil.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            evil.extend_from_slice(payload);
            evil.extend_from_slice(&checksum(payload).to_le_bytes());
            evil
        };
        assert!(matches!(
            Frame::from_bytes(&reframe(&w.buf)),
            Err(Error::Protocol(m)) if m.contains("workload flag")
        ));

        // inner workload length prefix far beyond the actual payload:
        // rejected before any buffer is sized from it
        let mut w = Wr::new();
        w.u32(0);
        w.u32(0);
        w.u8(0);
        w.u8(1); // workload present…
        w.u32(u32::MAX); // …claiming 4 GiB of bytes that are not there
        assert!(matches!(
            Frame::from_bytes(&reframe(&w.buf)),
            Err(Error::Protocol(m)) if m.contains("length prefix")
        ));

        // the pristine frame still decodes
        let (back, _) = Frame::from_bytes(&good).unwrap();
        assert_eq!(back, frame);
    }

    /// Satellite (ISSUE 7): adversarial decodes of the v3 lane
    /// messages — truncation at every prefix, lane counts 0 and >64,
    /// inner-length overflow before allocation, corrupted checksums,
    /// trailing bytes and v2↔v3 version skew must all come back as
    /// `Error::Protocol`, never a panic.
    #[test]
    fn adversarial_lane_decodes_error_cleanly() {
        let frame = Frame::LaneFrame {
            batch: 9,
            seq: 2,
            frame: sample_lane_frame(11),
        };
        let good = frame.to_bytes();
        // lane kinds are stamped v3 by construction
        assert_eq!(u16::from_le_bytes([good[4], good[5]]), LANE_VERSION);

        // truncation at every possible length
        for n in 0..good.len() {
            assert!(Frame::from_bytes(&good[..n]).is_err(), "prefix {n}");
        }

        // v2↔v3 skew: the identical payload under a v2 header is a
        // typed version-skew rejection (the checksum only covers the
        // payload, so nothing else is wrong with the frame)
        let mut bad = good.clone();
        bad[4..6].copy_from_slice(&2u16.to_le_bytes());
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("version skew")));

        // a future version is rejected at the header, before payload
        let mut bad = good.clone();
        bad[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("unsupported protocol version")));

        // corrupted checksum
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
            if m.contains("checksum")));

        // flipped payload bits: the checksum catches every position
        for i in HEADER_LEN..good.len() - 4 {
            let mut bad = good.clone();
            bad[i] ^= 0x10;
            assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
                if m.contains("checksum")));
        }

        let reframe = |kind: u8, payload: &[u8]| {
            let mut evil = Vec::new();
            evil.extend_from_slice(&MAGIC);
            evil.extend_from_slice(&LANE_VERSION.to_le_bytes());
            evil.push(kind);
            evil.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            evil.extend_from_slice(payload);
            evil.extend_from_slice(&checksum(payload).to_le_bytes());
            evil
        };

        // lane count 0 — in an open and in a lane frame
        for kind in [7u8, 8u8] {
            let mut w = Wr::new();
            w.u64(9); // batch
            if kind == 8 {
                w.u32(0); // seq
            }
            w.u8(0); // zero lanes
            assert!(matches!(
                Frame::from_bytes(&reframe(kind, &w.buf)),
                Err(Error::Protocol(m)) if m.contains("lane count")
            ));
        }

        // lane count 65 (> MAX_LANES), again for both kinds
        for kind in [7u8, 8u8] {
            let mut w = Wr::new();
            w.u64(9);
            if kind == 8 {
                w.u32(0);
            }
            w.u8(65);
            assert!(matches!(
                Frame::from_bytes(&reframe(kind, &w.buf)),
                Err(Error::Protocol(m)) if m.contains("lane count")
            ));
        }

        // inner-length overflow before allocation: a lane plane whose
        // claimed shape would dwarf the payload is rejected before any
        // buffer is sized from it
        let mut w = Wr::new();
        w.u64(9); // batch
        w.u32(0); // seq
        w.u8(64); // max lanes…
        w.u32(u32::MAX);
        w.u32(u32::MAX);
        w.u32(u32::MAX); // …on an absurd shape with no bytes behind it
        assert!(matches!(
            Frame::from_bytes(&reframe(8, &w.buf)),
            Err(Error::Protocol(m)) if m.contains("oversized lane plane")
        ));

        // a plausible shape whose packed bits are simply missing
        let mut w = Wr::new();
        w.u64(9);
        w.u32(0);
        w.u8(64);
        w.u32(2);
        w.u32(16);
        w.u32(16); // 512 cells x 64 lanes = 4 KiB of bits, absent
        assert!(matches!(
            Frame::from_bytes(&reframe(8, &w.buf)),
            Err(Error::Protocol(m)) if m.contains("truncated payload")
        ));

        // lane telemetry claiming absurd step counts caps before
        // allocating
        let mut w = Wr::new();
        w.u64(9); // batch
        w.u8(1); // one lane
        w.u32(u32::MAX); // steps count: 32 GiB of telemetry
        assert!(matches!(
            Frame::from_bytes(&reframe(9, &w.buf)),
            Err(Error::Protocol(m)) if m.contains("length prefix")
        ));

        // trailing bytes after a correctly-checksummed lane payload
        let mut w = Frame::LaneBatchOpen {
            batch: 9,
            clips: vec![9, 10],
        }
        .encode_payload();
        w.push(0xEE);
        assert!(matches!(
            Frame::from_bytes(&reframe(7, &w)),
            Err(Error::Protocol(m)) if m.contains("trailing")
        ));

        // the pristine frame still decodes (the cases above were real)
        let (back, ver, _) = Frame::from_bytes_versioned(&good).unwrap();
        assert_eq!(back, frame);
        assert_eq!(ver, LANE_VERSION);
    }

    /// Satellite (ISSUE 9): adversarial decodes of the trace sideband
    /// — truncation at every prefix, v2↔v3 skew, a bad instant flag,
    /// span counts far beyond the payload and trailing bytes must all
    /// come back as `Error::Protocol`, never a panic.
    #[test]
    fn adversarial_trace_decodes_error_cleanly() {
        let frame = Frame::TraceSpans {
            spans: vec![WireSpan {
                trace: 7,
                name: "shard_step".into(),
                start_us: 10,
                dur_us: 5,
                instant: false,
                tid: 1,
            }],
        };
        let good = frame.to_bytes();
        // trace kinds are stamped v3 by construction
        assert_eq!(u16::from_le_bytes([good[4], good[5]]), LANE_VERSION);

        // truncation at every possible length
        for n in 0..good.len() {
            assert!(Frame::from_bytes(&good[..n]).is_err(), "prefix {n}");
        }

        // v2↔v3 skew: any trace kind under a v2 header is a typed
        // version-skew rejection
        for f in [
            Frame::TraceSync {
                t0_us: 1,
                peer_us: 0,
            },
            Frame::TraceCtx { trace: 1, clip: 2 },
            Frame::TraceFlush,
            frame.clone(),
        ] {
            let mut bad = f.to_bytes();
            bad[4..6].copy_from_slice(&2u16.to_le_bytes());
            assert!(matches!(Frame::from_bytes(&bad), Err(Error::Protocol(m))
                if m.contains("version skew")));
        }

        let reframe = |kind: u8, payload: &[u8]| {
            let mut evil = Vec::new();
            evil.extend_from_slice(&MAGIC);
            evil.extend_from_slice(&LANE_VERSION.to_le_bytes());
            evil.push(kind);
            evil.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            evil.extend_from_slice(payload);
            evil.extend_from_slice(&checksum(payload).to_le_bytes());
            evil
        };

        // bad instant flag, behind a valid checksum
        let mut w = Wr::new();
        w.u32(1); // one span
        w.u64(7); // trace
        w.str("x");
        w.u64(10); // start
        w.u64(5); // dur
        w.u8(9); // bad instant flag
        w.u64(1); // tid
        assert!(matches!(
            Frame::from_bytes(&reframe(13, &w.buf)),
            Err(Error::Protocol(m)) if m.contains("instant flag")
        ));

        // span count far beyond the payload caps before allocating
        let mut w = Wr::new();
        w.u32(u32::MAX); // claims ~159 GiB of spans
        assert!(matches!(
            Frame::from_bytes(&reframe(13, &w.buf)),
            Err(Error::Protocol(m)) if m.contains("length prefix")
        ));

        // trailing bytes after a correctly-checksummed trace payload
        let mut w = Frame::TraceCtx { trace: 1, clip: 2 }.encode_payload();
        w.push(0xEE);
        assert!(matches!(
            Frame::from_bytes(&reframe(11, &w)),
            Err(Error::Protocol(m)) if m.contains("trailing")
        ));

        // the pristine frame still decodes (the cases above were real)
        let (back, _) = Frame::from_bytes(&good).unwrap();
        assert_eq!(back, frame);
    }

    /// The v2 grammar survives unchanged: scalar frames stamp v2,
    /// decode under v2 headers, and surface the negotiated version —
    /// and a lane plane's bit payload is `lanes` bits per cell, not 64.
    #[test]
    fn v2_scalar_frames_still_decode_and_lane_packing_is_compact() {
        let drain = Frame::Drain { clip: 1 };
        let bytes = drain.to_bytes();
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), MIN_VERSION);
        let (back, ver, used) = Frame::from_bytes_versioned(&bytes).unwrap();
        assert_eq!((back, ver, used), (drain.clone(), MIN_VERSION, bytes.len()));
        // the same scalar frame under a v3 stamp also decodes (a v3
        // peer may legitimately stamp high)
        let v3 = drain.to_bytes_versioned(VERSION);
        let (back, ver, _) = Frame::from_bytes_versioned(&v3).unwrap();
        assert_eq!((back, ver), (drain, VERSION));

        // wire cost: an 11-lane frame over 2x3x4 cells packs 24*11
        // bits = 33 bytes (+ shape/ids/framing), far below 11 scalar
        // frames
        let lane = Frame::LaneFrame {
            batch: 0,
            seq: 0,
            frame: sample_lane_frame(11),
        };
        let scalar = Frame::SpikeFrame {
            clip: 0,
            seq: 0,
            plane: SpikePlane::zeros(2, 3, 4),
        };
        assert!(lane.to_bytes().len() < 11 * scalar.to_bytes().len());
    }

    /// Build a small random-but-valid network for workload codec tests
    /// (conv, optional pool, accumulate fc — the builder invariants).
    fn rand_network(g: &mut Gen) -> Network {
        let in_ch = 1 + g.index(2);
        let h = 4 + g.index(5);
        let w = 4 + g.index(5);
        let precision = *g.choose(&[
            Precision::W4V7,
            Precision::W6V11,
            Precision::W8V15,
        ]);
        let mut b = crate::snn::network::NetworkBuilder::new(
            "wire-prop",
            precision,
            1 + g.index(8),
            (in_ch, h, w),
        );
        let hidden = 1 + g.index(2);
        for _ in 0..hidden {
            let (c, _, _) = b.shape();
            let out_ch = 1 + g.index(4);
            let mut m = Mat::zeros(c * 9, out_ch);
            for r in 0..c * 9 {
                for k in 0..out_ch {
                    m.set(r, k, g.i32_in(-7..=7));
                }
            }
            let neuron = NeuronConfig {
                theta: 1 + g.i32_in(0..=5),
                leak: g.i32_in(0..=2),
                leaky: g.chance(0.5),
                reset: if g.chance(0.5) {
                    ResetMode::Soft
                } else {
                    ResetMode::Hard
                },
            };
            b = b.conv3x3(out_ch, m, neuron, false).unwrap();
        }
        if g.chance(0.5) {
            b = b.pool(2, 2);
        }
        let (c, hh, ww) = b.shape();
        let out = 1 + g.index(4);
        let mut m = Mat::zeros(c * hh * ww, out);
        for r in 0..c * hh * ww {
            for k in 0..out {
                m.set(r, k, g.i32_in(-7..=7));
            }
        }
        b.fc(out, m, NeuronConfig::default(), true)
            .unwrap()
            .build()
            .unwrap()
    }

    /// Tentpole: the workload codec round-trips whole networks —
    /// topology, geometry, neuron config, precision and every weight
    /// bit — and the rebuilt network *executes* identically (spot
    /// check via one reference step).
    #[test]
    fn prop_network_roundtrips_bit_exactly() {
        check("network_roundtrip", 30, |g| {
            let net = rand_network(g);
            let back = decode_network(&encode_network(&net)).unwrap();
            if back.name != net.name
                || back.precision != net.precision
                || back.timesteps != net.timesteps
                || back.layers.len() != net.layers.len()
            {
                return false;
            }
            for (a, b) in net.layers.iter().zip(&back.layers) {
                let same = a.kind == b.kind
                    && a.in_shape == b.in_shape
                    && a.out_shape == b.out_shape
                    && a.neuron == b.neuron
                    && a.accumulate == b.accumulate
                    && (a.kh, a.kw, a.stride, a.pad) == (b.kh, b.kw, b.stride, b.pad)
                    && a.weight_scale == b.weight_scale
                    && match (&a.weights, &b.weights) {
                        (None, None) => true,
                        (Some(x), Some(y)) => x.as_slice() == y.as_slice(),
                        _ => false,
                    };
                if !same {
                    return false;
                }
            }
            // the decoded network steps bit-identically
            let (c, h, w) = net.layers[0].in_shape;
            let mut frame = SpikePlane::zeros(c, h, w);
            for i in 0..frame.len() {
                if g.chance(0.3) {
                    frame.as_mut_slice()[i] = 1;
                }
            }
            let mut s1 = net.init_state().unwrap();
            let mut s2 = back.init_state().unwrap();
            net.step(&frame, &mut s1).unwrap();
            back.step(&frame, &mut s2).unwrap();
            s1.vmems
                .iter()
                .zip(&s2.vmems)
                .all(|(a, b)| a.as_slice() == b.as_slice())
        });
    }

    /// Satellite: the workload decoder is total — truncation at every
    /// prefix, implausible geometry, mismatched weights and trailing
    /// bytes are all `Error::Protocol`, never a panic.
    #[test]
    fn adversarial_workload_decodes_error_cleanly() {
        let net = crate::snn::network::demo_serving_network(4).unwrap();
        let good = encode_network(&net);
        assert!(decode_network(&good).is_ok());

        // truncation at every possible length
        for n in 0..good.len() {
            assert!(
                matches!(decode_network(&good[..n]), Err(Error::Protocol(_))),
                "workload prefix {n} must fail as a protocol error"
            );
        }

        // trailing garbage
        let mut bad = good.clone();
        bad.push(0xAA);
        assert!(matches!(decode_network(&bad), Err(Error::Protocol(m))
            if m.contains("trailing")));

        // unsupported precision
        let mut w = Wr::new();
        w.str("x");
        w.u8(5); // not 4/6/8
        assert!(matches!(decode_network(&w.buf), Err(Error::Protocol(m))
            if m.contains("precision")));

        // implausible layer count
        let mut w = Wr::new();
        w.str("x");
        w.u8(4);
        w.u32(1); // timesteps
        w.u32(1);
        w.u32(4);
        w.u32(4); // input 1x4x4
        w.u32(u32::MAX); // 4 billion layers
        assert!(matches!(decode_network(&w.buf), Err(Error::Protocol(m))
            if m.contains("layer count")));

        // a conv kernel larger than the padded input
        let mut w = Wr::new();
        w.str("x");
        w.u8(4);
        w.u32(1);
        w.u32(1);
        w.u32(4);
        w.u32(4);
        w.u32(1); // one layer
        w.u8(0); // conv
        w.u32(1); // out_ch
        w.u32(100);
        w.u32(100); // 100x100 kernel on a 4x4 input
        w.u32(1); // stride
        w.u32(0); // pad
        assert!(matches!(decode_network(&w.buf), Err(Error::Protocol(m))
            if m.contains("exceeds the padded input") || m.contains("exceeds padded input")));

        // a spiking (non-accumulate) final layer violates the network
        // contract
        let mut spiking = net.clone();
        for l in &mut spiking.layers {
            l.accumulate = false;
        }
        assert!(matches!(
            decode_network(&encode_network(&spiking)),
            Err(Error::Protocol(m)) if m.contains("accumulate")
        ));
    }

    #[test]
    fn mid_stream_eof_is_a_protocol_error_not_a_clean_close() {
        let bytes = Frame::Drain { clip: 5 }.to_bytes();
        let mut r = &bytes[..bytes.len() - 2];
        assert!(matches!(
            Frame::read_from(&mut r),
            Err(Error::Protocol(m)) if m.contains("mid-frame")
        ));
    }

    #[test]
    fn plane_bit_packing_is_compact() {
        let frame = Frame::SpikeFrame {
            clip: 0,
            seq: 0,
            plane: SpikePlane::zeros(2, 16, 16),
        };
        // 512 cells pack into 64 bytes (+ shape/ids/framing), far under
        // the 512 bytes a raw u8 encoding would need.
        assert!(frame.to_bytes().len() < 2 * 16 * 16 / 8 + 64);
    }
}
