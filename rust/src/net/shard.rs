//! The shard host: one process/thread owning one layer-group span
//! (DESIGN.md §Distributed).
//!
//! A [`ShardHost`] is the remote half of the distributed engine: it
//! holds the whole network's weights locally (layer-stationary
//! placement — after provisioning, weights never cross the wire
//! again), is assigned one contiguous layer group by a `LoadGroup`
//! frame, and then services `SpikeFrame`s one timestep at a time
//! through the same [`Network::step_group`] core every in-process
//! executor uses — so distributed execution is bit-identical to the
//! reference by construction.
//!
//! A host can start **blank** ([`ShardHost::blank`], the
//! `spidr shard --listen` default): it owns no workload until the
//! coordinator's first `LoadGroup` pushes one over the wire
//! ([`crate::net::wire::encode_network`]), after which the installed
//! network stays resident across every later `LoadGroup` in the
//! session (failover re-pushes re-assign the span without resending
//! weights).
//!
//! Backpressure follows `coordinator/pipeline.rs`: the host serves
//! strictly one frame per reply, so the number of frames in flight
//! toward a shard is bounded by the coordinator's protocol window plus
//! the transport buffer — a saturated shard stalls its producer
//! through the link, exactly as a full handshaking FIFO stalls the
//! upstream compute unit on silicon; frames are never dropped.
//!
//! Protocol v3 also carries the **observability sideband** (DESIGN.md
//! §Observability): a `TraceSync` ping/echo lets the coordinator
//! estimate this host's clock offset, `TraceCtx` binds session clip
//! ids to coordinator-minted trace ids, and while any binding is live
//! the host records one bounded [`WireSpan`] per serviced frame
//! (`shard_step` / `shard_lane_step`, timestamps in the host's own
//! clock) into a session buffer that `TraceFlush` drains as a
//! `TraceSpans` reply. With no bindings the data path takes **zero**
//! timestamps — the sideband costs one map lookup per frame.
//!
//! Protocol v3 adds **lane sessions** (DESIGN.md §Distributed): a
//! `LaneBatchOpen` provisions one [`LaneBank`] per stateful span layer
//! and every following `LaneFrame` steps the whole batch — up to 64
//! clips packed into `u64` bit-lanes — through
//! [`SpidrCore::run_layer_lanes`] in one sweep. The bank round-trips
//! all functional state between frames, so per-timestep lane stepping
//! is bit-identical per lane to per-clip [`Network::step_group`]
//! (`lane_session_matches_per_lane_step_group`). A host built with
//! [`ShardHost::with_protocol`]`(2)` speaks the scalar-only v2 dialect
//! and rejects lane traffic instead of desyncing — the coordinator's
//! `Hello` version negotiation reads that and falls back to scalar
//! frames.

use std::collections::HashMap;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::net::transport::Transport;
use crate::net::wire::{Frame, LaneReport, Role, MIN_VERSION, VERSION};
use crate::obs::trace::WireSpan;
use crate::sim::config::SimConfig;
use crate::sim::{LaneBank, SpidrCore};
use crate::snn::layer::LayerKind;
use crate::snn::network::{pool_step_lanes, GroupSpan, Network, StepTelemetry};
use crate::snn::spikes::LaneFrame;
use crate::snn::tensor::Mat;

/// What one shard session served, for logs and smoke assertions.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardReport {
    /// Clips drained (each lane of a drained batch counts as one clip).
    pub clips: u64,
    /// Spike frames stepped (scalar and lane frames alike).
    pub frames: u64,
    /// Lane batches drained (v3 sessions only).
    pub batches: u64,
}

/// One open lane batch: the per-span-layer Vmem lane banks and the
/// per-lane telemetry accumulated between `LaneBatchOpen` and `Drain`.
struct LaneSession {
    batch: u64,
    lanes: usize,
    clips: Vec<u64>,
    core: SpidrCore,
    banks: Vec<LaneBank>,
    telemetry: Vec<Vec<StepTelemetry>>,
    seq: u32,
}

/// Cap on buffered [`WireSpan`]s per session — further spans are
/// dropped, never allocated, so a flush-less coordinator cannot grow
/// the host unboundedly.
const TRACE_SPAN_CAP: usize = 8192;

/// Cap on live `TraceCtx` clip→trace bindings (drained clips release
/// theirs, so this only binds how much an errant peer can pin).
const TRACE_CTX_CAP: usize = 1024;

/// Microseconds since the host's own trace epoch. Any monotonic base
/// works: `TraceSync` measures this clock's offset from the
/// coordinator's, and [`Tracer::inject`](crate::obs::trace::Tracer::inject)
/// re-bases the spans.
fn us_since(epoch: Instant) -> u64 {
    epoch.elapsed().as_micros() as u64
}

/// A shard host serving one layer-group span of a network.
pub struct ShardHost {
    network: Option<Network>,
    name: String,
    span: Option<GroupSpan>,
    vmems: Vec<Mat>,
    telemetry: Vec<StepTelemetry>,
    clip: Option<u64>,
    lane: Option<LaneSession>,
    protocol: u16,
    trace_epoch: Instant,
    trace_clips: HashMap<u64, u64>,
    trace_spans: Vec<WireSpan>,
}

impl ShardHost {
    /// A host around a locally-materialized network (the weights stay
    /// pinned here; only the group assignment and spike frames travel).
    pub fn new(network: Network) -> Self {
        let name = format!("{}-shard", network.name);
        ShardHost {
            network: Some(network),
            name,
            span: None,
            vmems: Vec::new(),
            telemetry: Vec::new(),
            clip: None,
            lane: None,
            protocol: VERSION,
            trace_epoch: Instant::now(), // lint: wall-clock
            trace_clips: HashMap::new(),
            trace_spans: Vec::new(),
        }
    }

    /// A host with no local workload: the coordinator must provision
    /// it over the wire with a weight-carrying `LoadGroup` before any
    /// spike frame is accepted (`spidr shard --listen` with no
    /// `--workload` starts here).
    pub fn blank(name: impl Into<String>) -> Self {
        ShardHost {
            network: None,
            name: name.into(),
            span: None,
            vmems: Vec::new(),
            telemetry: Vec::new(),
            clip: None,
            lane: None,
            protocol: VERSION,
            trace_epoch: Instant::now(), // lint: wall-clock
            trace_clips: HashMap::new(),
            trace_spans: Vec::new(),
        }
    }

    /// Pin the host to an older protocol dialect (clamped to the
    /// supported `MIN_VERSION..=VERSION` range). The `Hello` ack is
    /// stamped at this version — the capability signal the
    /// coordinator's negotiation reads — and any frame stamped above it
    /// is rejected, so a v2 host never half-decodes lane traffic
    /// (`spidr shard --protocol 2`).
    pub fn with_protocol(mut self, version: u16) -> Self {
        self.protocol = version.clamp(MIN_VERSION, VERSION);
        self
    }

    /// The protocol dialect this host speaks.
    pub fn protocol(&self) -> u16 {
        self.protocol
    }

    /// The span this host was assigned, once loaded.
    pub fn span(&self) -> Option<&GroupSpan> {
        self.span.as_ref()
    }

    /// The workload this host serves — `None` until a blank host is
    /// provisioned by a weight-carrying `LoadGroup`.
    pub fn network(&self) -> Option<&Network> {
        self.network.as_ref()
    }

    /// Drain the trace spans this host has buffered but not yet shipped
    /// to a coordinator via `TraceFlush` — e.g. when the peer never
    /// pulled them (a v2 coordinator, or one with tracing off). Start
    /// times are microseconds since this host was created. `spidr shard
    /// --trace` uses this to export a local session trace without
    /// double-counting spans the coordinator already collected.
    pub fn take_trace_spans(&mut self) -> Vec<WireSpan> {
        std::mem::take(&mut self.trace_spans)
    }

    /// Serve one session: handle frames until the peer closes the link
    /// (clean EOF → `Ok` with the session report). On a protocol or
    /// execution error the host sends an `Error` frame to the peer and
    /// returns the error.
    pub fn serve<T: Transport>(&mut self, link: &mut T) -> Result<ShardReport> {
        let mut report = ShardReport::default();
        loop {
            let (frame, ver) = match link.recv_versioned()? {
                Some(f) => f,
                None => return Ok(report),
            };
            let outcome = if ver > self.protocol {
                Err(Error::protocol(format!(
                    "version skew: peer sent a v{ver} frame to a host speaking v{}",
                    self.protocol
                )))
            } else {
                self.handle(frame, &mut report)
            };
            match outcome {
                // The Hello ack is stamped at the host's own dialect —
                // the capability signal version negotiation reads;
                // every other reply travels at its kind's wire version.
                Ok(Some(reply @ Frame::Hello { .. })) => {
                    link.send_versioned(&reply, self.protocol)?
                }
                Ok(Some(reply)) => link.send(&reply)?,
                Ok(None) => {}
                Err(e) => {
                    let _ = link.send(&Frame::Error {
                        message: e.to_string(),
                    });
                    return Err(e);
                }
            }
        }
    }

    /// Handle one frame, returning the reply to send (if any).
    fn handle(&mut self, frame: Frame, report: &mut ShardReport) -> Result<Option<Frame>> {
        match frame {
            Frame::Hello { role: Role::Coordinator, .. } => Ok(Some(Frame::Hello {
                role: Role::Shard,
                name: self.name.clone(),
            })),
            Frame::Hello { role: Role::Shard, .. } => {
                Err(Error::protocol("shard greeted by another shard"))
            }
            Frame::LoadGroup {
                shard,
                groups,
                workload,
                ..
            } => {
                // Weight push: install the serialized workload before
                // resolving the span. The installed network persists
                // for the rest of the session, so failover re-pushes
                // (workload = None) re-assign and reset without
                // resending weights.
                if let Some(bytes) = workload {
                    let net = crate::net::wire::decode_network(&bytes)?;
                    self.name = format!("{}-shard", net.name);
                    self.network = Some(net);
                }
                let network = self.network.as_ref().ok_or_else(|| {
                    Error::protocol(
                        "blank shard has no workload; the coordinator must push \
                         one in its first LoadGroup",
                    )
                })?;
                let plan: Vec<(usize, usize)> = groups
                    .iter()
                    .map(|&(a, b)| (a as usize, b as usize))
                    .collect();
                let spans = network.group_spans(&plan)?;
                let span = *spans.get(shard as usize).ok_or_else(|| {
                    Error::protocol(format!(
                        "shard index {shard} out of range for a {}-group plan",
                        spans.len()
                    ))
                })?;
                self.vmems = network.span_state(&span)?;
                self.telemetry.clear();
                self.clip = None;
                self.lane = None;
                self.span = Some(span);
                Ok(Some(Frame::LoadGroup {
                    shard,
                    groups,
                    span: Some(span),
                    workload: None,
                }))
            }
            Frame::SpikeFrame { clip, seq, plane } => {
                if let Some(lane) = &self.lane {
                    return Err(Error::protocol(format!(
                        "scalar spike frame while lane batch {} is in flight",
                        lane.batch
                    )));
                }
                let span = self
                    .span
                    .ok_or_else(|| Error::protocol("spike frame before a group was loaded"))?;
                let network = self
                    .network
                    .as_ref()
                    .ok_or_else(|| Error::protocol("spike frame on an unprovisioned shard"))?;
                match self.clip {
                    None => self.clip = Some(clip),
                    Some(current) if current != clip => {
                        return Err(Error::protocol(format!(
                            "frame for clip {clip} while clip {current} is in flight"
                        )));
                    }
                    Some(_) => {}
                }
                if seq as usize != self.telemetry.len() {
                    return Err(Error::protocol(format!(
                        "out-of-order frame: seq {seq}, expected {}",
                        self.telemetry.len()
                    )));
                }
                // Trace sideband: with no binding for this clip the
                // path takes zero timestamps — one map lookup only.
                let traced = self.trace_clips.get(&clip).copied();
                let t0 = traced.map(|_| us_since(self.trace_epoch));
                let (out, tele) = network.step_group(&span, &plane, &mut self.vmems)?;
                self.telemetry.push(tele);
                report.frames += 1;
                if let (Some(trace), Some(start_us)) = (traced, t0) {
                    self.push_span(trace, "shard_step", start_us);
                }
                Ok(Some(Frame::SpikeFrame {
                    clip,
                    seq,
                    plane: out,
                }))
            }
            Frame::Drain { clip } => {
                if self.span.is_none() {
                    return Err(Error::protocol("drain before a group was loaded"));
                }
                // An open lane session drains as a batch: one LaneReport
                // per lane, then the session ends (its banks die with
                // it — the next batch opens fresh zeroed banks).
                if let Some(lane) = self.lane.take() {
                    if lane.batch != clip {
                        return Err(Error::protocol(format!(
                            "drain for batch {clip} while batch {} is in flight",
                            lane.batch
                        )));
                    }
                    for c in &lane.clips {
                        self.trace_clips.remove(c);
                    }
                    let lanes: Vec<LaneReport> = (0..lane.lanes)
                        .map(|b| LaneReport {
                            steps: lane.telemetry[b].clone(),
                            vmems: lane.banks.iter().map(|bank| bank.lane_mat(b)).collect(),
                        })
                        .collect();
                    report.clips += lane.lanes as u64;
                    report.batches += 1;
                    return Ok(Some(Frame::LaneTelemetry { batch: clip, lanes }));
                }
                if let Some(current) = self.clip {
                    if current != clip {
                        return Err(Error::protocol(format!(
                            "drain for clip {clip} while clip {current} is in flight"
                        )));
                    }
                }
                self.trace_clips.remove(&clip);
                let reply = Frame::Telemetry {
                    clip,
                    steps: std::mem::take(&mut self.telemetry),
                    vmems: self.vmems.clone(),
                };
                // reset-on-drain: the next clip starts from zeroed banks
                for bank in &mut self.vmems {
                    bank.as_mut_slice().fill(0);
                }
                self.clip = None;
                report.clips += 1;
                Ok(Some(reply))
            }
            Frame::LaneBatchOpen { batch, clips } => {
                let span = self
                    .span
                    .ok_or_else(|| Error::protocol("lane batch before a group was loaded"))?;
                let network = self.network.as_ref().ok_or_else(|| {
                    Error::protocol("lane batch on an unprovisioned shard")
                })?;
                if let Some(current) = self.clip {
                    return Err(Error::protocol(format!(
                        "lane batch {batch} while scalar clip {current} is in flight"
                    )));
                }
                if let Some(lane) = &self.lane {
                    return Err(Error::protocol(format!(
                        "lane batch {batch} while batch {} is in flight",
                        lane.batch
                    )));
                }
                let lanes = clips.len();
                // The core validates every span layer's fan-in at open
                // time, so a batch never fails mid-frame on a layer the
                // chip could not host.
                let core = SpidrCore::new(SimConfig {
                    precision: network.precision,
                    ..SimConfig::default()
                });
                let (lo, hi) = span.layers;
                let mut banks = Vec::new();
                for layer in &network.layers[lo..hi] {
                    if layer.has_state() {
                        core.select_mode(layer.fan_in())?;
                        let (m, k) = layer.vmem_shape()?;
                        banks.push(LaneBank::zeros(m, k, lanes));
                    }
                }
                self.lane = Some(LaneSession {
                    batch,
                    lanes,
                    clips: clips.clone(),
                    core,
                    banks,
                    telemetry: vec![Vec::new(); lanes],
                    seq: 0,
                });
                Ok(Some(Frame::LaneBatchOpen { batch, clips }))
            }
            Frame::LaneFrame { batch, seq, frame } => {
                let span = self
                    .span
                    .ok_or_else(|| Error::protocol("lane frame before a group was loaded"))?;
                let network = self.network.as_ref().ok_or_else(|| {
                    Error::protocol("lane frame on an unprovisioned shard")
                })?;
                let lane = self.lane.as_mut().ok_or_else(|| {
                    Error::protocol(format!("lane frame for batch {batch} before LaneBatchOpen"))
                })?;
                if batch != lane.batch {
                    return Err(Error::protocol(format!(
                        "lane frame for batch {batch} while batch {} is in flight",
                        lane.batch
                    )));
                }
                if seq != lane.seq {
                    return Err(Error::protocol(format!(
                        "out-of-order lane frame: seq {seq}, expected {}",
                        lane.seq
                    )));
                }
                if frame.lanes() != lane.lanes {
                    return Err(Error::protocol(format!(
                        "lane frame carries {} lanes, batch {} opened with {}",
                        frame.lanes(),
                        lane.batch,
                        lane.lanes
                    )));
                }
                let (lo, hi) = span.layers;
                let in_shape = network.layers[lo].in_shape;
                if frame.shape() != in_shape {
                    return Err(Error::shape(format!(
                        "lane frame shape {:?} != layer {lo} input {:?}",
                        frame.shape(),
                        in_shape
                    )));
                }
                // Trace sideband: a lane batch is anchored on its
                // first traced lane (mirrors the coordinator's
                // `lane_batch` anchor); untraced batches take zero
                // timestamps.
                let traced = lane
                    .clips
                    .iter()
                    .find_map(|c| self.trace_clips.get(c))
                    .copied();
                let t0 = traced.map(|_| us_since(self.trace_epoch));
                for tele in &mut lane.telemetry {
                    tele.push(StepTelemetry::default());
                }
                let mut f = frame;
                let mut si = 0;
                for layer in &network.layers[lo..hi] {
                    match layer.kind {
                        LayerKind::Pool => f = pool_step_lanes(layer, &f),
                        LayerKind::Conv | LayerKind::Fc => {
                            let cells = f.plane().len() as u64;
                            for (b, spikes) in f.lane_counts().into_iter().enumerate() {
                                let step = lane.telemetry[b]
                                    .last_mut()
                                    .expect("pushed one step above");
                                step.layer_input_spikes.push(spikes);
                                step.layer_input_cells.push(cells);
                            }
                            let (mut out, _) = lane.core.run_layer_lanes(
                                layer,
                                std::slice::from_ref(&f),
                                &mut lane.banks[si],
                            )?;
                            f = out.pop().expect("one timestep in, one frame out");
                            si += 1;
                        }
                    }
                }
                lane.seq += 1;
                report.frames += 1;
                if let (Some(trace), Some(start_us)) = (traced, t0) {
                    self.push_span(trace, "shard_lane_step", start_us);
                }
                Ok(Some(Frame::LaneFrame {
                    batch,
                    seq,
                    frame: f,
                }))
            }
            // Observability sideband (DESIGN.md §Observability) — all
            // three are valid in any session state, even before a
            // group is loaded.
            Frame::TraceSync { t0_us, .. } => Ok(Some(Frame::TraceSync {
                t0_us,
                peer_us: us_since(self.trace_epoch),
            })),
            Frame::TraceCtx { trace, clip } => {
                // re-binding an in-flight clip is allowed (failover
                // replay re-sends the context); fresh bindings are
                // capped so an errant peer cannot pin unbounded state
                if self.trace_clips.len() < TRACE_CTX_CAP
                    || self.trace_clips.contains_key(&clip)
                {
                    self.trace_clips.insert(clip, trace);
                }
                Ok(None)
            }
            Frame::TraceFlush => Ok(Some(Frame::TraceSpans {
                spans: std::mem::take(&mut self.trace_spans),
            })),
            Frame::Error { message } => Err(Error::Protocol(message)),
            Frame::Telemetry { .. } => {
                Err(Error::protocol("unexpected telemetry frame on a shard"))
            }
            Frame::LaneTelemetry { .. } => {
                Err(Error::protocol("unexpected lane telemetry frame on a shard"))
            }
            Frame::TraceSpans { .. } => {
                Err(Error::protocol("unexpected trace spans frame on a shard"))
            }
        }
    }

    /// Record one completed span into the bounded session buffer
    /// (dropped past [`TRACE_SPAN_CAP`], never reallocated past it).
    fn push_span(&mut self, trace: u64, name: &'static str, start_us: u64) {
        if self.trace_spans.len() < TRACE_SPAN_CAP {
            let end_us = us_since(self.trace_epoch);
            self.trace_spans.push(WireSpan {
                trace,
                name: name.to_string(),
                start_us,
                dur_us: end_us.saturating_sub(start_us),
                instant: false,
                tid: 0,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::LoopbackTransport;
    use crate::prop::SplitMix64;
    use crate::snn::network::demo_serving_network;
    use crate::snn::spikes::SpikePlane;

    fn rand_frame(seed: u64) -> SpikePlane {
        let mut rng = SplitMix64::new(seed);
        let mut p = SpikePlane::zeros(2, 16, 16);
        for i in 0..p.len() {
            if rng.chance(0.25) {
                p.as_mut_slice()[i] = 1;
            }
        }
        p
    }

    /// Spawn a host over loopback; returns the coordinator end and the
    /// server thread handle.
    fn spawn_host() -> (
        LoopbackTransport,
        crate::sync::thread::JoinHandle<Result<ShardReport>>,
    ) {
        let (coord, mut shard_end) = LoopbackTransport::pair();
        let handle = crate::sync::thread::spawn(move || {
            ShardHost::new(demo_serving_network(4).unwrap()).serve(&mut shard_end)
        });
        (coord, handle)
    }

    #[test]
    fn session_matches_local_step_group() {
        let net = demo_serving_network(4).unwrap();
        let (mut link, host) = spawn_host();

        link.send(&Frame::Hello {
            role: Role::Coordinator,
            name: "test".into(),
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::Hello { role: Role::Shard, .. })
        ));

        // own the first of two groups: the conv layer
        let groups = vec![(0u32, 1u32), (1, 2)];
        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: groups.clone(),
            span: None,
            workload: None,
        })
        .unwrap();
        let spans = net.group_spans(&[(0, 1), (1, 2)]).unwrap();
        match link.recv().unwrap() {
            Some(Frame::LoadGroup { span: Some(s), .. }) => assert_eq!(s, spans[0]),
            other => panic!("want LoadGroup ack, got {other:?}"),
        }

        // drive two clips; the shard must match local stepping and
        // reset its banks between them (clip 1 == clip 2 bit-for-bit).
        let mut drained = Vec::new();
        for clip in 0..2u64 {
            let mut vmems = net.span_state(&spans[0]).unwrap();
            for seq in 0..3u32 {
                let frame = rand_frame(100 + seq as u64); // same frames per clip
                link.send(&Frame::SpikeFrame {
                    clip,
                    seq,
                    plane: frame.clone(),
                })
                .unwrap();
                let (want_out, _) = net.step_group(&spans[0], &frame, &mut vmems).unwrap();
                match link.recv().unwrap() {
                    Some(Frame::SpikeFrame { clip: c, seq: s, plane }) => {
                        assert_eq!((c, s), (clip, seq));
                        assert_eq!(plane, want_out, "clip {clip} seq {seq} diverged");
                    }
                    other => panic!("want SpikeFrame reply, got {other:?}"),
                }
            }
            link.send(&Frame::Drain { clip }).unwrap();
            match link.recv().unwrap() {
                Some(Frame::Telemetry { clip: c, steps, vmems: got }) => {
                    assert_eq!(c, clip);
                    assert_eq!(steps.len(), 3);
                    assert_eq!(got, vmems, "drained Vmems diverged");
                    drained.push(got);
                }
                other => panic!("want Telemetry reply, got {other:?}"),
            }
        }
        assert_eq!(drained[0], drained[1], "banks must reset between clips");

        drop(link);
        let report = host.join().unwrap().unwrap();
        assert_eq!((report.clips, report.frames), (2, 6));
    }

    /// Tentpole acceptance: a blank host (no local workload) is fully
    /// provisioned by a weight-carrying `LoadGroup` and then serves
    /// frames bit-identically to local `step_group` on the pushed
    /// network; a later weightless `LoadGroup` (the failover re-push)
    /// keeps working against the installed network.
    #[test]
    fn blank_host_is_provisioned_by_weight_push() {
        use crate::net::wire::encode_network;

        let net = demo_serving_network(4).unwrap();
        let (mut link, mut shard_end) = LoopbackTransport::pair();
        let host = crate::sync::thread::spawn(move || {
            let mut h = ShardHost::blank("blank");
            let r = h.serve(&mut shard_end);
            (r, h.network().map(|n| n.name.clone()))
        });

        let groups = vec![(0u32, 2u32)];
        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: groups.clone(),
            span: None,
            workload: Some(encode_network(&net)),
        })
        .unwrap();
        match link.recv().unwrap() {
            Some(Frame::LoadGroup { span: Some(s), workload, .. }) => {
                assert_eq!(s, net.full_span());
                assert!(workload.is_none(), "the ack must not echo weights back");
            }
            other => panic!("want LoadGroup ack, got {other:?}"),
        }

        let mut vmems = net.span_state(&net.full_span()).unwrap();
        for seq in 0..3u32 {
            let frame = rand_frame(500 + seq as u64);
            link.send(&Frame::SpikeFrame {
                clip: 0,
                seq,
                plane: frame.clone(),
            })
            .unwrap();
            let (want, _) = net
                .step_group(&net.full_span(), &frame, &mut vmems)
                .unwrap();
            match link.recv().unwrap() {
                Some(Frame::SpikeFrame { plane, .. }) => {
                    assert_eq!(plane, want, "provisioned shard diverged at seq {seq}");
                }
                other => panic!("want SpikeFrame reply, got {other:?}"),
            }
        }
        link.send(&Frame::Drain { clip: 0 }).unwrap();
        match link.recv().unwrap() {
            Some(Frame::Telemetry { vmems: got, .. }) => assert_eq!(got, vmems),
            other => panic!("want Telemetry reply, got {other:?}"),
        }

        // failover-style re-push: no weights, the installed network
        // is retained and the banks reset
        link.send(&Frame::LoadGroup {
            shard: 0,
            groups,
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LoadGroup { span: Some(_), .. })
        ));

        drop(link);
        let (report, name) = host.join().unwrap();
        assert_eq!(report.unwrap().clips, 1);
        assert_eq!(name.as_deref(), Some("serving-demo"));
    }

    /// A blank host must reject group assignment (and therefore every
    /// later frame) until a workload is pushed.
    #[test]
    fn blank_host_rejects_load_without_workload() {
        let (mut link, mut shard_end) = LoopbackTransport::pair();
        let host =
            crate::sync::thread::spawn(move || ShardHost::blank("blank").serve(&mut shard_end));
        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: vec![(0, 2)],
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::Error { message }) if message.contains("no workload")
        ));
        assert!(host.join().unwrap().is_err());
    }

    #[test]
    fn frames_before_load_group_fail_the_session() {
        let (mut link, host) = spawn_host();
        link.send(&Frame::SpikeFrame {
            clip: 0,
            seq: 0,
            plane: rand_frame(1),
        })
        .unwrap();
        // the host reports the violation and ends the session
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::Error { message }) if message.contains("before a group")
        ));
        assert!(host.join().unwrap().is_err());
    }

    #[test]
    fn out_of_order_frames_are_rejected() {
        let (mut link, host) = spawn_host();
        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: vec![(0, 2)],
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LoadGroup { span: Some(_), .. })
        ));
        link.send(&Frame::SpikeFrame {
            clip: 0,
            seq: 5, // skips 0..5
            plane: rand_frame(2),
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::Error { message }) if message.contains("out-of-order")
        ));
        assert!(host.join().unwrap().is_err());
    }

    /// Tentpole: a v3 lane session — `LaneBatchOpen`, lane frames, and
    /// a batch `Drain` — is bit-identical **per lane** to driving each
    /// clip through scalar `step_group` calls: output spikes every
    /// timestep, per-step telemetry, and drained Vmems all match, and
    /// the whole batch costs one frame per timestep on the wire.
    #[test]
    fn lane_session_matches_per_lane_step_group() {
        let net = demo_serving_network(4).unwrap();
        let (mut link, host) = spawn_host();
        let span = net.group_spans(&[(0, 2)]).unwrap()[0];
        let (lanes, timesteps, batch) = (5usize, 3usize, 77u64);

        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: vec![(0, 2)],
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LoadGroup { span: Some(_), .. })
        ));

        let clips: Vec<u64> = (0..lanes as u64).collect();
        link.send(&Frame::LaneBatchOpen {
            batch,
            clips: clips.clone(),
        })
        .unwrap();
        match link.recv().unwrap() {
            Some(Frame::LaneBatchOpen { batch: b, clips: c }) => {
                assert_eq!((b, c), (batch, clips));
            }
            other => panic!("want LaneBatchOpen ack, got {other:?}"),
        }

        // oracle: one scalar state per lane, stepped clip by clip
        let mut vmems: Vec<Vec<Mat>> =
            (0..lanes).map(|_| net.span_state(&span).unwrap()).collect();
        let mut steps: Vec<Vec<StepTelemetry>> = vec![Vec::new(); lanes];
        for seq in 0..timesteps as u32 {
            let planes: Vec<SpikePlane> = (0..lanes)
                .map(|b| rand_frame(1000 * (b as u64 + 1) + seq as u64))
                .collect();
            let refs: Vec<&SpikePlane> = planes.iter().collect();
            link.send(&Frame::LaneFrame {
                batch,
                seq,
                frame: LaneFrame::pack(&refs).unwrap(),
            })
            .unwrap();
            let out = match link.recv().unwrap() {
                Some(Frame::LaneFrame { batch: b, seq: s, frame }) => {
                    assert_eq!((b, s), (batch, seq));
                    frame
                }
                other => panic!("want LaneFrame reply, got {other:?}"),
            };
            assert_eq!(out.lanes(), lanes);
            for b in 0..lanes {
                let (want, tele) = net
                    .step_group(&span, &planes[b], &mut vmems[b])
                    .unwrap();
                assert_eq!(out.lane(b), want, "lane {b} diverged at seq {seq}");
                steps[b].push(tele);
            }
        }

        link.send(&Frame::Drain { clip: batch }).unwrap();
        match link.recv().unwrap() {
            Some(Frame::LaneTelemetry { batch: b, lanes: reports }) => {
                assert_eq!(b, batch);
                assert_eq!(reports.len(), lanes);
                for (b, report) in reports.iter().enumerate() {
                    assert_eq!(report.steps, steps[b], "lane {b} telemetry diverged");
                    assert_eq!(report.vmems, vmems[b], "lane {b} Vmems diverged");
                }
            }
            other => panic!("want LaneTelemetry reply, got {other:?}"),
        }

        drop(link);
        let report = host.join().unwrap().unwrap();
        assert_eq!(
            (report.clips, report.frames, report.batches),
            (lanes as u64, timesteps as u64, 1)
        );
    }

    /// Satellite (version negotiation): a host pinned to the v2 dialect
    /// advertises v2 in its `Hello` ack and rejects v3 lane traffic
    /// with a version-skew protocol error instead of desyncing; a
    /// scalar frame mid-lane-batch on a v3 host is likewise typed.
    #[test]
    fn v2_host_rejects_lane_frames() {
        let (mut link, mut shard_end) = LoopbackTransport::pair();
        let host = crate::sync::thread::spawn(move || {
            ShardHost::new(demo_serving_network(4).unwrap())
                .with_protocol(2)
                .serve(&mut shard_end)
        });

        link.send(&Frame::Hello {
            role: Role::Coordinator,
            name: "test".into(),
        })
        .unwrap();
        match link.recv_versioned().unwrap() {
            Some((Frame::Hello { role: Role::Shard, .. }, ver)) => {
                assert_eq!(ver, MIN_VERSION, "v2 host must advertise v2");
            }
            other => panic!("want Hello ack, got {other:?}"),
        }

        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: vec![(0, 2)],
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LoadGroup { span: Some(_), .. })
        ));

        // a lane frame is stamped v3 by its kind — the v2 host must
        // reject it before touching the session state
        link.send(&Frame::LaneBatchOpen {
            batch: 0,
            clips: vec![0, 1],
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::Error { message }) if message.contains("version skew")
        ));
        assert!(host.join().unwrap().is_err());
    }

    /// Scalar and lane sessions must not interleave: a scalar spike
    /// frame inside an open lane batch is a typed protocol error.
    #[test]
    fn scalar_frame_inside_lane_batch_is_rejected() {
        let (mut link, host) = spawn_host();
        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: vec![(0, 2)],
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LoadGroup { span: Some(_), .. })
        ));
        link.send(&Frame::LaneBatchOpen {
            batch: 3,
            clips: vec![0, 1, 2],
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LaneBatchOpen { .. })
        ));
        link.send(&Frame::SpikeFrame {
            clip: 9,
            seq: 0,
            plane: rand_frame(4),
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::Error { message }) if message.contains("lane batch 3")
        ));
        assert!(host.join().unwrap().is_err());
    }

    /// Satellite (ISSUE 9): the trace sideband on a live session —
    /// `TraceSync` echoes the request stamp with the host clock
    /// filled, a `TraceCtx`-bound clip gets one `shard_step` span per
    /// serviced frame (flushed by `TraceFlush`), and an unbound clip
    /// records nothing, so a trace-less session buffers zero spans.
    #[test]
    fn trace_sideband_records_and_flushes_spans() {
        let (mut link, host) = spawn_host();

        // sync works even before a group is loaded
        link.send(&Frame::TraceSync {
            t0_us: 42,
            peer_us: 0,
        })
        .unwrap();
        match link.recv().unwrap() {
            Some(Frame::TraceSync { t0_us, peer_us: _ }) => assert_eq!(t0_us, 42),
            other => panic!("want TraceSync echo, got {other:?}"),
        }

        link.send(&Frame::LoadGroup {
            shard: 0,
            groups: vec![(0, 2)],
            span: None,
            workload: None,
        })
        .unwrap();
        assert!(matches!(
            link.recv().unwrap(),
            Some(Frame::LoadGroup { span: Some(_), .. })
        ));

        // clip 5 is traced (ctx is fire-and-forget: no reply), clip 6 is not
        link.send(&Frame::TraceCtx { trace: 99, clip: 5 }).unwrap();
        for clip in [5u64, 6u64] {
            for seq in 0..2u32 {
                link.send(&Frame::SpikeFrame {
                    clip,
                    seq,
                    plane: rand_frame(10 + seq as u64),
                })
                .unwrap();
                assert!(matches!(
                    link.recv().unwrap(),
                    Some(Frame::SpikeFrame { .. })
                ));
            }
            link.send(&Frame::Drain { clip }).unwrap();
            assert!(matches!(
                link.recv().unwrap(),
                Some(Frame::Telemetry { .. })
            ));
        }

        // first flush: exactly the two spans of the traced clip,
        // attributed to its trace id, in arrival order
        link.send(&Frame::TraceFlush).unwrap();
        match link.recv().unwrap() {
            Some(Frame::TraceSpans { spans }) => {
                assert_eq!(spans.len(), 2, "one span per traced frame");
                for s in &spans {
                    assert_eq!(s.trace, 99);
                    assert_eq!(s.name, "shard_step");
                    assert!(!s.instant);
                }
            }
            other => panic!("want TraceSpans reply, got {other:?}"),
        }

        // the flush drained the buffer
        link.send(&Frame::TraceFlush).unwrap();
        match link.recv().unwrap() {
            Some(Frame::TraceSpans { spans }) => assert!(spans.is_empty()),
            other => panic!("want empty TraceSpans, got {other:?}"),
        }

        drop(link);
        host.join().unwrap().unwrap();
    }
}
