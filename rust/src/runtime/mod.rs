//! PJRT runtime: loads AOT HLO-text artifacts and executes them as the
//! golden model on the request path (Python is never invoked).

pub mod artifact;
pub mod client;
pub mod golden;
pub mod manifest;

pub use artifact::ArtifactStore;
pub use client::PjrtRuntime;
pub use golden::GoldenModel;
pub use manifest::{Manifest, ManifestEntry};
