//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! HLO *text* is the interchange format (never serialized protos):
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The real client is gated behind the `pjrt` cargo feature (which
//! additionally requires the `xla` crate, not vendored here). The
//! default build substitutes a stub with the same API whose every
//! entry point returns [`Error::Runtime`], keeping `cargo test`
//! hermetic; artifact-dependent tests skip themselves when no
//! `artifacts/manifest.txt` is present.

#[cfg(feature = "pjrt")]
mod imp {
    // The `xla` crate is not vendored in this environment (the default
    // build is dependency-free). Make enabling the feature without it
    // fail with an actionable message instead of an unresolved-import
    // cascade; vendor/add the crate and delete this guard to activate
    // the real client below.
    compile_error!(
        "the `pjrt` feature requires the `xla` crate: add it as a \
         dependency in Cargo.toml and remove this compile_error! guard \
         in rust/src/runtime/client.rs"
    );

    use crate::error::{Error, Result};
    use std::path::Path;

    /// A PJRT CPU runtime holding the client connection.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    /// A compiled executable plus its calling convention.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Number of outputs in the result tuple.
        pub num_outputs: usize,
    }

    impl PjrtRuntime {
        /// Connect to the in-process PJRT CPU backend.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
            Ok(PjrtRuntime { client })
        }

        /// Backend platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it.
        pub fn compile_hlo_file(
            &self,
            path: impl AsRef<Path>,
            num_outputs: usize,
        ) -> Result<Executable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::artifact("non-UTF-8 artifact path"))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
            Ok(Executable { exe, num_outputs })
        }
    }

    impl Executable {
        /// Execute with i32 tensor inputs; returns the flattened i32
        /// outputs (the artifact's outputs are all i32 by construction).
        ///
        /// `inputs` are `(flat_data, dims)` pairs in artifact argument
        /// order.
        pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<Vec<i32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    xla::Literal::vec1(data)
                        .reshape(dims)
                        .map_err(|e| Error::Runtime(format!("reshape input: {e}")))
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
            let parts = tuple
                .to_tuple()
                .map_err(|e| Error::Runtime(format!("untuple result: {e}")))?;
            if parts.len() != self.num_outputs {
                return Err(Error::Runtime(format!(
                    "artifact returned {} outputs, expected {}",
                    parts.len(),
                    self.num_outputs
                )));
            }
            parts
                .into_iter()
                .map(|l| {
                    l.to_vec::<i32>()
                        .map_err(|e| Error::Runtime(format!("read output: {e}")))
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::error::{Error, Result};
    use std::path::Path;

    fn unavailable() -> Error {
        Error::Runtime(
            "PJRT runtime not compiled in: rebuild with `--features pjrt` \
             (requires the `xla` crate) to execute AOT golden-model \
             artifacts"
                .into(),
        )
    }

    /// Stub PJRT runtime (the `pjrt` feature is off).
    pub struct PjrtRuntime {
        _priv: (),
    }

    /// Stub executable (never constructed; all constructors fail).
    pub struct Executable {
        /// Number of outputs in the result tuple.
        pub num_outputs: usize,
    }

    impl PjrtRuntime {
        /// Always fails: the backend is compiled out.
        pub fn cpu() -> Result<Self> {
            Err(unavailable())
        }

        /// Backend platform name (diagnostics).
        pub fn platform(&self) -> String {
            "pjrt-unavailable".into()
        }

        /// Always fails: the backend is compiled out.
        pub fn compile_hlo_file(
            &self,
            _path: impl AsRef<Path>,
            _num_outputs: usize,
        ) -> Result<Executable> {
            Err(unavailable())
        }
    }

    impl Executable {
        /// Always fails: the backend is compiled out.
        pub fn run_i32(&self, _inputs: &[(&[i32], &[i64])]) -> Result<Vec<Vec<i32>>> {
            Err(unavailable())
        }
    }
}

pub use imp::{Executable, PjrtRuntime};
