//! Parser for `artifacts/manifest.txt` (written by `aot.py`).
//!
//! A deliberately simple line-oriented format (no serde in this
//! environment): `artifact <name>` opens a stanza, indented
//! `<key> <values…>` lines describe it, `end` closes it.

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::Path;

/// One artifact stanza.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Artifact name (file stem of the `.hlo.txt`).
    pub name: String,
    /// `macro` or `network_step`.
    pub kind: String,
    /// Task name for network artifacts ("gesture", "flow").
    pub task: Option<String>,
    /// Weight precision.
    pub weight_bits: u32,
    /// Vmem precision.
    pub vmem_bits: u32,
    /// Timesteps the network was trained for.
    pub timesteps: Option<usize>,
    /// Input frame shape `(C, H, W)`.
    pub frame_shape: Option<(usize, usize, usize)>,
    /// Per-stateful-layer Vmem shapes `(M, K)`.
    pub vmem_shapes: Vec<(usize, usize)>,
    /// Output accumulator shape `(M, K)`.
    pub out_shape: Option<(usize, usize)>,
    /// Output scale (accumulator → float units).
    pub output_scale: Option<f64>,
    /// All raw key/value pairs (macro geometry etc.).
    pub raw: HashMap<String, String>,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Entries in file order.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        let mut cur: Option<ManifestEntry> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix("artifact ") {
                if cur.is_some() {
                    return Err(Error::artifact(format!(
                        "line {}: nested artifact stanza",
                        ln + 1
                    )));
                }
                cur = Some(ManifestEntry {
                    name: name.trim().to_string(),
                    kind: String::new(),
                    task: None,
                    weight_bits: 0,
                    vmem_bits: 0,
                    timesteps: None,
                    frame_shape: None,
                    vmem_shapes: Vec::new(),
                    out_shape: None,
                    output_scale: None,
                    raw: HashMap::new(),
                });
                continue;
            }
            if line == "end" {
                let e = cur.take().ok_or_else(|| {
                    Error::artifact(format!("line {}: stray 'end'", ln + 1))
                })?;
                if e.kind.is_empty() {
                    return Err(Error::artifact(format!(
                        "artifact {}: missing kind",
                        e.name
                    )));
                }
                entries.push(e);
                continue;
            }
            let e = cur.as_mut().ok_or_else(|| {
                Error::artifact(format!("line {}: key outside stanza", ln + 1))
            })?;
            let (key, val) = line
                .split_once(' ')
                .ok_or_else(|| Error::artifact(format!("line {}: bad line", ln + 1)))?;
            let val = val.trim();
            let parse_usize = |v: &str| {
                v.parse::<usize>()
                    .map_err(|_| Error::artifact(format!("line {}: bad int {v}", ln + 1)))
            };
            match key {
                "kind" => e.kind = val.to_string(),
                "task" => e.task = Some(val.to_string()),
                "weight_bits" => e.weight_bits = parse_usize(val)? as u32,
                "vmem_bits" => e.vmem_bits = parse_usize(val)? as u32,
                "timesteps" => e.timesteps = Some(parse_usize(val)?),
                "frame_shape" => {
                    let parts: Vec<usize> = val
                        .split_whitespace()
                        .map(parse_usize)
                        .collect::<Result<_>>()?;
                    if parts.len() != 3 {
                        return Err(Error::artifact(format!(
                            "line {}: frame_shape needs 3 dims",
                            ln + 1
                        )));
                    }
                    e.frame_shape = Some((parts[0], parts[1], parts[2]));
                }
                "vmem" => {
                    let parts: Vec<usize> = val
                        .split_whitespace()
                        .map(parse_usize)
                        .collect::<Result<_>>()?;
                    if parts.len() != 3 {
                        return Err(Error::artifact(format!(
                            "line {}: vmem needs index m k",
                            ln + 1
                        )));
                    }
                    if parts[0] != e.vmem_shapes.len() {
                        return Err(Error::artifact(format!(
                            "line {}: vmem index out of order",
                            ln + 1
                        )));
                    }
                    e.vmem_shapes.push((parts[1], parts[2]));
                }
                "out_shape" => {
                    let parts: Vec<usize> = val
                        .split_whitespace()
                        .map(parse_usize)
                        .collect::<Result<_>>()?;
                    e.out_shape = Some((parts[0], parts[1]));
                }
                "output_scale" => {
                    e.output_scale = Some(val.parse::<f64>().map_err(|_| {
                        Error::artifact(format!("line {}: bad float {val}", ln + 1))
                    })?);
                }
                _ => {
                    e.raw.insert(key.to_string(), val.to_string());
                }
            }
        }
        if cur.is_some() {
            return Err(Error::artifact("unterminated artifact stanza"));
        }
        Ok(Manifest { entries })
    }

    /// Load and parse `manifest.txt` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let path = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::artifact(format!(
                "cannot read {}: {e} (run `make artifacts`)",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Find an entry by name.
    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find a network entry by task + weight bits.
    pub fn network(&self, task: &str, weight_bits: u32) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| {
            e.kind == "network_step"
                && e.task.as_deref() == Some(task)
                && e.weight_bits == weight_bits
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
artifact macro_w4
  kind macro
  weight_bits 4
  vmem_bits 7
  m 128
end
artifact gesture_w4
  kind network_step
  task gesture
  weight_bits 4
  vmem_bits 7
  timesteps 10
  frame_shape 2 64 64
  output_scale 0.125
  vmem 0 4096 16
  vmem 1 64 11
  out_shape 1 11
  num_state_layers 2
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let g = m.network("gesture", 4).unwrap();
        assert_eq!(g.frame_shape, Some((2, 64, 64)));
        assert_eq!(g.vmem_shapes, vec![(4096, 16), (64, 11)]);
        assert_eq!(g.out_shape, Some((1, 11)));
        assert_eq!(g.output_scale, Some(0.125));
        assert_eq!(m.get("macro_w4").unwrap().raw["m"], "128");
        assert_eq!(g.raw["num_state_layers"], "2");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("end").is_err());
        assert!(Manifest::parse("artifact a\n  kind x").is_err());
        assert!(Manifest::parse("artifact a\nartifact b\nend").is_err());
        assert!(Manifest::parse("key outside").is_err());
    }

    #[test]
    fn missing_network_is_none() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.network("flow", 4).is_none());
        assert!(m.network("gesture", 6).is_none());
    }
}
