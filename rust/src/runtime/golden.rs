//! Golden-model executor: drives a network-step artifact timestep by
//! timestep from the Rust request path.
//!
//! The artifact computes one full-network timestep
//! `(frame, vmem_0..vmem_{L-1}) -> (out_acc, counts, vmem'_0..)` with
//! the trained integer weights baked in as constants. This executor
//! owns the Vmem state between calls, exactly mirroring what the SNN
//! core's neuron units hold on-chip.

use crate::error::{Error, Result};
use crate::snn::spikes::SpikePlane;

use super::artifact::ArtifactStore;

/// Stateful golden model for one (task, precision) artifact.
pub struct GoldenModel {
    name: String,
    frame_shape: (usize, usize, usize),
    vmem_shapes: Vec<(usize, usize)>,
    out_shape: (usize, usize),
    /// Output accumulator → float units.
    pub output_scale: f64,
    /// Timesteps the network was trained for.
    pub timesteps: usize,
    vmems: Vec<Vec<i32>>,
    /// Per-layer input spike counts from the last step (Fig. 5
    /// telemetry surfaced by the artifact itself).
    pub last_counts: Vec<i32>,
    /// Latest output accumulator.
    pub out_acc: Vec<i32>,
}

impl GoldenModel {
    /// Build from a manifest entry (does not compile yet).
    pub fn new(store: &ArtifactStore, name: &str) -> Result<Self> {
        let e = store.entry(name)?;
        let frame_shape = e
            .frame_shape
            .ok_or_else(|| Error::artifact(format!("{name}: missing frame_shape")))?;
        let out_shape = e
            .out_shape
            .ok_or_else(|| Error::artifact(format!("{name}: missing out_shape")))?;
        let vmem_shapes = e.vmem_shapes.clone();
        if vmem_shapes.is_empty() {
            return Err(Error::artifact(format!("{name}: no vmem shapes")));
        }
        Ok(GoldenModel {
            name: name.to_string(),
            frame_shape,
            vmem_shapes: vmem_shapes.clone(),
            out_shape,
            output_scale: e.output_scale.unwrap_or(1.0),
            timesteps: e.timesteps.unwrap_or(1),
            vmems: vmem_shapes.iter().map(|&(m, k)| vec![0; m * k]).collect(),
            last_counts: Vec::new(),
            out_acc: vec![0; out_shape.0 * out_shape.1],
        })
    }

    /// Input frame shape `(C, H, W)`.
    pub fn frame_shape(&self) -> (usize, usize, usize) {
        self.frame_shape
    }

    /// Reset all Vmem state (new clip).
    pub fn reset(&mut self) {
        for v in &mut self.vmems {
            v.fill(0);
        }
        self.out_acc.fill(0);
        self.last_counts.clear();
    }

    /// Current Vmem bank of stateful layer `i` (bit-exactness checks).
    pub fn vmem(&self, i: usize) -> &[i32] {
        &self.vmems[i]
    }

    /// Execute one timestep on the PJRT executable.
    pub fn step(&mut self, store: &mut ArtifactStore, frame: &SpikePlane) -> Result<()> {
        let (c, h, w) = self.frame_shape;
        if frame.shape() != (c, h, w) {
            return Err(Error::shape(format!(
                "frame {:?} != artifact input {:?}",
                frame.shape(),
                self.frame_shape
            )));
        }
        let frame_i32: Vec<i32> =
            frame.as_slice().iter().map(|&b| b as i32).collect();
        let frame_dims = [c as i64, h as i64, w as i64];

        let mut inputs: Vec<(&[i32], &[i64])> = Vec::with_capacity(1 + self.vmems.len());
        inputs.push((&frame_i32, &frame_dims));
        let vmem_dims: Vec<[i64; 2]> = self
            .vmem_shapes
            .iter()
            .map(|&(m, k)| [m as i64, k as i64])
            .collect();
        for (v, d) in self.vmems.iter().zip(&vmem_dims) {
            inputs.push((v.as_slice(), d.as_slice()));
        }

        let exe = store.network_executable(&self.name)?;
        let mut outputs = exe.run_i32(&inputs)?;
        // outputs: [out_acc, counts, vmem'_0, ..., vmem'_{L-1}]
        if outputs.len() != 2 + self.vmems.len() {
            return Err(Error::Runtime(format!(
                "unexpected output arity {}",
                outputs.len()
            )));
        }
        let mut rest = outputs.split_off(2);
        for (v, nv) in self.vmems.iter_mut().zip(rest.drain(..)) {
            *v = nv;
        }
        self.last_counts = outputs[1].clone();
        self.out_acc = outputs[0].clone();
        Ok(())
    }

    /// Run a whole clip (resets state first). Returns per-timestep
    /// per-layer input spike counts.
    pub fn run_clip(
        &mut self,
        store: &mut ArtifactStore,
        frames: &[SpikePlane],
    ) -> Result<Vec<Vec<i32>>> {
        self.reset();
        let mut counts = Vec::with_capacity(frames.len());
        for f in frames {
            self.step(store, f)?;
            counts.push(self.last_counts.clone());
        }
        Ok(counts)
    }

    /// Output accumulator in float units (flow field / logits).
    pub fn out_float(&self) -> Vec<f64> {
        self.out_acc
            .iter()
            .map(|&v| v as f64 * self.output_scale)
            .collect()
    }

    /// Argmax of the output accumulator (classification readout).
    pub fn argmax(&self) -> usize {
        self.out_acc
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Output shape `(M, K)`.
    pub fn out_shape(&self) -> (usize, usize) {
        self.out_shape
    }
}
