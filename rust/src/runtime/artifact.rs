//! Artifact store: locates, compiles and caches AOT executables.

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::client::{Executable, PjrtRuntime};
use super::manifest::{Manifest, ManifestEntry};

/// A directory of AOT artifacts plus compiled-executable cache.
pub struct ArtifactStore {
    dir: PathBuf,
    /// Parsed manifest.
    pub manifest: Manifest,
    runtime: PjrtRuntime,
    cache: HashMap<String, Executable>,
}

impl ArtifactStore {
    /// Open an artifacts directory (expects `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let runtime = PjrtRuntime::cpu()?;
        Ok(ArtifactStore {
            dir,
            manifest,
            runtime,
            cache: HashMap::new(),
        })
    }

    /// The artifacts directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of an artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Path of a task's weight bundle.
    pub fn swb_path(&self, task: &str, weight_bits: u32) -> PathBuf {
        self.dir
            .join("weights")
            .join(format!("{task}_w{weight_bits}.swb"))
    }

    /// Manifest entry by name.
    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.manifest
            .get(name)
            .ok_or_else(|| Error::artifact(format!("no artifact '{name}' in manifest")))
    }

    /// Compile (or fetch cached) an executable for a network-step
    /// artifact. Output count = out_acc + counts + one Vmem per layer.
    pub fn network_executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let entry = self.entry(name)?.clone();
            if entry.kind != "network_step" {
                return Err(Error::artifact(format!(
                    "artifact '{name}' is a {} (need network_step)",
                    entry.kind
                )));
            }
            let num_outputs = 2 + entry.vmem_shapes.len();
            let exe = self
                .runtime
                .compile_hlo_file(self.hlo_path(name), num_outputs)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Compile (or fetch cached) a standalone macro artifact (1 output).
    pub fn macro_executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let entry = self.entry(name)?.clone();
            if entry.kind != "macro" {
                return Err(Error::artifact(format!(
                    "artifact '{name}' is a {} (need macro)",
                    entry.kind
                )));
            }
            let exe = self.runtime.compile_hlo_file(self.hlo_path(name), 1)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }
}
