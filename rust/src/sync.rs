//! The crate-wide synchronization facade (DESIGN.md §Correctness).
//!
//! Every concurrency primitive the crate uses — mutexes, condvars,
//! `mpsc` channels, atomics, and thread creation — is imported from
//! here instead of `std::sync` / `std::thread` (enforced by
//! `spidr lint` rule 1). In a normal build this module is *pure
//! re-exports of `std`*: zero wrapper types, zero overhead (pinned by
//! the `facade_overhead_ratio` series in `BENCH_obs.json`).
//!
//! Under `RUSTFLAGS="--cfg spidr_model"` the same names resolve to
//! the deterministic model checker's shims ([`crate::check`]), which
//! route every operation through a cooperative scheduler so
//! `tests/model.rs` can exhaustively explore interleavings of the
//! serving-stack protocols. The facade is what makes that possible
//! without a single `#[cfg]` in protocol code.
//!
//! Intentionally *not* shimmed (always plain `std`): [`Arc`] and
//! [`OnceLock`] (no scheduling decisions worth exploring), and
//! `std::thread::scope` used by the data-parallel compute tiers
//! (`sim`, `coordinator/scheduler.rs`) whose fork-join structure has
//! no cross-thread protocol state.

#[cfg(not(spidr_model))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(spidr_model)]
pub use crate::check::shim::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

pub use std::sync::{Arc, OnceLock};

/// Multi-producer single-consumer channels (`std::sync::mpsc` or the
/// model-checked equivalent).
pub mod mpsc {
    #[cfg(not(spidr_model))]
    pub use std::sync::mpsc::{
        channel, sync_channel, Receiver, RecvError, RecvTimeoutError, SendError, Sender,
        SyncSender, TryRecvError, TrySendError,
    };

    #[cfg(spidr_model)]
    pub use crate::check::chan::{
        channel, sync_channel, Receiver, RecvError, RecvTimeoutError, SendError, Sender,
        SyncSender, TryRecvError, TrySendError,
    };
}

/// Atomic types (`std::sync::atomic` or the model-checked
/// equivalent, which is sequentially consistent regardless of the
/// requested ordering).
pub mod atomic {
    #[cfg(not(spidr_model))]
    pub use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicU64, AtomicUsize};

    #[cfg(spidr_model)]
    pub use crate::check::shim::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicU64, AtomicUsize};

    pub use std::sync::atomic::Ordering;
}

/// Thread creation (`std::thread` or the model-checked equivalent).
pub mod thread {
    #[cfg(not(spidr_model))]
    pub use std::thread::{
        available_parallelism, scope, sleep, spawn, yield_now, JoinHandle, Scope,
        ScopedJoinHandle,
    };

    #[cfg(spidr_model)]
    pub use crate::check::thread_shim::{
        available_parallelism, scope, sleep, spawn, spawn_named, yield_now, JoinHandle, Scope,
        ScopedJoinHandle,
    };

    /// Spawn a thread with a name (visible in panics, debuggers, and
    /// trace exports). The facade-level replacement for
    /// `std::thread::Builder::new().name(..).spawn(..)`.
    #[cfg(not(spidr_model))]
    pub fn spawn_named<F, T>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new().name(name.to_string()).spawn(f)
    }
}
