//! Energy and power modeling for the simulated SpiDR core.
//!
//! The paper's energy numbers come from silicon measurement; here they
//! come from an analytic per-operation model whose *structure* follows
//! the architecture (what scales with spikes, with parity switches,
//! with cycles, with voltage) and whose *coefficients* are calibrated
//! so the simulated core reproduces the Table-I corners (DESIGN.md §2).

pub mod calibration;
pub mod model;
pub mod tech;

pub use model::{Corner, EnergyBreakdown, EnergyParams};
pub use tech::{scale_efficiency_to_node, scale_energy_to_node};
