//! Technology-node scaling for cross-accelerator comparison.
//!
//! Table III scales energy across nodes assuming `energy ∝ tech²`
//! (footnote d): SpiDR's 5 TOPS/W at 65 nm becomes 26.95 TOPS/W at the
//! 28 nm reference node used by most of the compared chips.

/// Scale an energy value from one node to another (`energy ∝ tech²`).
pub fn scale_energy_to_node(energy: f64, from_nm: f64, to_nm: f64) -> f64 {
    energy * (to_nm / from_nm).powi(2)
}

/// Scale an efficiency value (TOPS/W ∝ 1/energy).
pub fn scale_efficiency_to_node(tops_w: f64, from_nm: f64, to_nm: f64) -> f64 {
    tops_w * (from_nm / to_nm).powi(2)
}

/// A row of the Table-III comparison (literature constants for the
/// compared accelerators; the SpiDR row comes from the simulator).
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    /// Accelerator name.
    pub name: &'static str,
    /// Publication venue.
    pub venue: &'static str,
    /// Technology node (nm).
    pub tech_nm: f64,
    /// Die/core area (mm²).
    pub area_mm2: f64,
    /// Supply range (V).
    pub supply: &'static str,
    /// Compute style.
    pub compute_type: &'static str,
    /// Weight precision description.
    pub weight_precision: &'static str,
    /// Native efficiency claim, in the paper's own unit.
    pub efficiency: &'static str,
    /// Efficiency in TOPS/W at the native node when expressible,
    /// `None` for pJ/SOP-style claims.
    pub tops_w_native: Option<f64>,
    /// Reconfigurable network architecture support.
    pub reconfigurable: bool,
    /// Requires a modified training methodology.
    pub modified_training: bool,
}

/// Literature rows of Table III (everything except SpiDR's own row).
pub fn literature_rows() -> Vec<ComparisonRow> {
    vec![
        ComparisonRow {
            name: "C-DNN",
            venue: "ISSCC'23",
            tech_nm: 28.0,
            area_mm2: 20.25,
            supply: "0.7-1.1",
            compute_type: "Digital",
            weight_precision: "4/8",
            efficiency: "CIFAR10: 63.3 TOPS/W @50MHz, 0.7V",
            tops_w_native: Some(63.3),
            reconfigurable: true,
            modified_training: true,
        },
        ComparisonRow {
            name: "ANP-I",
            venue: "ISSCC'23",
            tech_nm: 28.0,
            area_mm2: 1.63,
            supply: "0.56-0.9",
            compute_type: "Async. Digital",
            weight_precision: "hidden: 8, op: 10",
            efficiency: "1.5 pJ/SOP @40MHz, 0.56V",
            tops_w_native: None,
            reconfigurable: false,
            modified_training: true,
        },
        ComparisonRow {
            name: "ReckOn",
            venue: "ISSCC'22",
            tech_nm: 28.0,
            area_mm2: 0.87,
            supply: "0.5-0.8",
            compute_type: "Async Digital",
            weight_precision: "8",
            efficiency: "5.3 pJ/SOP @13MHz, 0.5V",
            tops_w_native: None,
            reconfigurable: false,
            modified_training: true,
        },
        ComparisonRow {
            name: "uBrain",
            venue: "Frontiers'21",
            tech_nm: 40.0,
            area_mm2: 2.82,
            supply: "1.1",
            compute_type: "Async Digital",
            weight_precision: "4",
            efficiency: "308 nJ/prediction (MNIST) @1.1V",
            tops_w_native: None,
            reconfigurable: false,
            modified_training: false,
        },
        ComparisonRow {
            name: "SD Training",
            venue: "ISSCC'19",
            tech_nm: 65.0,
            area_mm2: 10.08,
            supply: "0.8",
            compute_type: "Digital",
            weight_precision: "-",
            efficiency: "3.42 TOPS/W @20MHz, 0.8V",
            tops_w_native: Some(3.42),
            reconfigurable: false,
            modified_training: true,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_footnote_d_values() {
        // 5 / 3.34 / 2.5 TOPS/W at 65nm -> 26.95 / 18 / 13.5 at 28nm.
        let scaled = scale_efficiency_to_node(5.0, 65.0, 28.0);
        assert!((scaled - 26.95).abs() < 0.05, "{scaled}");
        let scaled = scale_efficiency_to_node(3.34, 65.0, 28.0);
        assert!((scaled - 18.0).abs() < 0.05, "{scaled}");
        let scaled = scale_efficiency_to_node(2.5, 65.0, 28.0);
        assert!((scaled - 13.5).abs() < 0.05, "{scaled}");
    }

    #[test]
    fn sd_training_scaling() {
        // Table III: 3.42 TOPS/W at 65nm -> (18.43) at 28nm.
        let scaled = scale_efficiency_to_node(3.42, 65.0, 28.0);
        assert!((scaled - 18.43).abs() < 0.05, "{scaled}");
    }

    #[test]
    fn energy_and_efficiency_are_inverse() {
        let e = scale_energy_to_node(10.0, 65.0, 28.0);
        assert!(e < 10.0);
        let eff = scale_efficiency_to_node(10.0, 65.0, 28.0);
        assert!(eff > 10.0);
        assert!((e * eff - 100.0).abs() < 1e-9);
    }

    #[test]
    fn literature_table_complete() {
        let rows = literature_rows();
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().any(|r| r.name == "ReckOn"));
        // only SpiDR and C-DNN are reconfigurable in Table III
        assert_eq!(rows.iter().filter(|r| r.reconfigurable).count(), 1);
    }
}
