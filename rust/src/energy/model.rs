//! Per-operation energy parameters and accounting.

/// A voltage/frequency operating corner (Table I: 50 MHz @ 0.9 V and
/// 150 MHz @ 1.0 V; the chip spans 0.9–1.2 V, 50–150 MHz).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Corner {
    /// Clock frequency in MHz.
    pub freq_mhz: f64,
    /// Supply voltage in volts.
    pub voltage: f64,
}

impl Corner {
    /// The 50 MHz / 0.9 V low-power corner.
    pub const LOW: Corner = Corner {
        freq_mhz: 50.0,
        voltage: 0.9,
    };

    /// The 150 MHz / 1.0 V high-throughput corner.
    pub const HIGH: Corner = Corner {
        freq_mhz: 150.0,
        voltage: 1.0,
    };

    /// Dynamic-energy scale factor relative to the 0.9 V reference
    /// (CV² switching energy).
    pub fn dynamic_scale(&self) -> f64 {
        (self.voltage / 0.9).powi(2)
    }

    /// Cycle period in nanoseconds.
    pub fn period_ns(&self) -> f64 {
        1e3 / self.freq_mhz
    }
}

/// Per-event energy coefficients, in pJ at the 0.9 V reference.
///
/// Defaults are calibrated against Table I (see
/// `energy::model::tests::table1_calibration` and the Table-I bench):
/// 4.9 mW / 5 TOPS/W at the LOW corner, 95 % sparsity, 4-bit weights.
#[derive(Debug, Clone, Copy)]
pub struct EnergyParams {
    /// One compute-macro accumulation pass (R+C+S pipeline, one parity,
    /// all active columns) at 4-bit precision. Scales with the active
    /// column count, i.e. linearly in B_w via [`EnergyParams::macro_op`].
    pub e_macro_op_4b: f64,
    /// Peripheral reconfiguration energy per even/odd parity switch
    /// (RBL switch + adder-chain re-latch, Fig. 8a / Fig. 10).
    pub e_parity_switch: f64,
    /// Spike-detector read of one IFspad row (trailing-zero scan).
    pub e_detect_row: f64,
    /// Address-queue FIFO push or pop.
    pub e_queue_op: f64,
    /// Neuron-macro energy per cycle of its 66-cycle pass.
    pub e_neuron_cycle: f64,
    /// Input-loader IFspad write (one row segment, im2col + stride/pad).
    pub e_il_write: f64,
    /// IFmem read per row fetched by the input loader.
    pub e_ifmem_read: f64,
    /// Partial-Vmem row transfer between adjacent units (CU→CU, CU→NU).
    pub e_transfer_row: f64,
    /// Distributed control overhead per unit-active cycle.
    pub e_ctrl_cycle: f64,
    /// Static leakage power for the whole core, in mW at 0.9 V.
    pub p_leak_mw: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        // Calibrated (see EXPERIMENTS.md §Calibration) so that the
        // simulated LOW corner at 95 % input sparsity / 4-bit weights
        // lands on Table I: 4.9 mW, 5 TOPS/W, 24.54 GOPS.
        EnergyParams {
            e_macro_op_4b: 12.35,
            e_parity_switch: 11.8,
            e_detect_row: 1.06,
            e_queue_op: 0.26,
            e_neuron_cycle: 8.2,
            e_il_write: 0.65,
            e_ifmem_read: 1.29,
            e_transfer_row: 1.88,
            e_ctrl_cycle: 3.76,
            p_leak_mw: 0.35,
        }
    }
}

impl EnergyParams {
    /// Compute-macro pass energy for a weight precision: the adder
    /// chain spans all 48 columns regardless, but the number of
    /// latched/driven sense paths per logical neuron grows with B_w;
    /// the per-pass energy is dominated by bit-line switching, which
    /// is constant per 48-column pass. A mild precision-dependent term
    /// accounts for the longer carry chains at higher B_v.
    pub fn macro_op(&self, weight_bits: u32) -> f64 {
        let carry_factor = 1.0 + 0.05 * (weight_bits as f64 - 4.0) / 2.0;
        self.e_macro_op_4b * carry_factor
    }
}

/// Accumulated energy by architectural component, in pJ (Fig. 14's
/// breakdown). `total()` includes leakage added by the caller.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Compute-macro array + column peripherals (R/C/S passes).
    pub compute_macro: f64,
    /// Parity-switch reconfiguration.
    pub peripheral_switch: f64,
    /// Neuron units (partial→full Vmem + threshold + reset).
    pub neuron_units: f64,
    /// Spike detector + address queues + SRAM controller.
    pub s2a: f64,
    /// Input loader (hardware im2col writes).
    pub input_loader: f64,
    /// IFmem reads.
    pub ifmem: f64,
    /// Partial-Vmem transfers between units (data movement).
    pub data_movement: f64,
    /// Distributed control.
    pub control: f64,
    /// Static leakage.
    pub leakage: f64,
}

impl EnergyBreakdown {
    /// Total energy in pJ.
    pub fn total(&self) -> f64 {
        self.compute_macro
            + self.peripheral_switch
            + self.neuron_units
            + self.s2a
            + self.input_loader
            + self.ifmem
            + self.data_movement
            + self.control
            + self.leakage
    }

    /// CIM-macro share (compute + neuron), the paper's headline
    /// "dominant consumer" claim in Fig. 14.
    pub fn cim_share(&self) -> f64 {
        (self.compute_macro + self.peripheral_switch + self.neuron_units) / self.total()
    }

    /// Data-movement share ("only a small fraction" claim).
    pub fn data_movement_share(&self) -> f64 {
        self.data_movement / self.total()
    }

    /// Element-wise accumulate.
    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.compute_macro += other.compute_macro;
        self.peripheral_switch += other.peripheral_switch;
        self.neuron_units += other.neuron_units;
        self.s2a += other.s2a;
        self.input_loader += other.input_loader;
        self.ifmem += other.ifmem;
        self.data_movement += other.data_movement;
        self.control += other.control;
        self.leakage += other.leakage;
    }

    /// Scale all dynamic components (everything but leakage) by `k` —
    /// used for voltage-corner scaling.
    pub fn scale_dynamic(&mut self, k: f64) {
        self.compute_macro *= k;
        self.peripheral_switch *= k;
        self.neuron_units *= k;
        self.s2a *= k;
        self.input_loader *= k;
        self.ifmem *= k;
        self.data_movement *= k;
        self.control *= k;
    }
}

/// Convert total pJ over a cycle count into average power (mW) at a
/// corner, including leakage.
pub fn average_power_mw(dynamic_pj: f64, cycles: u64, corner: Corner, params: &EnergyParams) -> f64 {
    if cycles == 0 {
        return params.p_leak_mw;
    }
    let seconds = cycles as f64 * corner.period_ns() * 1e-9;
    let dynamic_w = dynamic_pj * 1e-12 * corner.dynamic_scale() / seconds;
    dynamic_w * 1e3 + params.p_leak_mw * (corner.voltage / 0.9).powi(2)
}

/// Energy efficiency in TOPS/W given dense-equivalent ops and total
/// energy (pJ) at the 0.9 V reference, adjusted to a corner.
pub fn tops_per_watt(ops: u64, dynamic_pj: f64, cycles: u64, corner: Corner, params: &EnergyParams) -> f64 {
    let seconds = cycles as f64 * corner.period_ns() * 1e-9;
    let leak_pj = params.p_leak_mw * (corner.voltage / 0.9).powi(2) * 1e9 * seconds;
    let total_pj = dynamic_pj * corner.dynamic_scale() + leak_pj;
    if total_pj == 0.0 {
        return 0.0;
    }
    // ops / (pJ * 1e-12 J) / 1e12 = ops / total_pj
    ops as f64 / total_pj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corner_scaling() {
        assert!((Corner::LOW.dynamic_scale() - 1.0).abs() < 1e-12);
        let hi = Corner::HIGH.dynamic_scale();
        assert!((hi - (1.0f64 / 0.9).powi(2)).abs() < 1e-12);
        assert!((Corner::LOW.period_ns() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn macro_op_grows_with_precision() {
        let p = EnergyParams::default();
        assert!(p.macro_op(4) < p.macro_op(6));
        assert!(p.macro_op(6) < p.macro_op(8));
    }

    #[test]
    fn breakdown_totals() {
        let mut b = EnergyBreakdown {
            compute_macro: 10.0,
            neuron_units: 5.0,
            leakage: 1.0,
            ..Default::default()
        };
        assert!((b.total() - 16.0).abs() < 1e-12);
        assert!(b.cim_share() > 0.9);
        b.scale_dynamic(2.0);
        assert!((b.total() - 31.0).abs() < 1e-12); // leakage unscaled
    }

    #[test]
    fn power_includes_leakage() {
        let p = EnergyParams::default();
        let mw = average_power_mw(0.0, 1000, Corner::LOW, &p);
        assert!((mw - p.p_leak_mw).abs() < 1e-9);
    }

    #[test]
    fn tops_per_watt_sane() {
        let p = EnergyParams::default();
        // 1e9 ops in 1e6 cycles at LOW with 200_000 pJ dynamic:
        let eff = tops_per_watt(1_000_000_000, 200_000.0, 1_000_000, Corner::LOW, &p);
        assert!(eff > 0.0 && eff.is_finite());
    }

    #[test]
    fn high_corner_less_efficient_when_dynamic_dominates() {
        // Table I: 5 TOPS/W @LOW vs 4.09 @HIGH — the V² dynamic-energy
        // penalty outweighs the shorter leakage window.
        let p = EnergyParams::default();
        let lo = tops_per_watt(1_000_000, 100_000.0, 1_000, Corner::LOW, &p);
        let hi = tops_per_watt(1_000_000, 100_000.0, 1_000, Corner::HIGH, &p);
        assert!(hi < lo);
    }
}
