//! Table-I calibration: the peak-utilization workload and the
//! measurement harness that anchors the energy model to the chip's
//! reported corners.
//!
//! The workload is a Conv layer that fills the core: fan-in 378 (42
//! input channels x 3x3 — 98.4 % of Mode 1's 384 rows), 36 output
//! channels (one full pass of 3 pipelines x 12 neurons at 4-bit), 16x16
//! output pixels (16 tiles), at a controlled input sparsity.
//!
//! `measure` returns GOPS / TOPS/W / mW exactly the way Table I reports
//! them (dense-equivalent ops, dynamic + leakage energy at the corner).

use crate::energy::model::Corner;
use crate::prop::SplitMix64;
use crate::quant::Precision;
use crate::sim::config::SimConfig;
use crate::sim::core::SpidrCore;
use crate::snn::layer::{Layer, NeuronConfig};
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

/// Peak-workload geometry.
pub const PEAK_IN_CH: usize = 42;
/// Spatial size of the peak workload.
pub const PEAK_HW: usize = 16;
/// Output channels at 4-bit (one full Mode-1 pass: 3 x 48/B_w).
pub const PEAK_OUT_CH: usize = 36;
/// Timesteps simulated per measurement.
pub const PEAK_TIMESTEPS: usize = 4;

/// Output channels that exactly fill one Mode-1 pass at a precision.
pub fn peak_out_ch(precision: Precision) -> usize {
    3 * precision.neurons_per_row()
}

/// Build the peak-utilization layer for a precision (Table I's "peak
/// performance" point: every macro column carries a mapped neuron and
/// one weight pass covers all channels).
pub fn peak_layer(precision: Precision) -> Layer {
    Layer::conv(
        (PEAK_IN_CH, PEAK_HW, PEAK_HW),
        peak_out_ch(precision),
        3,
        3,
        1,
        1,
        Mat::zeros(PEAK_IN_CH * 9, peak_out_ch(precision)),
        NeuronConfig {
            theta: 4,
            ..Default::default()
        },
        false,
    )
    .expect("peak layer geometry")
}

/// Random frames at a given density for the peak layer.
pub fn peak_frames(density: f64, seed: u64) -> Vec<SpikePlane> {
    let mut rng = SplitMix64::new(seed);
    (0..PEAK_TIMESTEPS)
        .map(|_| {
            let mut p = SpikePlane::zeros(PEAK_IN_CH, PEAK_HW, PEAK_HW);
            for i in 0..p.len() {
                if rng.chance(density) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            p
        })
        .collect()
}

/// One measured operating point.
#[derive(Debug, Clone, Copy)]
pub struct OperatingPoint {
    /// Corner measured.
    pub corner: Corner,
    /// Weight precision.
    pub weight_bits: u32,
    /// Input sparsity achieved.
    pub sparsity: f64,
    /// Effective throughput (dense-equivalent GOPS).
    pub gops: f64,
    /// Energy efficiency (TOPS/W).
    pub tops_per_watt: f64,
    /// Average power (mW).
    pub power_mw: f64,
}

/// Measure the peak workload at a precision/corner/sparsity.
pub fn measure(precision: Precision, corner: Corner, sparsity: f64) -> OperatingPoint {
    let mut cfg = SimConfig::timing_only(precision);
    cfg.corner = corner;
    let core = SpidrCore::new(cfg);
    let layer = peak_layer(precision);
    let frames = peak_frames(1.0 - sparsity, 0xCA11B);
    let mut state = Mat::zeros(PEAK_HW * PEAK_HW, peak_out_ch(precision));
    let (_, stats) = core
        .run_layer(&layer, &frames, &mut state)
        .expect("peak workload runs");
    let mut run = stats.run;
    run.finalize_leakage(corner, &cfg.energy);
    OperatingPoint {
        corner,
        weight_bits: precision.weight_bits(),
        sparsity: run.sparsity(),
        gops: run.gops(corner),
        tops_per_watt: run.tops_per_watt(corner),
        power_mw: run.power_mw(corner),
    }
}

/// Paper Table-I targets at 95 % sparsity.
pub struct Table1Target {
    /// Weight precision.
    pub weight_bits: u32,
    /// TOPS/W at 50 MHz / 0.9 V.
    pub tops_w_low: f64,
    /// GOPS at 50 MHz / 0.9 V.
    pub gops_low: f64,
    /// TOPS/W at 150 MHz / 1.0 V.
    pub tops_w_high: f64,
    /// GOPS at 150 MHz / 1.0 V.
    pub gops_high: f64,
}

/// The Table-I reference rows.
pub fn table1_targets() -> [Table1Target; 3] {
    [
        Table1Target { weight_bits: 4, tops_w_low: 5.0, gops_low: 24.54, tops_w_high: 4.09, gops_high: 73.59 },
        Table1Target { weight_bits: 6, tops_w_low: 3.34, gops_low: 16.36, tops_w_high: 2.73, gops_high: 49.06 },
        Table1Target { weight_bits: 8, tops_w_low: 2.5, gops_low: 12.27, tops_w_high: 2.04, gops_high: 36.80 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ALL_PRECISIONS;

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / b
    }

    #[test]
    fn table1_calibration_low_corner() {
        // The calibration anchor: 4-bit, 95 % sparsity, LOW corner must
        // land near 5 TOPS/W / 24.54 GOPS / 4.9 mW (see EXPERIMENTS.md
        // for the measured values).
        let op = measure(Precision::W4V7, Corner::LOW, 0.95);
        assert!(rel_err(op.tops_per_watt, 5.0) < 0.25, "TOPS/W {}", op.tops_per_watt);
        assert!(rel_err(op.gops, 24.54) < 0.35, "GOPS {}", op.gops);
        assert!(rel_err(op.power_mw, 4.9) < 0.45, "mW {}", op.power_mw);
    }

    #[test]
    fn precision_scaling_matches_table1_ratios() {
        // 4b : 6b : 8b efficiency should scale like 12 : 8 : 6
        // (neurons per row), as Table I's 5 : 3.34 : 2.5 does.
        let pts: Vec<OperatingPoint> = ALL_PRECISIONS
            .iter()
            .map(|&p| measure(p, Corner::LOW, 0.95))
            .collect();
        assert!(pts[0].tops_per_watt > pts[1].tops_per_watt);
        assert!(pts[1].tops_per_watt > pts[2].tops_per_watt);
        let r64 = pts[0].gops / pts[1].gops;
        assert!((r64 - 1.5).abs() < 0.25, "4b/6b GOPS ratio {r64}");
        let r48 = pts[0].gops / pts[2].gops;
        assert!((r48 - 2.0).abs() < 0.35, "4b/8b GOPS ratio {r48}");
    }

    #[test]
    fn high_corner_triples_throughput() {
        let lo = measure(Precision::W4V7, Corner::LOW, 0.95);
        let hi = measure(Precision::W4V7, Corner::HIGH, 0.95);
        assert!((hi.gops / lo.gops - 3.0).abs() < 1e-6);
        assert!(hi.tops_per_watt < lo.tops_per_watt);
    }

    #[test]
    fn sparsity_improves_efficiency() {
        let s80 = measure(Precision::W4V7, Corner::LOW, 0.80);
        let s95 = measure(Precision::W4V7, Corner::LOW, 0.95);
        assert!(s95.tops_per_watt > s80.tops_per_watt);
        assert!(s95.gops > s80.gops);
    }
}
