//! Minimal in-repo property-testing harness.
//!
//! The environment's crate registry does not include `proptest`, so
//! this module provides the subset we need (DESIGN.md §2, toolchain
//! substitutions): a deterministic, language-portable PRNG
//! ([`rng::SplitMix64`], the same stream as `python/compile/data.py`),
//! value generators, and a [`check`] runner with linear shrinking of
//! failing cases.
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the xla rpath in this image
//! use spidr::prop::{check, Gen};
//!
//! // addition of u16s never overflows u32
//! check("add_no_overflow", 200, |g| {
//!     let a = g.u64_in(0..=u16::MAX as u64) as u32;
//!     let b = g.u64_in(0..=u16::MAX as u64) as u32;
//!     a.checked_add(b).is_some()
//! });
//! ```

pub mod gen;
pub mod rng;

pub use gen::Gen;
pub use rng::SplitMix64;

/// Run a property `times` times with fresh generated inputs.
///
/// On failure, retries with 64 nearby seeds to find (and report) the
/// smallest failing seed, then panics with a reproduction hint.
pub fn check<F>(name: &str, times: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> bool,
{
    check_seeded(name, times, 0x5EED_0000, &mut prop);
}

/// [`check`] with an explicit base seed (for reproducing failures).
pub fn check_seeded<F>(name: &str, times: u64, base_seed: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> bool,
{
    for i in 0..times {
        let seed = base_seed.wrapping_add(i);
        let mut g = Gen::new(seed);
        if !prop(&mut g) {
            // Shrink: replay with progressively smaller size budgets to
            // find a small failing case (size shrinks the magnitude of
            // generated values and lengths).
            let mut smallest = None;
            for size in [1usize, 2, 4, 8, 16, 32, 64] {
                let mut g = Gen::with_size(seed, size);
                if !prop(&mut g) {
                    smallest = Some(size);
                    break;
                }
            }
            panic!(
                "property '{name}' failed (seed {seed:#x}, iteration {i}, \
                 smallest failing size {smallest:?}); reproduce with \
                 prop::check_seeded(\"{name}\", 1, {seed:#x}, ..)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_tautology() {
        check("tautology", 50, |g| g.u64() | 1 > 0);
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn check_panics_for_falsum() {
        check("falsum", 5, |g| g.u64() == 1 && g.u64() == 0);
    }

    #[test]
    fn seeded_reproducible() {
        let mut vals = Vec::new();
        check_seeded("collect", 3, 42, &mut |g| {
            vals.push(g.u64());
            true
        });
        let mut vals2 = Vec::new();
        check_seeded("collect", 3, 42, &mut |g| {
            vals2.push(g.u64());
            true
        });
        assert_eq!(vals, vals2);
    }
}
