//! SplitMix64: the deterministic PRNG shared with the Python side.
//!
//! `python/compile/data.py` implements the identical stream; the
//! synthetic DVS generators in [`crate::dvs`] consume it so both
//! languages produce byte-identical event frames for a given seed
//! (checked by `python/tests/test_data.py::test_splitmix64_known_vector`
//! and the mirror test below).

/// SplitMix64 PRNG (public-domain algorithm by Sebastiano Vigna).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1): top 53 bits / 2^53 (same as Python).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform u64 in [0, n) via rejection-free multiply-shift.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Bernoulli event with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector_matches_python() {
        // Mirrors python/tests/test_data.py::test_splitmix64_known_vector.
        let mut rng = SplitMix64::new(0);
        assert_eq!(rng.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(rng.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(rng.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = SplitMix64::new(42);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 1000.0;
        assert!((0.3..0.7).contains(&mean), "mean {mean}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = SplitMix64::new(7);
        let mut seen = [false; 8];
        for _ in 0..500 {
            let v = rng.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
