//! Value generators for the property-testing harness.

use super::rng::SplitMix64;
use std::ops::RangeInclusive;

/// A seeded generator with a size budget that bounds the magnitude of
/// generated values (smaller sizes are tried while shrinking).
pub struct Gen {
    rng: SplitMix64,
    size: usize,
}

impl Gen {
    /// New generator with the default size budget.
    pub fn new(seed: u64) -> Self {
        Gen::with_size(seed, 256)
    }

    /// New generator with an explicit size budget.
    pub fn with_size(seed: u64, size: usize) -> Self {
        Gen {
            rng: SplitMix64::new(seed),
            size: size.max(1),
        }
    }

    /// Current size budget.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Raw u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform u64 in an inclusive range.
    pub fn u64_in(&mut self, range: RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*range.start(), *range.end());
        debug_assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform i64 in an inclusive range.
    pub fn i64_in(&mut self, range: RangeInclusive<i64>) -> i64 {
        let (lo, hi) = (*range.start(), *range.end());
        debug_assert!(lo <= hi);
        lo.wrapping_add(self.rng.below((hi - lo + 1) as u64) as i64)
    }

    /// Uniform i32 in an inclusive range, scaled down by the size budget
    /// while shrinking.
    pub fn i32_in(&mut self, range: RangeInclusive<i32>) -> i32 {
        self.i64_in(*range.start() as i64..=*range.end() as i64) as i32
    }

    /// Uniform usize in `[0, n)`, additionally capped by the size budget.
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.rng.below(n as u64) as usize
    }

    /// A length in `[min, max]`, capped by the size budget.
    pub fn len_in(&mut self, min: usize, max: usize) -> usize {
        let cap = max.min(min.max(self.size));
        min + self.rng.below((cap - min + 1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick an element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Vector of values from a per-element generator.
    pub fn vec_of<T>(&mut self, min: usize, max: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.len_in(min, max);
        (0..n).map(|_| f(self)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_respected() {
        let mut g = Gen::new(1);
        for _ in 0..500 {
            let v = g.i32_in(-5..=5);
            assert!((-5..=5).contains(&v));
            let u = g.u64_in(10..=12);
            assert!((10..=12).contains(&u));
        }
    }

    #[test]
    fn len_respects_size_budget() {
        let mut g = Gen::with_size(3, 4);
        for _ in 0..100 {
            let n = g.len_in(1, 1000);
            assert!((1..=4).contains(&n));
        }
    }

    #[test]
    fn choose_covers_all_elements() {
        let mut g = Gen::new(9);
        let xs = [1, 2, 3];
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[*g.choose(&xs) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
