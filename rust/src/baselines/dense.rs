//! Dense (no zero-skipping) execution baseline.
//!
//! Convenience wrapper that runs a layer through the cycle simulator
//! with zero-skipping disabled — every IFspad position is processed
//! regardless of spikes — quantifying what the S2A's sparse path saves
//! (Figs. 14 and 17 ablations).

use crate::error::Result;
use crate::sim::core::{LayerStats, SpidrCore};
use crate::sim::SimConfig;
use crate::snn::layer::Layer;
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

/// Run one layer densely (zero-skipping off) and return its stats.
pub fn dense_layer_stats(
    layer: &Layer,
    inputs: &[SpikePlane],
    cfg: &SimConfig,
) -> Result<LayerStats> {
    let mut dense_cfg = *cfg;
    dense_cfg.zero_skipping = false;
    let core = SpidrCore::new(dense_cfg);
    let (m, k) = layer.vmem_shape()?;
    let mut state = Mat::zeros(m, k);
    let (_, stats) = core.run_layer(layer, inputs, &mut state)?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::SplitMix64;
    use crate::quant::Precision;
    use crate::snn::layer::NeuronConfig;

    #[test]
    fn dense_ignores_sparsity() {
        let mut w = Mat::zeros(9, 4);
        for f in 0..9 {
            w.set(f, 0, 1);
        }
        let layer =
            Layer::conv((1, 6, 6), 4, 3, 3, 1, 1, w, NeuronConfig::default(), false).unwrap();
        let cfg = SimConfig::timing_only(Precision::W4V7);

        let mut rng = SplitMix64::new(4);
        let mut frames = Vec::new();
        for _ in 0..2 {
            let mut p = SpikePlane::zeros(1, 6, 6);
            for i in 0..p.len() {
                if rng.chance(0.02) {
                    p.as_mut_slice()[i] = 1;
                }
            }
            frames.push(p);
        }
        let dense = dense_layer_stats(&layer, &frames, &cfg).unwrap();

        let mut denser_frames = frames.clone();
        for f in &mut denser_frames {
            for v in f.as_mut_slice().iter_mut() {
                *v = 1;
            }
        }
        let dense_full = dense_layer_stats(&layer, &denser_frames, &cfg).unwrap();
        // dense-mode macro op count does not depend on spike density
        assert_eq!(dense.run.macro_ops, dense_full.run.macro_ops);
    }
}
