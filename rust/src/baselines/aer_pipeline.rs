//! AER-based input handling baseline (paper Fig. 4).
//!
//! With AER, each input spike arrives as an explicit address packet:
//! storage/bandwidth and handling costs scale with the *event count*.
//! With SpiDR's raw bitmap IFmem + spike detector, costs scale with
//! the *input size* (every row is scanned) but per-cell costs are tiny.
//! The crossover — AER only wins above ~94.7 % sparsity for the
//! example layer — is Fig. 4's argument for raw storage + zero-skip.

use crate::dvs::aer::{aer_address_bits, AER_BITS_PER_EVENT};
use crate::energy::model::EnergyParams;
use crate::snn::spikes::SpikePlane;

/// Input-handling cost of one layer input plane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InputCost {
    /// Storage / link traffic in bits.
    pub bits: u64,
    /// Input-path energy in pJ (memory reads + decode / scan).
    pub energy_pj: f64,
    /// Input-path cycles (fetch + decode / scan).
    pub cycles: u64,
}

/// Cost of AER-encoded input handling.
///
/// Per event: one address fetch of `addr_bits + overhead` bits, one
/// decode (modeled at queue-op energy), one IFspad-equivalent write.
pub fn aer_input_cost(plane: &SpikePlane, e: &EnergyParams) -> InputCost {
    let (c, h, w) = plane.shape();
    let events = plane.count_spikes();
    let bits_per_event = (aer_address_bits(c, h, w) + AER_BITS_PER_EVENT) as u64;
    let bits = events * bits_per_event;
    // fetch energy scales with packet width relative to a 16-bit row
    let fetch = e.e_ifmem_read * bits_per_event as f64 / 16.0;
    let energy = events as f64 * (fetch + e.e_queue_op + e.e_il_write);
    InputCost {
        bits,
        energy_pj: energy,
        cycles: events * 2, // fetch + decode per event
    }
}

/// Cost of raw-bitmap input handling (SpiDR's IFmem + detector scan).
///
/// Per 16-cell row: one IFmem read, one IFspad write, one detector
/// scan; plus one queue op per actual spike.
pub fn raw_input_cost(plane: &SpikePlane, e: &EnergyParams) -> InputCost {
    let cells = plane.len() as u64;
    let rows = cells.div_ceil(16);
    let events = plane.count_spikes();
    let energy = rows as f64 * (e.e_ifmem_read + e.e_il_write + e.e_detect_row)
        + events as f64 * e.e_queue_op;
    InputCost {
        bits: cells,
        energy_pj: energy,
        cycles: rows + events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::SplitMix64;

    fn plane_with_density(c: usize, h: usize, w: usize, d: f64, seed: u64) -> SpikePlane {
        let mut rng = SplitMix64::new(seed);
        let mut p = SpikePlane::zeros(c, h, w);
        for i in 0..p.len() {
            if rng.chance(d) {
                p.as_mut_slice()[i] = 1;
            }
        }
        p
    }

    #[test]
    fn aer_scales_with_events_raw_with_size() {
        let e = EnergyParams::default();
        let sparse = plane_with_density(2, 128, 128, 0.01, 1);
        let dense = plane_with_density(2, 128, 128, 0.30, 1);
        let a_s = aer_input_cost(&sparse, &e);
        let a_d = aer_input_cost(&dense, &e);
        assert!(a_d.bits > 10 * a_s.bits);
        let r_s = raw_input_cost(&sparse, &e);
        let r_d = raw_input_cost(&dense, &e);
        assert_eq!(r_s.bits, r_d.bits); // raw storage is size-fixed
    }

    #[test]
    fn crossover_near_papers_94_7_percent() {
        // The Fig.-4 example layer: 2x128x128 input -> 15-bit address
        // + 4-bit overhead = 19 bits/event -> bit crossover at
        // density 1/19 ≈ 5.26 % i.e. sparsity ≈ 94.7 %.
        let e = EnergyParams::default();
        let at = |d: f64| {
            let p = plane_with_density(2, 128, 128, d, 9);
            let a = aer_input_cost(&p, &e);
            let r = raw_input_cost(&p, &e);
            (a.bits, r.bits)
        };
        let (a_hi, r_hi) = at(0.03); // sparsity 97 % -> AER smaller
        assert!(a_hi < r_hi);
        let (a_lo, r_lo) = at(0.08); // sparsity 92 % -> AER bigger
        assert!(a_lo > r_lo);
    }

    #[test]
    fn empty_plane_costs() {
        let e = EnergyParams::default();
        let p = SpikePlane::zeros(1, 16, 16);
        let a = aer_input_cost(&p, &e);
        assert_eq!(a.bits, 0);
        assert_eq!(a.cycles, 0);
        let r = raw_input_cost(&p, &e);
        assert!(r.bits > 0); // bitmap always stored
    }
}
