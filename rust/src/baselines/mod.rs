//! Baseline input-handling and processing schemes the paper compares
//! against: AER event-driven input (Fig. 4) and dense, non-zero-
//! skipping execution (the sparsity ablation).

pub mod aer_pipeline;
pub mod dense;

pub use aer_pipeline::{aer_input_cost, raw_input_cost, InputCost};
pub use dense::dense_layer_stats;
