//! # SpiDR — Reconfigurable Digital Compute-in-Memory SNN Accelerator
//!
//! A full-system reproduction of *"SpiDR: A Reconfigurable Digital
//! Compute-in-Memory Spiking Neural Network Accelerator for Event-based
//! Perception"* (Sharma et al., 2024).
//!
//! The fabricated 65 nm chip is substituted by a cycle-level,
//! energy-accounted simulator (see `DESIGN.md §2`); the functional SNN
//! compute is AOT-compiled from JAX/Pallas to HLO-text artifacts and
//! executed through the PJRT C API as a *golden model* that the
//! simulator matches bit-for-bit.
//!
//! Module map (bottom-up):
//!
//! * [`prop`] — in-repo property-testing harness (splitmix64 PRNG,
//!   generators, shrinking) used across the test suite.
//! * [`quant`] — the fixed-point arithmetic contract (4/7, 6/11,
//!   8/15-bit precision pairs, two's-complement wrap).
//! * [`snn`] — tensors, layers, Table-II networks, weight bundles.
//! * [`dvs`] — synthetic event-camera workloads + AER codec.
//! * [`energy`] — per-operation energy model, voltage/frequency
//!   corners, technology scaling.
//! * [`sim`] — the cycle-level SpiDR core: CIM macros, IFspad, S2A,
//!   input loader, compute/neuron units, reconfigurable modes,
//!   timestep pipelining.
//! * [`baselines`] — AER event-driven pipeline and dense (no
//!   zero-skipping) baselines for the paper's comparisons.
//! * [`coordinator`] — layer mapper, network compiler, multi-core
//!   scheduler, streaming inference server and the sharded serving
//!   pool (the L3 request path; DESIGN.md §Serve).
//! * [`net`] — distributed shard serving: layer groups on remote
//!   hosts behind a binary wire protocol, TCP and loopback transports,
//!   the shard host and the distributed engine (DESIGN.md
//!   §Distributed).
//! * [`obs`] — end-to-end observability: cross-process clip tracing
//!   (Chrome `trace_event` export), O(1) latency histograms, and the
//!   live metrics registry + Prometheus scrape endpoint (DESIGN.md
//!   §Observability).
//! * [`runtime`] — PJRT client that loads and executes the AOT HLO
//!   artifacts (the golden model; Python never runs at request time).
//! * [`sync`] — the crate-wide synchronization facade: plain `std`
//!   re-exports in release builds, the deterministic model checker's
//!   shims under `--cfg spidr_model` (DESIGN.md §Correctness).
//! * `check` (`--cfg spidr_model` only) — the loom-style bounded
//!   model checker: DFS over scheduling decisions with a preemption
//!   bound and state-hash pruning, driven by `tests/model.rs`.
//! * [`lint`] — the repo-invariant source lint behind `spidr lint`
//!   (facade discipline, timestamp audit, total decoding, bench emit
//!   gate).

#![warn(missing_docs)]
#![forbid(unsafe_code)]

pub mod baselines;
#[cfg(spidr_model)]
pub mod check;
pub mod coordinator;
pub mod dvs;
pub mod energy;
pub mod error;
pub mod lint;
pub mod net;
pub mod obs;
pub mod prop;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod snn;
pub mod sync;

pub use error::{Error, Result};
