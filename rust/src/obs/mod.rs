//! End-to-end observability: cross-process clip tracing, O(1)
//! latency histograms, and live metrics export (DESIGN.md
//! §Observability).
//!
//! Zero-dependency, three layers:
//!
//! * [`trace`] — clip/batch-scoped [`TraceId`](trace::TraceId)s
//!   minted at ingest and threaded through dispatch → pool worker /
//!   pipeline stage → distributed hop → wire → drain → emit; spans
//!   land in bounded per-thread ring buffers and export as Chrome
//!   `trace_event` JSON (Perfetto-loadable), with shard-process
//!   spans joined onto the coordinator timeline via wire propagation
//!   and a session-start clock-offset estimate.
//! * [`hist`] — log-bucketed, mergeable latency histograms with O(1)
//!   memory and a documented 1/16 relative error bound; the storage
//!   behind `Metrics::percentile_us`.
//! * [`metrics`] + [`export`] — a process-wide named-series registry
//!   ([`metrics::MetricsHub`]) readable mid-run, rendered as
//!   Prometheus text and served by a TCP scrape endpoint
//!   (`spidr metrics`, `--metrics-listen`).
//!
//! The discipline throughout: **observability must never tax the
//! fast path it observes**. A disabled tracer takes zero timestamps
//! (audited by [`trace::Tracer::stamps`], benched in
//! `benches/obs_overhead.rs`), and the histograms cost one array
//! increment per sample.

pub mod export;
pub mod hist;
pub mod metrics;
pub mod trace;

pub use export::{scrape, MetricsServer};
pub use hist::LatencyHistogram;
pub use metrics::{hub, MetricsHub, MetricsSnapshot};
pub use trace::{tracer, TraceId, Tracer, WireSpan};
