//! Live metrics registry: named counters, gauges and mergeable
//! latency histograms with a thread-safe snapshot and Prometheus text
//! rendering (DESIGN.md §Observability).
//!
//! [`MetricsHub`] is the bridge from the end-of-run structs
//! (`coordinator::Metrics`, `StageMetrics`, `WorkerMetrics`) to a
//! **mid-run** view: serving tiers feed it per clip as responses
//! emit, and [`MetricsHub::snapshot`] can be read at any moment from
//! any thread — the direct prerequisite for SLO-driven autoscaling
//! (ROADMAP), and what the `spidr metrics` scrape endpoint
//! ([`super::export`]) serves.
//!
//! Series names follow Prometheus conventions (`spidr_*_total` for
//! counters, `_us`/`_seconds` units suffixes); a name may embed a
//! label set verbatim, e.g. `spidr_stage_steps_total{stage="2"}`.

use crate::sync::Mutex;
use std::collections::BTreeMap;

use super::hist::LatencyHistogram;

/// Process-wide metrics registry. Cheap to feed (one uncontended
/// mutex lock per update) and safe to snapshot mid-run.
pub struct MetricsHub {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, LatencyHistogram>,
}

static HUB: MetricsHub = MetricsHub {
    inner: Mutex::new(Inner {
        counters: BTreeMap::new(),
        gauges: BTreeMap::new(),
        hists: BTreeMap::new(),
    }),
};

/// The process-wide hub fed by the serving tiers.
pub fn hub() -> &'static MetricsHub {
    &HUB
}

impl MetricsHub {
    /// A fresh, private hub (tests; the serving tiers use [`hub`]).
    pub fn new() -> Self {
        MetricsHub {
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Add `v` to counter `name` (created at 0).
    pub fn counter_add(&self, name: &str, v: u64) {
        let mut inner = self.inner.lock().unwrap();
        match inner.counters.get_mut(name) {
            Some(c) => *c += v,
            None => {
                inner.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Set gauge `name` to `v`.
    pub fn gauge_set(&self, name: &str, v: f64) {
        self.inner
            .lock()
            .unwrap()
            .gauges
            .insert(name.to_string(), v);
    }

    /// Record one sample (µs) into histogram `name`.
    pub fn observe_us(&self, name: &str, v: u64) {
        let mut inner = self.inner.lock().unwrap();
        match inner.hists.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = LatencyHistogram::new();
                h.record(v);
                inner.hists.insert(name.to_string(), h);
            }
        }
    }

    /// Merge a whole histogram into series `name` (the per-worker /
    /// per-engine roll-up path).
    pub fn merge_hist(&self, name: &str, h: &LatencyHistogram) {
        let mut inner = self.inner.lock().unwrap();
        match inner.hists.get_mut(name) {
            Some(existing) => existing.merge(h),
            None => {
                inner.hists.insert(name.to_string(), h.clone());
            }
        }
    }

    /// A consistent copy of every series, readable mid-run.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: inner.counters.clone(),
            gauges: inner.gauges.clone(),
            hists: inner.hists.clone(),
        }
    }

    /// Drop every series (tests / between runs).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.counters.clear();
        inner.gauges.clear();
        inner.hists.clear();
    }

    /// Render the current state as Prometheus text exposition format
    /// (shorthand for `snapshot().render_prometheus()`).
    pub fn render_prometheus(&self) -> String {
        self.snapshot().render_prometheus()
    }
}

impl Default for MetricsHub {
    fn default() -> Self {
        Self::new()
    }
}

/// A point-in-time copy of the hub ([`MetricsHub::snapshot`]).
#[derive(Clone)]
pub struct MetricsSnapshot {
    /// Monotonic counters by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauges by name.
    pub gauges: BTreeMap<String, f64>,
    /// Latency histograms by name.
    pub hists: BTreeMap<String, LatencyHistogram>,
}

/// The base series name: the part before any embedded `{label}` set.
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

impl MetricsSnapshot {
    /// Counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Histogram for `name`, if any samples were recorded.
    pub fn histogram(&self, name: &str) -> Option<&LatencyHistogram> {
        self.hists.get(name)
    }

    /// Render as Prometheus text exposition format (version 0.0.4):
    /// `# TYPE` headers per base series, counter/gauge sample lines,
    /// and for each histogram the cumulative `_bucket{le="..."}`
    /// series over power-of-two boundaries plus `_sum`/`_count`
    /// (DESIGN.md §Observability documents the line grammar).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut typed: Option<String> = None;
        let mut type_line = |out: &mut String, typed: &mut Option<String>, base: &str, t: &str| {
            if typed.as_deref() != Some(base) {
                out.push_str(&format!("# TYPE {base} {t}\n"));
                *typed = Some(base.to_string());
            }
        };
        for (name, v) in &self.counters {
            type_line(&mut out, &mut typed, base_name(name), "counter");
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            type_line(&mut out, &mut typed, base_name(name), "gauge");
            if v.is_finite() {
                out.push_str(&format!("{name} {v}\n"));
            } else {
                out.push_str(&format!("{name} 0\n"));
            }
        }
        for (name, h) in &self.hists {
            let base = base_name(name);
            type_line(&mut out, &mut typed, base, "histogram");
            for (le, cum) in h.octave_buckets() {
                out.push_str(&format!("{base}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{base}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("{base}_sum {}\n", h.sum()));
            out.push_str(&format!("{base}_count {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_round_trip_through_snapshot() {
        let hub = MetricsHub::new();
        hub.counter_add("spidr_clips_total", 3);
        hub.counter_add("spidr_clips_total", 2);
        hub.gauge_set("spidr_pool_utilization", 0.75);
        for v in [100u64, 200, 300, 400] {
            hub.observe_us("spidr_clip_latency_us", v);
        }
        let snap = hub.snapshot();
        assert_eq!(snap.counter("spidr_clips_total"), 5);
        assert_eq!(snap.gauges["spidr_pool_utilization"], 0.75);
        let h = snap.histogram("spidr_clip_latency_us").unwrap();
        assert_eq!(h.count(), 4);
        assert_eq!(h.percentile(100.0), 400);
    }

    #[test]
    fn prometheus_rendering_is_well_formed() {
        let hub = MetricsHub::new();
        hub.counter_add("spidr_frames_total", 7);
        hub.counter_add("spidr_stage_steps_total{stage=\"0\"}", 12);
        hub.gauge_set("spidr_wall_seconds", 1.5);
        hub.observe_us("spidr_clip_latency_us", 900);
        hub.observe_us("spidr_clip_latency_us", 90_000);
        let text = hub.render_prometheus();
        assert!(text.contains("# TYPE spidr_frames_total counter\n"));
        assert!(text.contains("spidr_frames_total 7\n"));
        assert!(text.contains("spidr_stage_steps_total{stage=\"0\"} 12\n"));
        assert!(text.contains("# TYPE spidr_clip_latency_us histogram\n"));
        assert!(text.contains("spidr_clip_latency_us_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("spidr_clip_latency_us_count 2\n"));
        assert!(text.contains("spidr_clip_latency_us_sum 90900\n"));
        // buckets are cumulative and monotone
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("spidr_clip_latency_us_bucket{le=\"") {
                let count: u64 = rest.split("} ").nth(1).unwrap().parse().unwrap();
                assert!(count >= last, "non-monotone cumulative bucket: {line}");
                last = count;
            }
        }
        // every non-comment line is `name[{labels}] value`
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparsable value: {line}");
        }
    }

    #[test]
    fn merge_hist_rolls_up() {
        let hub = MetricsHub::new();
        let mut h = LatencyHistogram::new();
        h.record(10);
        h.record(20);
        hub.merge_hist("lat", &h);
        hub.merge_hist("lat", &h);
        assert_eq!(hub.snapshot().histogram("lat").unwrap().count(), 4);
    }
}
