//! Live metrics export: a minimal TCP scrape endpoint serving the
//! [`MetricsHub`](super::metrics::MetricsHub) as Prometheus text
//! (DESIGN.md §Observability).
//!
//! The endpoint speaks just enough HTTP/1.0 for `curl`, a Prometheus
//! scraper, or `spidr metrics --connect` to read it: any connection
//! gets a `200 OK` with `Content-Type: text/plain; version=0.0.4`
//! and the rendered snapshot, then the socket closes. It listens on
//! the same TCP stack as the shard wire protocol
//! ([`net::transport`](crate::net::transport)) but deliberately
//! stays plain text rather than binary frames — scrape tooling is
//! text-first.

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::Arc;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use crate::error::Result;

use super::metrics::MetricsHub;

/// A running metrics scrape endpoint (accept loop on its own thread).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<crate::sync::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `hub` snapshots until dropped or [`MetricsServer::stop`].
    pub fn spawn(listen: &str, hub: &'static MetricsHub) -> Result<MetricsServer> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = crate::sync::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop_flag.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                // One scrape per connection; errors only drop that
                // scrape, never the endpoint.
                let _ = serve_one(stream, hub);
            }
        });
        Ok(MetricsServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (for ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the server thread.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Answer one scrape: drain the request head (bounded, with a read
/// timeout so a stalled client cannot wedge the endpoint), then write
/// the snapshot.
fn serve_one(mut stream: TcpStream, hub: &MetricsHub) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut head = [0u8; 1024];
    // Best-effort: a bare TCP client may send nothing at all.
    let _ = stream.read(&mut head);
    let body = hub.render_prometheus();
    let response = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Scrape a metrics endpoint and return the Prometheus text body
/// (the `spidr metrics` client).
pub fn scrape(addr: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.write_all(b"GET /metrics HTTP/1.0\r\nConnection: close\r\n\r\n")?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    // Strip the response head if present (a raw-text server may omit it).
    match raw.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Ok(raw),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::hub;

    #[test]
    fn scrape_round_trips_prometheus_text() {
        hub().counter_add("spidr_export_test_total", 41);
        let mut server = MetricsServer::spawn("127.0.0.1:0", hub()).unwrap();
        let addr = server.local_addr().to_string();
        let body = scrape(&addr).unwrap();
        assert!(
            body.contains("spidr_export_test_total"),
            "scraped body missing series:\n{body}"
        );
        // A second scrape still works (one connection each).
        hub().counter_add("spidr_export_test_total", 1);
        let body2 = scrape(&addr).unwrap();
        assert!(body2.contains("spidr_export_test_total"));
        server.stop();
    }
}
