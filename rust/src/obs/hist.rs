//! Log-bucketed latency histogram: O(1) memory, mergeable, bounded
//! relative error (DESIGN.md §Observability).
//!
//! The serving tier used to buffer **every** per-clip latency in an
//! unbounded `Vec<u64>` and clone+sort it on every percentile query —
//! the measurement layer itself could not survive a sensor-scale
//! stream. [`LatencyHistogram`] replaces that with a fixed array of
//! bucket counters (HdrHistogram-style linear-within-octave layout):
//!
//! * values below [`LINEAR_MAX`] (4096 µs) get **one bucket each** —
//!   sub-4 ms latencies, the regime every existing percentile test
//!   pins, are reported *exactly*;
//! * above that, each power-of-two octave is split into
//!   [`SUB_BUCKETS`] (16) equal-width buckets, so a reported
//!   percentile is the bucket's lower bound and the true value `v`
//!   satisfies `bucket ≤ v ≤ bucket + bucket/16` — a relative error
//!   of at most **1/16 (6.25 %)**, typically half that.
//!
//! Memory is a compile-time constant ([`BUCKET_COUNT`] `u64`
//! counters ≈ 39 KiB) regardless of how many samples are recorded,
//! and two histograms merge by element-wise addition — the property
//! that lets per-worker and per-process histograms roll up into one
//! fleet-wide view ([`MetricsHub`](super::metrics::MetricsHub)).

/// Values below this (in µs) are counted exactly, one bucket per value.
pub const LINEAR_MAX: u64 = 4096;

/// Sub-buckets per power-of-two octave above [`LINEAR_MAX`].
pub const SUB_BUCKETS: usize = 16;

/// log2 of [`LINEAR_MAX`].
const LINEAR_BITS: u32 = 12;

/// Octaves above the linear region (covers values up to `u64::MAX`).
const OCTAVES: usize = (64 - LINEAR_BITS as usize) + 1;

/// Total bucket count (the histogram's fixed memory footprint).
pub const BUCKET_COUNT: usize = LINEAR_MAX as usize + OCTAVES * SUB_BUCKETS;

/// Fixed-memory, mergeable latency histogram over `u64` microsecond
/// samples. See the module docs for the bucket layout and error bound.
#[derive(Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("min", &self.min)
            .field("max", &self.max)
            .finish()
    }
}

/// Bucket index for a value (monotone in `v`).
fn bucket_of(v: u64) -> usize {
    if v < LINEAR_MAX {
        v as usize
    } else {
        let lz = 63 - v.leading_zeros(); // floor(log2 v) >= LINEAR_BITS
        let octave = (lz - LINEAR_BITS) as usize;
        let frac = ((v >> (lz - 4)) & 0xF) as usize; // top 4 bits below the leading one
        LINEAR_MAX as usize + octave * SUB_BUCKETS + frac
    }
}

/// Lower bound (the reported representative) of a bucket.
fn value_of(bucket: usize) -> u64 {
    if bucket < LINEAR_MAX as usize {
        bucket as u64
    } else {
        let rel = bucket - LINEAR_MAX as usize;
        let octave = (rel / SUB_BUCKETS) as u32;
        let frac = (rel % SUB_BUCKETS) as u64;
        // leading one at LINEAR_BITS + octave; 16 + frac is the 5-bit
        // significand, shifted back into place.
        (SUB_BUCKETS as u64 + frac) << (LINEAR_BITS + octave - 4)
    }
}

impl LatencyHistogram {
    /// An empty histogram (allocates the fixed bucket array once).
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; BUCKET_COUNT],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one sample (µs). O(1), no allocation.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (µs; saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample (exact, not bucketed).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact arithmetic mean of the recorded samples (0 when empty) —
    /// tracked outside the buckets, so the mean carries no bucket
    /// error.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The `p`-th percentile (0–100) of the recorded samples: the
    /// bucket lower bound of the sample at rank
    /// `round(p/100 · (count-1))` — the same rank the old
    /// clone-and-sort implementation selected, so sub-[`LINEAR_MAX`]
    /// values are bit-identical to it and larger values are within
    /// the 1/16 bucket error bound. Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return value_of(b);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (element-wise; the
    /// roll-up operation for per-worker / per-process views).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Cumulative count of samples ≤ `bound` — the Prometheus
    /// `_bucket{le="..."}` primitive. Because bucketing is monotone,
    /// this is exact whenever `bound` is a bucket boundary (all
    /// powers of two are).
    pub fn cumulative_le(&self, bound: u64) -> u64 {
        let cut = if bound == u64::MAX {
            BUCKET_COUNT
        } else {
            bucket_of(bound + 1)
        };
        self.counts[..cut.min(BUCKET_COUNT)].iter().sum()
    }

    /// Power-of-two `le` boundaries spanning the recorded range, for
    /// Prometheus histogram rendering: `(le, cumulative_count)` pairs,
    /// ending at the first boundary covering `max()`.
    pub fn octave_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        if self.count == 0 {
            return out;
        }
        let mut le = 1u64;
        loop {
            out.push((le, self.cumulative_le(le)));
            if le >= self.max || le >= (1u64 << 62) {
                break;
            }
            le <<= 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn bucket_layout_is_monotone_and_self_consistent() {
        // Every bucket's lower bound maps back to that bucket, and
        // bucket indices are monotone over a sweep of magnitudes.
        for b in 0..BUCKET_COUNT - SUB_BUCKETS {
            let v = value_of(b);
            assert_eq!(bucket_of(v), b, "value_of({b}) = {v} round-trips");
        }
        let mut last = 0usize;
        let mut v = 0u64;
        while v < 1 << 40 {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of not monotone at {v}");
            last = b;
            v = v * 2 + 1;
        }
    }

    #[test]
    fn exact_below_linear_max() {
        let mut h = LatencyHistogram::new();
        for v in [100u64, 300] {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), 100);
        assert_eq!(h.percentile(100.0), 300);
        assert_eq!(h.percentile(50.0), 300); // rank round(0.5*1)=1
        assert_eq!(h.mean(), 200.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert!(h.octave_buckets().is_empty());
    }

    /// Property (satellite: histogram swap): percentiles match the
    /// exact clone-and-sort reference bit-for-bit below `LINEAR_MAX`
    /// and within the documented 1/16 bucket bound above it.
    #[test]
    fn prop_percentiles_within_bucket_error_of_sorted_reference() {
        prop::check("hist_vs_sorted_reference", 60, |g| {
            let n = g.u64_in(1..=200) as usize;
            let big = g.u64_in(0..=1) == 1;
            let mut vals: Vec<u64> = (0..n)
                .map(|_| {
                    if big {
                        g.u64_in(0..=50_000_000)
                    } else {
                        g.u64_in(0..=4000)
                    }
                })
                .collect();
            let mut h = LatencyHistogram::new();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_unstable();
            for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
                let rank = ((p / 100.0) * (n - 1) as f64).round() as usize;
                let exact = vals[rank];
                let got = h.percentile(p);
                if exact < LINEAR_MAX {
                    if got != exact {
                        return false;
                    }
                } else if got > exact || exact > got + got / SUB_BUCKETS as u64 {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn merge_matches_recording_everything_into_one() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut both = LatencyHistogram::new();
        for v in [5u64, 80, 4096, 100_000, 7] {
            a.record(v);
            both.record(v);
        }
        for v in [9u64, 5_000_000, 4095] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum(), both.sum());
        assert_eq!(a.max(), both.max());
        for p in [0.0, 25.0, 50.0, 75.0, 100.0] {
            assert_eq!(a.percentile(p), both.percentile(p));
        }
    }

    #[test]
    fn cumulative_le_counts_power_of_two_boundaries_exactly() {
        let mut h = LatencyHistogram::new();
        for v in [1u64, 2, 3, 4, 1000, 5000, 70_000] {
            h.record(v);
        }
        assert_eq!(h.cumulative_le(1), 1);
        assert_eq!(h.cumulative_le(2), 2);
        assert_eq!(h.cumulative_le(4), 4);
        assert_eq!(h.cumulative_le(1024), 5);
        assert_eq!(h.cumulative_le(8192), 6);
        assert_eq!(h.cumulative_le(u64::MAX), 7);
        let buckets = h.octave_buckets();
        assert_eq!(buckets.last().unwrap().1, 7, "{buckets:?}");
    }
}
