//! Structured clip tracing: bounded per-thread span rings, sampling,
//! and Chrome `trace_event` export (DESIGN.md §Observability).
//!
//! A [`TraceId`] is minted once per clip (or per lane batch) at
//! ingest and threaded through every tier the clip crosses — pool
//! dispatch, worker inference, pipeline stages, distributed hops,
//! the wire, drain and reorder/emit. Each tier opens a [`SpanGuard`]
//! around its work; finished spans land in a **bounded per-thread
//! ring buffer** (overwrite-oldest), so tracing memory is O(threads ×
//! ring capacity) no matter how long the stream runs.
//!
//! The fast-path discipline mirrors PR-8's `stall_samples`: a
//! **disabled tracer takes zero timestamps** — [`Tracer::span`] is
//! one relaxed atomic load and returns an inert guard; only a
//! sampled span pays the two `Instant` reads. [`Tracer::stamps`]
//! counts every timestamp taken, so the discipline is testable, not
//! aspirational.
//!
//! Export is Chrome `trace_event` JSON (`{"traceEvents":[...]}`),
//! loadable in Perfetto / `chrome://tracing`: complete (`"X"`) spans,
//! instant (`"i"`) events (e.g. `failover`), and `process_name`
//! metadata. Spans from **other processes** (shard hosts) arrive as
//! [`WireSpan`]s over the wire protocol and are injected with a
//! clock-offset correction estimated at session start
//! ([`Tracer::inject`]), so one file shows the coordinator and every
//! shard on a single aligned timeline.

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::{Arc, Mutex, OnceLock};
use std::cell::{Cell, RefCell};
use std::time::Instant;

/// Default per-thread ring capacity (spans kept per thread).
pub const DEFAULT_RING_CAPACITY: usize = 8192;

/// Cap on injected / explicitly recorded events held by the tracer.
const EXTRA_CAPACITY: usize = 1 << 20;

/// A clip- or batch-scoped trace identity, minted at ingest
/// ([`Tracer::mint`]) and carried with the clip through every tier
/// (and across the wire to shard processes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// The "not traced" sentinel carried by untraced contexts.
    pub const NONE: TraceId = TraceId(0);
}

/// How a recorded event renders in the Chrome trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// A duration span (`ph:"X"`).
    Span,
    /// A zero-duration instant event (`ph:"i"`), e.g. a failover.
    Instant,
}

/// A span name: `&'static str` on the hot local path (no allocation
/// per span), owned for spans that crossed the wire.
#[derive(Debug, Clone)]
pub enum SpanName {
    /// A compile-time name from local instrumentation.
    Static(&'static str),
    /// An owned name (injected from another process).
    Owned(String),
}

impl SpanName {
    /// The name as a string slice.
    pub fn as_str(&self) -> &str {
        match self {
            SpanName::Static(s) => s,
            SpanName::Owned(s) => s,
        }
    }
}

/// One finished trace event, as held in the rings and returned by
/// [`Tracer::snapshot_events`].
#[derive(Debug, Clone)]
pub struct SpanEvent {
    /// The trace this event belongs to (0 = untraced context).
    pub trace: u64,
    /// Event name.
    pub name: SpanName,
    /// Start, µs since the local process epoch.
    pub start_us: u64,
    /// Duration in µs (0 for instants).
    pub dur_us: u64,
    /// Span or instant.
    pub kind: SpanKind,
    /// Recording thread (tracer-assigned ordinal, stable per thread).
    pub tid: u64,
    /// Originating process label; `None` = this process.
    pub pid: Option<String>,
}

/// A span as serialized over the wire protocol from a shard process
/// (encoded/decoded by `net::wire`): times are in the **shard's**
/// clock; [`Tracer::inject`] shifts them onto the local timeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireSpan {
    /// Coordinator-minted trace id (propagated via trace context).
    pub trace: u64,
    /// Span name.
    pub name: String,
    /// Start, µs since the shard's process epoch.
    pub start_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Renders as an instant event instead of a duration span.
    pub instant: bool,
    /// Shard-local thread ordinal.
    pub tid: u64,
}

/// Bounded overwrite-oldest span storage for one thread.
struct RingBuf {
    events: Vec<SpanEvent>,
    cap: usize,
    /// Write cursor once full.
    next: usize,
    /// Total events ever pushed (pushed - len = overwritten).
    pushed: u64,
}

impl RingBuf {
    fn push(&mut self, e: SpanEvent) -> bool {
        self.pushed += 1;
        if self.events.len() < self.cap {
            self.events.push(e);
            false
        } else {
            self.events[self.next] = e;
            self.next = (self.next + 1) % self.cap;
            true
        }
    }

    /// Events oldest-first.
    fn drain_ordered(&mut self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.events.len());
        out.extend_from_slice(&self.events[self.next..]);
        out.extend_from_slice(&self.events[..self.next]);
        self.events.clear();
        self.next = 0;
        out
    }
}

struct ThreadRing {
    tid: u64,
    buf: Mutex<RingBuf>,
}

/// The process-wide tracer. One static instance ([`tracer`]) serves
/// every tier; instrumentation is always compiled in and gated by the
/// `enabled` flag (one relaxed load on the disabled fast path).
pub struct Tracer {
    enabled: AtomicBool,
    /// Record spans only for traces with `id % sample_every == 0`.
    sample_every: AtomicU64,
    next_id: AtomicU64,
    next_tid: AtomicU64,
    ring_cap: AtomicUsize,
    /// Timestamps taken (`Instant` reads) — the fast-path audit
    /// counter: a disabled tracer must never advance it.
    stamps: AtomicU64,
    /// Events overwritten in rings or refused by the extra buffer.
    dropped: AtomicU64,
    epoch: OnceLock<Instant>,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    /// Injected foreign-process events.
    extra: Mutex<Vec<SpanEvent>>,
    /// `process_name` label for local events in the export.
    label: Mutex<String>,
}

static TRACER: Tracer = Tracer {
    enabled: AtomicBool::new(false),
    sample_every: AtomicU64::new(1),
    next_id: AtomicU64::new(1),
    next_tid: AtomicU64::new(1),
    ring_cap: AtomicUsize::new(DEFAULT_RING_CAPACITY),
    stamps: AtomicU64::new(0),
    dropped: AtomicU64::new(0),
    epoch: OnceLock::new(),
    rings: Mutex::new(Vec::new()),
    extra: Mutex::new(Vec::new()),
    label: Mutex::new(String::new()),
};

/// The process-wide tracer instance.
pub fn tracer() -> &'static Tracer {
    &TRACER
}

thread_local! {
    /// This thread's ring (registered on first span).
    static RING: RefCell<Option<Arc<ThreadRing>>> = const { RefCell::new(None) };
    /// The trace id of the clip this thread is currently serving.
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

/// The trace id bound to the current thread ([`TraceId::NONE`] when
/// outside any traced clip).
pub fn current() -> TraceId {
    TraceId(CURRENT.with(|c| c.get()))
}

/// Bind `t` as the current thread's trace, restoring the previous
/// binding when the returned scope drops. Worker/stage/hop threads
/// call this on picking up a clip, so nested instrumentation (and
/// instants like `failover`) attribute to the right trace without
/// threading ids through every signature.
pub fn bind(t: TraceId) -> TraceScope {
    let prev = CURRENT.with(|c| c.replace(t.0));
    TraceScope { prev }
}

/// RAII restore for [`bind`].
pub struct TraceScope {
    prev: u64,
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

/// A span in flight: records its duration and lands in the thread's
/// ring when dropped. Inert (zero timestamps) when the tracer is
/// disabled or the trace unsampled.
#[must_use = "a span measures the scope it is alive for"]
pub struct SpanGuard {
    /// `None` = inert.
    start_us: Option<u64>,
    trace: u64,
    name: &'static str,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start_us {
            let end = TRACER.now_us();
            TRACER.push_local(SpanEvent {
                trace: self.trace,
                name: SpanName::Static(self.name),
                start_us: start,
                dur_us: end.saturating_sub(start),
                kind: SpanKind::Span,
                tid: 0, // assigned at push
                pid: None,
            });
        }
    }
}

impl Tracer {
    /// Enable tracing, recording every `sample_every`-th trace
    /// (1 = all; 0 is treated as 1).
    pub fn enable(&self, sample_every: u64) {
        self.sample_every
            .store(sample_every.max(1), Ordering::Relaxed);
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Disable tracing (spans already recorded stay exportable).
    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    /// Whether the tracer is currently recording.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Set the `process_name` label used for local events in the
    /// Chrome export (e.g. `"coordinator"`, `"shard:7401"`).
    pub fn set_process_label(&self, label: &str) {
        *self.label.lock().unwrap() = label.to_string();
    }

    /// Ring capacity for threads that register from now on.
    pub fn set_ring_capacity(&self, cap: usize) {
        self.ring_cap.store(cap.max(16), Ordering::Relaxed);
    }

    /// Mint a fresh trace id (one atomic increment; valid — and
    /// cheap — whether or not tracing is enabled).
    pub fn mint(&self) -> TraceId {
        TraceId(self.next_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Whether spans for `t` should be recorded right now. This is
    /// the whole disabled fast path: one relaxed load, no timestamps.
    #[inline]
    pub fn should_sample(&self, t: TraceId) -> bool {
        self.enabled.load(Ordering::Relaxed)
            && t.0 % self.sample_every.load(Ordering::Relaxed) == 0
    }

    /// µs since the process epoch. Every call is counted in
    /// [`Tracer::stamps`] — the timestamp audit.
    pub fn now_us(&self) -> u64 {
        self.stamps.fetch_add(1, Ordering::Relaxed);
        let epoch = self.epoch.get_or_init(Instant::now);
        epoch.elapsed().as_micros() as u64
    }

    /// Timestamps taken so far (the fast-path audit counter).
    pub fn stamps(&self) -> u64 {
        self.stamps.load(Ordering::Relaxed)
    }

    /// Events lost to ring overwrite or the injection cap.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Open a span for trace `t`. Inert unless `t` is sampled.
    #[inline]
    pub fn span(&self, t: TraceId, name: &'static str) -> SpanGuard {
        let start_us = if self.should_sample(t) {
            Some(self.now_us())
        } else {
            None
        };
        SpanGuard {
            start_us,
            trace: t.0,
            name,
        }
    }

    /// Record an instant event (e.g. `failover`) for trace `t`.
    pub fn instant(&self, t: TraceId, name: &'static str) {
        if !self.should_sample(t) {
            return;
        }
        let now = self.now_us();
        self.push_local(SpanEvent {
            trace: t.0,
            name: SpanName::Static(name),
            start_us: now,
            dur_us: 0,
            kind: SpanKind::Instant,
            tid: 0,
            pid: None,
        });
    }

    /// Record a span with explicit endpoints (µs since the process
    /// epoch) — used for the root `clip` span, whose start (ingest)
    /// and end (emit) are observed on different threads.
    pub fn record_span(&self, t: TraceId, name: &'static str, start_us: u64, end_us: u64) {
        if !self.should_sample(t) {
            return;
        }
        self.push_local(SpanEvent {
            trace: t.0,
            name: SpanName::Static(name),
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            kind: SpanKind::Span,
            tid: 0,
            pid: None,
        });
    }

    /// Push onto the calling thread's ring, registering the thread on
    /// first use.
    fn push_local(&self, mut e: SpanEvent) {
        RING.with(|slot| {
            let mut slot = slot.borrow_mut();
            let ring = slot.get_or_insert_with(|| {
                let ring = Arc::new(ThreadRing {
                    tid: self.next_tid.fetch_add(1, Ordering::Relaxed),
                    buf: Mutex::new(RingBuf {
                        events: Vec::new(),
                        cap: self.ring_cap.load(Ordering::Relaxed),
                        next: 0,
                        pushed: 0,
                    }),
                });
                self.rings.lock().unwrap().push(Arc::clone(&ring));
                ring
            });
            e.tid = ring.tid;
            if ring.buf.lock().unwrap().push(e) {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        });
    }

    /// Inject spans recorded by another process (label `pid`), shifting
    /// their timestamps by `-offset_us` onto the local timeline
    /// (`offset_us` = remote clock minus local clock, as estimated by
    /// the session's trace-sync exchange).
    pub fn inject(&self, pid: &str, spans: Vec<WireSpan>, offset_us: i64) {
        let mut extra = self.extra.lock().unwrap();
        for ws in spans {
            if extra.len() >= EXTRA_CAPACITY {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let start = (ws.start_us as i64).saturating_sub(offset_us).max(0) as u64;
            extra.push(SpanEvent {
                trace: ws.trace,
                name: SpanName::Owned(ws.name),
                start_us: start,
                dur_us: ws.dur_us,
                kind: if ws.instant {
                    SpanKind::Instant
                } else {
                    SpanKind::Span
                },
                tid: ws.tid,
                pid: Some(pid.to_string()),
            });
        }
    }

    /// Copy out every recorded event (rings + injected), oldest-first
    /// per thread, without clearing anything.
    pub fn snapshot_events(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for ring in self.rings.lock().unwrap().iter() {
            let mut buf = ring.buf.lock().unwrap();
            let n = buf.events.len();
            let next = buf.next;
            out.extend_from_slice(&buf.events[next..n]);
            out.extend_from_slice(&buf.events[..next]);
        }
        out.extend(self.extra.lock().unwrap().iter().cloned());
        out
    }

    /// Drain every recorded event, clearing rings and the injected
    /// buffer (thread registrations survive).
    pub fn drain_events(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for ring in self.rings.lock().unwrap().iter() {
            out.extend(ring.buf.lock().unwrap().drain_ordered());
        }
        out.append(&mut self.extra.lock().unwrap());
        out
    }

    /// Clear all recorded events and the drop counter (for tests and
    /// between runs). Leaves enablement, sampling and registrations
    /// untouched.
    pub fn reset(&self) {
        let _ = self.drain_events();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// Render every recorded event as Chrome `trace_event` JSON
    /// (`{"traceEvents":[...]}`), loadable in Perfetto. Local events
    /// get `pid` 1 (labelled via `set_process_label`); each injected
    /// process label gets its own pid with a `process_name` metadata
    /// record.
    pub fn to_chrome_json(&self) -> String {
        let events = self.snapshot_events();
        let local_label = {
            let l = self.label.lock().unwrap();
            if l.is_empty() {
                "spidr".to_string()
            } else {
                l.clone()
            }
        };
        // Stable pid assignment: 1 = local, then first-seen order.
        fn pid_of(pids: &mut Vec<String>, label: &Option<String>) -> u64 {
            match label {
                None => 1,
                Some(l) => match pids.iter().position(|p| p == l) {
                    Some(i) => i as u64 + 2,
                    None => {
                        pids.push(l.clone());
                        pids.len() as u64 + 1
                    }
                },
            }
        }
        let mut pids: Vec<String> = Vec::new();
        let mut rows: Vec<String> = Vec::new();
        let mut body: Vec<(u64, String)> = Vec::new();
        for e in &events {
            let pid = pid_of(&mut pids, &e.pid);
            let name = json_escape(e.name.as_str());
            let row = match e.kind {
                SpanKind::Span => format!(
                    "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{ts},\
                     \"dur\":{dur},\"name\":\"{name}\",\"args\":{{\"trace\":{tr}}}}}",
                    tid = e.tid,
                    ts = e.start_us,
                    dur = e.dur_us,
                    tr = e.trace,
                ),
                SpanKind::Instant => format!(
                    "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{tid},\"ts\":{ts},\
                     \"s\":\"t\",\"name\":\"{name}\",\"args\":{{\"trace\":{tr}}}}}",
                    tid = e.tid,
                    ts = e.start_us,
                    tr = e.trace,
                ),
            };
            body.push((e.start_us, row));
        }
        body.sort_by_key(|(ts, _)| *ts);
        rows.push(format!(
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"name\":\"process_name\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            json_escape(&local_label)
        ));
        for (i, label) in pids.iter().enumerate() {
            rows.push(format!(
                "{{\"ph\":\"M\",\"pid\":{},\"tid\":0,\"name\":\"process_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                i as u64 + 2,
                json_escape(label)
            ));
        }
        rows.extend(body.into_iter().map(|(_, r)| r));
        format!("{{\"traceEvents\":[{}]}}", rows.join(","))
    }
}

/// Open a span on the calling thread's current trace ([`bind`]).
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    TRACER.span(current(), name)
}

/// Record an instant event on the calling thread's current trace.
pub fn instant(name: &'static str) {
    TRACER.instant(current(), name);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    /// Record a well-nested span tree on the calling thread: one
    /// guard per node, `fanout` children per level down to `depth`.
    fn record_tree(depth: usize, fanout: usize) {
        if depth == 0 {
            return;
        }
        const NAMES: [&str; 4] = ["stage", "hop", "infer", "drain"];
        let _s = span(NAMES[depth % NAMES.len()]);
        for _ in 0..fanout {
            record_tree(depth - 1, fanout);
        }
    }

    /// Interval containment with µs-tie tolerance (guards opened and
    /// closed within the same microsecond collapse to equal bounds).
    fn contains(outer: &SpanEvent, inner: &SpanEvent) -> bool {
        outer.start_us <= inner.start_us
            && inner.start_us + inner.dur_us <= outer.start_us + outer.dur_us
    }

    /// The global tracer is process-wide mutable state, so every
    /// phase lives in ONE sequential test — separate `#[test]`s would
    /// race each other's `enable`/`reset` across the parallel harness.
    /// Concurrent tests elsewhere in the binary may record spans under
    /// trace 0 while phase ≥2 has the tracer enabled; every assertion
    /// therefore filters by the trace ids minted here.
    #[test]
    fn tracer_lifecycle_audits_and_span_trees() {
        let tr = tracer();

        // Phase 1 — the disabled fast path takes ZERO timestamps and
        // records nothing, across guards, instants, explicit records
        // and worker threads (the `stamps` audit counter is bumped by
        // every `now_us`, so a clean delta proves no `Instant` reads).
        tr.disable();
        tr.reset();
        let stamps0 = tr.stamps();
        let t = tr.mint();
        {
            let _b = bind(t);
            assert_eq!(current(), t, "bind must set the thread's trace");
            let _root = span("clip");
            instant("failover");
            std::thread::scope(|sc| {
                sc.spawn(|| {
                    assert_eq!(
                        current(),
                        TraceId::NONE,
                        "bindings must not leak across threads"
                    );
                    let _b = bind(t);
                    let _s = span("hop");
                    record_tree(3, 2);
                });
            });
            tr.record_span(t, "clip", 0, 5);
        }
        assert_eq!(current(), TraceId::NONE, "bind must restore on drop");
        assert_eq!(
            tr.stamps() - stamps0,
            0,
            "a disabled tracer must take zero timestamps"
        );
        assert!(
            tr.snapshot_events().is_empty(),
            "a disabled tracer must record nothing"
        );

        // Phase 2 — enabled: for random thread/depth/fanout shapes,
        // every clip's recorded spans form a connected, well-nested
        // tree: one root enclosing all, and per-thread intervals that
        // never partially overlap.
        tr.enable(1);
        check("trace_span_trees_well_nested", 25, |g| {
            let clips: Vec<TraceId> = (0..g.index(3) + 1).map(|_| tr.mint()).collect();
            for &t in &clips {
                let workers = g.index(3) + 1;
                let shapes: Vec<(usize, usize)> = (0..workers)
                    .map(|_| (g.index(4) + 1, g.index(2) + 1))
                    .collect();
                let s0 = tr.now_us();
                std::thread::scope(|sc| {
                    for &(depth, fanout) in &shapes {
                        sc.spawn(move || {
                            let _b = bind(t);
                            record_tree(depth, fanout);
                        });
                    }
                });
                let s1 = tr.now_us();
                tr.record_span(t, "clip", s0, s1);
            }
            let events = tr.snapshot_events();
            for &t in &clips {
                let mine: Vec<&SpanEvent> =
                    events.iter().filter(|e| e.trace == t.0).collect();
                let roots: Vec<&&SpanEvent> =
                    mine.iter().filter(|e| e.name.as_str() == "clip").collect();
                if roots.len() != 1 {
                    return false;
                }
                let root = roots[0];
                // Connected: every span of the clip sits inside the root.
                if !mine.iter().all(|e| contains(root, e)) {
                    return false;
                }
                // Well-nested per recording thread: overlap ⇒ containment.
                for a in &mine {
                    for b in &mine {
                        if a.tid != b.tid {
                            continue;
                        }
                        let disjoint = a.start_us + a.dur_us <= b.start_us
                            || b.start_us + b.dur_us <= a.start_us;
                        if !(disjoint || contains(a, b) || contains(b, a)) {
                            return false;
                        }
                    }
                }
            }
            tr.reset();
            true
        });

        // Phase 3 — sampling: with `sample_every = 2` only even trace
        // ids record; odd ids stay inert (and take no timestamps).
        tr.enable(2);
        tr.reset();
        let even = loop {
            let t = tr.mint();
            if t.0 % 2 == 0 {
                break t;
            }
        };
        let odd = loop {
            let t = tr.mint();
            if t.0 % 2 == 1 {
                break t;
            }
        };
        // (No `stamps` delta assert here: with the tracer enabled,
        // concurrent tests elsewhere in the binary may legitimately
        // take timestamps for their own sampled traces.)
        {
            let _s = tr.span(odd, "clip");
        }
        {
            let _s = tr.span(even, "clip");
        }
        let events = tr.snapshot_events();
        assert!(events.iter().any(|e| e.trace == even.0));
        assert!(events.iter().all(|e| e.trace != odd.0));

        // Phase 4 — injection + export: shard spans re-base onto the
        // local timeline by -offset (clamped at 0), carry their pid
        // label, and the Chrome JSON names every process.
        tr.enable(1);
        tr.reset();
        tr.set_process_label("coordinator");
        let t = tr.mint();
        tr.record_span(t, "clip", 10, 90);
        tr.inject(
            "shard-0.1",
            vec![
                WireSpan {
                    trace: t.0,
                    name: "shard_step".into(),
                    start_us: 1_000_040,
                    dur_us: 5,
                    instant: false,
                    tid: 0,
                },
                WireSpan {
                    trace: t.0,
                    name: "early".into(),
                    start_us: 3,
                    instant: true,
                    dur_us: 0,
                    tid: 0,
                },
            ],
            1_000_000,
        );
        let events = tr.snapshot_events();
        let shard: Vec<&SpanEvent> = events
            .iter()
            .filter(|e| e.pid.as_deref() == Some("shard-0.1"))
            .collect();
        assert_eq!(shard.len(), 2);
        let step = shard.iter().find(|e| e.name.as_str() == "shard_step").unwrap();
        assert_eq!((step.start_us, step.dur_us), (40, 5), "offset re-base");
        assert_eq!(step.kind, SpanKind::Span);
        let early = shard.iter().find(|e| e.name.as_str() == "early").unwrap();
        assert_eq!(early.start_us, 0, "re-base clamps at the epoch");
        assert_eq!(early.kind, SpanKind::Instant);
        let json = tr.to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"name\":\"coordinator\""));
        assert!(json.contains("\"name\":\"shard-0.1\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains(&format!("\"trace\":{}", t.0)));

        // Leave the global tracer the way other tests expect it.
        tr.disable();
        tr.reset();
        tr.set_process_label("");
    }

    /// Ring buffers overwrite oldest and count drops; `drain_events`
    /// empties them. Uses explicit `record_span` (no wall clock), so
    /// it is deterministic.
    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut ring = RingBuf {
            events: Vec::new(),
            cap: 4,
            next: 0,
            pushed: 0,
        };
        let ev = |i: u64| SpanEvent {
            trace: 1,
            name: SpanName::Static("s"),
            start_us: i,
            dur_us: 1,
            kind: SpanKind::Span,
            tid: 7,
            pid: None,
        };
        for i in 0..6 {
            let overwrote = ring.push(ev(i));
            assert_eq!(overwrote, i >= 4, "push {i}");
        }
        assert_eq!(ring.pushed, 6);
        let order: Vec<u64> = ring.drain_ordered().iter().map(|e| e.start_us).collect();
        assert_eq!(order, vec![2, 3, 4, 5], "oldest-first after wraparound");
        assert!(ring.drain_ordered().is_empty());
    }
}

/// Minimal JSON string escaping for names/labels.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
