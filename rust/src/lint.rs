//! Repo-invariant source lint behind `spidr lint` (DESIGN.md
//! §Correctness).
//!
//! The concurrency-correctness story of this crate rests on
//! conventions no compiler checks: every synchronization primitive
//! must come from the [`crate::sync`] facade (or the model checker
//! cannot see it), wall-clock reads must stay out of protocol logic
//! (or model executions diverge on timing), wire decoding must be
//! total (or a malformed frame panics a shard host), and bench output
//! must flow through one emitter (or the `BENCH_*.json` validity gate
//! silently misses a series). This module makes those conventions
//! machine-checked: a line-based scan of the repo tree, run by the
//! `spidr lint` subcommand and gated in CI.
//!
//! Rules (see [`Rule`]):
//!
//! 1. **facade-only** — no `std::sync::{Mutex, Condvar, RwLock,
//!    mpsc}`, `std::thread::spawn`, or `std::thread::Builder` in
//!    `rust/src` outside the facade itself (`sync.rs`) and the model
//!    checker (`check/`). `Arc`, `OnceLock`, `thread::scope`,
//!    `thread::sleep`, and `available_parallelism` are deliberately
//!    exempt: they carry no protocol state worth model-checking
//!    (`sync.rs` docs).
//! 2. **wall-clock** — no `Instant::now()` in `rust/src` outside
//!    `obs/` unless the line carries a `// lint: wall-clock` audit
//!    marker, which asserts the read only feeds telemetry (stall /
//!    busy / latency accounting), never a protocol decision.
//! 3. **total-decode** — no `.unwrap()` / `.expect(` in the non-test
//!    portion of `net/wire.rs`: frame decoding must be total, every
//!    malformation an `Error::Protocol` (use the `fixed` helper for
//!    slice-to-array conversions).
//! 4. **bench-emit** — no filesystem writes (`File::create`,
//!    `OpenOptions`, `fs::write`) in `rust/benches/*.rs` outside
//!    `common/`: every `BENCH_*.json` row goes through
//!    `common::emit`, the single writer the validity gate audits.
//!
//! The scanner is deliberately dumb — per-line substring matches on
//! comment-stripped source, with `#[cfg(test)]` ending rules 2 and 3
//! for the remainder of a file (test modules sit at the bottom by
//! repo convention). Dumb is a feature: the rules stay greppable,
//! false negatives are bounded by convention, and the lint has no
//! parser to disagree with `rustc`.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One repo invariant the lint enforces (see the module docs for the
/// full rationale of each).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Rule 1: synchronization primitives only via [`crate::sync`].
    FacadeOnly,
    /// Rule 2: `Instant::now()` outside `obs/` needs an audit marker.
    WallClock,
    /// Rule 3: `net/wire.rs` decode paths never panic.
    TotalDecode,
    /// Rule 4: benches write files only through `common::emit`.
    BenchEmit,
}

impl Rule {
    /// Stable identifier printed in reports (and usable in greps).
    pub fn id(self) -> &'static str {
        match self {
            Rule::FacadeOnly => "facade-only",
            Rule::WallClock => "wall-clock",
            Rule::TotalDecode => "total-decode",
            Rule::BenchEmit => "bench-emit",
        }
    }

    /// One-line fix hint shown next to each violation.
    pub fn hint(self) -> &'static str {
        match self {
            Rule::FacadeOnly => "import from crate::sync so the model checker sees it",
            Rule::WallClock => {
                "move to obs/, or add `// lint: wall-clock` if this only feeds telemetry"
            }
            Rule::TotalDecode => "return Error::Protocol (see wire.rs `fixed`); decoding is total",
            Rule::BenchEmit => "emit through benches/common::emit so the validity gate sees it",
        }
    }
}

/// One lint finding.
#[derive(Debug)]
pub struct Violation {
    /// File the offending line is in (relative to the scanned root).
    pub file: PathBuf,
    /// 1-based line number.
    pub line: usize,
    /// Which invariant the line breaks.
    pub rule: Rule,
    /// The offending source line, trimmed.
    pub excerpt: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}\n    fix: {}",
            self.file.display(),
            self.line,
            self.rule.id(),
            self.excerpt,
            self.rule.hint()
        )
    }
}

/// How a file participates in the scan, derived from its repo path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FileKind {
    /// `rust/src` outside the exemptions: rules 1 and 2.
    Src,
    /// `rust/src/obs/`: rule 1 only (wall-clock reads are its job).
    Obs,
    /// `rust/src/net/wire.rs`: rules 1, 2, and 3.
    Wire,
    /// `rust/benches/*.rs` outside `common/`: rule 4.
    Bench,
    /// `rust/src/sync.rs`, `rust/src/check/`, `rust/benches/common/`:
    /// not scanned (they implement what the rules protect).
    Exempt,
}

/// Classify `rel`, a path relative to the scanned repo root (with
/// `/`-normalized separators).
fn classify(rel: &str) -> FileKind {
    if !rel.ends_with(".rs") {
        return FileKind::Exempt;
    }
    if let Some(in_src) = rel.strip_prefix("rust/src/") {
        return match in_src {
            "sync.rs" => FileKind::Exempt,
            // This file: it spells out the banned tokens in order to
            // match them, which the substring scanner cannot tell from
            // a use of them.
            "lint.rs" => FileKind::Exempt,
            "net/wire.rs" => FileKind::Wire,
            _ if in_src.starts_with("check/") => FileKind::Exempt,
            _ if in_src.starts_with("obs/") => FileKind::Obs,
            _ => FileKind::Src,
        };
    }
    if let Some(in_bench) = rel.strip_prefix("rust/benches/") {
        return if in_bench.starts_with("common/") {
            FileKind::Exempt
        } else {
            FileKind::Bench
        };
    }
    FileKind::Exempt
}

/// The code portion of a line: everything before a `//` comment.
/// Naive about `//` inside string literals — that only suppresses
/// findings on such lines, and none of the banned tokens belong in
/// strings anyway.
fn code_of(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// The audit marker that exempts a single line from rule 2.
const WALL_CLOCK_MARKER: &str = "lint: wall-clock";

/// Scan one file's source text. Pure over strings so the rules are
/// unit-testable without a filesystem; `rel` is only recorded into
/// findings.
fn scan_source(rel: &Path, kind: FileKind, text: &str) -> Vec<Violation> {
    let mut found = Vec::new();
    if kind == FileKind::Exempt {
        return found;
    }
    // Rules 2 and 3 stop at the first `#[cfg(test)]`: test modules sit
    // at the bottom of a file by repo convention, and tests may panic
    // on malformed input or time themselves freely. Rule 1 keeps going
    // — tests exercise the same protocols and must stay modelable.
    let mut in_tests = false;
    for (i, line) in text.lines().enumerate() {
        let code = code_of(line);
        if code.contains("#[cfg(test)]") {
            in_tests = true;
        }
        let mut hit = |rule: Rule| {
            found.push(Violation {
                file: rel.to_path_buf(),
                line: i + 1,
                rule,
                excerpt: line.trim().to_string(),
            });
        };
        match kind {
            FileKind::Src | FileKind::Obs | FileKind::Wire => {
                if code.contains("std::thread::spawn")
                    || code.contains("std::thread::Builder")
                    || code.contains("std::sync::mpsc")
                    || (code.contains("std::sync::")
                        && ["Mutex", "Condvar", "RwLock"]
                            .iter()
                            .any(|t| code.contains(t)))
                {
                    hit(Rule::FacadeOnly);
                }
                if kind != FileKind::Obs
                    && !in_tests
                    && code.contains("Instant::now()")
                    && !line.contains(WALL_CLOCK_MARKER)
                {
                    hit(Rule::WallClock);
                }
                if kind == FileKind::Wire
                    && !in_tests
                    && (code.contains(".unwrap()") || code.contains(".expect("))
                {
                    hit(Rule::TotalDecode);
                }
            }
            FileKind::Bench => {
                if code.contains("File::create")
                    || code.contains("OpenOptions")
                    || code.contains("fs::write")
                {
                    hit(Rule::BenchEmit);
                }
            }
            FileKind::Exempt => unreachable!(),
        }
    }
    found
}

/// Recursively collect `.rs` files under `dir`, as paths relative to
/// `root`. Missing directories are fine (a fixture tree may only
/// carry the files its seeded violations need).
fn collect(root: &Path, dir: &str, out: &mut Vec<PathBuf>) -> Result<()> {
    let abs = root.join(dir);
    if !abs.is_dir() {
        return Ok(());
    }
    let mut stack = vec![abs];
    while let Some(d) = stack.pop() {
        for entry in fs::read_dir(&d)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .map_err(|_| Error::config("lint: walked outside the scanned root"))?;
                out.push(rel.to_path_buf());
            }
        }
    }
    Ok(())
}

/// The result of a full lint run.
#[derive(Debug)]
pub struct LintReport {
    /// Files actually scanned (non-exempt).
    pub files_scanned: usize,
    /// Every violation found, in path order.
    pub violations: Vec<Violation>,
}

/// Lint the repo tree rooted at `root` (the directory holding
/// `rust/`). Scans `rust/src` and `rust/benches`; returns every
/// violation in path order. An empty tree lints clean.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect(root, "rust/src", &mut files)?;
    collect(root, "rust/benches", &mut files)?;
    files.sort();
    let mut report = LintReport {
        files_scanned: 0,
        violations: Vec::new(),
    };
    for rel in files {
        let rel_str = rel
            .to_str()
            .map(|s| s.replace('\\', "/"))
            .ok_or_else(|| Error::config("lint: non-UTF-8 source path"))?;
        let kind = classify(&rel_str);
        if kind == FileKind::Exempt {
            continue;
        }
        report.files_scanned += 1;
        let text = fs::read_to_string(root.join(&rel))?;
        report.violations.extend(scan_source(&rel, kind, &text));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(rel: &str, text: &str) -> Vec<Violation> {
        scan_source(Path::new(rel), classify(rel), text)
    }

    fn rules(v: &[Violation]) -> Vec<Rule> {
        v.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn classify_follows_repo_layout() {
        assert_eq!(classify("rust/src/coordinator/pool.rs"), FileKind::Src);
        assert_eq!(classify("rust/src/obs/trace.rs"), FileKind::Obs);
        assert_eq!(classify("rust/src/net/wire.rs"), FileKind::Wire);
        assert_eq!(classify("rust/src/sync.rs"), FileKind::Exempt);
        assert_eq!(classify("rust/src/lint.rs"), FileKind::Exempt);
        assert_eq!(classify("rust/src/check/rt.rs"), FileKind::Exempt);
        assert_eq!(classify("rust/benches/hotpath.rs"), FileKind::Bench);
        assert_eq!(classify("rust/benches/common/mod.rs"), FileKind::Exempt);
        assert_eq!(classify("examples/quickstart.rs"), FileKind::Exempt);
        assert_eq!(classify("rust/src/README.md"), FileKind::Exempt);
    }

    #[test]
    fn facade_rule_catches_direct_std_sync() {
        let v = scan(
            "rust/src/a.rs",
            "use std::sync::Mutex;\n\
             use std::sync::{Arc, Condvar};\n\
             use std::sync::mpsc::channel;\n\
             let t = std::thread::spawn(|| ());\n\
             let b = std::thread::Builder::new();\n",
        );
        assert_eq!(rules(&v), vec![Rule::FacadeOnly; 5]);
    }

    #[test]
    fn facade_rule_allows_exempt_primitives() {
        let v = scan(
            "rust/src/a.rs",
            "use std::sync::Arc;\n\
             use std::sync::OnceLock;\n\
             std::thread::scope(|s| ());\n\
             std::thread::sleep(d);\n\
             let n = std::thread::available_parallelism();\n\
             use crate::sync::{Condvar, Mutex};\n\
             // a comment naming std::sync::Mutex is fine\n",
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn facade_rule_applies_inside_sync_and_check_exemptions() {
        assert!(scan("rust/src/sync.rs", "use std::sync::Mutex;\n").is_empty());
        assert!(scan("rust/src/check/shim.rs", "use std::sync::Mutex;\n").is_empty());
    }

    #[test]
    fn wall_clock_rule_needs_marker_outside_obs() {
        let src = "let t0 = Instant::now();\n\
                   let t1 = Instant::now(); // lint: wall-clock\n";
        assert_eq!(rules(&scan("rust/src/a.rs", src)), vec![Rule::WallClock]);
        assert!(scan("rust/src/obs/t.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_rule_stops_at_tests() {
        let src = "fn f() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   let t0 = Instant::now();\n\
                   }\n";
        assert!(scan("rust/src/a.rs", src).is_empty());
    }

    #[test]
    fn total_decode_rule_is_wire_only_and_skips_tests() {
        let src = "let x = y.unwrap();\n\
                   let z = w.expect(\"boom\");\n\
                   #[cfg(test)]\n\
                   mod tests { let a = b.unwrap(); }\n";
        assert_eq!(
            rules(&scan("rust/src/net/wire.rs", src)),
            vec![Rule::TotalDecode, Rule::TotalDecode]
        );
        assert!(scan("rust/src/a.rs", src)
            .iter()
            .all(|f| f.rule != Rule::TotalDecode));
    }

    #[test]
    fn bench_emit_rule_bans_stray_writers() {
        let src = "let f = std::fs::File::create(\"BENCH_x.json\");\n\
                   std::fs::write(\"out\", b\"\");\n\
                   let r = std::fs::read_to_string(\"in\");\n";
        assert_eq!(
            rules(&scan("rust/benches/rogue.rs", src)),
            vec![Rule::BenchEmit, Rule::BenchEmit]
        );
        assert!(scan("rust/benches/common/mod.rs", src).is_empty());
    }

    #[test]
    fn violation_reports_position_and_hint() {
        let v = scan("rust/src/a.rs", "\n\nuse std::sync::Mutex;\n");
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
        let s = v[0].to_string();
        assert!(s.contains("rust/src/a.rs:3"), "{s}");
        assert!(s.contains("facade-only"), "{s}");
        assert!(s.contains("crate::sync"), "{s}");
    }

    #[test]
    fn the_repo_tree_itself_lints_clean() {
        // CARGO_MANIFEST_DIR is the repo root (the crate keeps its
        // sources under `rust/`). This is the same invariant the CI
        // lint gate enforces via the binary; having it here too means
        // plain `cargo test` catches a violation before push.
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = lint_tree(root).unwrap();
        assert!(
            report.files_scanned > 20,
            "scanned only {} files — layout drifted?",
            report.files_scanned
        );
        let msgs: Vec<String> = report.violations.iter().map(|v| v.to_string()).collect();
        assert!(msgs.is_empty(), "lint violations:\n{}", msgs.join("\n"));
    }

    #[test]
    fn seeded_fixture_fails_the_lint() {
        // The CI lint gate also runs the binary against this fixture
        // tree and expects a nonzero exit; the library-level check
        // pins the exact rule mix seeded there.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/lint-seeded");
        let report = lint_tree(&root).unwrap();
        let mut seen: Vec<&str> = report.violations.iter().map(|v| v.rule.id()).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(
            seen,
            vec!["bench-emit", "facade-only", "total-decode", "wall-clock"],
            "fixture must trip every rule: {:?}",
            report.violations
        );
    }
}
