//! Core geometry constants and simulation configuration.

use crate::energy::model::{Corner, EnergyParams};
use crate::quant::{Overflow, Precision};

/// Compute units in the core (paper Fig. 6).
pub const NUM_CU: usize = 9;
/// Neuron units in the core.
pub const NUM_NU: usize = 3;
/// IFspad rows (= weight rows per compute macro).
pub const IFSPAD_ROWS: usize = 128;
/// IFspad columns (= Vmem entries per macro: 32 physical rows / 2).
pub const IFSPAD_COLS: usize = 16;
/// Compute-macro SRAM columns.
pub const MACRO_COLS: usize = 48;
/// Even/odd address-FIFO depth (Fig. 10: deeper gives no further win).
pub const FIFO_DEPTH: usize = 16;
/// Neuron-macro pass length in cycles: 2·32 + 2 (paper eq. 3).
pub const NEURON_PASS_CYCLES: u64 = 2 * 32 + 2;

/// Reconfigurable operating mode (paper §II-E, Fig. 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperatingMode {
    /// Three parallel pipelines of 3 CUs + 1 NU; fan-in ≤ 3·128;
    /// 3·(48/B_w) output channels in parallel (eq. 2).
    Mode1,
    /// One pipeline of 9 CUs + 1 NU; fan-in ≤ 9·128; 48/B_w output
    /// channels in parallel.
    Mode2,
}

impl OperatingMode {
    /// Compute units chained per pipeline.
    pub fn cus_per_pipeline(self) -> usize {
        match self {
            OperatingMode::Mode1 => 3,
            OperatingMode::Mode2 => 9,
        }
    }

    /// Parallel pipelines.
    pub fn pipelines(self) -> usize {
        match self {
            OperatingMode::Mode1 => 3,
            OperatingMode::Mode2 => 1,
        }
    }

    /// Maximum mappable fan-in.
    pub fn max_fan_in(self) -> usize {
        self.cus_per_pipeline() * IFSPAD_ROWS
    }

    /// Output channels processed in parallel at a precision (eq. 2).
    pub fn parallel_channels(self, precision: Precision) -> usize {
        self.pipelines() * precision.neurons_per_row()
    }
}

/// Simulation configuration for one run.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Weight/Vmem precision operating point.
    pub precision: Precision,
    /// Adder-chain overflow policy (wrap is the architectural default).
    pub overflow: Overflow,
    /// Voltage/frequency corner.
    pub corner: Corner,
    /// Per-event energy coefficients.
    pub energy: EnergyParams,
    /// Simulate the functional datapath (weight/Vmem values). Timing
    /// and energy are value-independent, so sweeps can disable this.
    pub functional: bool,
    /// Zero-skipping enabled (the S2A processes only spikes). Disabling
    /// reproduces the dense baseline for the sparsity ablation.
    pub zero_skipping: bool,
    /// Cycles lost reconfiguring peripherals on an even/odd switch.
    pub parity_switch_cycles: u64,
    /// Cycles to transfer one partial-Vmem row between adjacent units.
    pub transfer_cycles_per_row: u64,
    /// Even/odd FIFO depth (16 in silicon; swept in the Fig.-10 bench).
    pub fifo_depth: usize,
    /// Detector cycles per extracted spike address.
    pub detector_cycles_per_spike: u64,
    /// Cycles to reset the macro's 32 partial-Vmem rows before each
    /// tile-timestep (the "R" stage in Fig. 13).
    pub tile_reset_cycles: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            precision: Precision::W4V7,
            overflow: Overflow::Wrap,
            corner: Corner::LOW,
            energy: EnergyParams::default(),
            functional: true,
            zero_skipping: true,
            parity_switch_cycles: 1,
            transfer_cycles_per_row: 1,
            fifo_depth: FIFO_DEPTH,
            detector_cycles_per_spike: 2,
            tile_reset_cycles: 32,
        }
    }
}

impl SimConfig {
    /// Timing-only configuration (functional datapath disabled).
    pub fn timing_only(precision: Precision) -> Self {
        SimConfig {
            precision,
            functional: false,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_geometry() {
        assert_eq!(OperatingMode::Mode1.max_fan_in(), 384);
        assert_eq!(OperatingMode::Mode2.max_fan_in(), 1152);
        assert_eq!(OperatingMode::Mode1.pipelines(), 3);
        assert_eq!(OperatingMode::Mode2.pipelines(), 1);
    }

    #[test]
    fn parallel_channels_eq2() {
        // eq. 2: 3·48/W_b (mode 1) or 48/W_b (mode 2)
        assert_eq!(OperatingMode::Mode1.parallel_channels(Precision::W4V7), 36);
        assert_eq!(OperatingMode::Mode2.parallel_channels(Precision::W4V7), 12);
        assert_eq!(OperatingMode::Mode1.parallel_channels(Precision::W8V15), 18);
    }

    #[test]
    fn neuron_pass_is_66() {
        assert_eq!(NEURON_PASS_CYCLES, 66);
    }
}
