//! The 128x16 dual-port input scratchpad (IFspad).
//!
//! Row `Y` maps to weight row `Y` of the compute macro; column `X`
//! maps to the staggered Vmem row pair `(2X, 2X+1)` (paper Fig. 9).
//! The input loader writes through one port while the spike detector
//! reads through the other, which is what hides the hardware-im2col
//! latency (paper §II-D).

use super::config::{IFSPAD_COLS, IFSPAD_ROWS};

/// IFspad contents: one 16-bit spike mask per row.
#[derive(Debug, Clone)]
pub struct IfSpad {
    rows: [u16; IFSPAD_ROWS],
    /// Rows that carry valid data for the current tile (fan-in slice
    /// length); the detector does not scan beyond this.
    pub valid_rows: usize,
    /// Columns that carry valid data (output pixels in the tile).
    pub valid_cols: usize,
}

impl Default for IfSpad {
    fn default() -> Self {
        Self::new()
    }
}

impl IfSpad {
    /// Empty scratchpad.
    pub fn new() -> Self {
        IfSpad {
            rows: [0; IFSPAD_ROWS],
            valid_rows: 0,
            valid_cols: 0,
        }
    }

    /// Clear all rows and validity (new tile).
    pub fn clear(&mut self, valid_rows: usize, valid_cols: usize) {
        debug_assert!(valid_rows <= IFSPAD_ROWS && valid_cols <= IFSPAD_COLS);
        self.rows = [0; IFSPAD_ROWS];
        self.valid_rows = valid_rows;
        self.valid_cols = valid_cols;
    }

    /// Write one spike bit (input-loader port).
    #[inline(always)]
    pub fn write(&mut self, y: usize, x: usize, v: bool) {
        debug_assert!(y < IFSPAD_ROWS && x < IFSPAD_COLS);
        if v {
            self.rows[y] |= 1 << x;
        } else {
            self.rows[y] &= !(1 << x);
        }
    }

    /// Write a whole row mask at once (the loader's row-granular path).
    #[inline(always)]
    pub fn write_row(&mut self, y: usize, mask: u16) {
        debug_assert!(y < IFSPAD_ROWS);
        self.rows[y] = mask;
    }

    /// Read one spike bit (detector port).
    #[inline(always)]
    pub fn read(&self, y: usize, x: usize) -> bool {
        self.rows[y] & (1 << x) != 0
    }

    /// Read a row mask (detector port).
    #[inline(always)]
    pub fn row_mask(&self, y: usize) -> u16 {
        self.rows[y]
    }

    /// Spikes currently stored (valid region only).
    pub fn count_spikes(&self) -> u32 {
        self.rows[..self.valid_rows]
            .iter()
            .map(|r| r.count_ones())
            .sum()
    }

    /// Density over the valid region.
    pub fn density(&self) -> f64 {
        let cells = (self.valid_rows * self.valid_cols) as f64;
        if cells == 0.0 {
            return 0.0;
        }
        self.count_spikes() as f64 / cells
    }
}

/// The batched-datapath scratchpad: the same 128×16 geometry as
/// [`IfSpad`], but each cell holds a full `u64` lane word (bit `b` =
/// clip `b`'s spike) instead of one bit. The union address stream is
/// extracted from it in one sweep — a cell participates if *any* lane
/// is set (DESIGN.md §Perf).
#[derive(Debug, Clone)]
pub struct LaneSpad {
    words: Vec<u64>,
    /// Rows that carry valid data for the current tile.
    pub valid_rows: usize,
    /// Columns that carry valid data (output pixels in the tile).
    pub valid_cols: usize,
}

impl Default for LaneSpad {
    fn default() -> Self {
        Self::new()
    }
}

impl LaneSpad {
    /// Empty scratchpad.
    pub fn new() -> Self {
        LaneSpad {
            words: vec![0; IFSPAD_ROWS * IFSPAD_COLS],
            valid_rows: 0,
            valid_cols: 0,
        }
    }

    /// Clear all cells and set the valid region (new tile).
    pub fn clear(&mut self, valid_rows: usize, valid_cols: usize) {
        debug_assert!(valid_rows <= IFSPAD_ROWS && valid_cols <= IFSPAD_COLS);
        self.words.fill(0);
        self.valid_rows = valid_rows;
        self.valid_cols = valid_cols;
    }

    /// Read one lane word (detector port).
    #[inline(always)]
    pub fn word(&self, y: usize, x: usize) -> u64 {
        debug_assert!(y < IFSPAD_ROWS && x < IFSPAD_COLS);
        self.words[y * IFSPAD_COLS + x]
    }

    /// Write one lane word (loader port).
    #[inline(always)]
    pub fn set_word(&mut self, y: usize, x: usize, w: u64) {
        debug_assert!(y < IFSPAD_ROWS && x < IFSPAD_COLS);
        self.words[y * IFSPAD_COLS + x] = w;
    }

    /// Total spikes stored across all lanes (valid region only).
    pub fn count_spikes(&self) -> u64 {
        let mut total = 0u64;
        for y in 0..self.valid_rows {
            for x in 0..self.valid_cols {
                total += self.word(y, x).count_ones() as u64;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut s = IfSpad::new();
        s.clear(128, 16);
        s.write(5, 3, true);
        assert!(s.read(5, 3));
        assert!(!s.read(5, 2));
        s.write(5, 3, false);
        assert!(!s.read(5, 3));
    }

    #[test]
    fn row_mask_and_count() {
        let mut s = IfSpad::new();
        s.clear(4, 16);
        s.write_row(0, 0b1010);
        s.write_row(3, 0b0001);
        assert_eq!(s.row_mask(0), 0b1010);
        assert_eq!(s.count_spikes(), 3);
        assert!((s.density() - 3.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn clear_resets() {
        let mut s = IfSpad::new();
        s.clear(128, 16);
        s.write(0, 0, true);
        s.clear(10, 8);
        assert_eq!(s.count_spikes(), 0);
        assert_eq!(s.valid_rows, 10);
        assert_eq!(s.valid_cols, 8);
    }

    #[test]
    fn lane_spad_words_and_counts() {
        let mut s = LaneSpad::new();
        s.clear(4, 8);
        s.set_word(1, 2, 0b1011);
        s.set_word(3, 0, 1 << 63);
        assert_eq!(s.word(1, 2), 0b1011);
        assert_eq!(s.count_spikes(), 4);
        // cells outside the valid region are ignored by the count
        s.set_word(3, 10, u64::MAX);
        assert_eq!(s.count_spikes(), 4);
        s.clear(2, 2);
        assert_eq!(s.count_spikes(), 0);
        assert_eq!(s.word(1, 2), 0);
    }
}
