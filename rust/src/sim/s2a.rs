//! Spike-to-address converter: spike detector, even/odd ping-pong
//! FIFOs, and the macro SRAM controller (paper §II-B/C, Figs. 9–11).
//!
//! The detector (a trailing-zero scanner) reads IFspad rows through the
//! read port as soon as the input loader has written them, emitting
//! `(Y, X)` address tuples into the *even* FIFO. The controller drains
//! the even FIFO — one macro pass per cycle — re-queuing each tuple
//! into the *odd* FIFO, and switches parity only when the current FIFO
//! runs empty or the other fills up. This batches same-parity passes,
//! amortizing the peripheral reconfiguration energy (Fig. 10: ~1.5x
//! energy/op at batch 15 vs. switching every cycle).

use std::collections::VecDeque;

use super::compute_macro::{ComputeMacro, Parity};
use super::ifspad::{IfSpad, LaneSpad};

/// S2A policy knobs (a view of the relevant `SimConfig` fields).
#[derive(Debug, Clone, Copy)]
pub struct S2aOptions {
    /// Even/odd FIFO depth.
    pub fifo_depth: usize,
    /// Cycles lost per parity switch.
    pub switch_cycles: u64,
    /// Ping-pong batching on (silicon behavior). When off, each tuple
    /// is processed even-then-odd immediately — the naive policy whose
    /// overhead Fig. 10 quantifies.
    pub ping_pong: bool,
    /// Detector cycles per extracted spike address (trailing-zero
    /// priority encode + FIFO write handshake).
    pub detector_cycles_per_spike: u64,
}

impl Default for S2aOptions {
    fn default() -> Self {
        S2aOptions {
            fifo_depth: super::config::FIFO_DEPTH,
            switch_cycles: 1,
            ping_pong: true,
            detector_cycles_per_spike: 2,
        }
    }
}

/// Per-tile, per-CU execution statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TileCuStats {
    /// Total cycles from tile start until the last odd pass retires.
    pub cycles: u64,
    /// Macro accumulation passes executed (even + odd).
    pub macro_ops: u64,
    /// Peripheral parity switches.
    pub parity_switches: u64,
    /// IFspad rows scanned by the detector.
    pub detect_rows: u64,
    /// Spike addresses extracted.
    pub detect_spikes: u64,
    /// FIFO pushes (even + odd).
    pub queue_pushes: u64,
    /// FIFO pops.
    pub queue_pops: u64,
    /// Cycles the detector stalled on a full even FIFO.
    pub detector_stalls: u64,
    /// Cycles the controller idled waiting for addresses.
    pub controller_idle: u64,
}

impl TileCuStats {
    /// Merge another tile's stats (sequential composition).
    pub fn add(&mut self, o: &TileCuStats) {
        self.cycles += o.cycles;
        self.macro_ops += o.macro_ops;
        self.parity_switches += o.parity_switches;
        self.detect_rows += o.detect_rows;
        self.detect_spikes += o.detect_spikes;
        self.queue_pushes += o.queue_pushes;
        self.queue_pops += o.queue_pops;
        self.detector_stalls += o.detector_stalls;
        self.controller_idle += o.controller_idle;
    }
}

/// Simulate one tile through the S2A + compute macro.
///
/// `row_ready[y]` is the cycle at which the input loader finished
/// writing IFspad row `y` (the dual-port overlap); the detector reads a
/// row no earlier than that.
pub fn run_tile(
    spad: &IfSpad,
    row_ready: &[u64],
    cm: &mut ComputeMacro,
    opts: &S2aOptions,
) -> TileCuStats {
    let mut st = TileCuStats::default();
    let valid_rows = spad.valid_rows;
    debug_assert!(row_ready.len() >= valid_rows);

    // Detector state.
    let mut det_y = 0usize; // next row to scan
    let mut det_pending: u16 = 0; // spikes left to extract from current row
    let mut det_row: usize = 0; // row the pending mask belongs to
    let mut det_t: u64 = 0; // detector's local clock

    // Controller state.
    let mut even_q: VecDeque<(u8, u8)> = VecDeque::with_capacity(opts.fifo_depth);
    let mut odd_q: VecDeque<(u8, u8)> = VecDeque::with_capacity(opts.fifo_depth);
    let mut parity = Parity::Even;
    let mut ctrl_t: u64 = 0;
    // R/C/S pipeline fill (2 cycles) before the first pass retires.
    let mut first_op_done = false;
    let mut busy_cycles: u64 = 0;

    loop {
        let det_done = det_y >= valid_rows && det_pending == 0;
        if det_done && even_q.is_empty() && odd_q.is_empty() {
            break;
        }

        // Earliest cycle at which the detector can take its next action
        // (reading a new row waits for the input loader's write).
        let det_next = if det_done {
            u64::MAX
        } else if det_pending != 0 {
            det_t
        } else {
            det_t.max(row_ready[det_y])
        };

        let ctrl_has_work = match parity {
            Parity::Even => !even_q.is_empty() && odd_q.len() < opts.fifo_depth,
            Parity::Odd => !odd_q.is_empty(),
        };
        // Switch policy (paper §II-C): leave Even when the odd FIFO is
        // full or the even FIFO has drained (and no address arrives by
        // the controller's current cycle); leave Odd when the odd FIFO
        // has drained. The naive non-ping-pong policy switches after
        // every op.
        let ctrl_should_switch = match parity {
            Parity::Even => {
                let odd_full = odd_q.len() >= opts.fifo_depth && !even_q.is_empty();
                let even_drained =
                    even_q.is_empty() && !odd_q.is_empty() && det_next > ctrl_t;
                let naive = !opts.ping_pong && !odd_q.is_empty();
                odd_full || even_drained || naive
            }
            Parity::Odd => odd_q.is_empty() && (!even_q.is_empty() || !det_done),
        };
        let ctrl_can_act = ctrl_has_work || ctrl_should_switch;

        // Causal interleave: the agent with the earlier clock acts;
        // ties go to the controller (a pop frees FIFO space for a push
        // in the same cycle).
        if ctrl_can_act && ctrl_t <= det_next {
            // A pending switch preempts further same-parity pops: for
            // ping-pong the two are mutually exclusive anyway; for the
            // naive policy the switch after every op is the whole point.
            if ctrl_should_switch {
                parity = parity.flip();
                st.parity_switches += 1;
                ctrl_t += opts.switch_cycles;
                busy_cycles += opts.switch_cycles;
            } else if ctrl_has_work {
                match parity {
                    Parity::Even => {
                        let (y, x) = even_q.pop_front().unwrap();
                        st.queue_pops += 1;
                        cm.op(y as usize, x as usize, Parity::Even);
                        st.macro_ops += 1;
                        odd_q.push_back((y, x));
                        st.queue_pushes += 1;
                    }
                    Parity::Odd => {
                        let (y, x) = odd_q.pop_front().unwrap();
                        st.queue_pops += 1;
                        cm.op(y as usize, x as usize, Parity::Odd);
                        st.macro_ops += 1;
                    }
                }
                if !first_op_done {
                    ctrl_t += 2; // pipeline fill
                    busy_cycles += 2;
                    first_op_done = true;
                }
                ctrl_t += 1;
                busy_cycles += 1;
            }
            continue;
        }

        if !det_done {
            // Detector acts at det_next.
            det_t = det_next;
            if det_pending == 0 {
                // read the next row (1 cycle), latch its spike mask
                det_pending = spad.row_mask(det_y) & mask_cols(spad.valid_cols);
                det_row = det_y;
                det_y += 1;
                st.detect_rows += 1;
                det_t += 1;
            } else if even_q.len() >= opts.fifo_depth {
                // stall until the controller frees a slot; the
                // controller necessarily has work (queues non-empty)
                let wait = ctrl_t.max(det_t + 1);
                st.detector_stalls += wait - det_t;
                det_t = wait;
            } else {
                // extract one trailing spike (1 cycle) and push it
                let x = det_pending.trailing_zeros() as u8;
                det_pending &= det_pending - 1;
                even_q.push_back((det_row as u8, x));
                st.queue_pushes += 1;
                st.detect_spikes += 1;
                det_t += opts.detector_cycles_per_spike;
            }
            // The controller cannot act before the detector's clock if
            // it has nothing to do: fast-forward it (starvation).
            if !ctrl_can_act && ctrl_t < det_t {
                ctrl_t = det_t;
            }
            continue;
        }

        // det_done and controller can't act => queues empty; loop exits.
        unreachable!("S2A interleave wedged");
    }

    st.cycles = det_t.max(ctrl_t);
    st.controller_idle = st.cycles.saturating_sub(busy_cycles);
    st
}

/// Extract the tile's spike addresses in detector order: rows scanned
/// top-down, spikes within a row popped lowest-X-first (the
/// trailing-zero priority encode). This is exactly the order in which
/// `run_tile`'s even FIFO — and therefore, FIFO discipline preserving
/// it, the odd FIFO too — retires macro passes for any ping-pong /
/// FIFO-depth configuration, which is what makes replaying the list
/// with [`ComputeMacro::op_row`] bit-exact (DESIGN.md §Perf).
pub fn extract_addresses(spad: &IfSpad) -> Vec<(u8, u8)> {
    let cols = mask_cols(spad.valid_cols);
    let mut out = Vec::with_capacity(spad.count_spikes() as usize);
    for y in 0..spad.valid_rows {
        let mut m = spad.row_mask(y) & cols;
        while m != 0 {
            let x = m.trailing_zeros() as u8;
            m &= m - 1;
            out.push((y as u8, x));
        }
    }
    out
}

/// One entry of a batched union address stream: an IFspad cell that
/// has *any* lane spiking, plus its full lane word. The batched
/// datapath's zero-skipping gate — cells with word 0 never appear
/// (DESIGN.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneAddr {
    /// IFspad row (weight row of the compute macro).
    pub y: u8,
    /// IFspad column (output pixel within the tile).
    pub x: u8,
    /// Lane word: bit `b` set iff clip `b` spikes at this cell.
    pub word: u64,
}

/// Extract the batched union address stream from a [`LaneSpad`] in the
/// same sorted `(y, x)` order as [`extract_addresses`]: rows top-down,
/// columns lowest-X-first. Restricting the stream to the entries whose
/// word has bit `b` set therefore yields exactly the address sequence
/// `extract_addresses` would emit for clip `b` alone — the per-lane
/// bit-exactness invariant the batched replay relies on (DESIGN.md
/// §Perf).
pub fn extract_lane_addresses(spad: &LaneSpad) -> Vec<LaneAddr> {
    let mut out = Vec::new();
    for y in 0..spad.valid_rows {
        for x in 0..spad.valid_cols {
            let word = spad.word(y, x);
            if word != 0 {
                out.push(LaneAddr {
                    y: y as u8,
                    x: x as u8,
                    word,
                });
            }
        }
    }
    out
}

#[inline(always)]
fn mask_cols(valid_cols: usize) -> u16 {
    if valid_cols >= 16 {
        u16::MAX
    } else {
        (1u16 << valid_cols) - 1
    }
}

/// Closed-form stats for the dense (no zero-skipping) controller: every
/// `(Y, X)` position is processed regardless of spikes. The detector
/// and FIFOs are bypassed; parity switches once per column sweep.
pub fn run_tile_dense(
    spad: &IfSpad,
    cm: &mut ComputeMacro,
    opts: &S2aOptions,
) -> TileCuStats {
    let rows = spad.valid_rows as u64;
    let cols = spad.valid_cols as u64;
    let macro_ops = 2 * rows * cols;
    let parity_switches = 2 * cols;
    let mut st = TileCuStats {
        macro_ops,
        parity_switches,
        cycles: macro_ops + parity_switches * opts.switch_cycles + 2,
        ..Default::default()
    };
    // Functional: only true spikes accumulate (the dense design gates
    // the add by the spike bit; it just cannot skip the cycle).
    for y in 0..spad.valid_rows {
        let mask = spad.row_mask(y) & mask_cols(spad.valid_cols);
        let mut m = mask;
        while m != 0 {
            let x = m.trailing_zeros() as usize;
            m &= m - 1;
            cm.op(y, x, Parity::Even);
            cm.op(y, x, Parity::Odd);
            st.detect_spikes += 1;
        }
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Overflow;
    use crate::snn::tensor::Mat;

    fn spad_with(spikes: &[(usize, usize)], rows: usize, cols: usize) -> IfSpad {
        let mut s = IfSpad::new();
        s.clear(rows, cols);
        for &(y, x) in spikes {
            s.write(y, x, true);
        }
        s
    }

    fn cm(rows: usize) -> ComputeMacro {
        let mut w = Mat::zeros(rows, 4);
        for r in 0..rows {
            for k in 0..4 {
                w.set(r, k, (r + k) as i32 % 3 + 1);
            }
        }
        ComputeMacro::new(w, 7, Overflow::Wrap, true)
    }

    fn ready_now(rows: usize) -> Vec<u64> {
        vec![0; rows]
    }

    #[test]
    fn empty_tile_scans_rows_only() {
        let spad = spad_with(&[], 8, 16);
        let mut m = cm(8);
        let st = run_tile(&spad, &ready_now(8), &mut m, &S2aOptions::default());
        assert_eq!(st.macro_ops, 0);
        assert_eq!(st.detect_rows, 8);
        assert_eq!(st.detect_spikes, 0);
        assert!(st.cycles >= 8);
    }

    #[test]
    fn each_spike_two_ops() {
        let spad = spad_with(&[(0, 0), (1, 3), (5, 7)], 8, 16);
        let mut m = cm(8);
        let st = run_tile(&spad, &ready_now(8), &mut m, &S2aOptions::default());
        assert_eq!(st.detect_spikes, 3);
        assert_eq!(st.macro_ops, 6);
        // every tuple pushed to even then to odd
        assert_eq!(st.queue_pushes, 6);
        assert_eq!(st.queue_pops, 6);
    }

    #[test]
    fn ping_pong_batches_switches() {
        // 20 spikes spread over rows: ping-pong should switch far fewer
        // than 2x per spike.
        let spikes: Vec<(usize, usize)> = (0..20).map(|i| (i % 16, (i * 7) % 16)).collect();
        let spad = spad_with(&spikes, 16, 16);
        let mut m1 = cm(16);
        let pp = S2aOptions {
            ping_pong: true,
            ..Default::default()
        };
        let st_pp = run_tile(&spad, &ready_now(16), &mut m1, &pp);
        let mut m2 = cm(16);
        let naive = S2aOptions {
            ping_pong: false,
            ..Default::default()
        };
        let st_naive = run_tile(&spad, &ready_now(16), &mut m2, &naive);
        assert_eq!(st_pp.macro_ops, st_naive.macro_ops);
        assert!(
            st_pp.parity_switches < st_naive.parity_switches,
            "pp {} vs naive {}",
            st_pp.parity_switches,
            st_naive.parity_switches
        );
        // functional result identical regardless of order
        assert_eq!(m1.vmem_entry(3), m2.vmem_entry(3));
    }

    #[test]
    fn functional_accumulation_matches_direct() {
        let spikes = [(0, 0), (2, 0), (0, 1)];
        let spad = spad_with(&spikes, 4, 16);
        let mut m = cm(4);
        run_tile(&spad, &ready_now(4), &mut m, &S2aOptions::default());
        // direct expectation for entry 0: rows 0 and 2 accumulated
        let mut expect = [0i32; 4];
        for &(y, _) in &[(0, 0), (2, 0)] {
            for (k, e) in expect.iter_mut().enumerate() {
                *e += (y + k) as i32 % 3 + 1;
            }
        }
        assert_eq!(m.vmem_entry(0), &expect);
    }

    #[test]
    fn row_ready_delays_detection() {
        let spad = spad_with(&[(7, 0)], 8, 16);
        let mut ready = ready_now(8);
        ready[7] = 100; // loader finishes row 7 late
        let mut m = cm(8);
        let st = run_tile(&spad, &ready, &mut m, &S2aOptions::default());
        assert!(st.cycles > 100);
    }

    #[test]
    fn extract_addresses_in_detector_order() {
        let spad = spad_with(&[(0, 5), (0, 1), (3, 0), (2, 7)], 8, 16);
        let addrs = extract_addresses(&spad);
        assert_eq!(addrs, vec![(0, 1), (0, 5), (2, 7), (3, 0)]);
        let mut m = cm(8);
        let st = run_tile(&spad, &ready_now(8), &mut m, &S2aOptions::default());
        assert_eq!(st.detect_spikes as usize, addrs.len());
        // out-of-validity columns are masked out
        let mut s = spad_with(&[(1, 2)], 4, 4);
        s.write(1, 9, true); // beyond valid_cols
        assert_eq!(extract_addresses(&s), vec![(1, 2)]);
    }

    #[test]
    fn lane_addresses_restrict_to_per_lane_detector_order() {
        // The invariant run_chain_lanes relies on: filtering the union
        // stream by one lane's bit reproduces extract_addresses of
        // that lane's own spad, in the same order.
        let mut rng = crate::prop::SplitMix64::new(0x5A2A);
        let (rows, cols) = (12, 16);
        let lanes = 7;
        let mut spads: Vec<IfSpad> = Vec::new();
        let mut lane_spad = LaneSpad::new();
        lane_spad.clear(rows, cols);
        for b in 0..lanes {
            let mut s = IfSpad::new();
            s.clear(rows, cols);
            for y in 0..rows {
                for x in 0..cols {
                    if rng.chance(0.2) {
                        s.write(y, x, true);
                        lane_spad.set_word(y, x, lane_spad.word(y, x) | 1 << b);
                    }
                }
            }
            spads.push(s);
        }
        let union = extract_lane_addresses(&lane_spad);
        assert_eq!(
            union.iter().map(|a| a.word.count_ones() as u64).sum::<u64>(),
            lane_spad.count_spikes()
        );
        for (b, s) in spads.iter().enumerate() {
            let restricted: Vec<(u8, u8)> = union
                .iter()
                .filter(|a| a.word >> b & 1 != 0)
                .map(|a| (a.y, a.x))
                .collect();
            assert_eq!(restricted, extract_addresses(s), "lane {b}");
        }
    }

    #[test]
    fn dense_processes_everything() {
        let spad = spad_with(&[(0, 0)], 4, 8);
        let mut m = cm(4);
        let st = run_tile_dense(&spad, &mut m, &S2aOptions::default());
        assert_eq!(st.macro_ops, 2 * 4 * 8);
        assert_eq!(st.detect_spikes, 1);
        // functional result only reflects the actual spike
        assert_eq!(m.vmem_entry(0)[0], 1); // w[0][0] = 1
    }

    #[test]
    fn dense_costs_more_at_high_sparsity() {
        let spad = spad_with(&[(3, 2)], 16, 16);
        let mut m1 = cm(16);
        let sparse = run_tile(&spad, &ready_now(16), &mut m1, &S2aOptions::default());
        let mut m2 = cm(16);
        let dense = run_tile_dense(&spad, &mut m2, &S2aOptions::default());
        assert!(dense.cycles > sparse.cycles);
        assert_eq!(m1.vmem_entry(2), m2.vmem_entry(2));
    }
}
