//! The SpiDR core: 9 compute units + 3 neuron units, reconfigurable
//! operating modes, tile/timestep scheduling (paper §II-E/F, Fig. 12).
//!
//! Execution plan for one layer (weight-stationary):
//!
//! * **Mode 1** (fan-in ≤ 3·128): three pipelines of 3 CUs + 1 NU run
//!   *different output-channel groups* of the same tile concurrently.
//! * **Mode 2** (fan-in ≤ 9·128): one pipeline of 9 CUs + 1 NU; one
//!   channel group at a time.
//!
//! Within a tile (16 output pixels), timesteps pipeline across the
//! chained units with asynchronous handshaking; across tiles the core
//! runs sequentially (the NU's 32 full-Vmem rows hold exactly one
//! tile, so all timesteps of a tile complete before it is swapped).
//! If a layer has more output channels than a mode can map, the input
//! is re-streamed once per extra pass (weights are reconfigured).

use crate::error::{Error, Result};
use crate::snn::layer::Layer;
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

use super::compute_unit::{split_fan_in, ComputeUnit};
use super::config::{OperatingMode, SimConfig, IFSPAD_COLS, NEURON_PASS_CYCLES};
use super::neuron_macro::NeuronMacro;
use super::pipeline::{
    pipeline_makespan, synchronous_makespan, worst_case_makespan, PipelineTimeline,
};
use super::stats::RunStats;

/// Per-layer execution report.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// Aggregate counters and energy.
    pub run: RunStats,
    /// Mode the mapper chose.
    pub mode: OperatingMode,
    /// Weight-reconfiguration passes needed for all channel groups.
    pub passes: usize,
    /// Pixel tiles processed per pass.
    pub tiles: usize,
    /// Example timeline (first pass, first tile) for Fig.-13-style
    /// visualization.
    pub example_timeline: Option<PipelineTimeline>,
}

/// The simulated SpiDR core.
#[derive(Debug, Clone)]
pub struct SpidrCore {
    /// Simulation configuration.
    pub cfg: SimConfig,
}

impl SpidrCore {
    /// New core with a configuration.
    pub fn new(cfg: SimConfig) -> Self {
        SpidrCore { cfg }
    }

    /// Select the operating mode for a fan-in (paper Fig. 12).
    pub fn select_mode(&self, fan_in: usize) -> Result<OperatingMode> {
        if fan_in <= OperatingMode::Mode1.max_fan_in() {
            Ok(OperatingMode::Mode1)
        } else if fan_in <= OperatingMode::Mode2.max_fan_in() {
            Ok(OperatingMode::Mode2)
        } else {
            Err(Error::mapping(format!(
                "fan-in {fan_in} exceeds Mode 2 capacity {} (layer must be \
                 split upstream)",
                OperatingMode::Mode2.max_fan_in()
            )))
        }
    }

    /// Execute one stateful layer over all timesteps.
    ///
    /// * `inputs` — one input spike plane per timestep.
    /// * `state` — the layer's full Vmem bank `(M, K)`, updated in
    ///   place (bit-exact vs. the golden model when
    ///   `cfg.functional`).
    ///
    /// Returns the output spike planes per timestep plus statistics.
    pub fn run_layer(
        &self,
        layer: &Layer,
        inputs: &[SpikePlane],
        state: &mut Mat,
    ) -> Result<(Vec<SpikePlane>, LayerStats)> {
        let weights = layer
            .weights
            .as_ref()
            .ok_or_else(|| Error::mapping("pool layers are not mapped to the core"))?;
        let fan_in = layer.fan_in();
        let mode = self.select_mode(fan_in)?;
        let (m_total, k_total) = layer.vmem_shape()?;
        if state.rows != m_total || state.cols != k_total {
            return Err(Error::shape(format!(
                "state {}x{} != expected {m_total}x{k_total}",
                state.rows, state.cols
            )));
        }
        let timesteps = inputs.len();
        if timesteps == 0 {
            return Err(Error::config("no timesteps"));
        }

        let npr = self.cfg.precision.neurons_per_row();
        let groups: Vec<(usize, usize)> = (0..k_total)
            .step_by(npr)
            .map(|lo| (lo, (lo + npr).min(k_total)))
            .collect();
        let pipelines = mode.pipelines();
        let passes = groups.len().div_ceil(pipelines);
        let tiles = m_total.div_ceil(IFSPAD_COLS);
        let chain = mode.cus_per_pipeline();
        let slices = split_fan_in(fan_in, chain);

        let (ko, ho, wo) = layer.out_shape;
        let mut outputs: Vec<SpikePlane> =
            (0..timesteps).map(|_| SpikePlane::zeros(ko, ho, wo)).collect();

        let mut run = RunStats::default();
        let e = &self.cfg.energy;
        let wb = self.cfg.precision.weight_bits();
        let mut example_timeline = None;

        // Layer-input sparsity telemetry (counted once, not per pass).
        for inp in inputs {
            run.spikes += inp.count_spikes();
            run.cells += inp.len() as u64;
        }
        run.dense_synops = layer.dense_synops() * timesteps as u64;

        for pass in 0..passes {
            // Active (pipeline, channel-group) assignments this pass.
            let active: Vec<(usize, usize)> = (0..pipelines)
                .filter_map(|pi| {
                    let g = pass * pipelines + pi;
                    (g < groups.len()).then_some((pi, g))
                })
                .collect();

            // Build each active pipeline's CU chain + NU.
            let mut chains: Vec<(Vec<ComputeUnit>, NeuronMacro, usize, usize)> =
                Vec::new();
            for &(_, g) in &active {
                let (ks, ke) = groups[g];
                let cus: Vec<ComputeUnit> = slices
                    .iter()
                    .map(|&(lo, hi)| {
                        let mut wslice = Mat::zeros(hi - lo, ke - ks);
                        for (r, f) in (lo..hi).enumerate() {
                            for (c, kk) in (ks..ke).enumerate() {
                                wslice.set(r, c, weights.get(f, kk));
                            }
                        }
                        ComputeUnit::new(lo, hi, wslice, &self.cfg)
                    })
                    .collect();
                let nm = NeuronMacro::new(
                    ke - ks,
                    self.cfg.precision.vmem_bits(),
                    self.cfg.overflow,
                    layer.neuron,
                    layer.accumulate,
                );
                chains.push((cus, nm, ks, ke));
            }

            for tile in 0..tiles {
                let pixel_base = tile * IFSPAD_COLS;
                let pixels = IFSPAD_COLS.min(m_total - pixel_base);
                let transfer =
                    self.cfg.transfer_cycles_per_row * 2 * pixels as u64;

                let mut tile_makespan = 0u64;
                let mut tile_sync = 0u64;
                let mut tile_worst = 0u64;

                for (ci, (cus, nm, ks, ke)) in chains.iter_mut().enumerate() {
                    let neurons = *ke - *ks;
                    // Restore this tile's full Vmems into the NU.
                    let mut full = vec![0i32; IFSPAD_COLS * neurons];
                    for p in 0..pixels {
                        for (c, kk) in (*ks..*ke).enumerate() {
                            full[p * neurons + c] = state.get(pixel_base + p, kk);
                        }
                    }
                    nm.load_vmems(&full);

                    let mut durations: Vec<Vec<u64>> =
                        vec![vec![0; timesteps]; cus.len()];
                    // §Perf: one partial buffer reused across timesteps
                    let mut partial = vec![0i32; pixels * neurons];
                    for (t, input) in inputs.iter().enumerate() {
                        partial.fill(0);
                        for (i, cu) in cus.iter_mut().enumerate() {
                            let r = cu.process_tile(layer, input, pixel_base, pixels);
                            // + the Fig.-13 "R" stage: partial-Vmem reset
                            durations[i][t] =
                                r.stats.cycles + self.cfg.tile_reset_cycles;
                            // energy from this CU's tile execution
                            run.energy.compute_macro +=
                                r.stats.macro_ops as f64 * e.macro_op(wb);
                            run.energy.peripheral_switch +=
                                r.stats.parity_switches as f64 * e.e_parity_switch;
                            run.energy.s2a += r.stats.detect_rows as f64
                                * e.e_detect_row
                                + (r.stats.queue_pushes + r.stats.queue_pops) as f64
                                    * e.e_queue_op;
                            run.energy.input_loader +=
                                r.load.spad_writes as f64 * e.e_il_write;
                            run.energy.ifmem +=
                                r.load.ifmem_reads as f64 * e.e_ifmem_read;
                            run.energy.control +=
                                r.stats.cycles as f64 * e.e_ctrl_cycle;
                            run.macro_ops += r.stats.macro_ops;
                            run.synops +=
                                r.stats.detect_spikes as u64 * neurons as u64;
                            run.parity_switches += r.stats.parity_switches;
                            // functional: chain-merge this CU's partials
                            if self.cfg.functional {
                                for p in 0..pixels {
                                    let src = cu.partial_entry(p);
                                    let dst =
                                        &mut partial[p * neurons..(p + 1) * neurons];
                                    for (d, &s) in dst.iter_mut().zip(src) {
                                        *d = self.cfg.overflow.apply(
                                            *d + s,
                                            self.cfg.precision.vmem_bits(),
                                        );
                                    }
                                }
                            }
                        }
                        // transfers along the chain (CU→CU…→NU)
                        let hops = cus.len() as u64;
                        run.energy.data_movement +=
                            hops as f64 * 2.0 * pixels as f64 * e.e_transfer_row;

                        // neuron pass
                        let out = nm.pass(&partial, pixels);
                        run.energy.neuron_units +=
                            out.cycles as f64 * e.e_neuron_cycle;
                        run.energy.control += out.cycles as f64 * e.e_ctrl_cycle;
                        if !layer.accumulate && self.cfg.functional {
                            for p in 0..pixels {
                                let m = pixel_base + p;
                                let (y, x) = (m / wo, m % wo);
                                for (c, kk) in (*ks..*ke).enumerate() {
                                    if out.spikes[p * neurons + c] != 0 {
                                        outputs[t].set(kk, y, x, 1);
                                    }
                                }
                            }
                        }
                    }
                    // persist the tile's full Vmems back to layer state
                    if self.cfg.functional {
                        let v = nm.vmems();
                        for p in 0..pixels {
                            for (c, kk) in (*ks..*ke).enumerate() {
                                state.set(pixel_base + p, kk, v[p * neurons + c]);
                            }
                        }
                    }

                    // timing for this pipeline over the tile
                    let tl = pipeline_makespan(&durations, transfer, NEURON_PASS_CYCLES);
                    tile_sync = tile_sync
                        .max(synchronous_makespan(&durations, transfer, NEURON_PASS_CYCLES));
                    tile_worst = tile_worst
                        .max(worst_case_makespan(&durations, transfer, NEURON_PASS_CYCLES));
                    tile_makespan = tile_makespan.max(tl.makespan);
                    if pass == 0 && tile == 0 && ci == 0 && example_timeline.is_none() {
                        example_timeline = Some(tl);
                    }
                }

                run.cycles += tile_makespan;
                run.sync_cycles += tile_sync;
                run.worst_case_cycles += tile_worst;
            }
        }

        Ok((
            outputs,
            LayerStats {
                run,
                mode,
                passes,
                tiles,
                example_timeline,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;
    use crate::snn::layer::{NeuronConfig, ResetMode};
    use crate::snn::network::{NetworkBuilder, NetworkState};
    use crate::prop::check;

    fn mat_fill(rows: usize, cols: usize, f: impl Fn(usize, usize) -> i32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, f(r, c));
            }
        }
        m
    }

    fn conv_layer(in_ch: usize, out_ch: usize, h: usize, w: usize) -> Layer {
        let f = in_ch * 9;
        Layer::conv(
            (in_ch, h, w),
            out_ch,
            3,
            3,
            1,
            1,
            mat_fill(f, out_ch, |r, c| ((r * 31 + c * 7) % 11) as i32 - 5),
            NeuronConfig {
                theta: 4,
                leak: 1,
                leaky: true,
                reset: ResetMode::Soft,
            },
            false,
        )
        .unwrap()
    }

    fn random_frames(
        c: usize,
        h: usize,
        w: usize,
        t: usize,
        density: f64,
        seed: u64,
    ) -> Vec<SpikePlane> {
        let mut rng = crate::prop::SplitMix64::new(seed);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if rng.chance(density) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn mode_selection() {
        let core = SpidrCore::new(SimConfig::default());
        assert_eq!(core.select_mode(288).unwrap(), OperatingMode::Mode1);
        assert_eq!(core.select_mode(385).unwrap(), OperatingMode::Mode2);
        assert!(core.select_mode(1153).is_err());
    }

    #[test]
    fn sim_matches_reference_network() {
        // The core's functional output must equal Network::step's.
        let layer = conv_layer(2, 4, 6, 6);
        let frames = random_frames(2, 6, 6, 3, 0.3, 42);

        // reference
        let net = NetworkBuilder::new("t", Precision::W4V7, 3, (2, 6, 6))
            .conv3x3(4, layer.weights.clone().unwrap(), layer.neuron, false)
            .unwrap()
            .fc(
                1,
                mat_fill(4 * 36, 1, |_, _| 0),
                NeuronConfig::default(),
                true,
            )
            .unwrap()
            .build()
            .unwrap();
        let mut ref_state: NetworkState = net.init_state().unwrap();

        // simulator
        let core = SpidrCore::new(SimConfig::default());
        let mut sim_state = Mat::zeros(36, 4);
        let (sim_out, stats) = core.run_layer(&layer, &frames, &mut sim_state).unwrap();

        // step the reference layer-by-layer to extract layer-1 spikes
        for (t, f) in frames.iter().enumerate() {
            net.step(f, &mut ref_state).unwrap();
            // recompute reference layer output independently:
            // (Network::step consumed it internally; easiest check is
            // state equality below plus spike count sanity)
            let _ = t;
        }
        assert_eq!(
            ref_state.vmems[0].as_slice(),
            sim_state.as_slice(),
            "sim Vmem trajectory diverged from reference"
        );
        assert!(stats.run.macro_ops > 0);
        assert_eq!(sim_out.len(), 3);
    }

    #[test]
    fn multi_pass_when_channels_exceed_mode_capacity() {
        // 40 output channels at 4-bit: mode 1 maps 36/pass -> 2 passes.
        let layer = conv_layer(2, 40, 4, 4);
        let frames = random_frames(2, 4, 4, 1, 0.3, 7);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(16, 40);
        let (_, stats) = core.run_layer(&layer, &frames, &mut state).unwrap();
        assert_eq!(stats.passes, 2);
        assert_eq!(stats.mode, OperatingMode::Mode1);
    }

    #[test]
    fn mode2_used_for_large_fan_in() {
        // 48 input channels * 9 = 432 fan-in > 384 -> mode 2
        let layer = conv_layer(48, 4, 3, 3);
        let frames = random_frames(48, 3, 3, 1, 0.2, 9);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(9, 4);
        let (_, stats) = core.run_layer(&layer, &frames, &mut state).unwrap();
        assert_eq!(stats.mode, OperatingMode::Mode2);
    }

    #[test]
    fn sparser_input_is_cheaper() {
        let layer = conv_layer(2, 8, 8, 8);
        let core = SpidrCore::new(SimConfig::timing_only(Precision::W4V7));
        let dense = random_frames(2, 8, 8, 2, 0.4, 1);
        let sparse = random_frames(2, 8, 8, 2, 0.05, 1);
        let mut s1 = Mat::zeros(64, 8);
        let (_, st_dense) = core.run_layer(&layer, &dense, &mut s1).unwrap();
        let mut s2 = Mat::zeros(64, 8);
        let (_, st_sparse) = core.run_layer(&layer, &sparse, &mut s2).unwrap();
        assert!(st_sparse.run.cycles < st_dense.run.cycles);
        assert!(st_sparse.run.energy.total() < st_dense.run.energy.total());
    }

    #[test]
    fn async_beats_sync_beats_worst_case() {
        let layer = conv_layer(2, 4, 8, 8);
        let frames = random_frames(2, 8, 8, 4, 0.25, 3);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(64, 4);
        let (_, st) = core.run_layer(&layer, &frames, &mut state).unwrap();
        assert!(st.run.cycles <= st.run.sync_cycles);
        assert!(st.run.sync_cycles <= st.run.worst_case_cycles);
    }

    #[test]
    fn prop_functional_independent_of_precision_geometry() {
        // Same weights, same inputs: functional Vmems must not depend
        // on timing knobs (fifo depth, switch cost, zero-skipping).
        check("functional_invariance", 10, |g| {
            let layer = conv_layer(1, 3, 5, 5);
            let frames = random_frames(1, 5, 5, 2, 0.3, g.u64());
            let mut base_state = Mat::zeros(25, 3);
            let core = SpidrCore::new(SimConfig::default());
            core.run_layer(&layer, &frames, &mut base_state).unwrap();

            let mut cfg = SimConfig::default();
            cfg.fifo_depth = 1 + g.index(32);
            cfg.parity_switch_cycles = g.u64_in(0..=4);
            cfg.zero_skipping = g.chance(0.5);
            let core2 = SpidrCore::new(cfg);
            let mut state2 = Mat::zeros(25, 3);
            core2.run_layer(&layer, &frames, &mut state2).unwrap();
            base_state.as_slice() == state2.as_slice()
        });
    }
}
