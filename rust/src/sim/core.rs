//! The SpiDR core: 9 compute units + 3 neuron units, reconfigurable
//! operating modes, tile/timestep scheduling (paper §II-E/F, Fig. 12).
//!
//! Execution plan for one layer (weight-stationary):
//!
//! * **Mode 1** (fan-in ≤ 3·128): three pipelines of 3 CUs + 1 NU run
//!   *different output-channel groups* of the same tile concurrently.
//! * **Mode 2** (fan-in ≤ 9·128): one pipeline of 9 CUs + 1 NU; one
//!   channel group at a time.
//!
//! Within a tile (16 output pixels), timesteps pipeline across the
//! chained units with asynchronous handshaking; across tiles the core
//! runs sequentially (the NU's 32 full-Vmem rows hold exactly one
//! tile, so all timesteps of a tile complete before it is swapped).
//! If a layer has more output channels than a mode can map, the input
//! is re-streamed once per extra pass (weights are reconfigured).
//!
//! **Host execution strategy (§Perf, DESIGN.md §Perf):** the spike
//! content of a tile is weight-independent, so the input loader + S2A
//! interleave runs once per `(tile, fan-slice, timestep)` into a
//! [`StreamCache`], and every `(pass × pipeline)` channel group
//! *replays* the cached address stream through its own weights via the
//! fused [`ComputeMacro::op_row`] pass. Channel groups touch disjoint
//! weight columns, Vmem columns and output channels, so they execute
//! on independent host threads (`std::thread::scope`, mirroring
//! `coordinator/scheduler.rs`) — Mode 1's three pipelines genuinely
//! run concurrently on the host. `ComputeUnit::process_tile` remains
//! the reference implementation the fast path is property-tested
//! against (`sim::stream`).

use crate::error::{Error, Result};
use crate::snn::layer::Layer;
use crate::snn::spikes::{LaneFrame, LanePlane, SpikePlane, MAX_LANES};
use crate::snn::tensor::Mat;

use super::compute_macro::{ComputeMacro, LaneMacro};
use super::compute_unit::split_fan_in;
use super::config::{OperatingMode, SimConfig, IFSPAD_COLS, NEURON_PASS_CYCLES};
use super::neuron_macro::NeuronMacro;
use super::pipeline::{
    pipeline_makespan, synchronous_makespan, worst_case_makespan, PipelineTimeline,
};
use super::stats::RunStats;
use super::stream::{LaneStreamCache, StreamCache};

/// Per-layer execution report.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// Aggregate counters and energy.
    pub run: RunStats,
    /// Mode the mapper chose.
    pub mode: OperatingMode,
    /// Weight-reconfiguration passes needed for all channel groups.
    pub passes: usize,
    /// Pixel tiles processed per pass.
    pub tiles: usize,
    /// Example timeline (first pass, first tile) for Fig.-13-style
    /// visualization.
    pub example_timeline: Option<PipelineTimeline>,
}

/// The simulated SpiDR core.
#[derive(Debug, Clone)]
pub struct SpidrCore {
    /// Simulation configuration.
    pub cfg: SimConfig,
}

/// A batched Vmem bank: the layer state of up to [`MAX_LANES`] clips,
/// `(M, lanes, K)` row-major — lane `b`'s bank is the `(M, K)` matrix
/// [`LaneBank::lane_mat`] extracts. The batched executor's counterpart
/// of the per-clip `Mat` state [`SpidrCore::run_layer`] updates.
#[derive(Debug, Clone)]
pub struct LaneBank {
    rows: usize,
    cols: usize,
    lanes: usize,
    data: Vec<i32>,
}

impl LaneBank {
    /// Zeroed bank for `lanes` clips of an `(rows, cols)` layer state.
    pub fn zeros(rows: usize, cols: usize, lanes: usize) -> Self {
        assert!(lanes >= 1 && lanes <= MAX_LANES, "lanes out of range");
        LaneBank {
            rows,
            cols,
            lanes,
            data: vec![0; rows * lanes * cols],
        }
    }

    /// Vmem rows (output pixels `M`).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Vmem columns (output channels `K`).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Clips held.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Read lane `b`'s Vmem at `(m, k)`.
    #[inline(always)]
    pub fn get(&self, m: usize, b: usize, k: usize) -> i32 {
        debug_assert!(m < self.rows && b < self.lanes && k < self.cols);
        self.data[(m * self.lanes + b) * self.cols + k]
    }

    /// Write lane `b`'s Vmem at `(m, k)`.
    #[inline(always)]
    pub fn set(&mut self, m: usize, b: usize, k: usize, v: i32) {
        debug_assert!(m < self.rows && b < self.lanes && k < self.cols);
        self.data[(m * self.lanes + b) * self.cols + k] = v;
    }

    /// Extract lane `b`'s full `(M, K)` Vmem bank — bit-comparable to
    /// the per-clip state `run_layer` would have produced for clip `b`.
    pub fn lane_mat(&self, b: usize) -> Mat {
        debug_assert!(b < self.lanes);
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                m.set(r, k, self.get(r, b, k));
            }
        }
        m
    }
}

/// Everything one channel group's pipeline produces over a layer run.
/// Built on a worker thread; merged deterministically (group order) by
/// `run_layer`.
struct ChainOutcome {
    /// Channel-group bounds `[ks, ke)`.
    ks: usize,
    ke: usize,
    /// Per-tile `(async, synchronous, worst-case)` makespans.
    per_tile: Vec<(u64, u64, u64)>,
    /// Energy + op counters (cycle fields left zero; timing is reduced
    /// across pipelines per pass, not summed per chain).
    run: RunStats,
    /// Updated Vmem columns `(m_total, ke-ks)`; `None` when
    /// timing-only.
    state: Option<Mat>,
    /// Output spikes as `(timestep, local channel, pixel)` tuples;
    /// empty when timing-only or in accumulate mode.
    spikes: Vec<(u32, u32, u32)>,
    /// Fig.-13 example timeline (first tile of group 0 only).
    timeline: Option<PipelineTimeline>,
}

/// One channel group's results from the batched (lane-major) executor.
struct LaneChainOutcome {
    /// Channel-group bounds `[ks, ke)`.
    ks: usize,
    ke: usize,
    /// Per-tile sequential union-sweep makespans.
    per_tile: Vec<u64>,
    /// Energy + op counters (cycle fields left zero, reduced by the
    /// caller like the per-clip path).
    run: RunStats,
    /// Updated Vmems, `(m_total, lanes, ke-ks)` row-major.
    state: Vec<i32>,
    /// Output spikes as `(timestep, local channel, pixel, lane word)`.
    spikes: Vec<(u32, u32, u32, u64)>,
}

impl SpidrCore {
    /// New core with a configuration.
    pub fn new(cfg: SimConfig) -> Self {
        SpidrCore { cfg }
    }

    /// Select the operating mode for a fan-in (paper Fig. 12).
    pub fn select_mode(&self, fan_in: usize) -> Result<OperatingMode> {
        if fan_in <= OperatingMode::Mode1.max_fan_in() {
            Ok(OperatingMode::Mode1)
        } else if fan_in <= OperatingMode::Mode2.max_fan_in() {
            Ok(OperatingMode::Mode2)
        } else {
            Err(Error::mapping(format!(
                "fan-in {fan_in} exceeds Mode 2 capacity {} (layer must be \
                 split upstream)",
                OperatingMode::Mode2.max_fan_in()
            )))
        }
    }

    /// Execute one stateful layer over all timesteps.
    ///
    /// * `inputs` — one input spike plane per timestep.
    /// * `state` — the layer's full Vmem bank `(M, K)`, updated in
    ///   place (bit-exact vs. the golden model when
    ///   `cfg.functional`).
    ///
    /// Returns the output spike planes per timestep plus statistics.
    pub fn run_layer(
        &self,
        layer: &Layer,
        inputs: &[SpikePlane],
        state: &mut Mat,
    ) -> Result<(Vec<SpikePlane>, LayerStats)> {
        let weights = layer
            .weights
            .as_ref()
            .ok_or_else(|| Error::mapping("pool layers are not mapped to the core"))?;
        let fan_in = layer.fan_in();
        let mode = self.select_mode(fan_in)?;
        let (m_total, k_total) = layer.vmem_shape()?;
        if state.rows != m_total || state.cols != k_total {
            return Err(Error::shape(format!(
                "state {}x{} != expected {m_total}x{k_total}",
                state.rows, state.cols
            )));
        }
        let timesteps = inputs.len();
        if timesteps == 0 {
            return Err(Error::config("no timesteps"));
        }

        let npr = self.cfg.precision.neurons_per_row();
        let groups: Vec<(usize, usize)> = (0..k_total)
            .step_by(npr)
            .map(|lo| (lo, (lo + npr).min(k_total)))
            .collect();
        let pipelines = mode.pipelines();
        let passes = groups.len().div_ceil(pipelines);
        let tiles = m_total.div_ceil(IFSPAD_COLS);
        let chain = mode.cus_per_pipeline();
        let slices = split_fan_in(fan_in, chain);

        let (ko, ho, wo) = layer.out_shape;
        let mut outputs: Vec<SpikePlane> =
            (0..timesteps).map(|_| SpikePlane::zeros(ko, ho, wo)).collect();

        let mut run = RunStats::default();

        // Layer-input sparsity telemetry (counted once, not per pass).
        for inp in inputs {
            run.spikes += inp.count_spikes();
            run.cells += inp.len() as u64;
        }
        run.dense_synops = layer.dense_synops() * timesteps as u64;

        // §Perf: every weight-independent tile stream is computed
        // exactly once and shared by all channel groups below.
        let cache = StreamCache::build(layer, inputs, &slices, tiles, m_total, &self.cfg);

        let outcomes: Vec<ChainOutcome> = if groups.len() == 1 {
            vec![self.run_chain(
                layer, weights, state, &cache, &slices, groups[0], m_total, tiles, true,
            )]
        } else {
            let state_ref: &Mat = state;
            let cache_ref = &cache;
            let slices_ref = &slices[..];
            let groups_ref = &groups[..];
            // Cap the fan-out at the host's parallelism (contiguous
            // group chunks, same pattern as the stream-cache build).
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(groups.len());
            let chunk = groups.len().div_ceil(workers);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|wi| {
                        let lo = (wi * chunk).min(groups_ref.len());
                        let hi = ((wi + 1) * chunk).min(groups_ref.len());
                        scope.spawn(move || {
                            groups_ref[lo..hi]
                                .iter()
                                .enumerate()
                                .map(|(off, &grp)| {
                                    self.run_chain(
                                        layer, weights, state_ref, cache_ref, slices_ref,
                                        grp, m_total, tiles, lo + off == 0,
                                    )
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                let mut all = Vec::with_capacity(groups_ref.len());
                for h in handles {
                    all.extend(h.join().expect("pipeline-chain thread panicked"));
                }
                all
            })
        };

        // Deterministic merge, group order. Energy + op counters first.
        for oc in &outcomes {
            run.energy.add(&oc.run.energy);
            run.macro_ops += oc.run.macro_ops;
            run.synops += oc.run.synops;
            run.parity_switches += oc.run.parity_switches;
        }
        // Timing: within a pass the active pipelines run concurrently
        // in silicon, so each tile costs the slowest of them; passes
        // and tiles are sequential.
        for pass in 0..passes {
            for tile in 0..tiles {
                let mut mk = 0u64;
                let mut sync = 0u64;
                let mut worst = 0u64;
                for pi in 0..pipelines {
                    let g = pass * pipelines + pi;
                    if g >= groups.len() {
                        break;
                    }
                    let (m, s, w) = outcomes[g].per_tile[tile];
                    mk = mk.max(m);
                    sync = sync.max(s);
                    worst = worst.max(w);
                }
                run.cycles += mk;
                run.sync_cycles += sync;
                run.worst_case_cycles += worst;
            }
        }
        // Functional write-back: groups own disjoint channel slices.
        let mut example_timeline = None;
        for (gi, oc) in outcomes.into_iter().enumerate() {
            if gi == 0 {
                example_timeline = oc.timeline;
            }
            if let Some(os) = oc.state {
                for m in 0..m_total {
                    for (c, kk) in (oc.ks..oc.ke).enumerate() {
                        state.set(m, kk, os.get(m, c));
                    }
                }
            }
            for &(t, c, m) in &oc.spikes {
                let m = m as usize;
                outputs[t as usize].set(oc.ks + c as usize, m / wo, m % wo, 1);
            }
        }

        Ok((
            outputs,
            LayerStats {
                run,
                mode,
                passes,
                tiles,
                example_timeline,
            },
        ))
    }

    /// Per-timestep stepping API for staged layer-group pipelines
    /// (`coordinator::pipeline`, DESIGN.md §Pipeline): execute one
    /// stateful layer for a single timestep, carrying Vmem state in
    /// `state`.
    ///
    /// Functionally this is exactly [`Self::run_layer`] — the full
    /// Vmem bank round-trips through `state` between calls, so
    /// stepping a clip frame by frame produces bit-identical Vmems
    /// and spikes to one whole-clip call
    /// (`stepwise_equals_whole_clip_run`). The *timing* model
    /// differs: whole-clip execution keeps a tile's full Vmems
    /// resident in the neuron unit across all timesteps, while
    /// per-timestep stepping swaps every tile in and out each call —
    /// the stage-resident cost a hardware layer-group pipeline pays
    /// at its boundaries. Cycle/energy sums therefore upper-bound the
    /// whole-clip numbers.
    pub fn step_layer(
        &self,
        layer: &Layer,
        frame: &SpikePlane,
        state: &mut Mat,
    ) -> Result<(SpikePlane, LayerStats)> {
        let (mut out, stats) = self.run_layer(layer, std::slice::from_ref(frame), state)?;
        Ok((out.pop().expect("one timestep in, one plane out"), stats))
    }

    /// Execute one stateful layer over all timesteps for a whole batch
    /// of clips packed into bit-plane lanes (DESIGN.md §Perf).
    ///
    /// * `inputs` — one [`LaneFrame`] per timestep (all with the same
    ///   lane count and shape; see [`LaneFrame::pack_clips`]).
    /// * `state` — the batched Vmem bank, updated in place.
    ///
    /// The loader + address extraction run **once per batch**: the
    /// union address stream visits a cell iff *any* lane spikes there,
    /// and [`LaneMacro::op_row`] fans each union address out to the
    /// lanes whose bit is set. Because union extraction preserves the
    /// per-clip detector order and every merge/neuron stage is
    /// elementwise, lane `b`'s Vmems and output spikes are bit-exact
    /// against a per-clip [`Self::run_layer`] of clip `b` for any
    /// overflow policy — see `prop_batched_layer_matches_per_clip`.
    ///
    /// This is a host-throughput datapath: the functional result is
    /// exact per lane, while cycle/energy totals use a sequential
    /// union-sweep model (makespan = sync = worst-case), not the
    /// per-clip dual-port interleave. Cycle-accurate numbers still
    /// come from the per-clip path.
    pub fn run_layer_lanes(
        &self,
        layer: &Layer,
        inputs: &[LaneFrame],
        state: &mut LaneBank,
    ) -> Result<(Vec<LaneFrame>, LayerStats)> {
        let weights = layer
            .weights
            .as_ref()
            .ok_or_else(|| Error::mapping("pool layers are not mapped to the core"))?;
        let fan_in = layer.fan_in();
        let mode = self.select_mode(fan_in)?;
        let (m_total, k_total) = layer.vmem_shape()?;
        let timesteps = inputs.len();
        if timesteps == 0 {
            return Err(Error::config("no timesteps"));
        }
        let lanes = inputs[0].lanes();
        for (t, f) in inputs.iter().enumerate() {
            if f.lanes() != lanes || f.shape() != inputs[0].shape() {
                return Err(Error::shape(format!(
                    "lane frame {t} ({} lanes, {:?}) != frame 0 ({lanes} lanes, {:?})",
                    f.lanes(),
                    f.shape(),
                    inputs[0].shape()
                )));
            }
        }
        if state.rows() != m_total || state.cols() != k_total || state.lanes() != lanes {
            return Err(Error::shape(format!(
                "lane state {}x{}x{} != expected {m_total}x{lanes}x{k_total}",
                state.rows(),
                state.lanes(),
                state.cols()
            )));
        }

        let npr = self.cfg.precision.neurons_per_row();
        let groups: Vec<(usize, usize)> = (0..k_total)
            .step_by(npr)
            .map(|lo| (lo, (lo + npr).min(k_total)))
            .collect();
        let pipelines = mode.pipelines();
        let passes = groups.len().div_ceil(pipelines);
        let tiles = m_total.div_ceil(IFSPAD_COLS);
        let chain = mode.cus_per_pipeline();
        let slices = split_fan_in(fan_in, chain);

        let (ko, ho, wo) = layer.out_shape;
        let mut out_planes: Vec<LanePlane> =
            (0..timesteps).map(|_| LanePlane::zeros(ko, ho, wo)).collect();

        let mut run = RunStats::default();
        for inp in inputs {
            run.spikes += inp.count_spikes();
            run.cells += (inp.plane().len() * lanes) as u64;
        }
        run.dense_synops = layer.dense_synops() * timesteps as u64 * lanes as u64;

        // The batched amortization point: one union stream for every
        // channel group, built from one im2col walk per (tile, slice,
        // timestep) for the *whole batch*.
        let cache = LaneStreamCache::build(layer, inputs, &slices, tiles, m_total);

        let outcomes: Vec<LaneChainOutcome> = if groups.len() == 1 {
            vec![self.run_chain_lanes(
                layer, weights, state, &cache, &slices, groups[0], m_total, tiles, lanes,
            )]
        } else {
            let state_ref: &LaneBank = state;
            let cache_ref = &cache;
            let slices_ref = &slices[..];
            let groups_ref = &groups[..];
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(groups.len());
            let chunk = groups.len().div_ceil(workers);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|wi| {
                        let lo = (wi * chunk).min(groups_ref.len());
                        let hi = ((wi + 1) * chunk).min(groups_ref.len());
                        scope.spawn(move || {
                            groups_ref[lo..hi]
                                .iter()
                                .map(|&grp| {
                                    self.run_chain_lanes(
                                        layer, weights, state_ref, cache_ref, slices_ref,
                                        grp, m_total, tiles, lanes,
                                    )
                                })
                                .collect::<Vec<_>>()
                        })
                    })
                    .collect();
                let mut all = Vec::with_capacity(groups_ref.len());
                for h in handles {
                    all.extend(h.join().expect("lane-chain thread panicked"));
                }
                all
            })
        };

        for oc in &outcomes {
            run.energy.add(&oc.run.energy);
            run.macro_ops += oc.run.macro_ops;
            run.synops += oc.run.synops;
            run.parity_switches += oc.run.parity_switches;
        }
        // Timing: same pass×tile reduction as the per-clip path, with
        // a single (sequential-sweep) makespan per tile.
        for pass in 0..passes {
            for tile in 0..tiles {
                let mut mk = 0u64;
                for pi in 0..pipelines {
                    let g = pass * pipelines + pi;
                    if g >= groups.len() {
                        break;
                    }
                    mk = mk.max(outcomes[g].per_tile[tile]);
                }
                run.cycles += mk;
                run.sync_cycles += mk;
                run.worst_case_cycles += mk;
            }
        }
        for oc in outcomes {
            let neurons = oc.ke - oc.ks;
            for m in 0..m_total {
                for b in 0..lanes {
                    for c in 0..neurons {
                        state.set(m, b, oc.ks + c, oc.state[(m * lanes + b) * neurons + c]);
                    }
                }
            }
            for &(t, c, m, word) in &oc.spikes {
                let m = m as usize;
                out_planes[t as usize].set(oc.ks + c as usize, m / wo, m % wo, word);
            }
        }

        let outputs = out_planes
            .into_iter()
            .map(|p| LaneFrame::from_plane(p, lanes))
            .collect();
        Ok((
            outputs,
            LayerStats {
                run,
                mode,
                passes,
                tiles,
                example_timeline: None,
            },
        ))
    }

    /// Run one channel group of the batched executor: replay the union
    /// address stream through a [`LaneMacro`] per fan-in slice, merge
    /// partials elementwise, and drive a `lanes × neurons` neuron
    /// macro (elementwise, therefore per-lane exact).
    #[allow(clippy::too_many_arguments)]
    fn run_chain_lanes(
        &self,
        layer: &Layer,
        weights: &Mat,
        state: &LaneBank,
        cache: &LaneStreamCache,
        slices: &[(usize, usize)],
        (ks, ke): (usize, usize),
        m_total: usize,
        tiles: usize,
        lanes: usize,
    ) -> LaneChainOutcome {
        let e = &self.cfg.energy;
        let wb = self.cfg.precision.weight_bits();
        let bits = self.cfg.precision.vmem_bits();
        let overflow = self.cfg.overflow;
        let timesteps = cache.timesteps();
        let neurons = ke - ks;
        let chain_len = slices.len();
        let stride = lanes * neurons;

        let mut cms: Vec<LaneMacro> = slices
            .iter()
            .map(|&(lo, hi)| {
                LaneMacro::new(weights.submatrix(lo, hi, ks, ke), lanes, bits, overflow)
            })
            .collect();
        // One NU spanning all lanes: `pass` is elementwise over
        // entries × (lanes·neurons), so lane b's elements follow the
        // exact per-clip neuron ordering contract.
        let mut nm =
            NeuronMacro::new(stride, bits, overflow, layer.neuron, layer.accumulate);

        let mut run = RunStats::default();
        let mut per_tile = Vec::with_capacity(tiles);
        let mut out_state = vec![0i32; m_total * stride];
        let mut spikes: Vec<(u32, u32, u32, u64)> = Vec::new();
        let mut partial = vec![0i32; IFSPAD_COLS * stride];
        let mut full = vec![0i32; IFSPAD_COLS * stride];

        for tile in 0..tiles {
            let pixel_base = tile * IFSPAD_COLS;
            let pixels = IFSPAD_COLS.min(m_total - pixel_base);
            let transfer = self.cfg.transfer_cycles_per_row * 2 * pixels as u64;
            let mut tile_cycles = 0u64;

            for p in 0..pixels {
                for b in 0..lanes {
                    for (c, kk) in (ks..ke).enumerate() {
                        full[(p * lanes + b) * neurons + c] =
                            state.get(pixel_base + p, b, kk);
                    }
                }
            }
            nm.load_vmems(&full);

            for t in 0..timesteps {
                partial[..pixels * stride].fill(0);
                for (i, cm) in cms.iter_mut().enumerate() {
                    let s = cache.get(tile, i, t);
                    // sequential union sweep: one row op per union
                    // address, plus the tile reset stage
                    tile_cycles += s.addrs().len() as u64 + self.cfg.tile_reset_cycles;
                    // silicon-equivalent counters: each lane's
                    // accumulation is an even+odd macro-op pair, same
                    // as the per-clip path summed over the batch
                    run.macro_ops += 2 * s.lane_ops;
                    run.synops += s.lane_ops * neurons as u64;
                    run.energy.compute_macro += 2.0 * s.lane_ops as f64 * e.macro_op(wb);
                    run.energy.s2a += s.addrs().len() as f64 * e.e_detect_row;
                    run.energy.input_loader += s.load.spad_writes as f64 * e.e_il_write;
                    run.energy.ifmem += s.load.ifmem_reads as f64 * e.e_ifmem_read;
                    cm.reset_vmems();
                    for a in s.addrs() {
                        cm.op_row(a.y as usize, a.x as usize, a.word);
                    }
                    for p in 0..pixels {
                        let src = cm.entry(p);
                        let dst = &mut partial[p * stride..(p + 1) * stride];
                        for (d, &sv) in dst.iter_mut().zip(src) {
                            *d = overflow.apply(*d + sv, bits);
                        }
                    }
                }
                tile_cycles += transfer + NEURON_PASS_CYCLES;
                run.energy.data_movement +=
                    chain_len as f64 * 2.0 * pixels as f64 * e.e_transfer_row;
                run.energy.neuron_units +=
                    lanes as f64 * NEURON_PASS_CYCLES as f64 * e.e_neuron_cycle;
                run.energy.control += NEURON_PASS_CYCLES as f64 * e.e_ctrl_cycle;
                let out = nm.pass(&partial[..pixels * stride], pixels);
                if !layer.accumulate {
                    for p in 0..pixels {
                        for c in 0..neurons {
                            let mut word = 0u64;
                            for b in 0..lanes {
                                if out.spikes[(p * lanes + b) * neurons + c] != 0 {
                                    word |= 1 << b;
                                }
                            }
                            if word != 0 {
                                spikes.push((
                                    t as u32,
                                    c as u32,
                                    (pixel_base + p) as u32,
                                    word,
                                ));
                            }
                        }
                    }
                }
            }
            let v = nm.vmems();
            out_state[pixel_base * stride..(pixel_base + pixels) * stride]
                .copy_from_slice(&v[..pixels * stride]);
            run.energy.control += tile_cycles as f64 * e.e_ctrl_cycle;
            per_tile.push(tile_cycles);
        }

        LaneChainOutcome {
            ks,
            ke,
            per_tile,
            run,
            state: out_state,
            spikes,
        }
    }

    /// Run one channel group's pipeline over every tile and timestep,
    /// replaying cached tile streams through this group's weights.
    #[allow(clippy::too_many_arguments)]
    fn run_chain(
        &self,
        layer: &Layer,
        weights: &Mat,
        state: &Mat,
        cache: &StreamCache,
        slices: &[(usize, usize)],
        (ks, ke): (usize, usize),
        m_total: usize,
        tiles: usize,
        want_timeline: bool,
    ) -> ChainOutcome {
        let e = &self.cfg.energy;
        let wb = self.cfg.precision.weight_bits();
        let bits = self.cfg.precision.vmem_bits();
        let overflow = self.cfg.overflow;
        let functional = self.cfg.functional;
        let timesteps = cache.timesteps();
        let neurons = ke - ks;
        let chain_len = slices.len();

        // Weight slices land in the macros once per group — row-slice
        // copies (§Perf), not per-element get/set, and not at all when
        // the functional datapath is off.
        let mut cms: Vec<ComputeMacro> = if functional {
            slices
                .iter()
                .map(|&(lo, hi)| {
                    ComputeMacro::new(weights.submatrix(lo, hi, ks, ke), bits, overflow, true)
                })
                .collect()
        } else {
            Vec::new()
        };
        let mut nm =
            NeuronMacro::new(neurons, bits, overflow, layer.neuron, layer.accumulate);

        let mut run = RunStats::default();
        let mut per_tile = Vec::with_capacity(tiles);
        let mut timeline = None;
        let mut out_state = if functional {
            Some(Mat::zeros(m_total, neurons))
        } else {
            None
        };
        let mut spikes: Vec<(u32, u32, u32)> = Vec::new();
        let mut durations = vec![vec![0u64; timesteps]; chain_len];
        let mut partial = vec![0i32; IFSPAD_COLS * neurons];
        let mut full = vec![0i32; IFSPAD_COLS * neurons];

        for tile in 0..tiles {
            let pixel_base = tile * IFSPAD_COLS;
            let pixels = IFSPAD_COLS.min(m_total - pixel_base);
            let transfer = self.cfg.transfer_cycles_per_row * 2 * pixels as u64;

            if functional {
                // Restore this tile's full Vmems into the NU.
                for p in 0..pixels {
                    for (c, kk) in (ks..ke).enumerate() {
                        full[p * neurons + c] = state.get(pixel_base + p, kk);
                    }
                }
                nm.load_vmems(&full);
            }

            for t in 0..timesteps {
                if functional {
                    partial[..pixels * neurons].fill(0);
                }
                for (i, dur) in durations.iter_mut().enumerate() {
                    let s = cache.get(tile, i, t);
                    // + the Fig.-13 "R" stage: partial-Vmem reset
                    dur[t] = s.stats.cycles + self.cfg.tile_reset_cycles;
                    // energy from this CU's (cached) tile execution
                    run.energy.compute_macro +=
                        s.stats.macro_ops as f64 * e.macro_op(wb);
                    run.energy.peripheral_switch +=
                        s.stats.parity_switches as f64 * e.e_parity_switch;
                    run.energy.s2a += s.stats.detect_rows as f64 * e.e_detect_row
                        + (s.stats.queue_pushes + s.stats.queue_pops) as f64
                            * e.e_queue_op;
                    run.energy.input_loader +=
                        s.load.spad_writes as f64 * e.e_il_write;
                    run.energy.ifmem += s.load.ifmem_reads as f64 * e.e_ifmem_read;
                    run.energy.control += s.stats.cycles as f64 * e.e_ctrl_cycle;
                    run.macro_ops += s.stats.macro_ops;
                    run.synops += s.stats.detect_spikes * neurons as u64;
                    run.parity_switches += s.stats.parity_switches;
                    // functional: fused replay, then chain-merge this
                    // CU's partials (identical structure to the
                    // reference interleave, see DESIGN.md §Perf)
                    if functional {
                        let cm = &mut cms[i];
                        cm.reset_vmems();
                        for &(y, x) in s.addrs() {
                            cm.op_row(y as usize, x as usize);
                        }
                        for p in 0..pixels {
                            let src = cm.vmem_entry(p);
                            let dst = &mut partial[p * neurons..(p + 1) * neurons];
                            for (d, &sv) in dst.iter_mut().zip(src) {
                                *d = overflow.apply(*d + sv, bits);
                            }
                        }
                    }
                }
                // transfers along the chain (CU→CU…→NU)
                run.energy.data_movement +=
                    chain_len as f64 * 2.0 * pixels as f64 * e.e_transfer_row;
                // neuron pass (fixed 66-cycle cost; arithmetic only on
                // the functional datapath)
                run.energy.neuron_units +=
                    NEURON_PASS_CYCLES as f64 * e.e_neuron_cycle;
                run.energy.control += NEURON_PASS_CYCLES as f64 * e.e_ctrl_cycle;
                if functional {
                    let out = nm.pass(&partial[..pixels * neurons], pixels);
                    if !layer.accumulate {
                        for p in 0..pixels {
                            for c in 0..neurons {
                                if out.spikes[p * neurons + c] != 0 {
                                    spikes.push((
                                        t as u32,
                                        c as u32,
                                        (pixel_base + p) as u32,
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            // persist the tile's full Vmems back to the group state
            if let Some(os) = out_state.as_mut() {
                let v = nm.vmems();
                for p in 0..pixels {
                    for c in 0..neurons {
                        os.set(pixel_base + p, c, v[p * neurons + c]);
                    }
                }
            }

            // timing for this pipeline over the tile
            let tl = pipeline_makespan(&durations, transfer, NEURON_PASS_CYCLES);
            let sync = synchronous_makespan(&durations, transfer, NEURON_PASS_CYCLES);
            let worst = worst_case_makespan(&durations, transfer, NEURON_PASS_CYCLES);
            let mk = tl.makespan;
            if want_timeline && tile == 0 {
                timeline = Some(tl);
            }
            per_tile.push((mk, sync, worst));
        }

        ChainOutcome {
            ks,
            ke,
            per_tile,
            run,
            state: out_state,
            spikes,
            timeline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;
    use crate::quant::Precision;
    use crate::snn::layer::{NeuronConfig, ResetMode};
    use crate::snn::network::{NetworkBuilder, NetworkState};

    fn mat_fill(rows: usize, cols: usize, f: impl Fn(usize, usize) -> i32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, f(r, c));
            }
        }
        m
    }

    fn conv_layer(in_ch: usize, out_ch: usize, h: usize, w: usize) -> Layer {
        let f = in_ch * 9;
        Layer::conv(
            (in_ch, h, w),
            out_ch,
            3,
            3,
            1,
            1,
            mat_fill(f, out_ch, |r, c| ((r * 31 + c * 7) % 11) as i32 - 5),
            NeuronConfig {
                theta: 4,
                leak: 1,
                leaky: true,
                reset: ResetMode::Soft,
            },
            false,
        )
        .unwrap()
    }

    fn random_frames(
        c: usize,
        h: usize,
        w: usize,
        t: usize,
        density: f64,
        seed: u64,
    ) -> Vec<SpikePlane> {
        let mut rng = crate::prop::SplitMix64::new(seed);
        (0..t)
            .map(|_| {
                let mut p = SpikePlane::zeros(c, h, w);
                for i in 0..p.len() {
                    if rng.chance(density) {
                        p.as_mut_slice()[i] = 1;
                    }
                }
                p
            })
            .collect()
    }

    #[test]
    fn mode_selection() {
        let core = SpidrCore::new(SimConfig::default());
        assert_eq!(core.select_mode(288).unwrap(), OperatingMode::Mode1);
        assert_eq!(core.select_mode(385).unwrap(), OperatingMode::Mode2);
        assert!(core.select_mode(1153).is_err());
    }

    #[test]
    fn sim_matches_reference_network() {
        // The core's functional output must equal Network::step's.
        let layer = conv_layer(2, 4, 6, 6);
        let frames = random_frames(2, 6, 6, 3, 0.3, 42);

        // reference
        let net = NetworkBuilder::new("t", Precision::W4V7, 3, (2, 6, 6))
            .conv3x3(4, layer.weights.clone().unwrap(), layer.neuron, false)
            .unwrap()
            .fc(
                1,
                mat_fill(4 * 36, 1, |_, _| 0),
                NeuronConfig::default(),
                true,
            )
            .unwrap()
            .build()
            .unwrap();
        let mut ref_state: NetworkState = net.init_state().unwrap();

        // simulator
        let core = SpidrCore::new(SimConfig::default());
        let mut sim_state = Mat::zeros(36, 4);
        let (sim_out, stats) = core.run_layer(&layer, &frames, &mut sim_state).unwrap();

        // step the reference layer-by-layer to extract layer-1 spikes
        for (t, f) in frames.iter().enumerate() {
            net.step(f, &mut ref_state).unwrap();
            // recompute reference layer output independently:
            // (Network::step consumed it internally; easiest check is
            // state equality below plus spike count sanity)
            let _ = t;
        }
        assert_eq!(
            ref_state.vmems[0].as_slice(),
            sim_state.as_slice(),
            "sim Vmem trajectory diverged from reference"
        );
        assert!(stats.run.macro_ops > 0);
        assert_eq!(sim_out.len(), 3);
    }

    #[test]
    fn multi_group_functional_matches_reference() {
        // 40 output channels -> 4 groups over 2 passes: the
        // group-parallel replay path must still be bit-exact.
        let layer = conv_layer(2, 40, 4, 4);
        let frames = random_frames(2, 4, 4, 3, 0.3, 11);
        let net = NetworkBuilder::new("t", Precision::W4V7, 3, (2, 4, 4))
            .conv3x3(40, layer.weights.clone().unwrap(), layer.neuron, false)
            .unwrap()
            .fc(
                1,
                mat_fill(40 * 16, 1, |_, _| 0),
                NeuronConfig::default(),
                true,
            )
            .unwrap()
            .build()
            .unwrap();
        let mut ref_state = net.init_state().unwrap();
        for f in &frames {
            net.step(f, &mut ref_state).unwrap();
        }
        let core = SpidrCore::new(SimConfig::default());
        let mut sim_state = Mat::zeros(16, 40);
        let (_, stats) = core.run_layer(&layer, &frames, &mut sim_state).unwrap();
        assert_eq!(stats.passes, 2);
        assert_eq!(
            ref_state.vmems[0].as_slice(),
            sim_state.as_slice(),
            "multi-group Vmem trajectory diverged from reference"
        );
    }

    #[test]
    fn stepwise_equals_whole_clip_run() {
        // Per-timestep stepping (the pipeline-stage API) must be
        // functionally identical to the whole-clip run: same Vmems,
        // same output spikes.
        let layer = conv_layer(2, 4, 6, 6);
        let frames = random_frames(2, 6, 6, 4, 0.3, 31);
        let core = SpidrCore::new(SimConfig::default());

        let mut whole_state = Mat::zeros(36, 4);
        let (whole_out, _) = core.run_layer(&layer, &frames, &mut whole_state).unwrap();

        let mut step_state = Mat::zeros(36, 4);
        let mut step_out = Vec::new();
        for f in &frames {
            let (o, st) = core.step_layer(&layer, f, &mut step_state).unwrap();
            assert_eq!(st.tiles, 3);
            step_out.push(o);
        }

        assert_eq!(whole_state.as_slice(), step_state.as_slice());
        for (a, b) in whole_out.iter().zip(&step_out) {
            assert_eq!(a.as_slice(), b.as_slice());
        }
    }

    #[test]
    fn multi_pass_when_channels_exceed_mode_capacity() {
        // 40 output channels at 4-bit: mode 1 maps 36/pass -> 2 passes.
        let layer = conv_layer(2, 40, 4, 4);
        let frames = random_frames(2, 4, 4, 1, 0.3, 7);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(16, 40);
        let (_, stats) = core.run_layer(&layer, &frames, &mut state).unwrap();
        assert_eq!(stats.passes, 2);
        assert_eq!(stats.mode, OperatingMode::Mode1);
    }

    #[test]
    fn mode2_used_for_large_fan_in() {
        // 48 input channels * 9 = 432 fan-in > 384 -> mode 2
        let layer = conv_layer(48, 4, 3, 3);
        let frames = random_frames(48, 3, 3, 1, 0.2, 9);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(9, 4);
        let (_, stats) = core.run_layer(&layer, &frames, &mut state).unwrap();
        assert_eq!(stats.mode, OperatingMode::Mode2);
    }

    #[test]
    fn sparser_input_is_cheaper() {
        let layer = conv_layer(2, 8, 8, 8);
        let core = SpidrCore::new(SimConfig::timing_only(Precision::W4V7));
        let dense = random_frames(2, 8, 8, 2, 0.4, 1);
        let sparse = random_frames(2, 8, 8, 2, 0.05, 1);
        let mut s1 = Mat::zeros(64, 8);
        let (_, st_dense) = core.run_layer(&layer, &dense, &mut s1).unwrap();
        let mut s2 = Mat::zeros(64, 8);
        let (_, st_sparse) = core.run_layer(&layer, &sparse, &mut s2).unwrap();
        assert!(st_sparse.run.cycles < st_dense.run.cycles);
        assert!(st_sparse.run.energy.total() < st_dense.run.energy.total());
    }

    #[test]
    fn async_beats_sync_beats_worst_case() {
        let layer = conv_layer(2, 4, 8, 8);
        let frames = random_frames(2, 8, 8, 4, 0.25, 3);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(64, 4);
        let (_, st) = core.run_layer(&layer, &frames, &mut state).unwrap();
        assert!(st.run.cycles <= st.run.sync_cycles);
        assert!(st.run.sync_cycles <= st.run.worst_case_cycles);
    }

    #[test]
    fn stats_independent_of_functional_flag() {
        // Timing/energy must not depend on whether the functional
        // datapath runs (it is value-independent by construction).
        let layer = conv_layer(2, 40, 6, 6);
        let frames = random_frames(2, 6, 6, 2, 0.25, 5);
        let run = |functional: bool| {
            let mut cfg = SimConfig::timing_only(Precision::W4V7);
            cfg.functional = functional;
            let core = SpidrCore::new(cfg);
            let mut state = Mat::zeros(36, 40);
            let (_, st) = core.run_layer(&layer, &frames, &mut state).unwrap();
            st
        };
        let a = run(true);
        let b = run(false);
        assert_eq!(a.run.cycles, b.run.cycles);
        assert_eq!(a.run.sync_cycles, b.run.sync_cycles);
        assert_eq!(a.run.worst_case_cycles, b.run.worst_case_cycles);
        assert_eq!(a.run.macro_ops, b.run.macro_ops);
        assert_eq!(a.run.parity_switches, b.run.parity_switches);
        assert!((a.run.energy.total() - b.run.energy.total()).abs() < 1e-6);
    }

    /// Tentpole invariant at the layer level: every lane of the
    /// batched executor — Vmems *and* output spikes — must be
    /// bit-identical to a per-clip `run_layer` of that clip, under
    /// wrap AND saturate, across random densities and batch sizes.
    #[test]
    fn prop_batched_layer_matches_per_clip() {
        use crate::quant::Overflow;
        use crate::snn::spikes::LaneFrame;
        check("batched_layer_equiv", 12, |g| {
            let out_ch = if g.chance(0.3) { 40 } else { 4 }; // multi-group sometimes
            let layer = conv_layer(2, out_ch, 5, 5);
            let overflow = if g.chance(0.5) {
                Overflow::Wrap
            } else {
                Overflow::Saturate
            };
            let cfg = SimConfig {
                overflow,
                ..SimConfig::default()
            };
            let core = SpidrCore::new(cfg);
            let lanes = 1 + g.index(8);
            let clips: Vec<Vec<SpikePlane>> = (0..lanes)
                .map(|_| {
                    // include the all-zero-lane (fully skipped) case
                    let density = if g.chance(0.2) { 0.0 } else { g.f64() * 0.5 };
                    random_frames(2, 5, 5, 3, density, g.u64())
                })
                .collect();
            let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
            let frames = LaneFrame::pack_clips(&refs).unwrap();

            let mut bank = LaneBank::zeros(25, out_ch, lanes);
            let (lane_out, _) = core.run_layer_lanes(&layer, &frames, &mut bank).unwrap();

            (0..lanes).all(|b| {
                let mut state = Mat::zeros(25, out_ch);
                let (out, _) = core.run_layer(&layer, &clips[b], &mut state).unwrap();
                bank.lane_mat(b).as_slice() == state.as_slice()
                    && out
                        .iter()
                        .zip(&lane_out)
                        .all(|(o, lf)| lf.lane(b).as_slice() == o.as_slice())
            })
        });
    }

    #[test]
    fn batched_degenerate_single_lane_matches() {
        // batch = 1: the lane datapath degenerates to the per-clip one
        let layer = conv_layer(2, 4, 6, 6);
        let frames = random_frames(2, 6, 6, 3, 0.3, 99);
        let core = SpidrCore::new(SimConfig::default());
        let mut state = Mat::zeros(36, 4);
        let (out, _) = core.run_layer(&layer, &frames, &mut state).unwrap();
        let lane_frames =
            crate::snn::spikes::LaneFrame::pack_clips(&[frames.as_slice()]).unwrap();
        let mut bank = LaneBank::zeros(36, 4, 1);
        let (lane_out, stats) = core.run_layer_lanes(&layer, &lane_frames, &mut bank).unwrap();
        assert_eq!(bank.lane_mat(0).as_slice(), state.as_slice());
        for (o, lf) in out.iter().zip(&lane_out) {
            assert_eq!(lf.lane(0).as_slice(), o.as_slice());
        }
        assert!(stats.run.cycles > 0);
        assert!(stats.run.macro_ops > 0);
    }

    #[test]
    fn batched_all_zero_batch_is_inert_and_cheap() {
        let layer = conv_layer(2, 4, 6, 6);
        let zeros: Vec<SpikePlane> = (0..3).map(|_| SpikePlane::zeros(2, 6, 6)).collect();
        let core = SpidrCore::new(SimConfig::default());
        let lane_frames =
            crate::snn::spikes::LaneFrame::pack_clips(&[&zeros[..], &zeros[..]]).unwrap();
        let mut bank = LaneBank::zeros(36, 4, 2);
        let (out, stats) = core.run_layer_lanes(&layer, &lane_frames, &mut bank).unwrap();
        // every cell skipped: no macro ops, no spikes, zero state
        assert_eq!(stats.run.macro_ops, 0);
        assert_eq!(stats.run.synops, 0);
        assert!(bank.lane_mat(0).as_slice().iter().all(|&v| v == 0));
        assert!(out.iter().all(|f| f.count_spikes() == 0));
    }

    #[test]
    fn prop_functional_independent_of_precision_geometry() {
        // Same weights, same inputs: functional Vmems must not depend
        // on timing knobs (fifo depth, switch cost, zero-skipping).
        check("functional_invariance", 10, |g| {
            let layer = conv_layer(1, 3, 5, 5);
            let frames = random_frames(1, 5, 5, 2, 0.3, g.u64());
            let mut base_state = Mat::zeros(25, 3);
            let core = SpidrCore::new(SimConfig::default());
            core.run_layer(&layer, &frames, &mut base_state).unwrap();

            let cfg = SimConfig {
                fifo_depth: 1 + g.index(32),
                parity_switch_cycles: g.u64_in(0..=4),
                zero_skipping: g.chance(0.5),
                ..SimConfig::default()
            };
            let core2 = SpidrCore::new(cfg);
            let mut state2 = Mat::zeros(25, 3);
            core2.run_layer(&layer, &frames, &mut state2).unwrap();
            base_state.as_slice() == state2.as_slice()
        });
    }
}
