//! The input loader: hardware im2col into the IFspad (paper §II-D).
//!
//! For each tile (a group of ≤16 output pixels) and each CU's fan-in
//! slice, the loader reads rows from the IFmem (the raw input spike
//! frame) and writes aligned rows into the IFspad, folding zero
//! padding and stride into the layout. The write port runs concurrently
//! with the S2A's read port, so detection starts as soon as the first
//! rows land — modeled by the per-row ready schedule this module emits.

use crate::snn::layer::{Layer, LayerKind};
use crate::snn::spikes::{LaneFrame, SpikePlane};

use super::ifspad::{IfSpad, LaneSpad};

/// Per-tile loader output: IFspad contents plus the write schedule.
#[derive(Debug, Clone)]
pub struct LoadedTile {
    /// Cycle at which each IFspad row became valid (one row per cycle
    /// through the write port, starting at cycle 1).
    pub row_ready: Vec<u64>,
    /// IFmem rows read to assemble this tile.
    pub ifmem_reads: u64,
    /// IFspad row writes performed.
    pub spad_writes: u64,
}

/// Fill the IFspad for one conv/FC tile.
///
/// * `layer` — the layer being executed.
/// * `input` — the input spike plane for this timestep.
/// * `pixel_base` — first output-pixel index of the tile (`m` index).
/// * `pixels` — pixels in this tile (≤ 16).
/// * `fan_lo..fan_hi` — this CU's fan-in slice.
pub fn load_tile(
    layer: &Layer,
    input: &SpikePlane,
    pixel_base: usize,
    pixels: usize,
    fan_lo: usize,
    fan_hi: usize,
    spad: &mut IfSpad,
) -> LoadedTile {
    debug_assert!(pixels <= super::config::IFSPAD_COLS);
    let rows = fan_hi - fan_lo;
    spad.clear(rows, pixels);

    let (_, _, wo) = layer.out_shape;
    let mut ready = Vec::with_capacity(rows);
    let mut ifmem_reads = 0u64;

    match layer.kind {
        LayerKind::Conv => {
            // Hot path (§Perf): decompose the fan-in index once per row
            // and walk output pixels incrementally instead of calling
            // patch_value per cell (saves 2 div/mod per cell).
            let kh = layer.kh;
            let kw = layer.kw;
            let stride = layer.stride as isize;
            let pad = layer.pad as isize;
            let (ih, iw) = (input.h as isize, input.w as isize);
            for (r, f) in (fan_lo..fan_hi).enumerate() {
                let c = f / (kh * kw);
                let rem = f % (kh * kw);
                let dy = (rem / kw) as isize;
                let dx = (rem % kw) as isize;
                let mut mask: u16 = 0;
                let mut oy = (pixel_base / wo) as isize;
                let mut ox = (pixel_base % wo) as isize;
                for p in 0..pixels {
                    let iy = oy * stride + dy - pad;
                    let ix = ox * stride + dx - pad;
                    if iy >= 0
                        && ix >= 0
                        && iy < ih
                        && ix < iw
                        && input.get(c, iy as usize, ix as usize) != 0
                    {
                        mask |= 1 << p;
                    }
                    ox += 1;
                    if ox == wo as isize {
                        ox = 0;
                        oy += 1;
                    }
                }
                debug_assert_eq!(mask & !((1u32 << pixels) as u16).wrapping_sub(1), 0);
                // §Perf: `clear` already zeroed the row; skip the
                // store for spike-free rows (the common case at high
                // sparsity). Stats are unaffected — the hardware write
                // happens either way.
                if mask != 0 {
                    spad.write_row(r, mask);
                }
                // The loader streams one IFmem row read + one IFspad
                // row write per cycle; row r is readable at cycle r+1.
                ready.push(r as u64 + 1);
                ifmem_reads += 1;
            }
        }
        LayerKind::Fc => {
            // FC: tile is the single output "pixel"; fan-in is the
            // flattened input. Each IFspad row holds one input bit in
            // column 0 (no weight reuse: only 2 of 32 Vmem rows used).
            let flat = input.as_slice();
            for (r, f) in (fan_lo..fan_hi).enumerate() {
                if flat[f] != 0 {
                    spad.write_row(r, 1);
                }
                ready.push(r as u64 + 1);
                ifmem_reads += 1;
            }
        }
        LayerKind::Pool => panic!("pool layers are not mapped to compute units"),
    }

    LoadedTile {
        row_ready: ready,
        ifmem_reads,
        spad_writes: rows as u64,
    }
}

/// Fill a [`LaneSpad`] for one conv/FC tile of a whole batch: the
/// lane-major mirror of [`load_tile`]. The same im2col walk runs once,
/// but each IFspad cell receives the input cell's full `u64` lane word,
/// so lane `b` of the scratchpad equals `load_tile` of clip `b`
/// (DESIGN.md §Perf). No per-row ready schedule is emitted — the
/// batched path models a sequential union sweep, not the dual-port
/// cycle interleave.
pub fn load_tile_lanes(
    layer: &Layer,
    input: &LaneFrame,
    pixel_base: usize,
    pixels: usize,
    fan_lo: usize,
    fan_hi: usize,
    spad: &mut LaneSpad,
) {
    debug_assert!(pixels <= super::config::IFSPAD_COLS);
    let rows = fan_hi - fan_lo;
    spad.clear(rows, pixels);

    let plane = input.plane();
    let (_, _, wo) = layer.out_shape;

    match layer.kind {
        LayerKind::Conv => {
            let kh = layer.kh;
            let kw = layer.kw;
            let stride = layer.stride as isize;
            let pad = layer.pad as isize;
            let (ih, iw) = (plane.h as isize, plane.w as isize);
            for (r, f) in (fan_lo..fan_hi).enumerate() {
                let c = f / (kh * kw);
                let rem = f % (kh * kw);
                let dy = (rem / kw) as isize;
                let dx = (rem % kw) as isize;
                let mut oy = (pixel_base / wo) as isize;
                let mut ox = (pixel_base % wo) as isize;
                for p in 0..pixels {
                    let iy = oy * stride + dy - pad;
                    let ix = ox * stride + dx - pad;
                    if iy >= 0 && ix >= 0 && iy < ih && ix < iw {
                        let word = plane.get(c, iy as usize, ix as usize);
                        if word != 0 {
                            spad.set_word(r, p, word);
                        }
                    }
                    ox += 1;
                    if ox == wo as isize {
                        ox = 0;
                        oy += 1;
                    }
                }
            }
        }
        LayerKind::Fc => {
            let flat = plane.as_slice();
            for (r, f) in (fan_lo..fan_hi).enumerate() {
                if flat[f] != 0 {
                    spad.set_word(r, 0, flat[f]);
                }
            }
        }
        LayerKind::Pool => panic!("pool layers are not mapped to compute units"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::layer::NeuronConfig;
    use crate::snn::tensor::Mat;

    fn conv_layer() -> Layer {
        Layer::conv(
            (1, 4, 4),
            2,
            3,
            3,
            1,
            1,
            Mat::zeros(9, 2),
            NeuronConfig::default(),
            false,
        )
        .unwrap()
    }

    #[test]
    fn conv_tile_matches_patch_values() {
        let layer = conv_layer();
        let mut input = SpikePlane::zeros(1, 4, 4);
        input.set(0, 1, 1, 1);
        input.set(0, 2, 3, 1);
        let mut spad = IfSpad::new();
        let t = load_tile(&layer, &input, 0, 16, 0, 9, &mut spad);
        assert_eq!(t.spad_writes, 9);
        assert_eq!(t.ifmem_reads, 9);
        // spot-check: output pixel m=0 (0,0), tap f=8 is input (1,1)
        assert!(spad.read(8, 0));
        // output pixel m=5 (1,1), center tap f=4 is input (1,1)
        assert!(spad.read(4, 5));
    }

    #[test]
    fn fan_in_slicing() {
        let layer = conv_layer();
        let mut input = SpikePlane::zeros(1, 4, 4);
        input.set(0, 1, 1, 1);
        let mut spad = IfSpad::new();
        load_tile(&layer, &input, 0, 16, 4, 9, &mut spad);
        assert_eq!(spad.valid_rows, 5);
        // f=4 now lands at local row 0
        assert!(spad.read(0, 5));
    }

    #[test]
    fn partial_tile_fewer_cols() {
        let layer = conv_layer();
        let input = SpikePlane::zeros(1, 4, 4);
        let mut spad = IfSpad::new();
        load_tile(&layer, &input, 0, 7, 0, 9, &mut spad);
        assert_eq!(spad.valid_cols, 7);
    }

    #[test]
    fn fc_tile_uses_column_zero() {
        let layer = Layer::fc(
            (1, 2, 2),
            3,
            Mat::zeros(4, 3),
            NeuronConfig::default(),
            true,
        )
        .unwrap();
        let mut input = SpikePlane::zeros(1, 2, 2);
        input.set(0, 1, 0, 1); // flat index 2
        let mut spad = IfSpad::new();
        load_tile(&layer, &input, 0, 1, 0, 4, &mut spad);
        assert!(spad.read(2, 0));
        assert!(!spad.read(1, 0));
        assert_eq!(spad.count_spikes(), 1);
    }

    #[test]
    fn ready_schedule_is_streaming() {
        let layer = conv_layer();
        let input = SpikePlane::zeros(1, 4, 4);
        let mut spad = IfSpad::new();
        let t = load_tile(&layer, &input, 0, 16, 0, 9, &mut spad);
        assert_eq!(t.row_ready, (1..=9).collect::<Vec<u64>>());
    }

    #[test]
    fn lane_load_matches_per_clip_load() {
        let layer = conv_layer();
        let mut rng = crate::prop::SplitMix64::new(0xBA7C);
        let clips: Vec<SpikePlane> = (0..5)
            .map(|_| {
                let mut p = SpikePlane::zeros(1, 4, 4);
                for cell in p.as_mut_slice() {
                    if rng.chance(0.4) {
                        *cell = 1;
                    }
                }
                p
            })
            .collect();
        let refs: Vec<&SpikePlane> = clips.iter().collect();
        let frame = LaneFrame::pack(&refs).unwrap();
        let mut lanes = LaneSpad::new();
        load_tile_lanes(&layer, &frame, 0, 16, 0, 9, &mut lanes);
        for (b, clip) in clips.iter().enumerate() {
            let mut spad = IfSpad::new();
            load_tile(&layer, clip, 0, 16, 0, 9, &mut spad);
            for y in 0..9 {
                for x in 0..16 {
                    assert_eq!(
                        (lanes.word(y, x) >> b) & 1 != 0,
                        spad.read(y, x),
                        "lane {b} cell ({y},{x})"
                    );
                }
            }
        }
    }
}
