//! Tile-stream caching: run the input loader + S2A detector **once**
//! per `(tile, fan-slice, timestep)` and reuse the result across every
//! channel group, pass and pipeline (§Perf; DESIGN.md §Perf).
//!
//! The spike content of a tile depends only on the layer geometry, the
//! input frame, the tile's pixel window and the CU's fan-in slice —
//! *not* on which output-channel group is currently mapped. The
//! weight-stationary schedule therefore used to redo identical host
//! work (hardware-im2col into the IFspad plus the cycle-accurate S2A /
//! controller interleave) once per `(pass × pipeline)` combination. A
//! [`TileStream`] captures everything that interleave produces that is
//! weight-independent:
//!
//! * the extracted `(Y, X)` spike-address list in detector order,
//! * the full cycle-accurate [`TileCuStats`] (cycles, FIFO traffic,
//!   parity switches, stalls), and
//! * the loader's read/write counts.
//!
//! Functional execution then *replays* the address list into a
//! [`ComputeMacro`](super::compute_macro::ComputeMacro) via the fused
//! `op_row` pass, and timing/energy accounting reads the cached stats.
//! Replay is bit-exact against the interleave — including under
//! saturating overflow — because both FIFOs preserve extraction order,
//! so every Vmem element sees the same additions in the same order
//! (see `prop_stream_replay_bit_identical` below and DESIGN.md §Perf).

use crate::snn::layer::Layer;
use crate::snn::spikes::{LaneFrame, SpikePlane};

use super::compute_macro::ComputeMacro;
use super::config::{SimConfig, IFSPAD_COLS};
use super::ifspad::{IfSpad, LaneSpad};
use super::input_loader::{load_tile, load_tile_lanes};
use super::s2a::{
    extract_addresses, extract_lane_addresses, run_tile, run_tile_dense, LaneAddr, S2aOptions,
    TileCuStats,
};

/// Loader statistics kept per stream (the `row_ready` schedule is
/// consumed during the build and not retained — it would dominate the
/// cache's memory footprint on large layers).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadStats {
    /// IFmem rows read to assemble the tile.
    pub ifmem_reads: u64,
    /// IFspad row writes performed.
    pub spad_writes: u64,
}

/// One precomputed, weight-independent tile execution.
#[derive(Debug, Clone)]
pub struct TileStream {
    /// Spike addresses in detector-extraction order (empty in
    /// timing-only runs, where no replay happens).
    addrs: Vec<(u8, u8)>,
    /// Cycle-accurate S2A + controller statistics.
    pub stats: TileCuStats,
    /// Loader statistics.
    pub load: LoadStats,
}

impl TileStream {
    /// The `(Y, X)` spike-address list, in detector-extraction order.
    pub fn addrs(&self) -> &[(u8, u8)] {
        &self.addrs
    }
}

/// All of a layer's tile streams, indexed by `(tile, slice, timestep)`.
#[derive(Debug, Clone)]
pub struct StreamCache {
    streams: Vec<TileStream>,
    slices: usize,
    timesteps: usize,
}

impl StreamCache {
    /// Build every stream for a layer run.
    ///
    /// * `slices` — the per-CU fan-in slices (identical for every
    ///   pipeline of the mode, which is what makes the cache shareable).
    /// * `tiles` / `m_total` — the pixel tiling of the output plane.
    ///
    /// Tiles are independent, so the build fans out over host threads
    /// when there is enough work to amortize the spawns.
    pub fn build(
        layer: &Layer,
        inputs: &[SpikePlane],
        slices: &[(usize, usize)],
        tiles: usize,
        m_total: usize,
        cfg: &SimConfig,
    ) -> StreamCache {
        let timesteps = inputs.len();
        let entries = tiles * slices.len() * timesteps;
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(tiles);
        let streams = if workers <= 1 || entries < 64 {
            build_tile_range(layer, inputs, slices, 0, tiles, m_total, cfg)
        } else {
            let chunk = tiles.div_ceil(workers);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|wi| {
                        let lo = (wi * chunk).min(tiles);
                        let hi = ((wi + 1) * chunk).min(tiles);
                        scope.spawn(move || {
                            build_tile_range(layer, inputs, slices, lo, hi, m_total, cfg)
                        })
                    })
                    .collect();
                let mut all = Vec::with_capacity(entries);
                for h in handles {
                    all.extend(h.join().expect("stream-build thread panicked"));
                }
                all
            })
        };
        debug_assert_eq!(streams.len(), entries);
        StreamCache {
            streams,
            slices: slices.len(),
            timesteps,
        }
    }

    /// The stream for `(tile, slice, timestep)`.
    #[inline]
    pub fn get(&self, tile: usize, slice: usize, t: usize) -> &TileStream {
        debug_assert!(slice < self.slices && t < self.timesteps);
        &self.streams[(tile * self.slices + slice) * self.timesteps + t]
    }

    /// Timesteps covered per `(tile, slice)` pair.
    pub fn timesteps(&self) -> usize {
        self.timesteps
    }

    /// Total cached streams (diagnostics).
    pub fn len(&self) -> usize {
        self.streams.len()
    }

    /// True when the cache holds no streams.
    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }
}

/// Build the streams of tiles `tile_lo..tile_hi`, in
/// `(tile, slice, timestep)` index order.
fn build_tile_range(
    layer: &Layer,
    inputs: &[SpikePlane],
    slices: &[(usize, usize)],
    tile_lo: usize,
    tile_hi: usize,
    m_total: usize,
    cfg: &SimConfig,
) -> Vec<TileStream> {
    let opts = S2aOptions {
        fifo_depth: cfg.fifo_depth,
        switch_cycles: cfg.parity_switch_cycles,
        ping_pong: true,
        detector_cycles_per_spike: cfg.detector_cycles_per_spike,
    };
    let mut spad = IfSpad::new();
    let mut out = Vec::with_capacity((tile_hi - tile_lo) * slices.len() * inputs.len());
    for tile in tile_lo..tile_hi {
        let pixel_base = tile * IFSPAD_COLS;
        let pixels = IFSPAD_COLS.min(m_total - pixel_base);
        for &(lo, hi) in slices {
            // Timing-only macro: `run_tile` needs a macro for its ops,
            // but stats are weight- and value-independent, so a
            // 1-neuron no-op geometry suffices.
            let mut cm = ComputeMacro::timing_only(hi - lo, 1, cfg.precision.vmem_bits());
            for input in inputs {
                let load = load_tile(layer, input, pixel_base, pixels, lo, hi, &mut spad);
                let stats = if cfg.zero_skipping {
                    run_tile(&spad, &load.row_ready, &mut cm, &opts)
                } else {
                    run_tile_dense(&spad, &mut cm, &opts)
                };
                let addrs = if cfg.functional {
                    extract_addresses(&spad)
                } else {
                    Vec::new()
                };
                out.push(TileStream {
                    addrs,
                    stats,
                    load: LoadStats {
                        ifmem_reads: load.ifmem_reads,
                        spad_writes: load.spad_writes,
                    },
                });
            }
        }
    }
    out
}

/// One precomputed *batched* tile execution: the union address stream
/// of up to 64 clips plus aggregate counters. The whole point of the
/// batched datapath (DESIGN.md §Perf): the im2col walk and the address
/// extraction run **once per batch** instead of once per clip.
#[derive(Debug, Clone)]
pub struct LaneTileStream {
    /// Union spike addresses with lane words, sorted `(y, x)` — the
    /// same order [`extract_addresses`] yields per clip.
    addrs: Vec<LaneAddr>,
    /// Total per-lane accumulations this stream triggers (Σ popcounts
    /// of the address words) — the batched synop counter.
    pub lane_ops: u64,
    /// Loader statistics (one batched load, counted once).
    pub load: LoadStats,
}

impl LaneTileStream {
    /// The union address list in sorted `(y, x)` order.
    pub fn addrs(&self) -> &[LaneAddr] {
        &self.addrs
    }
}

/// All of a layer's batched tile streams, indexed by
/// `(tile, slice, timestep)` — the lane-major mirror of
/// [`StreamCache`].
#[derive(Debug, Clone)]
pub struct LaneStreamCache {
    streams: Vec<LaneTileStream>,
    slices: usize,
    timesteps: usize,
}

impl LaneStreamCache {
    /// Build every batched stream for a layer run (same tiling
    /// contract as [`StreamCache::build`]; fans out over host threads
    /// when there is enough work).
    pub fn build(
        layer: &Layer,
        inputs: &[LaneFrame],
        slices: &[(usize, usize)],
        tiles: usize,
        m_total: usize,
    ) -> LaneStreamCache {
        let timesteps = inputs.len();
        let entries = tiles * slices.len() * timesteps;
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(tiles);
        let streams = if workers <= 1 || entries < 64 {
            build_lane_tile_range(layer, inputs, slices, 0, tiles, m_total)
        } else {
            let chunk = tiles.div_ceil(workers);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|wi| {
                        let lo = (wi * chunk).min(tiles);
                        let hi = ((wi + 1) * chunk).min(tiles);
                        scope.spawn(move || {
                            build_lane_tile_range(layer, inputs, slices, lo, hi, m_total)
                        })
                    })
                    .collect();
                let mut all = Vec::with_capacity(entries);
                for h in handles {
                    all.extend(h.join().expect("lane-stream-build thread panicked"));
                }
                all
            })
        };
        debug_assert_eq!(streams.len(), entries);
        LaneStreamCache {
            streams,
            slices: slices.len(),
            timesteps,
        }
    }

    /// The stream for `(tile, slice, timestep)`.
    #[inline]
    pub fn get(&self, tile: usize, slice: usize, t: usize) -> &LaneTileStream {
        debug_assert!(slice < self.slices && t < self.timesteps);
        &self.streams[(tile * self.slices + slice) * self.timesteps + t]
    }

    /// Timesteps covered per `(tile, slice)` pair.
    pub fn timesteps(&self) -> usize {
        self.timesteps
    }

    /// Total cached streams (diagnostics).
    pub fn len(&self) -> usize {
        self.streams.len()
    }

    /// True when the cache holds no streams.
    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }
}

/// Build the batched streams of tiles `tile_lo..tile_hi`, in
/// `(tile, slice, timestep)` index order.
fn build_lane_tile_range(
    layer: &Layer,
    inputs: &[LaneFrame],
    slices: &[(usize, usize)],
    tile_lo: usize,
    tile_hi: usize,
    m_total: usize,
) -> Vec<LaneTileStream> {
    let mut spad = LaneSpad::new();
    let mut out = Vec::with_capacity((tile_hi - tile_lo) * slices.len() * inputs.len());
    for tile in tile_lo..tile_hi {
        let pixel_base = tile * IFSPAD_COLS;
        let pixels = IFSPAD_COLS.min(m_total - pixel_base);
        for &(lo, hi) in slices {
            for input in inputs {
                load_tile_lanes(layer, input, pixel_base, pixels, lo, hi, &mut spad);
                let addrs = extract_lane_addresses(&spad);
                let lane_ops: u64 = addrs.iter().map(|a| a.word.count_ones() as u64).sum();
                out.push(LaneTileStream {
                    addrs,
                    lane_ops,
                    load: LoadStats {
                        ifmem_reads: (hi - lo) as u64,
                        spad_writes: (hi - lo) as u64,
                    },
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;
    use crate::quant::Overflow;
    use crate::sim::compute_unit::ComputeUnit;
    use crate::snn::layer::NeuronConfig;
    use crate::snn::tensor::Mat;

    fn rand_layer_and_input(g: &mut crate::prop::Gen) -> (Layer, SpikePlane) {
        let in_ch = 1 + g.index(2);
        let h = 3 + g.index(4);
        let w = 3 + g.index(4);
        let out_ch = 1 + g.index(6);
        let fan = in_ch * 9;
        let mut wm = Mat::zeros(fan, out_ch);
        for r in 0..fan {
            for c in 0..out_ch {
                wm.set(r, c, g.i32_in(-8..=7));
            }
        }
        let layer = Layer::conv(
            (in_ch, h, w),
            out_ch,
            3,
            3,
            1,
            1,
            wm,
            NeuronConfig::default(),
            false,
        )
        .unwrap();
        let density = g.f64() * 0.6;
        let mut input = SpikePlane::zeros(in_ch, h, w);
        for i in 0..input.len() {
            if g.chance(density) {
                input.as_mut_slice()[i] = 1;
            }
        }
        (layer, input)
    }

    /// Satellite: the fast path must be *bit-identical* to the old
    /// `run_tile` interleave — Vmems and every `TileCuStats` field —
    /// across random tiles, densities, FIFO depths, switch costs,
    /// overflow policies and the dense (no-zero-skipping) mode.
    /// `ComputeUnit::process_tile` stays as the reference
    /// implementation.
    #[test]
    fn prop_stream_replay_bit_identical() {
        check("stream_replay_equiv", 40, |g| {
            let (layer, input) = rand_layer_and_input(g);
            let cfg = SimConfig {
                fifo_depth: 1 + g.index(32),
                parity_switch_cycles: g.u64_in(0..=4),
                detector_cycles_per_spike: g.u64_in(1..=3),
                zero_skipping: g.chance(0.8),
                overflow: if g.chance(0.5) {
                    Overflow::Wrap
                } else {
                    Overflow::Saturate
                },
                ..SimConfig::default()
            };
            let fan = layer.fan_in();
            let (m_total, _) = layer.vmem_shape().unwrap();
            let pixels = m_total.min(IFSPAD_COLS);
            let wmat = layer.weights.clone().unwrap();

            // Reference: the original loader + interleave.
            let mut cu = ComputeUnit::new(0, fan, wmat.clone(), &cfg);
            let r = cu.process_tile(&layer, &input, 0, pixels);

            // Fast path: cached stream + fused replay.
            let inputs = [input];
            let cache = StreamCache::build(&layer, &inputs, &[(0, fan)], 1, m_total, &cfg);
            let s = cache.get(0, 0, 0);
            if s.stats != r.stats {
                return false;
            }
            if s.load.ifmem_reads != r.load.ifmem_reads
                || s.load.spad_writes != r.load.spad_writes
            {
                return false;
            }
            let mut cm = ComputeMacro::new(
                wmat,
                cfg.precision.vmem_bits(),
                cfg.overflow,
                true,
            );
            for &(y, x) in s.addrs() {
                cm.op_row(y as usize, x as usize);
            }
            (0..pixels).all(|p| cm.vmem_entry(p) == cu.partial_entry(p))
        });
    }

    #[test]
    fn cache_indexing_covers_all_timesteps_and_slices() {
        let mut wm = Mat::zeros(18, 4);
        for r in 0..18 {
            wm.set(r, 0, 1);
        }
        let layer = Layer::conv((2, 8, 8), 4, 3, 3, 1, 1, wm, NeuronConfig::default(), false)
            .unwrap();
        let mut inputs = Vec::new();
        for t in 0..3 {
            let mut p = SpikePlane::zeros(2, 8, 8);
            p.set(0, t, t, 1);
            inputs.push(p);
        }
        let slices = [(0usize, 9usize), (9, 18)];
        let cache = StreamCache::build(&layer, &inputs, &slices, 4, 64, &SimConfig::default());
        assert_eq!(cache.len(), 4 * 2 * 3);
        assert!(!cache.is_empty());
        // every entry carries a full loader schedule's worth of rows
        for tile in 0..4 {
            for si in 0..2 {
                for t in 0..3 {
                    assert_eq!(cache.get(tile, si, t).load.spad_writes, 9);
                }
            }
        }
    }

    /// Per-lane restriction of the batched cache must reproduce the
    /// per-clip cache's address stream exactly, tile by tile.
    #[test]
    fn prop_lane_cache_restricts_to_per_clip_streams() {
        check("lane_cache_restrict", 25, |g| {
            let (layer, _) = rand_layer_and_input(g);
            let (in_ch, h, w) = layer.in_shape;
            let lanes = 1 + g.index(8);
            let clips: Vec<Vec<SpikePlane>> = (0..lanes)
                .map(|_| {
                    let density = g.f64() * 0.6;
                    (0..2)
                        .map(|_| {
                            let mut p = SpikePlane::zeros(in_ch, h, w);
                            for i in 0..p.len() {
                                if g.chance(density) {
                                    p.as_mut_slice()[i] = 1;
                                }
                            }
                            p
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[SpikePlane]> = clips.iter().map(|c| c.as_slice()).collect();
            let frames = LaneFrame::pack_clips(&refs).unwrap();
            let fan = layer.fan_in();
            let (m_total, _) = layer.vmem_shape().unwrap();
            let tiles = m_total.div_ceil(IFSPAD_COLS);
            let cfg = SimConfig::default();
            let lane_cache =
                LaneStreamCache::build(&layer, &frames, &[(0, fan)], tiles, m_total);
            (0..lanes).all(|b| {
                let clip = &clips[b];
                let cache =
                    StreamCache::build(&layer, clip, &[(0, fan)], tiles, m_total, &cfg);
                (0..tiles).all(|tile| {
                    (0..2).all(|t| {
                        let restricted: Vec<(u8, u8)> = lane_cache
                            .get(tile, 0, t)
                            .addrs()
                            .iter()
                            .filter(|a| a.word >> b & 1 != 0)
                            .map(|a| (a.y, a.x))
                            .collect();
                        restricted == cache.get(tile, 0, t).addrs()
                    })
                })
            })
        });
    }

    #[test]
    fn timing_only_cache_skips_address_storage() {
        let mut wm = Mat::zeros(9, 2);
        wm.set(0, 0, 1);
        let layer = Layer::conv((1, 6, 6), 2, 3, 3, 1, 1, wm, NeuronConfig::default(), false)
            .unwrap();
        let mut p = SpikePlane::zeros(1, 6, 6);
        for i in 0..p.len() {
            p.as_mut_slice()[i] = 1;
        }
        let cfg = SimConfig::timing_only(crate::quant::Precision::W4V7);
        let cache = StreamCache::build(&layer, &[p], &[(0, 9)], 3, 36, &cfg);
        for tile in 0..3 {
            let s = cache.get(tile, 0, 0);
            assert!(s.addrs().is_empty());
            assert!(s.stats.detect_spikes > 0);
        }
    }
}
