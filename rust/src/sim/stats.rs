//! Aggregated run statistics and derived metrics.

use crate::energy::model::{Corner, EnergyBreakdown, EnergyParams};

/// Statistics accumulated over a whole run (layers x timesteps).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Total core-busy cycles (asynchronous-pipeline makespan).
    pub cycles: u64,
    /// What a lockstep-synchronous pipeline would have taken.
    pub sync_cycles: u64,
    /// What a worst-case-provisioned pipeline would have taken.
    pub worst_case_cycles: u64,
    /// Dynamic energy by component (pJ at the 0.9 V reference).
    pub energy: EnergyBreakdown,
    /// Macro accumulation passes executed.
    pub macro_ops: u64,
    /// Executed synaptic operations (spike-triggered accumulates).
    pub synops: u64,
    /// Dense-equivalent synaptic operations (the GOPS denominator).
    pub dense_synops: u64,
    /// Parity switches.
    pub parity_switches: u64,
    /// Input spikes consumed.
    pub spikes: u64,
    /// Input cells observed (for sparsity).
    pub cells: u64,
}

impl RunStats {
    /// Merge another run's statistics (sequential composition).
    pub fn add(&mut self, o: &RunStats) {
        self.cycles += o.cycles;
        self.sync_cycles += o.sync_cycles;
        self.worst_case_cycles += o.worst_case_cycles;
        self.energy.add(&o.energy);
        self.macro_ops += o.macro_ops;
        self.synops += o.synops;
        self.dense_synops += o.dense_synops;
        self.parity_switches += o.parity_switches;
        self.spikes += o.spikes;
        self.cells += o.cells;
    }

    /// Mean input sparsity over the run.
    pub fn sparsity(&self) -> f64 {
        if self.cells == 0 {
            return 1.0;
        }
        1.0 - self.spikes as f64 / self.cells as f64
    }

    /// Finalize leakage for a corner (leak power x wall time).
    pub fn finalize_leakage(&mut self, corner: Corner, params: &EnergyParams) {
        let leak_scale = (corner.voltage / 0.9).powi(2);
        self.energy.leakage =
            params.p_leak_mw * leak_scale * corner.period_ns() * self.cycles as f64;
    }

    /// Total energy at a corner in pJ (dynamic scaled by V², leakage
    /// must have been finalized for the same corner).
    pub fn total_energy_pj(&self, corner: Corner) -> f64 {
        let mut e = self.energy;
        let leak = e.leakage;
        e.leakage = 0.0;
        e.total() * corner.dynamic_scale() + leak
    }

    /// Wall-clock seconds at a corner.
    pub fn seconds(&self, corner: Corner) -> f64 {
        self.cycles as f64 * corner.period_ns() * 1e-9
    }

    /// Effective throughput in GOPS (dense-equivalent ops / time) —
    /// the paper's throughput convention for sparse workloads.
    pub fn gops(&self, corner: Corner) -> f64 {
        let s = self.seconds(corner);
        if s == 0.0 {
            return 0.0;
        }
        self.dense_synops as f64 / s / 1e9
    }

    /// Energy efficiency in TOPS/W (dense-equivalent ops per joule).
    pub fn tops_per_watt(&self, corner: Corner) -> f64 {
        let e = self.total_energy_pj(corner);
        if e == 0.0 {
            return 0.0;
        }
        self.dense_synops as f64 / e
    }

    /// Average power in mW at a corner.
    pub fn power_mw(&self, corner: Corner) -> f64 {
        let s = self.seconds(corner);
        if s == 0.0 {
            return 0.0;
        }
        self.total_energy_pj(corner) * 1e-12 / s * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> RunStats {
        let mut s = RunStats {
            cycles: 1_000_000,
            dense_synops: 500_000_000,
            spikes: 50,
            cells: 1000,
            ..Default::default()
        };
        s.energy.compute_macro = 2_000_000.0;
        s.energy.neuron_units = 500_000.0;
        s
    }

    #[test]
    fn sparsity() {
        assert!((stats().sparsity() - 0.95).abs() < 1e-12);
    }

    #[test]
    fn gops_scales_with_frequency() {
        let s = stats();
        let lo = s.gops(Corner::LOW);
        let hi = s.gops(Corner::HIGH);
        assert!((hi / lo - 3.0).abs() < 1e-9);
    }

    #[test]
    fn tops_w_inverse_to_energy() {
        let mut s = stats();
        s.finalize_leakage(Corner::LOW, &EnergyParams::default());
        let t1 = s.tops_per_watt(Corner::LOW);
        s.energy.compute_macro *= 2.0;
        let t2 = s.tops_per_watt(Corner::LOW);
        assert!(t2 < t1);
    }

    #[test]
    fn power_consistent_with_energy_and_time() {
        let mut s = stats();
        s.finalize_leakage(Corner::LOW, &EnergyParams::default());
        let p = s.power_mw(Corner::LOW);
        let expect =
            s.total_energy_pj(Corner::LOW) * 1e-12 / s.seconds(Corner::LOW) * 1e3;
        assert!((p - expect).abs() < 1e-9);
    }

    #[test]
    fn add_accumulates() {
        let mut a = stats();
        let b = stats();
        a.add(&b);
        assert_eq!(a.cycles, 2_000_000);
        assert_eq!(a.dense_synops, 1_000_000_000);
    }
}
