//! The 72x48 neuron macro (paper §II-A).
//!
//! 32 rows hold incoming partial Vmems, 32 rows hold full Vmems, and 8
//! parameter rows hold thresholds/leaks. One pass costs 66 cycles
//! (eq. 3: 2·32 partial→full accumulation + threshold cycles, +2
//! pipeline fill/drain) regardless of spike activity — the fixed-time
//! stage the asynchronous handshake hides behind variable CU times.

use crate::quant::Overflow;
use crate::snn::layer::{NeuronConfig, ResetMode};

use super::config::{IFSPAD_COLS, NEURON_PASS_CYCLES};

/// One neuron macro holding full Vmems for the current tile.
#[derive(Debug, Clone)]
pub struct NeuronMacro {
    /// Full Vmems: `IFSPAD_COLS` entries x `neurons`, row-major.
    vmem: Vec<i32>,
    /// Neurons per entry.
    pub neurons: usize,
    /// Vmem bit width.
    pub vmem_bits: u32,
    /// Overflow policy.
    pub overflow: Overflow,
    /// Neuron dynamics configuration (from the parameter rows).
    pub config: NeuronConfig,
    /// Non-spiking accumulator mode (output layers).
    pub accumulate: bool,
}

/// Result of one neuron pass.
#[derive(Debug, Clone)]
pub struct NeuronPass {
    /// Spikes emitted: `entries x neurons`, row-major (empty in
    /// accumulate mode).
    pub spikes: Vec<u8>,
    /// Fixed pass latency in cycles.
    pub cycles: u64,
}

impl NeuronMacro {
    /// New neuron macro for up to `neurons` mapped columns.
    pub fn new(
        neurons: usize,
        vmem_bits: u32,
        overflow: Overflow,
        config: NeuronConfig,
        accumulate: bool,
    ) -> Self {
        NeuronMacro {
            vmem: vec![0; IFSPAD_COLS * neurons],
            neurons,
            vmem_bits,
            overflow,
            config,
            accumulate,
        }
    }

    /// Load full Vmems for a new tile (restored from the layer's state).
    pub fn load_vmems(&mut self, values: &[i32]) {
        debug_assert_eq!(values.len(), self.vmem.len());
        self.vmem.copy_from_slice(values);
    }

    /// Current full Vmems (to persist back into layer state).
    pub fn vmems(&self) -> &[i32] {
        &self.vmem
    }

    /// Run one pass: shift-leak, integrate partials, fire, reset,
    /// floor-clamp (the ordering contract of
    /// `kernels/ref.py::neuron_update_ref`).
    ///
    /// `partials` is `entries x neurons` row-major, `entries` the
    /// number of valid Vmem entries in the tile.
    pub fn pass(&mut self, partials: &[i32], entries: usize) -> NeuronPass {
        debug_assert!(entries <= IFSPAD_COLS);
        debug_assert_eq!(partials.len(), entries * self.neurons);
        let mut spikes = if self.accumulate {
            Vec::new()
        } else {
            vec![0u8; entries * self.neurons]
        };
        let NeuronConfig {
            theta,
            leak,
            leaky,
            reset,
        } = self.config;
        for e in 0..entries {
            for k in 0..self.neurons {
                let idx = e * self.neurons + k;
                let mut v = self.vmem[idx];
                if !self.accumulate && leaky && leak > 0 {
                    v -= v >> leak.clamp(1, 30) as u32;
                }
                v = self.overflow.apply(v + partials[idx], self.vmem_bits);
                if !self.accumulate && v >= theta {
                    spikes[idx] = 1;
                    v = match reset {
                        ResetMode::Hard => 0,
                        ResetMode::Soft => {
                            self.overflow.apply(v - theta, self.vmem_bits)
                        }
                    };
                }
                if !self.accumulate {
                    // digital underflow floor (see DESIGN.md §2)
                    v = v.max(-theta);
                }
                self.vmem[idx] = v;
            }
        }
        NeuronPass {
            spikes,
            cycles: NEURON_PASS_CYCLES,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::wrap_to_bits;

    fn nm(theta: i32, leaky: bool, reset: ResetMode, accumulate: bool) -> NeuronMacro {
        NeuronMacro::new(
            4,
            7,
            Overflow::Wrap,
            NeuronConfig {
                theta,
                leak: 2,
                leaky,
                reset,
            },
            accumulate,
        )
    }

    #[test]
    fn pass_cost_is_fixed_66() {
        let mut m = nm(10, false, ResetMode::Soft, false);
        let p = vec![0i32; 16 * 4];
        assert_eq!(m.pass(&p, 16).cycles, 66);
    }

    #[test]
    fn integrate_fire_soft_reset() {
        let mut m = nm(10, false, ResetMode::Soft, false);
        let mut partials = vec![0i32; 4];
        partials[0] = 25;
        let out = m.pass(&partials, 1);
        assert_eq!(out.spikes[0], 1);
        assert_eq!(m.vmems()[0], 15); // 25 - 10
    }

    #[test]
    fn integrate_fire_hard_reset() {
        let mut m = nm(10, false, ResetMode::Hard, false);
        let mut partials = vec![0i32; 4];
        partials[0] = 25;
        m.pass(&partials, 1);
        assert_eq!(m.vmems()[0], 0);
    }

    #[test]
    fn leak_applies_before_integration() {
        let mut m = nm(100, true, ResetMode::Soft, false);
        m.load_vmems(&{
            let mut v = vec![0i32; 16 * 4];
            v[0] = 10;
            v
        });
        let mut partials = vec![0i32; 4];
        partials[0] = 5;
        m.pass(&partials, 1);
        // leak 2: 10 -> 8, then +5 -> 13
        assert_eq!(m.vmems()[0], 13);
    }

    #[test]
    fn accumulate_mode_never_fires_and_wraps() {
        let mut m = nm(1, false, ResetMode::Soft, true);
        let partials = vec![60i32; 4];
        let o1 = m.pass(&partials, 1);
        assert!(o1.spikes.is_empty());
        m.pass(&partials, 1);
        assert_eq!(m.vmems()[0], wrap_to_bits(120, 7));
    }

    #[test]
    fn threshold_is_inclusive() {
        let mut m = nm(10, false, ResetMode::Soft, false);
        let mut partials = vec![0i32; 4];
        partials[0] = 10;
        partials[1] = 9;
        let out = m.pass(&partials, 1);
        assert_eq!(out.spikes[0], 1);
        assert_eq!(out.spikes[1], 0);
    }
}
