//! Cycle-level simulator of the SpiDR SNN core (the paper's §II).
//!
//! The simulated microarchitecture:
//!
//! ```text
//!              ┌────────────────────────── SNN core ───────────────────────────┐
//!              │  CU1 ─ CU2 ─ CU3 ─► NU1     (Mode 1: three 3-CU pipelines)    │
//!  events ──►  │  CU4 ─ CU5 ─ CU6 ─► NU2     (Mode 2: CU1─…─CU9 ─► NU1)        │
//!              │  CU7 ─ CU8 ─ CU9 ─► NU3                                       │
//!              └────────────────────────────────────────────────────────────────┘
//!  CU = IFmem → input loader → IFspad(128x16) → S2A → compute macro (160x48)
//!  NU = neuron SRAM controller → neuron macro (72x48)
//! ```
//!
//! Timing is cycle-approximate at the unit level (every FIFO push/pop,
//! parity switch, macro pass, transfer and neuron pass is counted;
//! bit-level switching inside a pass is aggregated), and the functional
//! datapath is bit-exact against the JAX golden model (wrap-around
//! B_v-bit accumulation, the [`crate::quant`] contract).

pub mod compute_macro;
pub mod compute_unit;
pub mod config;
pub mod core;
pub mod ifspad;
pub mod input_loader;
pub mod neuron_macro;
pub mod pipeline;
pub mod s2a;
pub mod stats;
pub mod stream;

pub use compute_macro::{ComputeMacro, LaneMacro};
pub use compute_unit::{ComputeUnit, TileCuStats};
pub use config::{OperatingMode, SimConfig, IFSPAD_COLS, IFSPAD_ROWS, NUM_CU, NUM_NU};
pub use core::{LaneBank, LayerStats, SpidrCore};
pub use ifspad::{IfSpad, LaneSpad};
pub use neuron_macro::NeuronMacro;
pub use pipeline::{pipeline_makespan, synchronous_makespan, PipelineTimeline};
pub use s2a::LaneAddr;
pub use stats::RunStats;
pub use stream::{LaneStreamCache, LaneTileStream, StreamCache, TileStream};
