//! Timestep pipelining with asynchronous handshaking (paper §II-F,
//! Fig. 13).
//!
//! Within one tile, compute units process *different timesteps*
//! concurrently: CU_i integrates its local fan-in slice for timestep
//! `t`, then the partial Vmems hop along the chain
//! (CU_1 → CU_2 → … → NU), each hop a rendezvous handshake. A unit can
//! start its next timestep the moment it has forwarded the previous
//! one — so delays come only from true data dependence, not from a
//! global clocked schedule.
//!
//! This module computes the resulting schedule as a discrete-event
//! recurrence (the simulator's timing model) and, for comparison, the
//! synchronous-baseline schedules the paper argues against.

/// Timeline of one pipeline over a tile: per-unit busy intervals.
#[derive(Debug, Clone)]
pub struct PipelineTimeline {
    /// `intervals[i][t] = (start, end)` of unit `i`'s local compute for
    /// timestep `t` (units: chained CUs, then the NU last).
    pub intervals: Vec<Vec<(u64, u64)>>,
    /// Total makespan in cycles.
    pub makespan: u64,
}

/// Asynchronous-handshake schedule.
///
/// * `cu_durations[i][t]` — local compute cycles of chained unit `i`
///   at timestep `t` (sparsity-dependent).
/// * `transfer` — cycles to hand a tile's partial Vmems to the next
///   unit (32 staggered rows, row per cycle, plus handshake).
/// * `nu_cycles` — the neuron unit's fixed pass time (66).
///
/// Recurrence: unit `i` starts timestep `t` once it has forwarded
/// timestep `t-1`; it forwards `t` once its local compute is done AND
/// the upstream partial for `t` has arrived.
pub fn pipeline_makespan(
    cu_durations: &[Vec<u64>],
    transfer: u64,
    nu_cycles: u64,
) -> PipelineTimeline {
    let n = cu_durations.len();
    assert!(n > 0);
    let timesteps = cu_durations[0].len();
    let mut intervals = vec![vec![(0u64, 0u64); timesteps]; n + 1];
    // forward[i][t]: cycle at which unit i has handed timestep t on.
    let mut forward = vec![vec![0u64; timesteps]; n];
    let mut nu_end = vec![0u64; timesteps];

    for t in 0..timesteps {
        for i in 0..n {
            let free = if t == 0 { 0 } else { forward[i][t - 1] };
            let start = free;
            let local_end = start + cu_durations[i][t];
            intervals[i][t] = (start, local_end);
            let upstream = if i == 0 {
                0
            } else {
                forward[i - 1][t]
            };
            forward[i][t] = local_end.max(upstream) + transfer;
        }
        let nu_free = if t == 0 { 0 } else { nu_end[t - 1] };
        let nu_start = forward[n - 1][t].max(nu_free);
        nu_end[t] = nu_start + nu_cycles;
        intervals[n][t] = (nu_start, nu_end[t]);
    }

    PipelineTimeline {
        makespan: nu_end[timesteps - 1],
        intervals,
    }
}

/// Lockstep-synchronous baseline: every stage advances on a global
/// barrier per timestep (stage time = the slowest unit that timestep).
pub fn synchronous_makespan(
    cu_durations: &[Vec<u64>],
    transfer: u64,
    nu_cycles: u64,
) -> u64 {
    let n = cu_durations.len();
    let timesteps = cu_durations[0].len();
    let mut total = 0u64;
    for t in 0..timesteps {
        let slowest = (0..n).map(|i| cu_durations[i][t]).max().unwrap_or(0);
        total += slowest + n as u64 * transfer + nu_cycles;
    }
    total
}

/// Worst-case-provisioned baseline: a fixed schedule sized for the
/// slowest unit-timestep anywhere (what a constant-time pipeline must
/// assume, per §II-F).
pub fn worst_case_makespan(
    cu_durations: &[Vec<u64>],
    transfer: u64,
    nu_cycles: u64,
) -> u64 {
    let n = cu_durations.len();
    let timesteps = cu_durations[0].len() as u64;
    let worst = cu_durations
        .iter()
        .flat_map(|d| d.iter().copied())
        .max()
        .unwrap_or(0);
    timesteps * (worst + n as u64 * transfer + nu_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    #[test]
    fn single_unit_single_timestep() {
        let tl = pipeline_makespan(&[vec![100]], 2, 66);
        assert_eq!(tl.makespan, 100 + 2 + 66);
        assert_eq!(tl.intervals[0][0], (0, 100));
    }

    #[test]
    fn timesteps_pipeline_across_units() {
        // 3 units, 4 timesteps, uniform 100-cycle work: async should
        // approach 100/timestep steady-state, not 300.
        let d = vec![vec![100; 4]; 3];
        let tl = pipeline_makespan(&d, 1, 66);
        let sync = synchronous_makespan(&d, 1, 66);
        assert!(tl.makespan < sync, "async {} sync {}", tl.makespan, sync);
        // unit 0 starts timestep 1 right after forwarding timestep 0
        let (s1, _) = tl.intervals[0][1];
        assert_eq!(s1, 100 + 1);
    }

    #[test]
    fn variable_durations_only_data_dependent_delay() {
        // CU2 slow at t0; CU1's t1 shouldn't wait for CU2 beyond the
        // forwarding handshake.
        let d = vec![vec![10, 10], vec![500, 10]];
        let tl = pipeline_makespan(&d, 1, 66);
        let (s, _) = tl.intervals[0][1];
        assert_eq!(s, 11); // forwarded t0 at 10+1
    }

    #[test]
    fn worst_case_dominates_all() {
        let d = vec![vec![10, 200, 30], vec![40, 50, 60]];
        let wc = worst_case_makespan(&d, 2, 66);
        let sync = synchronous_makespan(&d, 2, 66);
        let tl = pipeline_makespan(&d, 2, 66);
        assert!(wc >= sync);
        assert!(sync >= tl.makespan);
    }

    #[test]
    fn prop_async_never_worse_than_sync() {
        check("async_le_sync", 100, |g| {
            let units = 1 + g.index(9);
            let steps = 1 + g.index(6);
            let d: Vec<Vec<u64>> = (0..units)
                .map(|_| (0..steps).map(|_| g.u64_in(1..=300)).collect())
                .collect();
            let transfer = g.u64_in(0..=8);
            let tl = pipeline_makespan(&d, transfer, 66);
            tl.makespan <= synchronous_makespan(&d, transfer, 66)
        });
    }

    #[test]
    fn prop_makespan_at_least_critical_path() {
        check("critical_path", 100, |g| {
            let units = 1 + g.index(5);
            let steps = 1 + g.index(5);
            let d: Vec<Vec<u64>> = (0..units)
                .map(|_| (0..steps).map(|_| g.u64_in(1..=100)).collect())
                .collect();
            let tl = pipeline_makespan(&d, 1, 66);
            // lower bounds: any single unit's total work; NU serial time
            let nu_lb = steps as u64 * 66;
            let unit_lb = (0..units)
                .map(|i| d[i].iter().sum::<u64>())
                .max()
                .unwrap();
            tl.makespan >= nu_lb && tl.makespan >= unit_lb
        });
    }
}
