//! A compute unit: IFmem → input loader → IFspad → S2A → compute macro.
//!
//! [`ComputeUnit::process_tile`] is the *reference* execution path: it
//! re-runs the loader and the cycle-accurate S2A interleave every call.
//! The hot path in `sim::core` instead replays cached
//! [`TileStream`](super::stream::TileStream)s (computed once per
//! `(tile, fan-slice, timestep)`) and is property-tested bit-identical
//! against this implementation (`sim::stream`).

use crate::snn::layer::Layer;
use crate::snn::spikes::SpikePlane;
use crate::snn::tensor::Mat;

use super::compute_macro::ComputeMacro;
use super::config::SimConfig;
use super::ifspad::IfSpad;
use super::input_loader::{load_tile, LoadedTile};
use super::s2a::{run_tile, run_tile_dense, S2aOptions};

pub use super::s2a::TileCuStats;

/// One compute unit executing a fan-in slice of the current layer.
#[derive(Debug, Clone)]
pub struct ComputeUnit {
    /// This unit's fan-in slice `[fan_lo, fan_hi)`.
    pub fan_lo: usize,
    /// Slice end (exclusive).
    pub fan_hi: usize,
    cm: ComputeMacro,
    spad: IfSpad,
    s2a_opts: S2aOptions,
    zero_skipping: bool,
}

/// Per-tile result from one compute unit.
#[derive(Debug, Clone)]
pub struct CuTileResult {
    /// S2A / macro statistics.
    pub stats: TileCuStats,
    /// Loader statistics.
    pub load: LoadedTile,
}

impl ComputeUnit {
    /// Configure a unit for a layer: `weights_slice` is the layer's
    /// `(fan_hi - fan_lo, group_neurons)` weight sub-matrix.
    pub fn new(
        fan_lo: usize,
        fan_hi: usize,
        weights_slice: Mat,
        cfg: &SimConfig,
    ) -> Self {
        let cm = ComputeMacro::new(
            weights_slice,
            cfg.precision.vmem_bits(),
            cfg.overflow,
            cfg.functional,
        );
        ComputeUnit {
            fan_lo,
            fan_hi,
            cm,
            spad: IfSpad::new(),
            s2a_opts: S2aOptions {
                fifo_depth: cfg.fifo_depth,
                switch_cycles: cfg.parity_switch_cycles,
                ping_pong: true,
                detector_cycles_per_spike: cfg.detector_cycles_per_spike,
            },
            zero_skipping: cfg.zero_skipping,
        }
    }

    /// Number of neurons mapped on this unit's macro columns.
    pub fn neurons(&self) -> usize {
        self.cm.neurons
    }

    /// Process one tile for one timestep: load the IFspad, run the
    /// S2A + macro, leave partial Vmems in the macro.
    pub fn process_tile(
        &mut self,
        layer: &Layer,
        input: &SpikePlane,
        pixel_base: usize,
        pixels: usize,
    ) -> CuTileResult {
        self.cm.reset_vmems();
        let load = load_tile(
            layer,
            input,
            pixel_base,
            pixels,
            self.fan_lo,
            self.fan_hi,
            &mut self.spad,
        );
        let stats = if self.zero_skipping {
            run_tile(&self.spad, &load.row_ready, &mut self.cm, &self.s2a_opts)
        } else {
            run_tile_dense(&self.spad, &mut self.cm, &self.s2a_opts)
        };
        CuTileResult { stats, load }
    }

    /// Partial Vmems of entry `x` after `process_tile`.
    pub fn partial_entry(&self, x: usize) -> &[i32] {
        self.cm.vmem_entry(x)
    }

    /// Merge an upstream unit's partials into this one (chain hop).
    pub fn merge_from(&mut self, x: usize, incoming: &[i32]) {
        self.cm.merge_entry(x, incoming);
    }

    /// Replace the macro weights (layer reconfiguration, multi-pass).
    pub fn reload_weights(&mut self, weights_slice: Mat, cfg: &SimConfig) {
        self.cm = ComputeMacro::new(
            weights_slice,
            cfg.precision.vmem_bits(),
            cfg.overflow,
            cfg.functional,
        );
    }
}

/// Split a fan-in evenly across `n` units (the balanced distribution
/// of §II-F: equal row counts minimize pipeline wait variance).
pub fn split_fan_in(fan_in: usize, n: usize) -> Vec<(usize, usize)> {
    let base = fan_in / n;
    let extra = fan_in % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::layer::NeuronConfig;

    fn layer() -> Layer {
        let mut w = Mat::zeros(9, 4);
        for f in 0..9 {
            for k in 0..4 {
                w.set(f, k, (f + k) as i32 % 3);
            }
        }
        Layer::conv((1, 4, 4), 4, 3, 3, 1, 1, w, NeuronConfig::default(), false).unwrap()
    }

    #[test]
    fn split_fan_in_balanced() {
        assert_eq!(split_fan_in(288, 3), vec![(0, 96), (96, 192), (192, 288)]);
        assert_eq!(split_fan_in(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        let total: usize = split_fan_in(1151, 9).iter().map(|(a, b)| b - a).sum();
        assert_eq!(total, 1151);
        // balanced: sizes differ by at most 1
        let sizes: Vec<usize> = split_fan_in(1151, 9).iter().map(|(a, b)| b - a).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn process_tile_counts_and_partials() {
        let l = layer();
        let cfg = SimConfig::default();
        let w = l.weights.as_ref().unwrap().clone();
        let mut cu = ComputeUnit::new(0, 9, w, &cfg);
        let mut input = SpikePlane::zeros(1, 4, 4);
        input.set(0, 1, 1, 1);
        let r = cu.process_tile(&l, &input, 0, 16);
        assert!(r.stats.detect_spikes > 0);
        assert_eq!(r.stats.macro_ops, 2 * r.stats.detect_spikes);
        // pixel m=5 sees the spike at its center tap f=4: weights row 4
        let expect: Vec<i32> = (0..4).map(|k| (4 + k) as i32 % 3).collect();
        assert_eq!(cu.partial_entry(5), &expect[..]);
    }

    #[test]
    fn dense_mode_same_function_more_ops() {
        let l = layer();
        let mut cfg = SimConfig::default();
        let w = l.weights.as_ref().unwrap().clone();
        let mut input = SpikePlane::zeros(1, 4, 4);
        input.set(0, 2, 2, 1);

        let mut cu = ComputeUnit::new(0, 9, w.clone(), &cfg);
        let sparse = cu.process_tile(&l, &input, 0, 16);
        let p_sparse: Vec<i32> = cu.partial_entry(5).to_vec();

        cfg.zero_skipping = false;
        let mut cu2 = ComputeUnit::new(0, 9, w, &cfg);
        let dense = cu2.process_tile(&l, &input, 0, 16);
        assert_eq!(cu2.partial_entry(5), &p_sparse[..]);
        assert!(dense.stats.macro_ops > sparse.stats.macro_ops);
    }

    #[test]
    fn merge_chains_partials() {
        let l = layer();
        let cfg = SimConfig::default();
        let w = l.weights.as_ref().unwrap().clone();
        let mut cu = ComputeUnit::new(0, 9, w, &cfg);
        let mut input = SpikePlane::zeros(1, 4, 4);
        input.set(0, 1, 1, 1);
        cu.process_tile(&l, &input, 0, 16);
        let before = cu.partial_entry(5).to_vec();
        cu.merge_from(5, &[1, 1, 1, 1]);
        let after = cu.partial_entry(5).to_vec();
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(a - b, 1);
        }
    }
}
