//! The 160x48 compute-in-memory macro (paper §II-A, Figs. 7–8).
//!
//! Top 128 rows store weights; 32 bottom rows store partial Vmems in a
//! staggered layout (B_v ≈ 2·B_w, so one weight row's Vmems occupy two
//! physical rows — even-indexed neurons at row 2X, odd-indexed at
//! 2X+1). One `(Y, X)` address pair therefore takes *two* pipelined
//! R/C/S passes: an even-parity pass and an odd-parity pass, each
//! accumulating half of the row's neurons into the selected Vmem row.
//!
//! The functional model here works on logical integers; the staggering
//! is preserved in which neurons each parity touches, so parity-batched
//! execution orders are exercised for real.

use crate::quant::Overflow;
use crate::snn::tensor::Mat;

use super::config::{IFSPAD_COLS, IFSPAD_ROWS, MACRO_COLS};

/// Even or odd accumulation pass (which neuron parity / Vmem row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parity {
    /// Even-indexed neurons → Vmem row 2X.
    Even,
    /// Odd-indexed neurons → Vmem row 2X+1.
    Odd,
}

impl Parity {
    /// The other parity.
    pub fn flip(self) -> Parity {
        match self {
            Parity::Even => Parity::Odd,
            Parity::Odd => Parity::Even,
        }
    }

    /// Starting neuron index of this parity.
    pub fn start(self) -> usize {
        match self {
            Parity::Even => 0,
            Parity::Odd => 1,
        }
    }
}

/// One compute macro: a fan-in slice of weights plus 16 logical Vmem
/// entries for the current tile.
#[derive(Debug, Clone)]
pub struct ComputeMacro {
    /// Weight slice `(rows ≤ 128, neurons ≤ 48/B_w)`.
    weights: Mat,
    /// Partial Vmems, `IFSPAD_COLS` entries x `neurons`, row-major.
    vmem: Vec<i32>,
    /// Logical neurons mapped on the columns.
    pub neurons: usize,
    /// Vmem bit width.
    pub vmem_bits: u32,
    /// Overflow policy.
    pub overflow: Overflow,
    /// Functional datapath enabled.
    pub functional: bool,
}

impl ComputeMacro {
    /// Create a macro holding a weight slice. `weights` is
    /// `(fan_in_slice, neurons)` with `fan_in_slice ≤ 128` and
    /// `neurons ≤ 48 / B_w`.
    pub fn new(
        weights: Mat,
        vmem_bits: u32,
        overflow: Overflow,
        functional: bool,
    ) -> Self {
        assert!(weights.rows <= IFSPAD_ROWS, "weight slice too tall");
        assert!(weights.cols <= MACRO_COLS, "too many neurons per macro");
        let neurons = weights.cols;
        ComputeMacro {
            weights,
            vmem: vec![0; IFSPAD_COLS * neurons],
            neurons,
            vmem_bits,
            overflow,
            functional,
        }
    }

    /// A timing-only macro with a given geometry and no weight data.
    pub fn timing_only(rows: usize, neurons: usize, vmem_bits: u32) -> Self {
        ComputeMacro::new(
            Mat::zeros(rows, neurons),
            vmem_bits,
            Overflow::Wrap,
            false,
        )
    }

    /// Weight rows held (the CU's fan-in slice length).
    pub fn rows(&self) -> usize {
        self.weights.rows
    }

    /// Reset all partial Vmems (start of a tile/timestep).
    pub fn reset_vmems(&mut self) {
        self.vmem.fill(0);
    }

    /// Perform one accumulation pass for address pair `(y, x)` at a
    /// parity: adds the parity's neurons of weight row `y` into Vmem
    /// entry `x`. One R/C/S pipeline pass = one cycle once the
    /// pipeline is full (counted by the caller).
    #[inline]
    pub fn op(&mut self, y: usize, x: usize, parity: Parity) {
        if !self.functional {
            return;
        }
        debug_assert!(y < self.weights.rows && x < IFSPAD_COLS);
        let w = self.weights.row(y);
        let v = &mut self.vmem[x * self.neurons..(x + 1) * self.neurons];
        let (bits, overflow) = (self.vmem_bits, self.overflow);
        let mut k = parity.start();
        while k < w.len() {
            v[k] = overflow.apply(v[k] + w[k], bits);
            k += 2;
        }
    }

    /// Fused even+odd accumulation for address pair `(y, x)`: one
    /// contiguous `v[k] += w[k]` sweep over all neurons instead of two
    /// strided parity passes (§Perf, used by the tile-stream replay
    /// path).
    ///
    /// Bit-exact vs. `op(y, x, Even); op(y, x, Odd)` for *any* overflow
    /// policy: the parities touch disjoint neuron indices, so each
    /// element sees exactly one `overflow.apply(v + w)` either way —
    /// only the (irrelevant) interleaving across disjoint elements
    /// changes. See DESIGN.md §Perf for why replaying address pairs in
    /// detector-extraction order also preserves each element's
    /// *cross-address* operation order exactly.
    #[inline]
    pub fn op_row(&mut self, y: usize, x: usize) {
        if !self.functional {
            return;
        }
        debug_assert!(y < self.weights.rows && x < IFSPAD_COLS);
        let w = self.weights.row(y);
        let v = &mut self.vmem[x * self.neurons..(x + 1) * self.neurons];
        let (bits, overflow) = (self.vmem_bits, self.overflow);
        for (vk, &wk) in v.iter_mut().zip(w) {
            *vk = overflow.apply(*vk + wk, bits);
        }
    }

    /// Read the partial Vmems of entry `x` (transfer to the next unit).
    pub fn vmem_entry(&self, x: usize) -> &[i32] {
        &self.vmem[x * self.neurons..(x + 1) * self.neurons]
    }

    /// Accumulate another unit's partials into entry `x` (Mode-2 /
    /// Mode-1 chain merge; wrap keeps this order-independent).
    pub fn merge_entry(&mut self, x: usize, incoming: &[i32]) {
        if !self.functional {
            return;
        }
        let (bits, overflow) = (self.vmem_bits, self.overflow);
        let v = &mut self.vmem[x * self.neurons..(x + 1) * self.neurons];
        for (vi, &inc) in v.iter_mut().zip(incoming) {
            *vi = overflow.apply(*vi + inc, bits);
        }
    }
}

/// The batched compute macro: the same weight slice as a
/// [`ComputeMacro`], but `lanes` independent Vmem columns per tile
/// entry — one per clip in the bit-plane batch. [`LaneMacro::op_row`]
/// sweeps the CIM row once per *union* address and accumulates into
/// every lane whose bit is set in the address's lane word, so lane `b`
/// sees exactly the `op_row` sequence a per-clip macro would have run
/// for clip `b` alone (DESIGN.md §Perf; bit-exact for any overflow
/// policy, wrap or saturate).
#[derive(Debug, Clone)]
pub struct LaneMacro {
    /// Weight slice `(fan_in_slice ≤ 128, neurons ≤ 48/B_w)`.
    weights: Mat,
    /// Partial Vmems: `IFSPAD_COLS` entries × `lanes` × `neurons`,
    /// `(x, b, k)` row-major — each lane's column is contiguous.
    vmem: Vec<i32>,
    /// Logical neurons mapped on the columns.
    pub neurons: usize,
    /// Bit-lanes (clips) accumulated in parallel.
    pub lanes: usize,
    /// Vmem bit width.
    pub vmem_bits: u32,
    /// Overflow policy.
    pub overflow: Overflow,
}

impl LaneMacro {
    /// Create a batched macro holding a weight slice for `lanes` clips.
    pub fn new(weights: Mat, lanes: usize, vmem_bits: u32, overflow: Overflow) -> Self {
        assert!(weights.rows <= IFSPAD_ROWS, "weight slice too tall");
        assert!(weights.cols <= MACRO_COLS, "too many neurons per macro");
        assert!(
            lanes >= 1 && lanes <= crate::snn::spikes::MAX_LANES,
            "lanes out of range"
        );
        let neurons = weights.cols;
        LaneMacro {
            weights,
            vmem: vec![0; IFSPAD_COLS * lanes * neurons],
            neurons,
            lanes,
            vmem_bits,
            overflow,
        }
    }

    /// Reset all partial Vmems (start of a tile/timestep).
    pub fn reset_vmems(&mut self) {
        self.vmem.fill(0);
    }

    /// One union-stream accumulation: add weight row `y` into tile
    /// entry `x` of every lane set in `word`. The inner loop is the
    /// same contiguous `v[k] += w[k]` sweep as
    /// [`ComputeMacro::op_row`], run once per set lane.
    #[inline]
    pub fn op_row(&mut self, y: usize, x: usize, word: u64) {
        debug_assert!(y < self.weights.rows && x < IFSPAD_COLS);
        let w = self.weights.row(y);
        let (bits, overflow) = (self.vmem_bits, self.overflow);
        let base = x * self.lanes;
        let mut m = word;
        while m != 0 {
            let b = m.trailing_zeros() as usize;
            m &= m - 1;
            let v = &mut self.vmem[(base + b) * self.neurons..(base + b + 1) * self.neurons];
            for (vk, &wk) in v.iter_mut().zip(w) {
                *vk = overflow.apply(*vk + wk, bits);
            }
        }
    }

    /// Read entry `x`'s partial Vmems for all lanes (`lanes × neurons`,
    /// lane-major — lane `b`'s slice is `[b*neurons .. (b+1)*neurons]`).
    pub fn entry(&self, x: usize) -> &[i32] {
        &self.vmem[x * self.lanes * self.neurons..(x + 1) * self.lanes * self.neurons]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::wrap_to_bits;

    fn macro_with(rows: usize, neurons: usize, f: impl Fn(usize, usize) -> i32) -> ComputeMacro {
        let mut m = Mat::zeros(rows, neurons);
        for r in 0..rows {
            for c in 0..neurons {
                m.set(r, c, f(r, c));
            }
        }
        ComputeMacro::new(m, 7, Overflow::Wrap, true)
    }

    #[test]
    fn even_odd_touch_disjoint_neurons() {
        let mut cm = macro_with(4, 6, |_, k| (k + 1) as i32);
        cm.op(0, 0, Parity::Even);
        assert_eq!(cm.vmem_entry(0), &[1, 0, 3, 0, 5, 0]);
        cm.op(0, 0, Parity::Odd);
        assert_eq!(cm.vmem_entry(0), &[1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn op_accumulates_with_wrap() {
        let mut cm = macro_with(1, 2, |_, _| 60);
        cm.op(0, 0, Parity::Even);
        cm.op(0, 0, Parity::Even);
        // 120 wraps at 7 bits to -8
        assert_eq!(cm.vmem_entry(0)[0], wrap_to_bits(120, 7));
    }

    #[test]
    fn entries_are_independent() {
        let mut cm = macro_with(2, 2, |r, _| r as i32 + 1);
        cm.op(1, 3, Parity::Even);
        assert_eq!(cm.vmem_entry(3)[0], 2);
        assert_eq!(cm.vmem_entry(0)[0], 0);
    }

    #[test]
    fn merge_wraps() {
        let mut cm = macro_with(1, 2, |_, _| 0);
        cm.merge_entry(0, &[60, 10]);
        cm.merge_entry(0, &[60, 10]);
        assert_eq!(cm.vmem_entry(0), &[wrap_to_bits(120, 7), 20]);
    }

    #[test]
    fn op_row_equals_even_plus_odd() {
        use crate::quant::Overflow;
        for overflow in [Overflow::Wrap, Overflow::Saturate] {
            let mut w = Mat::zeros(3, 5);
            for r in 0..3 {
                for k in 0..5 {
                    w.set(r, k, 40 * (r as i32 + 1) - 7 * k as i32);
                }
            }
            let mut a = ComputeMacro::new(w.clone(), 7, overflow, true);
            let mut b = ComputeMacro::new(w, 7, overflow, true);
            // several address pairs, repeated to exercise wrap/saturate
            for &(y, x) in &[(0usize, 0usize), (1, 0), (0, 0), (2, 3), (1, 0)] {
                a.op(y, x, Parity::Even);
                a.op(y, x, Parity::Odd);
                b.op_row(y, x);
            }
            for x in [0usize, 3] {
                assert_eq!(a.vmem_entry(x), b.vmem_entry(x), "{overflow:?}");
            }
        }
    }

    #[test]
    fn timing_only_skips_functional_work() {
        let mut cm = ComputeMacro::timing_only(4, 6, 7);
        cm.op(0, 0, Parity::Even);
        assert_eq!(cm.vmem_entry(0), &[0, 0, 0, 0, 0, 0]);
    }

    #[test]
    fn reset_clears() {
        let mut cm = macro_with(1, 2, |_, _| 3);
        cm.op(0, 0, Parity::Even);
        cm.reset_vmems();
        assert_eq!(cm.vmem_entry(0), &[0, 0]);
    }

    #[test]
    fn lane_op_row_matches_per_clip_op_row() {
        for overflow in [Overflow::Wrap, Overflow::Saturate] {
            let mut w = Mat::zeros(3, 5);
            for r in 0..3 {
                for k in 0..5 {
                    w.set(r, k, 40 * (r as i32 + 1) - 7 * k as i32);
                }
            }
            let lanes = 5usize;
            let mut lm = LaneMacro::new(w.clone(), lanes, 7, overflow);
            let mut per_clip: Vec<ComputeMacro> = (0..lanes)
                .map(|_| ComputeMacro::new(w.clone(), 7, overflow, true))
                .collect();
            // a union stream whose words select different lane subsets,
            // repeated to exercise wrap/saturate
            let stream: &[(usize, usize, u64)] = &[
                (0, 0, 0b10101),
                (1, 0, 0b00111),
                (0, 0, 0b11111),
                (2, 3, 0b01000),
                (1, 0, 0b10001),
            ];
            for &(y, x, word) in stream {
                lm.op_row(y, x, word);
                for (b, cm) in per_clip.iter_mut().enumerate() {
                    if word >> b & 1 != 0 {
                        cm.op_row(y, x);
                    }
                }
            }
            for x in [0usize, 3] {
                let entry = lm.entry(x);
                for (b, cm) in per_clip.iter().enumerate() {
                    assert_eq!(
                        &entry[b * 5..(b + 1) * 5],
                        cm.vmem_entry(x),
                        "{overflow:?} lane {b} entry {x}"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_zero_word_is_inert() {
        let mut lm = LaneMacro::new(Mat::from_vec(1, 2, vec![3, 4]).unwrap(), 2, 7, Overflow::Wrap);
        lm.op_row(0, 0, 0);
        assert!(lm.entry(0).iter().all(|&v| v == 0));
        lm.op_row(0, 0, 0b10);
        assert_eq!(lm.entry(0), &[0, 0, 3, 4]);
    }
}
