//! Lightweight latency/throughput metrics for the streaming server.

use std::time::Duration;

/// Online metrics aggregator.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    /// Clips processed.
    pub clips: u64,
    /// Frames processed.
    pub frames: u64,
    /// Total busy wall time.
    pub busy: Duration,
}

impl Metrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed clip.
    pub fn record_clip(&mut self, latency: Duration, frames: u64) {
        self.latencies_us.push(latency.as_micros() as u64);
        self.clips += 1;
        self.frames += frames;
        self.busy += latency;
    }

    /// Mean latency in microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64 / self.latencies_us.len() as f64
    }

    /// Latency percentile (0–100) in microseconds.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Throughput in clips/second over the busy time.
    pub fn clips_per_second(&self) -> f64 {
        let s = self.busy.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.clips as f64 / s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = Metrics::new();
        m.record_clip(Duration::from_micros(100), 10);
        m.record_clip(Duration::from_micros(300), 10);
        assert_eq!(m.clips, 2);
        assert_eq!(m.frames, 20);
        assert!((m.mean_latency_us() - 200.0).abs() < 1e-9);
        assert_eq!(m.percentile_us(0.0), 100);
        assert_eq!(m.percentile_us(100.0), 300);
        assert!(m.clips_per_second() > 0.0);
    }

    #[test]
    fn empty_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.percentile_us(50.0), 0);
        assert_eq!(m.clips_per_second(), 0.0);
    }
}
