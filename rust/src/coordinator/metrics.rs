//! Lightweight latency/throughput metrics for the streaming server,
//! the sharded serving pool, and the timestep-staged layer-group
//! pipeline.
//!
//! Per-clip latencies are held in a fixed-memory log-bucketed
//! histogram ([`LatencyHistogram`], DESIGN.md §Observability) — the
//! old unbounded `Vec<u64>` buffer, whose `percentile_us` cloned and
//! sorted every sample on every query, could not survive a
//! sensor-scale stream. The public API (`mean_latency_us`,
//! `percentile_us`, `record_clip`) is unchanged; percentiles are
//! exact below 4096 µs and within the histogram's 1/16 bucket error
//! bound above it.

use std::time::Duration;

use crate::obs::hist::LatencyHistogram;
use crate::obs::metrics::MetricsHub;

/// Per-stage counters from pipelined clip execution
/// (`coordinator::pipeline`, DESIGN.md §Pipeline): how a stage's wall
/// time split between stepping its layer group (`busy`), waiting on
/// its upstream spike-frame channel (`stall_in`) and blocking on a
/// full downstream channel (`stall_out`), plus the fill/drain
/// latencies it observed. Counters accumulate across clips when one
/// engine serves several ([`StageMetrics::absorb`]).
#[derive(Debug, Clone, Default)]
pub struct StageMetrics {
    /// Stage index (= layer-group index, upstream to downstream).
    pub stage: usize,
    /// Full-layer index span `[lo, hi)` of this stage's group.
    pub layers: (usize, usize),
    /// Timesteps stepped.
    pub steps: u64,
    /// Wall time inside `Network::step_group`.
    pub busy: Duration,
    /// Wall time blocked on the upstream channel — **steady-state**
    /// starvation only. The wait for a clip's first frame to reach
    /// this stage is the pipeline filling, not the upstream starving
    /// it, and is accounted in [`StageMetrics::fill`] instead (it
    /// used to land here, which made deep pipelines under-report
    /// [`StageMetrics::occupancy`]).
    pub stall_in: Duration,
    /// Wall time blocked on a full downstream channel (the
    /// backpressure counter — a full FIFO stalls its producer, never
    /// drops).
    pub stall_out: Duration,
    /// Latency from clip start until this stage's first frame arrived
    /// (the fill front reaching this stage).
    pub fill: Duration,
    /// Wall time between this stage finishing its last timestep and
    /// the whole pipeline completing (the drain tail behind it).
    pub drain: Duration,
    /// How many stall timings were actually taken: channel operations
    /// that would have blocked, and therefore paid an `Instant::now()`
    /// pair. Fast-path operations (the channel was ready) take no
    /// timestamp at all, so `stall_samples` staying low under load is
    /// the proof the per-frame timer overhead is gone
    /// (`timed_stall_sampling_skips_the_fast_path`).
    pub stall_samples: u64,
}

impl StageMetrics {
    /// Fresh counters for stage `stage` covering full-layer span
    /// `layers`.
    pub fn new(stage: usize, layers: (usize, usize)) -> Self {
        StageMetrics {
            stage,
            layers,
            ..StageMetrics::default()
        }
    }

    /// Fraction of this stage's accounted wall time spent stepping
    /// its layer group (0 when it never ran).
    pub fn occupancy(&self) -> f64 {
        let total = self.busy + self.stall_in + self.stall_out;
        if total.is_zero() {
            return 0.0;
        }
        self.busy.as_secs_f64() / total.as_secs_f64()
    }

    /// Fold another run's counters for the same stage into this one.
    pub fn absorb(&mut self, other: &StageMetrics) {
        self.steps += other.steps;
        self.busy += other.busy;
        self.stall_in += other.stall_in;
        self.stall_out += other.stall_out;
        self.fill += other.fill;
        self.drain += other.drain;
        self.stall_samples += other.stall_samples;
    }
}

/// Per-worker counters from one pool run (DESIGN.md §Serve): how many
/// clips each worker served, how its wall time split between busy and
/// idle, how much work it stole from peers, how deep its bounded
/// inbox ever got, and whether dynamic sizing retired it before the
/// stream closed.
#[derive(Debug, Clone, Default)]
pub struct WorkerMetrics {
    /// Worker id (index into the pool).
    pub worker: usize,
    /// Clips served by this worker.
    pub clips: u64,
    /// Clips acquired from a peer's inbox (work stealing).
    pub stolen: u64,
    /// Wall time spent inside `Engine::infer`.
    pub busy: Duration,
    /// Wall time spent waiting for work.
    pub idle: Duration,
    /// Queue-depth high-water mark of this worker's bounded inbox.
    pub inbox_high_water: usize,
    /// Dynamic sizing retired this worker over a drained queue
    /// (`PoolConfig::sizing`; always `false` for fixed pools).
    pub retired: bool,
    /// Replica failovers absorbed by this worker's engine (non-zero
    /// only when the worker drives a distributed constellation; see
    /// `Engine::failovers`).
    pub failovers: u64,
}

impl WorkerMetrics {
    /// Fresh counters for worker `worker`.
    pub fn new(worker: usize) -> Self {
        WorkerMetrics {
            worker,
            ..WorkerMetrics::default()
        }
    }

    /// Fraction of this worker's accounted wall time spent serving
    /// clips (0 when it never ran).
    pub fn utilization(&self) -> f64 {
        let total = self.busy.as_secs_f64() + self.idle.as_secs_f64();
        if total == 0.0 {
            return 0.0;
        }
        self.busy.as_secs_f64() / total
    }
}

/// Online metrics aggregator.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Fixed-memory per-clip latency distribution (µs).
    latencies: LatencyHistogram,
    /// Clips processed.
    pub clips: u64,
    /// Frames processed.
    pub frames: u64,
    /// Sum of per-clip end-to-end latencies. Latencies **overlap**
    /// across pool workers (and include queue wait), so this exceeds
    /// elapsed time on the pool path — use [`Metrics::wall`] for
    /// throughput.
    pub busy: Duration,
    /// Wall-clock span of the serve call that produced these metrics
    /// (zero when metrics are composed manually).
    pub wall: Duration,
    /// Per-worker counters (empty for the single-engine `serve` path;
    /// one entry per pool worker for `serve_pool`).
    pub workers: Vec<WorkerMetrics>,
    /// Per-pipeline-stage counters (empty unless a pipelined engine's
    /// accumulated [`StageMetrics`] were attached after serving; see
    /// `PipelinedEngine::stage_metrics`).
    pub stages: Vec<StageMetrics>,
    /// Replica failovers absorbed by the serving engine (previously
    /// only visible on `DistributedEngine::failovers`; surfaced here
    /// so the serve paths report them uniformly — pool workers report
    /// theirs through [`WorkerMetrics::failovers`] instead, summed by
    /// [`Metrics::total_failovers`]).
    pub failovers: u64,
}

impl Metrics {
    /// Empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed clip. O(1): one histogram increment, no
    /// per-sample allocation.
    pub fn record_clip(&mut self, latency: Duration, frames: u64) {
        self.latencies.record(latency.as_micros() as u64);
        self.clips += 1;
        self.frames += frames;
        self.busy += latency;
    }

    /// Mean latency in microseconds (exact — the histogram tracks the
    /// sample sum outside its buckets).
    pub fn mean_latency_us(&self) -> f64 {
        self.latencies.mean()
    }

    /// Latency percentile (0–100) in microseconds. O(buckets) per
    /// query instead of the old clone-and-sort; exact below 4096 µs,
    /// within the 1/16 bucket error bound above
    /// ([`LatencyHistogram::percentile`]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        self.latencies.percentile(p)
    }

    /// The per-clip latency distribution itself, for rolling up into
    /// a [`MetricsHub`] histogram series or inspecting bucket counts.
    pub fn latency_histogram(&self) -> &LatencyHistogram {
        &self.latencies
    }

    /// Throughput in clips/second — over the wall-clock span when the
    /// serve path recorded one, else over summed busy time (correct
    /// only while clips never overlap, i.e. a single engine).
    pub fn clips_per_second(&self) -> f64 {
        let span = if self.wall > Duration::ZERO {
            self.wall
        } else {
            self.busy
        };
        let s = span.as_secs_f64();
        if s == 0.0 {
            return 0.0;
        }
        self.clips as f64 / s
    }

    /// Mean busy fraction across pool workers (0 without a pool).
    pub fn pool_utilization(&self) -> f64 {
        if self.workers.is_empty() {
            return 0.0;
        }
        self.workers.iter().map(|w| w.utilization()).sum::<f64>()
            / self.workers.len() as f64
    }

    /// Total clips that changed workers via stealing.
    pub fn total_stolen(&self) -> u64 {
        self.workers.iter().map(|w| w.stolen).sum()
    }

    /// Workers dynamic sizing retired before the stream closed.
    pub fn total_retired(&self) -> u64 {
        self.workers.iter().filter(|w| w.retired).count() as u64
    }

    /// Replica failovers absorbed across the serve: the engine's own
    /// plus every pool worker's.
    pub fn total_failovers(&self) -> u64 {
        self.failovers + self.workers.iter().map(|w| w.failovers).sum::<u64>()
    }

    /// Publish this run's counters and gauges into a live
    /// [`MetricsHub`] under the `spidr_*` series names (DESIGN.md
    /// §Observability). Counters accumulate across runs; gauges are
    /// overwritten. The per-clip latency histogram is **not** merged
    /// here — the serve paths feed `spidr_clip_latency_us` live as
    /// clips emit, so a publish at drain time would double-count.
    pub fn publish(&self, hub: &MetricsHub) {
        hub.counter_add("spidr_clips_total", self.clips);
        hub.counter_add("spidr_frames_total", self.frames);
        hub.counter_add("spidr_failovers_total", self.total_failovers());
        hub.counter_add("spidr_clips_stolen_total", self.total_stolen());
        hub.counter_add("spidr_workers_retired_total", self.total_retired());
        hub.gauge_set("spidr_wall_seconds", self.wall.as_secs_f64());
        hub.gauge_set("spidr_busy_seconds", self.busy.as_secs_f64());
        if !self.workers.is_empty() {
            hub.gauge_set("spidr_pool_utilization", self.pool_utilization());
        }
        for s in &self.stages {
            hub.counter_add(
                &format!("spidr_stage_steps_total{{stage=\"{}\"}}", s.stage),
                s.steps,
            );
            hub.gauge_set(
                &format!("spidr_stage_occupancy{{stage=\"{}\"}}", s.stage),
                s.occupancy(),
            );
            hub.counter_add(
                &format!("spidr_stage_stall_samples_total{{stage=\"{}\"}}", s.stage),
                s.stall_samples,
            );
        }
    }

    /// Mean busy fraction across pipeline stages (0 without stage
    /// counters attached).
    pub fn pipeline_occupancy(&self) -> f64 {
        if self.stages.is_empty() {
            return 0.0;
        }
        self.stages.iter().map(|s| s.occupancy()).sum::<f64>() / self.stages.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = Metrics::new();
        m.record_clip(Duration::from_micros(100), 10);
        m.record_clip(Duration::from_micros(300), 10);
        assert_eq!(m.clips, 2);
        assert_eq!(m.frames, 20);
        assert!((m.mean_latency_us() - 200.0).abs() < 1e-9);
        assert_eq!(m.percentile_us(0.0), 100);
        assert_eq!(m.percentile_us(100.0), 300);
        assert!(m.clips_per_second() > 0.0);
    }

    #[test]
    fn throughput_prefers_wall_clock_over_overlapping_latencies() {
        // Two clips served concurrently: latencies sum to 400 us but
        // only 200 us of wall time elapsed. Throughput must use wall.
        let mut m = Metrics::new();
        m.record_clip(Duration::from_micros(200), 1);
        m.record_clip(Duration::from_micros(200), 1);
        m.wall = Duration::from_micros(200);
        assert!((m.clips_per_second() - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn empty_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.percentile_us(50.0), 0);
        assert_eq!(m.clips_per_second(), 0.0);
        assert_eq!(m.pool_utilization(), 0.0);
        assert_eq!(m.total_stolen(), 0);
        assert_eq!(m.pipeline_occupancy(), 0.0);
    }

    #[test]
    fn stage_counters_compose() {
        let mut s0 = StageMetrics::new(0, (0, 2));
        s0.steps = 4;
        s0.busy = Duration::from_millis(30);
        s0.stall_in = Duration::from_millis(5);
        s0.stall_out = Duration::from_millis(5);
        s0.stall_samples = 3;
        assert!((s0.occupancy() - 0.75).abs() < 1e-9);
        assert_eq!(StageMetrics::new(1, (2, 3)).occupancy(), 0.0);

        // absorb accumulates every counter
        let mut acc = StageMetrics::new(0, (0, 2));
        acc.absorb(&s0);
        acc.absorb(&s0);
        assert_eq!(acc.steps, 8);
        assert_eq!(acc.busy, Duration::from_millis(60));
        assert_eq!(acc.stall_in, Duration::from_millis(10));
        assert_eq!(acc.stall_samples, 6);

        let mut m = Metrics::new();
        m.stages = vec![s0, StageMetrics::new(1, (2, 3))];
        assert!((m.pipeline_occupancy() - 0.375).abs() < 1e-9);
    }

    /// Satellite (histogram swap): the latency store stays O(1) no
    /// matter how many clips are recorded, and percentiles on a long
    /// stream stay within the documented bucket bound.
    #[test]
    fn long_stream_percentiles_stay_bounded() {
        let mut m = Metrics::new();
        for i in 0..100_000u64 {
            // latencies 0..100_000 us, exact region and log region both
            m.record_clip(Duration::from_micros(i), 1);
        }
        assert_eq!(m.clips, 100_000);
        // p50 rank = round(0.5 * 99_999) = 50_000; value 50_000 us is
        // in the log region: within 1/16 below the exact answer.
        let p50 = m.percentile_us(50.0);
        assert!(p50 <= 50_000 && 50_000 <= p50 + p50 / 16, "p50 = {p50}");
        let p0 = m.percentile_us(0.0);
        assert_eq!(p0, 0);
        assert!((m.mean_latency_us() - 49_999.5).abs() < 1e-6);
    }

    #[test]
    fn failovers_surface_and_sum() {
        let mut m = Metrics::new();
        m.failovers = 2;
        let mut w = WorkerMetrics::new(0);
        w.failovers = 3;
        m.workers = vec![w, WorkerMetrics::new(1)];
        assert_eq!(m.total_failovers(), 5);
        assert_eq!(m.total_retired(), 0);
    }

    #[test]
    fn publish_feeds_hub_series() {
        let hub = MetricsHub::new();
        let mut m = Metrics::new();
        m.record_clip(Duration::from_micros(150), 10);
        m.failovers = 1;
        let mut s = StageMetrics::new(2, (0, 1));
        s.steps = 40;
        s.busy = Duration::from_millis(10);
        m.stages = vec![s];
        m.publish(&hub);
        let snap = hub.snapshot();
        assert_eq!(snap.counter("spidr_clips_total"), 1);
        assert_eq!(snap.counter("spidr_frames_total"), 10);
        assert_eq!(snap.counter("spidr_failovers_total"), 1);
        assert_eq!(snap.counter("spidr_stage_steps_total{stage=\"2\"}"), 40);
        // publishing again accumulates counters
        m.publish(&hub);
        assert_eq!(hub.snapshot().counter("spidr_clips_total"), 2);
    }

    #[test]
    fn worker_counters_compose() {
        let mut m = Metrics::new();
        let mut w0 = WorkerMetrics::new(0);
        w0.clips = 3;
        w0.stolen = 1;
        w0.busy = Duration::from_millis(30);
        w0.idle = Duration::from_millis(10);
        let mut w1 = WorkerMetrics::new(1);
        w1.busy = Duration::from_millis(0);
        w1.idle = Duration::from_millis(40);
        m.workers = vec![w0, w1];
        assert!((m.workers[0].utilization() - 0.75).abs() < 1e-9);
        assert_eq!(m.workers[1].utilization(), 0.0);
        assert!((m.pool_utilization() - 0.375).abs() < 1e-9);
        assert_eq!(m.total_stolen(), 1);
    }
}
