//! Layer mapper: decides how each layer occupies the core (paper
//! §II-E, Fig. 12) and reports the mapping for planning and benches.

use crate::error::{Error, Result};
use crate::quant::Precision;
use crate::sim::config::{OperatingMode, IFSPAD_COLS, IFSPAD_ROWS};
use crate::snn::layer::{Layer, LayerKind};
use crate::snn::network::Network;

/// How one layer maps onto the SpiDR core.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMapping {
    /// Selected operating mode.
    pub mode: OperatingMode,
    /// Fan-in rows per chained compute unit.
    pub rows_per_cu: Vec<usize>,
    /// Output-channel groups of `48/B_w` neurons.
    pub channel_groups: usize,
    /// Weight-reconfiguration passes (input re-streams).
    pub passes: usize,
    /// Output-pixel tiles of 16.
    pub tiles: usize,
    /// Fraction of weight-memory rows actually used (utilization).
    pub row_utilization: f64,
}

/// The mapper.
#[derive(Debug, Clone, Copy)]
pub struct Mapper {
    /// Precision in effect (determines neurons/row).
    pub precision: Precision,
}

impl Mapper {
    /// New mapper at a precision.
    pub fn new(precision: Precision) -> Self {
        Mapper { precision }
    }

    /// Map one stateful layer.
    pub fn map_layer(&self, layer: &Layer) -> Result<LayerMapping> {
        if layer.kind == LayerKind::Pool {
            return Err(Error::mapping("pool layers run in the loader, not the core"));
        }
        let fan_in = layer.fan_in();
        let mode = if fan_in <= OperatingMode::Mode1.max_fan_in() {
            OperatingMode::Mode1
        } else if fan_in <= OperatingMode::Mode2.max_fan_in() {
            OperatingMode::Mode2
        } else {
            return Err(Error::mapping(format!(
                "layer fan-in {fan_in} exceeds Mode-2 capacity {}",
                OperatingMode::Mode2.max_fan_in()
            )));
        };
        let chain = mode.cus_per_pipeline();
        let base = fan_in / chain;
        let extra = fan_in % chain;
        let rows_per_cu: Vec<usize> = (0..chain)
            .map(|i| base + usize::from(i < extra))
            .collect();
        let npr = self.precision.neurons_per_row();
        let k = layer.out_shape.0;
        let channel_groups = k.div_ceil(npr);
        let passes = channel_groups.div_ceil(mode.pipelines());
        let (m, _) = layer.vmem_shape()?;
        let tiles = m.div_ceil(IFSPAD_COLS);
        let used_rows: usize = rows_per_cu.iter().sum();
        let row_utilization =
            used_rows as f64 / (chain * IFSPAD_ROWS) as f64;
        Ok(LayerMapping {
            mode,
            rows_per_cu,
            channel_groups,
            passes,
            tiles,
            row_utilization,
        })
    }

    /// Map every stateful layer of a network, in `stateful_layers()`
    /// order — the plan the compiler and the serving tier's layer-group
    /// sharding both consume. Fails on the first unmappable layer.
    pub fn map_network(&self, network: &Network) -> Result<Vec<LayerMapping>> {
        network
            .stateful_layers()
            .map(|l| self.map_layer(l))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::layer::NeuronConfig;
    use crate::snn::tensor::Mat;

    fn conv(in_ch: usize, out_ch: usize, h: usize, w: usize) -> Layer {
        Layer::conv(
            (in_ch, h, w),
            out_ch,
            3,
            3,
            1,
            1,
            Mat::zeros(in_ch * 9, out_ch),
            NeuronConfig::default(),
            false,
        )
        .unwrap()
    }

    #[test]
    fn flow_layer_maps_to_mode1() {
        // Conv(32,32): fan-in 288 <= 384 -> mode 1, 96 rows/CU.
        let m = Mapper::new(Precision::W4V7)
            .map_layer(&conv(32, 32, 288, 384))
            .unwrap();
        assert_eq!(m.mode, OperatingMode::Mode1);
        assert_eq!(m.rows_per_cu, vec![96, 96, 96]);
        assert_eq!(m.channel_groups, 3); // 32 channels / 12 per group
        assert_eq!(m.passes, 1);
        assert_eq!(m.tiles, (288 * 384usize).div_ceil(16));
    }

    #[test]
    fn large_fan_in_needs_mode2() {
        let m = Mapper::new(Precision::W4V7)
            .map_layer(&conv(48, 12, 8, 8))
            .unwrap();
        assert_eq!(m.mode, OperatingMode::Mode2);
        assert_eq!(m.rows_per_cu.len(), 9);
        assert_eq!(m.rows_per_cu.iter().sum::<usize>(), 432);
    }

    #[test]
    fn precision_changes_groups() {
        let l = conv(16, 16, 16, 16);
        let m4 = Mapper::new(Precision::W4V7).map_layer(&l).unwrap();
        let m8 = Mapper::new(Precision::W8V15).map_layer(&l).unwrap();
        assert_eq!(m4.channel_groups, 2); // 16/12
        assert_eq!(m8.channel_groups, 3); // 16/6
        assert!(m8.passes >= m4.passes);
    }

    #[test]
    fn oversized_rejected() {
        let l = conv(129, 4, 4, 4); // fan-in 1161 > 1152
        assert!(Mapper::new(Precision::W4V7).map_layer(&l).is_err());
    }

    #[test]
    fn pool_rejected() {
        let p = Layer::pool((4, 8, 8), 2, 2);
        assert!(Mapper::new(Precision::W4V7).map_layer(&p).is_err());
    }
}
